"""Activation-sharding context.

Models are written mesh-agnostic and call ``shard(x, kind)`` at layer
boundaries. The active distribution strategy (set by the step factories in
``repro.train.step`` / ``repro.serve.decode``) maps each activation *kind*
to a PartitionSpec; with no strategy active, ``shard`` is the identity, so
all model code runs unmodified on a single CPU device.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Optional

import jax

_SHARDER: contextvars.ContextVar = contextvars.ContextVar("sharder", default=None)


class Sharder:
    """Maps activation kinds -> PartitionSpec under a given mesh."""

    def __init__(self, mesh, act_specs, batch_axes=("data",)):
        self.mesh = mesh
        self.act_specs = dict(act_specs)
        self.batch_axes = tuple(batch_axes)

    def _divisible(self, shape, spec) -> bool:
        for dim, names in zip(shape, tuple(spec) + (None,) * len(shape)):
            if names is None:
                continue
            names = names if isinstance(names, tuple) else (names,)
            n = 1
            for a in names:
                n *= self.mesh.shape[a]
            if dim % n:
                return False
        return True

    def constrain(self, x, kind: str):
        spec = self.act_specs.get(kind)
        if spec is None:
            return x
        if len(spec) > x.ndim or not self._divisible(x.shape, spec):
            # never let GSPMD pad implicitly (keeps cost_analysis honest);
            # undersized smoke shapes simply stay replicated
            return x
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(self.mesh, spec))


@contextlib.contextmanager
def sharding_ctx(sharder: Optional[Sharder]):
    tok = _SHARDER.set(sharder)
    try:
        yield
    finally:
        _SHARDER.reset(tok)


def shard(x, kind: str):
    s = _SHARDER.get()
    if s is None:
        return x
    return s.constrain(x, kind)


def current_sharder() -> Optional[Sharder]:
    return _SHARDER.get()
