"""Sharding strategies: logical param/activation axes -> PartitionSpecs.

Three strategies (DESIGN.md §4):

* ``dp_tp``  — baseline. Batch over ("pod","data"); Megatron column/row TP
  over "model" on flattened feature dims; attention runs with the *query
  sequence* block-sharded over "model" and K/V gathered (GQA keeps K/V
  small), which avoids every head-divisibility problem with zero padding.
* ``fsdp``   — optimized training. Weights/master/moments sharded over
  ("data","model") (largest divisible dim per leaf, ZeRO-3 style); pure-DP
  compute; GSPMD all-gathers block weights inside the scan (overlappable).
* ``tp_serve`` — decoding. Megatron TP weights; KV cache sharded over
  "model" by sequence chunks — each shard computes partial attention and
  XLA decomposes the softmax reduction across shards (flash-decoding).
  For models whose TP-16 bf16 weights exceed one chip's HBM, weights are
  spread over ("data","model") instead (weight-gathered serving).

Divisibility rule: a dim is only sharded if the mesh axis divides it —
otherwise the dim stays replicated (never implicit GSPMD padding, so
cost_analysis FLOPs stay honest). Small leaves (< 64 KiB) replicate.
"""
from __future__ import annotations

import numpy as np
from typing import Any, Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as PS

from repro.sharding.ctx import Sharder

# priority order in which dims of one leaf may claim a mesh axis
_PRIORITY = ("experts", "vocab", "ffn", "q_feat", "kv_feat", "ssm_inner", "embed")
_SMALL = 16384  # leaves under 16Ki elements stay replicated


def _axis_size(mesh, name) -> int:
    if isinstance(name, tuple):
        return int(np.prod([mesh.shape[a] for a in name]))
    return int(mesh.shape[name])


def _dp_axes(mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.shape else ("data",)


def leaf_spec_tp(axes: Tuple[Optional[str], ...], shape, mesh) -> PS:
    """Megatron TP: shard the highest-priority divisible feature dim on 'model'."""
    if int(np.prod(shape)) < _SMALL:
        return PS()
    best, best_rank = None, len(_PRIORITY)
    for i, ax in enumerate(axes):
        if ax in _PRIORITY:
            rank = _PRIORITY.index(ax)
            if rank < best_rank and shape[i] % mesh.shape["model"] == 0:
                # embed only ranks for row-parallel second dims; skip embed on
                # dim 0 of 2D weights (keeps column-parallel layout canonical)
                if ax == "embed" and i == 0 and len(shape) > 1:
                    continue
                best, best_rank = i, rank
    spec = [None] * len(shape)
    if best is not None:
        spec[best] = "model"
    return PS(*spec)


def leaf_spec_fsdp(axes, shape, mesh) -> PS:
    """ZeRO-3: shard the largest divisible dim over (data,model) combined,
    else over 'model' alone, else replicate."""
    if int(np.prod(shape)) < _SMALL:
        return PS()
    order = sorted(range(len(shape)), key=lambda i: -shape[i])
    combined = _axis_size(mesh, ("data", "model")) if "data" in mesh.shape else None
    for i in order:
        if axes[i] == "layers":
            continue
        if combined and shape[i] % combined == 0:
            spec = [None] * len(shape)
            spec[i] = ("data", "model")
            return PS(*spec)
    for i in order:
        if axes[i] == "layers":
            continue
        if shape[i] % mesh.shape["model"] == 0:
            spec = [None] * len(shape)
            spec[i] = "model"
            return PS(*spec)
    return PS()


def _tree_specs(axes_tree, abstract_params, mesh, leaf_fn):
    return jax.tree_util.tree_map(
        lambda ax, p: leaf_fn(ax, p.shape, mesh), axes_tree, abstract_params,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x))


class Strategy:
    name: str = "base"

    def __init__(self, mesh):
        self.mesh = mesh
        self.dp = _dp_axes(mesh)

    @property
    def batch_axes(self) -> Tuple[str, ...]:
        """Mesh axes the global batch is sharded over."""
        return self.dp

    # ---- param specs -------------------------------------------------
    def param_specs(self, model) -> Any:
        raise NotImplementedError

    def opt_specs(self, model) -> Any:
        """Fully-sharded specs for master/m/v (ZeRO-1)."""
        return _tree_specs(model.param_axes(), model.abstract_params(),
                           self.mesh, leaf_spec_fsdp)

    # ---- activation specs --------------------------------------------
    def act_specs(self) -> dict:
        raise NotImplementedError

    def sharder(self) -> Sharder:
        return Sharder(self.mesh, self.act_specs(), self.batch_axes)

    def named(self, spec: PS) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    # ---- data specs ----------------------------------------------------
    def batch_spec(self) -> PS:
        return PS(self.dp)


class DpTp(Strategy):
    name = "dp_tp"

    def param_specs(self, model):
        return _tree_specs(model.param_axes(), model.abstract_params(),
                           self.mesh, leaf_spec_tp)

    def act_specs(self):
        dp = self.dp
        # NOTE: no bshd/bskv/bshp constraints — attention/ssm head sharding
        # propagates from the column-sharded projections (Megatron layout);
        # forcing a different layout mid-layer makes GSPMD insert
        # catastrophic reshard-replicate copies (measured: 44 GB/layer).
        return {
            "btd": PS(dp, None, None),
            "btf": PS(dp, None, "model"),
            "btv": PS(dp, None, "model"),
            "head_w": PS("model", None),           # lm-head grad (V,d)
            "becd": PS(dp, "model", None, None),   # MoE expert-sharded
            "becf": PS(dp, "model", None, None),
            "btd_dec": PS(dp, None, None),
        }


class Fsdp(Strategy):
    name = "fsdp"

    @property
    def batch_axes(self):
        # weights are gathered per block -> compute is pure DP over every
        # chip: batch shards over (pod, data, model)
        return self.dp + ("model",)

    def param_specs(self, model):
        return _tree_specs(model.param_axes(), model.abstract_params(),
                           self.mesh, leaf_spec_fsdp)

    def act_specs(self):
        bd = self.batch_axes
        return {
            "btd": PS(bd, None, None),
            "btf": PS(bd, None, None),
            "btv": PS(bd, None, None),
            "bshd": PS(bd, None, None, None),
            "bskv": PS(bd, None, None, None),
            "bshp": PS(bd, None, None, None),
            "becd": PS(bd, None, None, None),
            "becf": PS(bd, None, None, None),
            "btd_dec": PS(bd, None, None),
        }


class TpServe(Strategy):
    name = "tp_serve"

    def __init__(self, mesh, weight_gathered: bool = False):
        super().__init__(mesh)
        self.weight_gathered = weight_gathered

    def param_specs(self, model):
        if self.weight_gathered:
            return _tree_specs(model.param_axes(), model.abstract_params(),
                               self.mesh, leaf_spec_fsdp)
        return _tree_specs(model.param_axes(), model.abstract_params(),
                           self.mesh, leaf_spec_tp)

    def cache_specs(self, cache_abstract, batch: int) -> Any:
        """Stacked caches are (L, B, S, ...): batch over dp when divisible,
        KV sequence chunks over 'model' (flash-decoding combine). When the
        batch cannot shard (e.g. long_500k B=1), the sequence dim spreads
        over ('data','model') instead so all chips hold cache shards."""
        dp = self.dp
        mesh = self.mesh
        dpn = int(np.prod([mesh.shape[a] for a in dp]))

        def leaf(x):
            shape = x.shape
            # stacked layout: (L, B, S, ...); per-layer layout: (B, S, ...)
            if len(shape) >= 2 and shape[1] == batch:
                bdim = 1
            elif len(shape) >= 1 and shape and shape[0] == batch:
                bdim = 0
            else:
                return PS()
            sdim = bdim + 1
            spec = [None] * len(shape)
            batch_ok = batch % dpn == 0
            if batch_ok:
                spec[bdim] = dp
            if len(shape) >= sdim + 2 and shape[sdim] >= 1024:
                if batch_ok and shape[sdim] % mesh.shape["model"] == 0:
                    spec[sdim] = "model"
                elif not batch_ok:
                    full = dp + ("model",)
                    n = int(np.prod([mesh.shape[a] for a in full]))
                    if shape[sdim] % n == 0:
                        spec[sdim] = full
                    elif shape[sdim] % mesh.shape["model"] == 0:
                        spec[sdim] = "model"
            return PS(*spec)
        return jax.tree_util.tree_map(leaf, cache_abstract)

    def paged_cache_specs(self, cache_abstract, batch: int) -> Any:
        """Paged-pool analogue of cache_specs: the page dimension of each
        (L, P, page, Hkv, D) pool chunks over 'model' (pages play the
        dense layout's sequence-shard role — serve/flash_decode.py's
        paged combine), page tables/indices shard over dp with the slot
        batch when divisible."""
        mesh = self.mesh
        dp = self.dp
        dpn = int(np.prod([mesh.shape[a] for a in dp]))
        batch_ok = batch % dpn == 0

        def leaf(x):
            shape = x.shape
            if len(shape) == 5:            # stacked pool (L,P,page,Hkv,D)
                pages, ps = shape[1], shape[2]
                if pages * ps >= 1024 and pages % mesh.shape["model"] == 0:
                    return PS(None, "model", None, None, None)
                return PS()
            if len(shape) >= 2 and shape[1] == batch:   # (L,B[,M]) pt/idx
                return PS(None, dp if batch_ok else None,
                          *([None] * (len(shape) - 2)))
            return PS()
        return jax.tree_util.tree_map(leaf, cache_abstract)

    def act_specs(self):
        dp = self.dp
        return {
            "btd": PS(dp, None, None),
            "btf": PS(dp, None, "model"),
            "btv": PS(dp, None, "model"),
            "head_w": PS("model", None),
            "bshd": PS(dp, None, None, None),
            "bskv": PS(dp, None, None, None),
            "bshp": PS(dp, None, None, None),
            "becd": PS(dp, "model", None, None),
            "becf": PS(dp, "model", None, None),
            "btd_dec": PS(dp, None, None),
        }


def make_strategy(name: str, mesh, **kw) -> Strategy:
    return {"dp_tp": DpTp, "fsdp": Fsdp, "tp_serve": TpServe}[name](mesh, **kw)
