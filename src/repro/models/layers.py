"""Core transformer layers: norms, RoPE, GQA attention (qk-norm, cross-attn,
KV-cache decode), gated/plain MLP. Functional style: ``decl_*`` builds the
parameter declaration tree, ``apply_*`` consumes the materialized params.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import params as P
from repro.sharding.ctx import shard


# ----------------------------------------------------------------------
# Norms
# ----------------------------------------------------------------------
def apply_rmsnorm(p, x, eps: float = 1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dt)


# ----------------------------------------------------------------------
# RoPE
# ----------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, D); positions: (S,) or (B, S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                      # (D/2,)
    ang = positions.astype(jnp.float32)[..., None] * freqs   # (..., S, D/2)
    if ang.ndim == 2:                                  # (S, D/2) -> (1, S, D/2)
        ang = ang[None]
    cos = jnp.cos(ang)[:, :, None, :]                  # (B|1, S, 1, D/2)
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------
# Attention (GQA; ref path — the Pallas flash kernel is dispatched in
# repro.kernels.ops for TPU deployments)
# ----------------------------------------------------------------------
def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool, q_offset=0,
              kv_len: Optional[jax.Array] = None,
              kv_valid: Optional[jax.Array] = None) -> jax.Array:
    """q: (B,Sq,Hq,D), k/v: (B,Skv,Hkv,D) -> (B,Sq,Hq,D).

    GQA via head grouping; scores accumulated in f32. ``q_offset`` is the
    absolute position of q[0] (for decode); ``kv_len`` masks cache slots
    >= kv_len (decode with preallocated cache); ``kv_valid`` masks
    unmapped page-table positions of a paged cache's gathered view.
    """
    from repro.kernels import ops
    return ops.attention(q, k, v, causal=causal, q_offset=q_offset,
                         kv_len=kv_len, kv_valid=kv_valid)


def decl_attention(cfg: ModelConfig, cross: bool = False) -> Dict[str, Any]:
    d = cfg.d_model
    decl = {
        "wq": P.linear(d, cfg.q_dim, "embed", "q_feat"),
        "wk": P.linear(d, cfg.kv_dim, "embed", "kv_feat"),
        "wv": P.linear(d, cfg.kv_dim, "embed", "kv_feat"),
        "wo": P.linear(cfg.q_dim, d, "q_feat", "embed"),
    }
    if cfg.qk_norm:
        decl["q_norm"] = P.norm(cfg.head_dim, None)
        decl["k_norm"] = P.norm(cfg.head_dim, None)
    return decl


def apply_attention(p, cfg: ModelConfig, x: jax.Array, *,
                    kv_src: Optional[jax.Array] = None,
                    positions: Optional[jax.Array] = None,
                    causal: bool = True,
                    cache: Optional[Dict[str, jax.Array]] = None,
                    use_rope: bool = True,
                    spec: Optional[str] = None,
                    kv_valid: Optional[jax.Array] = None,
                    ) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Self- or cross-attention with optional KV cache.

    cache: {"k": (B,Smax,Hkv,D), "v": ..., "idx": scalar int32} — decode
    writes the new K/V at idx and attends over [0, idx+len).

    ``kv_valid``: optional (B, Skv) key-validity mask for the cache-free
    paths (encoder self-attention over right-padded frames, cross-attn
    over a padded source): masked keys never contribute, so outputs on
    valid rows are independent of the padded extent — what makes
    length-bucketed encoder prefill bit-identical to padded-to-capacity.

    ``spec`` marks a speculative width-k verify forward (LM.verify):
      "overwrite" — all S window rows are stored, but bounded: rows past
          the cache extent / page table drop instead of clamp-shifting
          onto committed history (rejected rows become Def.-1 dead
          stores, the waste `rejected_draft_store` measures);
      "defer" (paged only) — the pool is untouched; the window's K/V
          ride in ``win_k``/``win_v`` for `LM.commit_verify` to scatter
          only the accepted prefix (rollback: rejected rows never become
          cache stores at all).
    """
    B, S, _ = x.shape
    H, Hkv, D = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    dt = x.dtype

    q = (x @ p["wq"]["w"].astype(dt)).reshape(B, S, H, D)
    src = x if kv_src is None else kv_src
    Bk, Skv = src.shape[:2]
    k = (src @ p["wk"]["w"].astype(dt)).reshape(Bk, Skv, Hkv, D)
    v = (src @ p["wv"]["w"].astype(dt)).reshape(Bk, Skv, Hkv, D)

    if cfg.qk_norm:
        q = apply_rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = apply_rmsnorm(p["k_norm"], k, cfg.norm_eps)

    q_offset = 0
    if use_rope and kv_src is None:
        if cache is not None:
            idx0 = cache["idx"]
            if jnp.ndim(idx0) == 1:              # per-slot positions (B,)
                pos_q = idx0[:, None] + jnp.arange(S)[None, :]
            else:
                pos_q = (idx0 + jnp.arange(S))[None, :]
            q = apply_rope(q, pos_q, cfg.rope_theta)
            k = apply_rope(k, pos_q, cfg.rope_theta)
        else:
            pos = positions if positions is not None else jnp.arange(S)
            q = apply_rope(q, pos, cfg.rope_theta)
            k = apply_rope(k, pos, cfg.rope_theta)

    new_cache = None
    kv_len = None
    if (cache is not None and kv_src is None and "pt" in cache
            and spec == "defer"):
        # speculative verify, rollback mode: the pool is NOT written.
        # Attention runs over the committed history with the verify
        # window spliced in at its positions — pure activation memory —
        # and the window K/V ride in win_k/win_v for LM.commit_verify to
        # scatter only the accepted prefix. Values round-trip through
        # the pool dtype exactly like the scatter-then-gather path, so
        # the logits are bit-identical to overwrite mode. Dispatches to
        # the fused paged window kernel (store disabled) on Pallas
        # backends, the spliced-gather ref composition elsewhere.
        from repro.kernels import ops
        idx = cache["idx"]
        pt = cache["pt"]
        counters = "kcnt" in cache
        out, _, _, cnt = ops.paged_window(
            q, k, v, cache["k"], cache["v"], pt, idx,
            store=False, counters=counters)
        new_cache = {**cache, "idx": idx + S, "win_k": k, "win_v": v}
        if counters:
            new_cache["kcnt"] = cnt     # all-zero: no stores in defer mode
        out = out.reshape(B, S, H * D)
        out = out @ p["wo"]["w"].astype(dt)
        return shard(out, "btd"), new_cache
    elif cache is not None and kv_src is None and "pt" in cache:
        # block-paged cache (serve/kv_cache.py): pool (P,page,Hkv,D),
        # page table (B,M), per-slot positions (B,). Stores scatter
        # through the table (out-of-table/idle writes DROP — no dead
        # rewrites); reads gather the logical view back, masked where
        # the table is unmapped.
        from repro.kernels import ops
        idx = cache["idx"]
        pt = cache["pt"]
        counters = "kcnt" in cache

        def _finish(out, ck, cv, cnt):
            new_cache = {**cache, "k": ck, "v": cv, "idx": idx + S}
            if counters:
                if cnt is None:       # sharded paths count host-side
                    cnt = ops.paged_store_counts(
                        cache["k"], cache["v"], k, v, pt, idx,
                        tol=ops.COUNTER_TOL)
                new_cache["kcnt"] = cnt
            out = out.reshape(B, S, H * D)
            out = out @ p["wo"]["w"].astype(dt)
            return shard(out, "btd"), new_cache

        if S == 1:
            from repro.serve.flash_decode import (
                decode_paged_attention_sharded, paged_shard_plan)
            from repro.sharding.ctx import current_sharder
            sharder = current_sharder()
            plan = paged_shard_plan(sharder, B, cache["k"].shape[0],
                                    cache["k"].shape[1])
            if plan is not None:
                b_ax, s_ax = plan
                out, ck, cv = decode_paged_attention_sharded(
                    q, k, v, cache["k"], cache["v"], pt, idx,
                    mesh=sharder.mesh, batch_axes=b_ax, seq_axes=s_ax)
                return _finish(out, ck, cv, None)
            out, ck, cv, cnt = ops.paged_decode(
                q, k, v, cache["k"], cache["v"], pt, idx, counters=counters)
            return _finish(out, ck, cv, cnt)
        if spec == "overwrite":
            # width-k speculative verify against a page-chunk-sharded
            # pool: each shard scatters the window rows it owns and the
            # per-query partials combine flash-style
            from repro.serve.flash_decode import (
                paged_shard_plan, verify_paged_attention_sharded)
            from repro.sharding.ctx import current_sharder
            sharder = current_sharder()
            plan = paged_shard_plan(sharder, B, cache["k"].shape[0],
                                    cache["k"].shape[1])
            if plan is not None:
                b_ax, s_ax = plan
                out, ck, cv = verify_paged_attention_sharded(
                    q, k, v, cache["k"], cache["v"], pt, idx,
                    mesh=sharder.mesh, batch_axes=b_ax, seq_axes=s_ax)
                return _finish(out, ck, cv, None)
        # prefill chunk / verify-overwrite window: fused window forward
        # (Pallas kernel with in-kernel page gather + paged-write
        # epilogue, or the scatter-then-gather ref composition)
        out, ck, cv, cnt = ops.paged_window(
            q, k, v, cache["k"], cache["v"], pt, idx,
            store=True, counters=counters)
        return _finish(out, ck, cv, cnt)
    elif cache is not None and kv_src is None:
        idx = cache["idx"]
        if S == 1 and jnp.ndim(idx) == 0:
            # one-token decode: sharded flash-decoding when the cache is
            # sequence-chunk sharded (see serve/flash_decode.py)
            from repro.serve.flash_decode import (decode_attention_sharded,
                                                  decode_shard_plan)
            from repro.sharding.ctx import current_sharder
            sharder = current_sharder()
            plan = decode_shard_plan(sharder, Bk if kv_src is None else B,
                                     cache["k"].shape[1])
            if plan is not None:
                b_ax, s_ax = plan
                out, ck, cv = decode_attention_sharded(
                    q, k, v, cache["k"], cache["v"], idx,
                    mesh=sharder.mesh, batch_axes=b_ax, seq_axes=s_ax)
                new_cache = {"k": ck, "v": cv, "idx": idx + S}
                out = out.reshape(B, S, H * D)
                out = out @ p["wo"]["w"].astype(dt)
                return shard(out, "btd"), new_cache
        # fallback: in-place update + masked attention (single device /
        # unshardable shapes)
        if jnp.ndim(idx) == 1 and spec is not None:
            # width-k verify over dense per-slot rows: a bounded scatter
            # instead of the DUS below — DUS clamps an overflowing start
            # index, which would shift the window back onto committed
            # history; here rows past the cache extent simply drop
            # (committed tokens never reach there, only rejected drafts
            # and padding — see LM.verify)
            Smax = cache["k"].shape[1]
            pos = idx[:, None] + jnp.arange(S)[None, :]
            tgt = jnp.where((pos >= 0) & (pos < Smax), pos, Smax)
            bidx = jnp.arange(B)[:, None]
            ck = cache["k"].at[bidx, tgt].set(
                k.astype(cache["k"].dtype), mode="drop")
            cv = cache["v"].at[bidx, tgt].set(
                v.astype(cache["v"].dtype), mode="drop")
        elif jnp.ndim(idx) == 1:
            # per-slot write positions (serving engine): each row lands at
            # its own sequence offset
            upd = jax.vmap(
                lambda c, u, i: jax.lax.dynamic_update_slice_in_dim(
                    c, u, i, axis=0))
            ck = upd(cache["k"], k.astype(cache["k"].dtype), idx)
            cv = upd(cache["v"], v.astype(cache["v"].dtype), idx)
        else:
            ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), idx, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), idx, axis=1)
        new_cache = {"k": ck, "v": cv, "idx": idx + S}
        k, v = ck.astype(dt), cv.astype(dt)
        kv_len = idx + S
        q_offset = idx
        causal = True

    q = shard(q, "bshd")
    k = shard(k, "bskv")
    v = shard(v, "bskv")
    out = attention(q, k, v, causal=causal and kv_src is None,
                    q_offset=q_offset, kv_len=kv_len, kv_valid=kv_valid)
    out = out.reshape(B, S, H * D)
    out = out @ p["wo"]["w"].astype(dt)
    return shard(out, "btd"), new_cache


# ----------------------------------------------------------------------
# LM head with shard-local gradients.
#
# GSPMD's default plan for the head-matmul backward all-gathers the full
# (B,S,V) cotangent over the vocab axis before forming d_embed (observed:
# 40 GB/device at qwen3 scale). The gradient contractions are expressible
# entirely shard-local (+ a small all-reduce), so we write the vjp by hand
# with explicit constraints. w: (V, d) vocab-major (the embedding table
# itself when tied).
# ----------------------------------------------------------------------
@jax.custom_vjp
def lm_head(x: jax.Array, w: jax.Array) -> jax.Array:
    return jnp.einsum("bsd,vd->bsv", x, w)


def _lm_head_fwd(x, w):
    return lm_head(x, w), (x, w)


def _lm_head_bwd(res, g):
    x, w = res
    g = shard(g, "btv")
    dx = shard(jnp.einsum("bsv,vd->bsd", g, w), "btd")
    dw = shard(jnp.einsum("bsv,bsd->vd", g, x.astype(g.dtype)), "head_w")
    return dx.astype(x.dtype), dw.astype(w.dtype)


lm_head.defvjp(_lm_head_fwd, _lm_head_bwd)


# ----------------------------------------------------------------------
# MLP
# ----------------------------------------------------------------------
def decl_mlp(cfg: ModelConfig, d_ff: Optional[int] = None) -> Dict[str, Any]:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    decl = {
        "up": P.linear(d, f, "embed", "ffn"),
        "down": P.linear(f, d, "ffn", "embed"),
    }
    if cfg.gated_mlp:
        decl["gate"] = P.linear(d, f, "embed", "ffn")
    return decl


def apply_mlp(p, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    dt = x.dtype
    h = x @ p["up"]["w"].astype(dt)
    if cfg.gated_mlp:
        g = x @ p["gate"]["w"].astype(dt)
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    h = shard(h, "btf")
    return shard(h @ p["down"]["w"].astype(dt), "btd")


# ----------------------------------------------------------------------
# Standard decoder block: (rmsnorm -> attn -> +res) (rmsnorm -> mlp -> +res)
# ----------------------------------------------------------------------
def decl_dense_block(cfg: ModelConfig) -> Dict[str, Any]:
    return {
        "ln1": P.norm(cfg.d_model),
        "attn": decl_attention(cfg),
        "ln2": P.norm(cfg.d_model),
        "mlp": decl_mlp(cfg),
    }


def apply_dense_block(p, cfg: ModelConfig, x, *, causal=True, cache=None,
                      positions=None, use_rope=True, spec=None,
                      kv_valid=None):
    h, new_cache = apply_attention(
        p["attn"], cfg, apply_rmsnorm(p["ln1"], x, cfg.norm_eps),
        causal=causal, cache=cache, positions=positions, use_rope=use_rope,
        spec=spec, kv_valid=kv_valid)
    x = x + h
    x = x + apply_mlp(p["mlp"], cfg, apply_rmsnorm(p["ln2"], x, cfg.norm_eps))
    return x, new_cache


# Cross-attention block (VLM image layers / enc-dec decoder cross part).
def decl_xattn_block(cfg: ModelConfig) -> Dict[str, Any]:
    return {
        "ln1": P.norm(cfg.d_model),
        "xattn": decl_attention(cfg, cross=True),
        "gate_attn": P.ParamDecl((), (), "zeros"),
        "ln2": P.norm(cfg.d_model),
        "mlp": decl_mlp(cfg),
        "gate_mlp": P.ParamDecl((), (), "zeros"),
    }


def apply_xattn_block(p, cfg: ModelConfig, x, kv_src):
    h, _ = apply_attention(
        p["xattn"], cfg, apply_rmsnorm(p["ln1"], x, cfg.norm_eps),
        kv_src=kv_src, causal=False, use_rope=False)
    x = x + jnp.tanh(p["gate_attn"].astype(x.dtype)) * h
    h = apply_mlp(p["mlp"], cfg, apply_rmsnorm(p["ln2"], x, cfg.norm_eps))
    x = x + jnp.tanh(p["gate_mlp"].astype(x.dtype)) * h
    return x
