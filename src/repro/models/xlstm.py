"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel) and sLSTM
(scalar memory, strictly recurrent), following arXiv:2405.04517.

TPU adaptation: the mLSTM recurrence is computed in its chunkwise-parallel
form — within-chunk quadratic gating matrices on the MXU, across-chunk
(d_k x d_v) matrix-state recurrence via a short lax.scan — mirroring how
the Mamba2 SSD maps to TPU. sLSTM is inherently sequential (recurrent
hidden mixing) and runs as a lax.scan over time with block-diagonal
per-head recurrent matrices. Both have exact recurrent decode paths.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import params as P
from repro.models.layers import apply_rmsnorm
from repro.sharding.ctx import shard


def _log_sigmoid(x: jax.Array) -> jax.Array:
    """log sigmoid(x) = min(x, 0) - log1p(exp(-|x|)).

    Not jax.nn.log_sigmoid: that routes through logaddexp(x, 0), whose
    lowering carries an identity add and sub against literal 0 over the
    full gate tensor (tier-0 silent_store, xlstm.py). Same stabilized
    value, no literal-zero ops.
    """
    return jnp.minimum(x, 0.0) - jnp.log1p(jnp.exp(-jnp.abs(x)))


# ======================================================================
# mLSTM
# ======================================================================
def _mlstm_dims(cfg: ModelConfig):
    H = cfg.num_heads
    d_in = int(cfg.d_model * cfg.xlstm.proj_factor_mlstm)
    dk = d_in // H
    return H, d_in, dk


def decl_mlstm(cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    H, d_in, dk = _mlstm_dims(cfg)
    return {
        "ln": P.norm(d),
        "up_proj": P.linear(d, 2 * d_in, "embed", "ffn"),   # [x_in, z_gate]
        # block-diagonal per-head projections (xLSTM paper §mLSTM): (H,dk,dk)
        "wq": P.ParamDecl((H, dk, dk), (None, None, None), "normal",
                          1.0 / math.sqrt(dk)),
        "wk": P.ParamDecl((H, dk, dk), (None, None, None), "normal",
                          1.0 / math.sqrt(dk)),
        "wv": P.ParamDecl((H, dk, dk), (None, None, None), "normal",
                          1.0 / math.sqrt(dk)),
        "w_i": P.ParamDecl((d_in, H), ("ffn", None), "normal", 0.02),
        "w_f": P.ParamDecl((d_in, H), ("ffn", None), "normal", 0.02),
        "b_i": P.ParamDecl((H,), (None,), "zeros"),
        "b_f": P.ParamDecl((H,), (None,), "ones"),
        "out_norm": P.norm(d_in, "ffn"),
        "down_proj": P.linear(d_in, d, "ffn", "embed"),
    }


def _mlstm_chunked(q, k, v, logf, logi, chunk: int):
    """Stabilized chunkwise mLSTM.

    q/k/v: (B,S,H,D) f32; logf/logi: (B,S,H) log forget(/input) gates.
    Returns h: (B,S,H,D), final (C,n,m) state.
    """
    with jax.named_scope("mlstm_vmem"):
        return _mlstm_chunked_impl(q, k, v, logf, logi, chunk)


def _mlstm_chunked_impl(q, k, v, logf, logi, chunk: int):
    B, S, H, D = q.shape
    nc = S // chunk
    f32 = jnp.float32
    qc = q.reshape(B, nc, chunk, H, D)
    kc = k.reshape(B, nc, chunk, H, D) / math.sqrt(D)
    vc = v.reshape(B, nc, chunk, H, D)
    lf = logf.reshape(B, nc, chunk, H)
    li = logi.reshape(B, nc, chunk, H)

    F = jnp.cumsum(lf, axis=2)                                # (B,nc,Q,H)
    Fend = F[:, :, -1]                                        # (B,nc,H)

    # intra-chunk log weights: W[z,l] = F_z - F_l + i_l  (z >= l)
    Wlog = (F[:, :, :, None] - F[:, :, None, :] +
            li[:, :, None, :])                                # (B,nc,Q,Q,H) z,l
    # iota comparison, not jnp.tril(ones): tril's diagonal shift lowers
    # as `iota + 0`, an identity add per mask element (tier-0
    # silent_store, xlstm.py)
    tri = jnp.arange(chunk)[:, None] >= jnp.arange(chunk)[None, :]
    Wlog = jnp.where(tri[None, None, :, :, None], Wlog, -jnp.inf)

    # inter-chunk: contribution of state entering the chunk decays by F_z
    # log-scale bookkeeping with running max m for stabilization.
    state_decay = F                                           # (B,nc,Q,H)

    def body(carry, inp):
        C_s, n_s, m_s = carry
        qi, ki, vi, Wl, sd, li_c, F_c, Fe = inp
        m_local = jnp.max(Wl, axis=2)
        m_new = jnp.maximum(m_local, sd + m_s[:, None, :])
        Dmat = jnp.exp(Wl - m_new[:, :, None, :])
        s_intra = jnp.einsum("bzhd,blhd->bzlh", qi, ki)
        h_intra = jnp.einsum("bzlh,bzlh,blhd->bzhd", s_intra, Dmat, vi)
        n_intra = jnp.einsum("bzlh,bzlh->bzh", s_intra, Dmat)
        inter_w = jnp.exp(sd + m_s[:, None, :] - m_new)
        h_inter = jnp.einsum("bzhd,bhde->bzhe", qi, C_s) * inter_w[..., None]
        n_inter = jnp.einsum("bzhd,bhd->bzh", qi, n_s) * inter_w
        n_tot = jnp.maximum(jnp.abs(n_intra + n_inter), jnp.exp(-m_new))
        h = (h_intra + h_inter) / n_tot[..., None]

        # state update (stabilized): per key l weight exp(Fe - F_l + i_l)
        kw_log = Fe[:, None, :] - F_c + li_c                  # (B,Q,H)
        m_kw = jnp.max(kw_log, axis=1)                        # (B,H)
        m_state = jnp.maximum(Fe + m_s, m_kw)
        decay = jnp.exp(Fe + m_s - m_state)                   # (B,H)
        kw = jnp.exp(kw_log - m_state[:, None, :])            # (B,Q,H)
        C_new = (C_s * decay[..., None, None] +
                 jnp.einsum("blh,blhd,blhe->bhde", kw, ki, vi))
        n_new = (n_s * decay[..., None] +
                 jnp.einsum("blh,blhd->bhd", kw, ki))
        return (C_new, n_new, m_state), h

    C0 = jnp.zeros((B, H, D, D), f32)
    n0 = jnp.zeros((B, H, D), f32)
    m0 = jnp.full((B, H), -1e30, f32)
    xs = (qc.transpose(1, 0, 2, 3, 4), kc.transpose(1, 0, 2, 3, 4),
          vc.transpose(1, 0, 2, 3, 4), Wlog.transpose(1, 0, 2, 3, 4),
          state_decay.transpose(1, 0, 2, 3), li.transpose(1, 0, 2, 3),
          F.transpose(1, 0, 2, 3), Fend.transpose(1, 0, 2))
    (Cf, nf, mf), hs = jax.lax.scan(body, (C0, n0, m0), xs)
    h = hs.transpose(1, 0, 2, 3, 4).reshape(B, S, H, D)
    return h, (Cf, nf, mf)


def _mlstm_recurrent_step(q, k, v, logf, logi, state):
    """One-token exact recurrence. q/k/v: (B,H,D); logf/logi: (B,H)."""
    C_s, n_s, m_s = state
    m_new = jnp.maximum(logf + m_s, logi)
    fg = jnp.exp(logf + m_s - m_new)
    ig = jnp.exp(logi - m_new)
    C_new = C_s * fg[..., None, None] + ig[..., None, None] * \
        jnp.einsum("bhd,bhe->bhde", k, v)
    n_new = n_s * fg[..., None] + ig[..., None] * k
    num = jnp.einsum("bhd,bhde->bhe", q, C_new)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q, n_new)),
                      jnp.exp(-m_new))
    return num / den[..., None], (C_new, n_new, m_new)


def apply_mlstm(p, cfg: ModelConfig, x: jax.Array, *,
                state: Optional[Tuple] = None):
    H, d_in, dk = _mlstm_dims(cfg)
    B, S, _ = x.shape
    dt = x.dtype
    h = apply_rmsnorm(p["ln"], x, cfg.norm_eps)
    up = h @ p["up_proj"]["w"].astype(dt)
    xi, z = jnp.split(up, 2, axis=-1)
    xi = shard(xi, "btf")

    f32 = jnp.float32
    xh = xi.reshape(B, S, H, dk)
    q = jnp.einsum("bshd,hde->bshe", xh, p["wq"].astype(dt)).astype(f32)
    k = jnp.einsum("bshd,hde->bshe", xh, p["wk"].astype(dt)).astype(f32)
    v = jnp.einsum("bshd,hde->bshe", xh, p["wv"].astype(dt)).astype(f32)
    logi = (xi.astype(f32) @ p["w_i"].astype(f32) + p["b_i"].astype(f32))
    logf = _log_sigmoid(
        xi.astype(f32) @ p["w_f"].astype(f32) + p["b_f"].astype(f32))

    if state is None:
        Q = min(cfg.xlstm.chunk_size, S)
        S_pad = -(-S // Q) * Q
        if S_pad != S:
            padw = ((0, 0), (0, S_pad - S))
            q = jnp.pad(q, padw + ((0, 0), (0, 0)))
            k = jnp.pad(k, padw + ((0, 0), (0, 0)))
            v = jnp.pad(v, padw + ((0, 0), (0, 0)))
            logf = jnp.pad(logf, padw + ((0, 0),))
            logi = jnp.pad(logi, padw + ((0, 0),), constant_values=-1e30)
        hseq, new_state = _mlstm_chunked(q, k, v, logf, logi, Q)
        hseq = hseq[:, :S]
    else:
        outs = []
        for t in range(S):
            # chunked path scales k by 1/sqrt(dk); mirror exactly here
            o, state = _mlstm_recurrent_step(
                q[:, t], k[:, t] / math.sqrt(dk),
                v[:, t], logf[:, t], logi[:, t], state)
            outs.append(o)
        hseq = jnp.stack(outs, axis=1)
        new_state = state

    hseq = hseq.reshape(B, S, d_in).astype(dt)
    hseq = apply_rmsnorm(p["out_norm"], hseq, cfg.norm_eps)
    hseq = hseq * jax.nn.silu(z)
    out = hseq @ p["down_proj"]["w"].astype(dt)
    return x + shard(out, "btd"), new_state


def init_mlstm_state(cfg: ModelConfig, batch: int):
    H, d_in, dk = _mlstm_dims(cfg)
    return (jnp.zeros((batch, H, dk, dk), jnp.float32),
            jnp.zeros((batch, H, dk), jnp.float32),
            jnp.full((batch, H), -1e30, jnp.float32))


# ======================================================================
# sLSTM
# ======================================================================
def _slstm_dims(cfg: ModelConfig):
    H = cfg.num_heads
    dh = cfg.d_model // H
    return H, dh


def decl_slstm(cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    H, dh = _slstm_dims(cfg)
    d_up = int(cfg.d_model * cfg.xlstm.proj_factor_slstm)
    gates = {}
    for g in ("z", "i", "f", "o"):
        gates[f"w_{g}"] = P.linear(d, d, "embed", "q_feat")
        # block-diagonal recurrent mixing: per-head (dh, dh)
        gates[f"r_{g}"] = P.ParamDecl((H, dh, dh), (None, None, None),
                                      "normal", 1.0 / math.sqrt(dh))
        gates[f"b_{g}"] = P.ParamDecl((d,), ("embed",),
                                      "ones" if g == "f" else "zeros")
    return {
        "ln": P.norm(d),
        **gates,
        "out_norm": P.norm(d),
        "up": P.linear(d, d_up, "embed", "ffn"),
        "gate": P.linear(d, d_up, "embed", "ffn"),
        "down": P.linear(d_up, d, "ffn", "embed"),
    }


def _slstm_cell(p, cfg, xt, carry):
    """xt: (B,d) pre-activations W·x already applied outside? No: full cell."""
    h_prev, c_prev, n_prev, m_prev = carry                    # (B,d) each, m (B,d)
    H, dh = _slstm_dims(cfg)
    B = h_prev.shape[0]
    hb = h_prev.reshape(B, H, dh)

    def rmix(r):                                              # (H,dh,dh)
        return jnp.einsum("bhd,hde->bhe", hb, r).reshape(B, H * dh)

    z = jnp.tanh(xt["z"] + rmix(p["r_z"].astype(jnp.float32)))
    o = jax.nn.sigmoid(xt["o"] + rmix(p["r_o"].astype(jnp.float32)))
    logi = xt["i"] + rmix(p["r_i"].astype(jnp.float32))
    logf = _log_sigmoid(xt["f"] + rmix(p["r_f"].astype(jnp.float32)))

    m_new = jnp.maximum(logf + m_prev, logi)
    ig = jnp.exp(logi - m_new)
    fg = jnp.exp(logf + m_prev - m_new)
    c_new = fg * c_prev + ig * z
    n_new = jnp.maximum(fg * n_prev + ig, jnp.exp(-m_new))
    h_new = o * c_new / n_new
    return h_new, c_new, n_new, m_new


def apply_slstm(p, cfg: ModelConfig, x: jax.Array, *,
                state: Optional[Tuple] = None):
    B, S, d = x.shape
    dt = x.dtype
    f32 = jnp.float32
    h = apply_rmsnorm(p["ln"], x, cfg.norm_eps)
    pre = {g: (h @ p[f"w_{g}"]["w"].astype(dt)).astype(f32) +
              p[f"b_{g}"].astype(f32)
           for g in ("z", "i", "f", "o")}

    if state is None:
        zero = jnp.zeros((B, d), f32)
        carry = (zero, zero, jnp.ones((B, d), f32), jnp.zeros((B, d), f32))
    else:
        carry = state

    def step(carry, xt):
        new = _slstm_cell(p, cfg, xt, carry)
        return new, new[0]

    xs = {g: pre[g].transpose(1, 0, 2) for g in pre}
    carry, hs = jax.lax.scan(step, carry, xs)
    hseq = hs.transpose(1, 0, 2).astype(dt)                   # (B,S,d)
    hseq = apply_rmsnorm(p["out_norm"], hseq, cfg.norm_eps)
    # post-cell gated up/down projection (xLSTM block structure)
    u = jax.nn.gelu(hseq @ p["up"]["w"].astype(dt))
    g = hseq @ p["gate"]["w"].astype(dt)
    out = (u * jax.nn.sigmoid(g)) @ p["down"]["w"].astype(dt)
    return x + shard(out, "btd"), carry


def init_slstm_state(cfg: ModelConfig, batch: int):
    d = cfg.d_model
    zero = jnp.zeros((batch, d), jnp.float32)
    return (zero, zero, jnp.ones((batch, d), jnp.float32),
            jnp.zeros((batch, d), jnp.float32))
