"""Parameter declaration machinery.

Models *declare* their parameters as trees of :class:`ParamDecl` (shape +
logical axis names + initializer). From one declaration tree we derive, in
lockstep: materialized parameters, abstract ShapeDtypeStructs, logical
sharding specs, and analytic parameter counts. This guarantees the sharding
rules can never drift out of sync with the actual parameter tree.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# Logical axis vocabulary (mapped to mesh axes in repro.sharding.rules):
#   embed   : d_model
#   ffn     : feed-forward hidden
#   q_feat  : flattened num_heads*head_dim
#   kv_feat : flattened num_kv_heads*head_dim
#   vocab   : vocabulary
#   experts : MoE expert dim
#   heads   : explicit head dim (only where unavoidable)
#   ssm_*   : state-space dims
#   layers  : stacked scan dim (never sharded)
#   None    : replicated


@dataclass(frozen=True)
class ParamDecl:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"            # normal | zeros | ones
    # stddev scale; None => 1/sqrt(fan_in) with fan_in = shape[-2] (or [-1])
    scale: Optional[float] = None

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)

    @property
    def size(self) -> int:
        return int(np.prod(self.shape)) if self.shape else 1


def is_decl(x: Any) -> bool:
    return isinstance(x, ParamDecl)


def _leaf_init(key, decl: ParamDecl, dtype) -> jax.Array:
    if decl.init == "zeros":
        return jnp.zeros(decl.shape, dtype)
    if decl.init == "ones":
        return jnp.ones(decl.shape, dtype)
    if decl.scale is not None:
        std = decl.scale
    else:
        fan_in = decl.shape[-2] if len(decl.shape) >= 2 else max(decl.shape[-1], 1)
        std = 1.0 / np.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, decl.shape, jnp.float32) * std).astype(dtype)


def init_tree(key: jax.Array, decls: Any, dtype=jnp.float32,
              registry=None, owner: str = "params") -> Any:
    """Materialize a declaration tree into a parameter pytree.

    With an `ObjectRegistry` (core/objects.py) every leaf registers as a
    live ``param`` object under ``owner/<path>`` — THIS call is the
    allocation site the object tier reports, so replica findings on
    duplicated weights point here."""
    leaves, treedef = jax.tree_util.tree_flatten(decls, is_leaf=is_decl)
    keys = jax.random.split(key, len(leaves))
    vals = [_leaf_init(k, d, dtype) for k, d in zip(keys, leaves)]
    tree = jax.tree_util.tree_unflatten(treedef, vals)
    if registry is not None:
        from repro.core.objects import register_tree
        register_tree(registry, owner, tree, kind="param")
    return tree


def abstract_tree(decls: Any, dtype=jnp.float32) -> Any:
    """ShapeDtypeStruct tree (no allocation) matching ``init_tree``."""
    return jax.tree_util.tree_map(
        lambda d: jax.ShapeDtypeStruct(d.shape, dtype), decls, is_leaf=is_decl)


def axes_tree(decls: Any) -> Any:
    """Logical axes tree matching ``init_tree`` structure."""
    return jax.tree_util.tree_map(lambda d: d.axes, decls, is_leaf=is_decl)


def count_tree(decls: Any) -> int:
    return sum(d.size for d in
               jax.tree_util.tree_leaves(decls, is_leaf=is_decl))


def stack_decls(decls: Any, n: int) -> Any:
    """Declaration tree for ``n`` stacked (scanned) copies of a block."""
    def _stack(d: ParamDecl) -> ParamDecl:
        return dataclasses.replace(
            d, shape=(n,) + d.shape, axes=("layers",) + d.axes)
    return jax.tree_util.tree_map(_stack, decls, is_leaf=is_decl)


def init_stacked(key: jax.Array, decls: Any, n: int, dtype=jnp.float32) -> Any:
    """Init ``n`` stacked copies (vmap over per-layer keys)."""
    keys = jax.random.split(key, n)

    def one(k):
        return init_tree(k, decls, dtype)
    return jax.vmap(one)(keys)


# ----------------------------------------------------------------------
# Declaration helpers
# ----------------------------------------------------------------------
def linear(d_in: int, d_out: int, in_ax: Optional[str], out_ax: Optional[str],
           init: str = "normal", scale: Optional[float] = None) -> Dict[str, ParamDecl]:
    return {"w": ParamDecl((d_in, d_out), (in_ax, out_ax), init, scale)}


def norm(d: int, ax: Optional[str] = "embed") -> Dict[str, ParamDecl]:
    return {"scale": ParamDecl((d,), (ax,), "ones")}
