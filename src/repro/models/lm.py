"""Decoder LM assembly: heterogeneous block schedules compiled into
scan-over-superblocks so the HLO is O(1) in network depth.

A *superblock* is the repeating pattern unit of an architecture:
  dense/moe : 1 block
  vlm       : (period-1) dense + 1 cross-attn block        (llama-3.2-vision)
  hybrid    : `attn_period` mamba + 1 SHARED attn block    (zamba2)
  ssm       : (slstm_period-1) mLSTM + 1 sLSTM             (xlstm)
  audio     : separate encoder scan + decoder scan          (whisper)

Shared blocks (zamba2's attention) have ONE parameter set closed over the
scan — faithful to the published weight sharing — while their KV caches are
per-invocation (stacked, carried through the scan like all other caches).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import params as P
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as SSM
from repro.models import xlstm as XL
from repro.sharding.ctx import shard


def _mask_pad_vocab(logits: jax.Array, cfg: ModelConfig) -> jax.Array:
    """-inf the padded vocab tail so sampling/eval never selects it."""
    if cfg.padded_vocab == cfg.vocab_size:
        return logits
    pad = logits.shape[-1] - cfg.vocab_size
    neg = jnp.full(logits.shape[:-1] + (pad,), -1e30, logits.dtype)
    return jnp.concatenate([logits[..., :cfg.vocab_size], neg], axis=-1)


# ----------------------------------------------------------------------
# Schedule
# ----------------------------------------------------------------------
@dataclass(frozen=True)
class Schedule:
    pattern: Tuple[str, ...]      # sub-block types within one superblock
    n_super: int
    tail: Tuple[str, ...] = ()    # leftover blocks appended after the scan
    has_shared: bool = False
    has_encoder: bool = False


def make_schedule(cfg: ModelConfig) -> Schedule:
    if cfg.family == "dense":
        return Schedule(("dense",), cfg.num_layers)
    if cfg.family == "moe":
        return Schedule(("moe",), cfg.num_layers)
    if cfg.family == "vlm":
        p = cfg.cross_attn_period
        assert cfg.num_layers % p == 0, "vlm layers must divide the period"
        return Schedule(("dense",) * (p - 1) + ("xattn",), cfg.num_layers // p)
    if cfg.family == "hybrid":
        p = cfg.attn_period
        n, r = divmod(cfg.num_layers, p)
        return Schedule(("mamba",) * p + ("shared",), n,
                        tail=("mamba",) * r, has_shared=True)
    if cfg.family == "ssm":
        sp = cfg.xlstm.slstm_period
        assert cfg.num_layers % sp == 0
        return Schedule(("mlstm",) * (sp - 1) + ("slstm",), cfg.num_layers // sp)
    if cfg.family == "audio":
        return Schedule(("encdec",), cfg.num_layers, has_encoder=True)
    raise ValueError(cfg.family)


# ----------------------------------------------------------------------
# Sub-block declarations / applications
# ----------------------------------------------------------------------
def decl_moe_block(cfg: ModelConfig) -> Dict[str, Any]:
    return {
        "ln1": P.norm(cfg.d_model),
        "attn": L.decl_attention(cfg),
        "ln2": P.norm(cfg.d_model),
        "moe": M.decl_moe(cfg),
    }


def decl_encdec_block(cfg: ModelConfig) -> Dict[str, Any]:
    return {
        "ln1": P.norm(cfg.d_model),
        "attn": L.decl_attention(cfg),
        "lnx": P.norm(cfg.d_model),
        "xattn": L.decl_attention(cfg, cross=True),
        "ln2": P.norm(cfg.d_model),
        "mlp": L.decl_mlp(cfg),
    }


def _decl_sub(cfg: ModelConfig, typ: str) -> Dict[str, Any]:
    if typ == "dense":
        return L.decl_dense_block(cfg)
    if typ == "moe":
        return decl_moe_block(cfg)
    if typ == "xattn":
        return L.decl_xattn_block(cfg)
    if typ == "mamba":
        return SSM.decl_mamba(cfg)
    if typ == "mlstm":
        return XL.decl_mlstm(cfg)
    if typ == "slstm":
        return XL.decl_slstm(cfg)
    if typ == "encdec":
        return decl_encdec_block(cfg)
    if typ == "shared":
        return {}                     # params live outside the scan
    raise ValueError(typ)


def decl_superblock(cfg: ModelConfig, pattern) -> Dict[str, Any]:
    return {f"b{i}_{t}": _decl_sub(cfg, t) for i, t in enumerate(pattern)
            if t != "shared"}


# ----------------------------------------------------------------------
# Caches / states
# ----------------------------------------------------------------------
def _init_sub_cache(cfg: ModelConfig, typ: str, batch: int, max_len: int,
                    kv_dtype) -> Any:
    Hkv, D = cfg.num_kv_heads, cfg.head_dim
    if typ in ("dense", "moe", "shared", "encdec"):
        c = {"k": jnp.zeros((batch, max_len, Hkv, D), kv_dtype),
             "v": jnp.zeros((batch, max_len, Hkv, D), kv_dtype),
             "idx": jnp.zeros((), jnp.int32)}
        if typ == "encdec":
            c["xk"] = jnp.zeros((batch, cfg.encoder_frames, Hkv, D), kv_dtype)
            c["xv"] = jnp.zeros((batch, cfg.encoder_frames, Hkv, D), kv_dtype)
        return c
    if typ == "xattn":
        return {"xk": jnp.zeros((batch, cfg.num_image_tokens, Hkv, D), kv_dtype),
                "xv": jnp.zeros((batch, cfg.num_image_tokens, Hkv, D), kv_dtype)}
    if typ == "mamba":
        return SSM.init_mamba_state(cfg, batch, kv_dtype)
    if typ == "mlstm":
        return XL.init_mlstm_state(cfg, batch)
    if typ == "slstm":
        return XL.init_slstm_state(cfg, batch)
    raise ValueError(typ)


def _stack_cache(tree, n: int):
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (n,) + x.shape), tree)


# ----------------------------------------------------------------------
# Model
# ----------------------------------------------------------------------
class LM:
    """Functional LM: holds config + schedule, params passed explicitly."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.sched = make_schedule(cfg)
        # activation checkpointing for the scanned superblock:
        #   "none" | "full" | "dots"  (set by the train-step factory)
        self.remat = "none"

    def _maybe_remat(self, fn):
        if self.remat == "full":
            return jax.checkpoint(fn, prevent_cse=False)
        if self.remat == "dots":
            return jax.checkpoint(
                fn, prevent_cse=False,
                policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
        return fn

    # -------------------------- declarations -------------------------
    def decl(self) -> Dict[str, Any]:
        cfg, sch = self.cfg, self.sched
        d = {
            "embed": P.ParamDecl((cfg.padded_vocab, cfg.d_model),
                                 ("vocab", "embed"), "normal", 0.02),
            "final_norm": P.norm(cfg.d_model),
            "main": P.stack_decls(decl_superblock(cfg, sch.pattern), sch.n_super),
        }
        if not cfg.tie_embeddings:
            # vocab-major (V, d) so the lm_head vjp is transpose-free
            d["head"] = P.ParamDecl((cfg.padded_vocab, cfg.d_model),
                                    ("vocab", "embed"), "normal",
                                    1.0 / (cfg.d_model ** 0.5))
        if sch.tail:
            d["tail"] = P.stack_decls(_decl_sub(cfg, sch.tail[0]), len(sch.tail))
        if sch.has_shared:
            d["shared"] = L.decl_dense_block(cfg)
        if sch.has_encoder:
            d["enc"] = {
                "blocks": P.stack_decls(L.decl_dense_block(cfg), cfg.encoder_layers),
                "norm": P.norm(cfg.d_model),
            }
        return d

    def init(self, key: jax.Array, dtype=None) -> Any:
        dtype = dtype or jnp.dtype(self.cfg.param_dtype)
        return P.init_tree(key, self.decl(), dtype)

    def abstract_params(self, dtype=None) -> Any:
        dtype = dtype or jnp.dtype(self.cfg.param_dtype)
        return P.abstract_tree(self.decl(), dtype)

    def param_axes(self) -> Any:
        return P.axes_tree(self.decl())

    def decode_params(self, params) -> Any:
        """The decode-path view of ``params``.

        The encoder tower and the cross-attention K/V projections (and
        their k_norm) only feed ``init_cache``'s cross-KV precompute;
        ``decode_step``/``prefill``/``verify`` read the cached ``xk``/
        ``xv`` instead. Handing the full tree to a traced decode step
        leaves those leaves as dead jaxpr invars (tier-0 dead_param) and
        ships dead bytes to the device on a real serving host. Families
        without cross-attention get ``params`` back unchanged.
        """
        sch = self.sched
        xattn_blocks = [f"b{i}_{t}" for i, t in enumerate(sch.pattern)
                        if t in ("xattn", "encdec")]
        if not xattn_blocks and not sch.has_encoder:
            return params
        out = dict(params)
        if sch.has_encoder:
            out.pop("enc", None)
        if xattn_blocks:
            main = dict(out["main"])
            for name in xattn_blocks:
                blk = dict(main[name])
                blk["xattn"] = {k: v for k, v in blk["xattn"].items()
                                if k not in ("wk", "wv", "k_norm")}
                main[name] = blk
            out["main"] = main
        return out

    # ----------------------------- encoder ---------------------------
    def encode(self, params, frames: jax.Array,
               frame_lengths: Optional[jax.Array] = None) -> jax.Array:
        """audio/whisper encoder over stubbed frame embeddings (B,F,d).

        ``frame_lengths``: optional (B,) true frame counts for
        right-padded inputs. Padded keys are masked out of every
        encoder self-attention, so rows below each true length are
        independent of how far the batch was padded — the invariant
        that lets serving bucket the encoder extent (launch/serve.py)
        instead of always padding to cfg.encoder_frames.
        """
        cfg = self.cfg
        x = frames.astype(jnp.dtype(cfg.dtype))
        valid = None
        if frame_lengths is not None:
            valid = (jnp.arange(x.shape[1])[None, :]
                     < jnp.asarray(frame_lengths, jnp.int32)[:, None])

        def blk(x, p):
            y, _ = L.apply_dense_block(p, cfg, x, causal=False,
                                       use_rope=True, kv_valid=valid)
            return y, None
        x, _ = jax.lax.scan(blk, x, params["enc"]["blocks"])
        return L.apply_rmsnorm(params["enc"]["norm"], x, cfg.norm_eps)

    # ----------------------------- forward ---------------------------
    def backbone(self, params, tokens: jax.Array, *,
                 img: Optional[jax.Array] = None,
                 frames: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
        """Everything up to (and incl.) the final norm: (hidden, moe_aux)."""
        cfg, sch = self.cfg, self.sched
        dt = jnp.dtype(cfg.dtype)
        x = jnp.take(params["embed"], tokens, axis=0).astype(dt)
        x = shard(x, "btd")

        enc_out = None
        if sch.has_encoder:
            assert frames is not None, "audio family needs frame embeddings"
            enc_out = self.encode(params, frames)
        if cfg.family == "vlm":
            assert img is not None, "vlm family needs image patch embeddings"
            img = img.astype(dt)

        def superblock(carry, p_layer):
            x, aux = carry
            for i, typ in enumerate(sch.pattern):
                name = f"b{i}_{typ}"
                if typ == "dense":
                    x, _ = L.apply_dense_block(p_layer[name], cfg, x)
                elif typ == "moe":
                    blk = p_layer[name]
                    h, _ = L.apply_attention(
                        blk["attn"], cfg,
                        L.apply_rmsnorm(blk["ln1"], x, cfg.norm_eps))
                    x = x + h
                    h, a = M.apply_moe(
                        blk["moe"], cfg,
                        L.apply_rmsnorm(blk["ln2"], x, cfg.norm_eps))
                    x = x + h
                    aux = aux + a
                elif typ == "xattn":
                    x = L.apply_xattn_block(p_layer[name], cfg, x, img)
                elif typ == "mamba":
                    x, _ = SSM.apply_mamba(p_layer[name], cfg, x)
                elif typ == "mlstm":
                    x, _ = XL.apply_mlstm(p_layer[name], cfg, x)
                elif typ == "slstm":
                    x, _ = XL.apply_slstm(p_layer[name], cfg, x)
                elif typ == "shared":
                    x, _ = L.apply_dense_block(params["shared"], cfg, x)
                elif typ == "encdec":
                    blk = p_layer[name]
                    h, _ = L.apply_attention(
                        blk["attn"], cfg,
                        L.apply_rmsnorm(blk["ln1"], x, cfg.norm_eps))
                    x = x + h
                    h, _ = L.apply_attention(
                        blk["xattn"], cfg,
                        L.apply_rmsnorm(blk["lnx"], x, cfg.norm_eps),
                        kv_src=enc_out, causal=False, use_rope=False)
                    x = x + h
                    x = x + L.apply_mlp(
                        blk["mlp"], cfg,
                        L.apply_rmsnorm(blk["ln2"], x, cfg.norm_eps))
                else:
                    raise ValueError(typ)
            return (x, aux), None

        (x, aux), _ = jax.lax.scan(self._maybe_remat(superblock),
                                   (x, jnp.zeros((), jnp.float32)),
                                   params["main"])
        if sch.tail:
            def tailblk(x, p):
                y, _ = SSM.apply_mamba(p, cfg, x)
                return y, None
            x, _ = jax.lax.scan(tailblk, x, params["tail"])

        x = L.apply_rmsnorm(params["final_norm"], x, cfg.norm_eps)
        return x, aux

    def head_weight(self, params) -> jax.Array:
        """(V_padded, d) vocab-major head weight (embedding when tied)."""
        return (params["embed"] if self.cfg.tie_embeddings
                else params["head"])

    def forward(self, params, tokens: jax.Array, *,
                img: Optional[jax.Array] = None,
                frames: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
        """Train/prefill forward. Returns (logits, moe_aux_loss)."""
        cfg = self.cfg
        x, aux = self.backbone(params, tokens, img=img, frames=frames)
        w = self.head_weight(params)
        logits = L.lm_head(x, w.astype(x.dtype))
        logits = _mask_pad_vocab(logits, cfg)
        return shard(logits, "btv"), aux

    def loss(self, params, batch: Dict[str, Any], *,
             z_loss: float = 0.0) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        """Fused vocab-parallel LM loss (never materializes global logits)."""
        from repro.sharding.ctx import current_sharder
        from repro.train.fused_xent import lm_loss
        x, aux = self.backbone(params, batch["tokens"],
                               img=batch.get("img"),
                               frames=batch.get("frames"))
        w = self.head_weight(params)
        nll = lm_loss(x, w.astype(x.dtype), batch["labels"],
                      z_loss=z_loss, sharder=current_sharder())
        return nll + aux, {"nll": nll, "moe_aux": aux}

    # ------------------------------ decode ---------------------------
    def init_cache(self, params, batch: int, max_len: int, *,
                   img: Optional[jax.Array] = None,
                   frames: Optional[jax.Array] = None,
                   frame_lengths: Optional[jax.Array] = None,
                   kv_dtype=jnp.bfloat16) -> Any:
        """Preallocate decode caches; precompute cross-attn KV.

        ``frame_lengths``: (B,) true frame counts when ``frames`` is
        right-padded (and possibly bucketed below cfg.encoder_frames).
        The encoder masks padded keys and the cross-KV cache carries an
        ``xvalid`` mask so decode cross-attention ignores them too —
        greedy outputs are then independent of the padded extent."""
        cfg, sch = self.cfg, self.sched
        main = {}
        for i, typ in enumerate(sch.pattern):
            sub = _init_sub_cache(cfg, typ, batch, max_len, kv_dtype)
            main[f"b{i}_{typ}"] = sub
        if getattr(self, "decode_unroll", False):
            # per-layer leaves: every layer's cache is its own buffer, so
            # unrolled decode aliases updates in place (no scan xs/ys
            # slice-copies) — §Perf hillclimb C
            cache = {"main": [jax.tree_util.tree_map(lambda x: x + 0, main)
                              for _ in range(sch.n_super)]}
        else:
            cache = {"main": _stack_cache(main, sch.n_super)}
        if sch.tail:
            tail = _init_sub_cache(cfg, sch.tail[0], batch, max_len, kv_dtype)
            cache["tail"] = _stack_cache(tail, len(sch.tail))

        # Precompute cross-attention K/V (vlm images / encdec encoder out).
        if cfg.family == "vlm" and img is not None:
            cache = self._fill_cross_kv(params, cache, img.astype(jnp.dtype(cfg.dtype)),
                                        "xattn", "xattn")
        if sch.has_encoder and frames is not None:
            enc_out = self.encode(params, frames, frame_lengths)
            cache = self._fill_cross_kv(params, cache, enc_out, "encdec",
                                        "xattn", src_lengths=frame_lengths)
        return cache

    def _fill_cross_kv(self, params, cache, src, typ, attn_key,
                       src_lengths=None):
        """Compute per-layer cross KV from src for all scanned layers."""
        cfg, sch = self.cfg, self.sched
        Hkv, D = cfg.num_kv_heads, cfg.head_dim
        B, Skv = src.shape[:2]
        valid = None
        if src_lengths is not None:
            valid = (jnp.arange(Skv)[None, :]
                     < jnp.asarray(src_lengths, jnp.int32)[:, None])
        for i, t in enumerate(sch.pattern):
            if t != typ:
                continue
            name = f"b{i}_{t}"
            blk_p = params["main"][name]
            ap = blk_p[attn_key] if attn_key in blk_p else blk_p["xattn"]

            def kv_of(p_attn, x):
                k = (x @ p_attn["wk"]["w"].astype(x.dtype)).reshape(B, Skv, Hkv, D)
                v = (x @ p_attn["wv"]["w"].astype(x.dtype)).reshape(B, Skv, Hkv, D)
                if cfg.qk_norm:
                    k = L.apply_rmsnorm(p_attn["k_norm"], k, cfg.norm_eps)
                return k, v
            # vmap over the stacked layer dim
            ks, vs = jax.vmap(kv_of, in_axes=(0, None))(ap, src)
            sub = dict(cache["main"][name])
            sub["xk"] = ks.astype(sub["xk"].dtype)
            sub["xv"] = vs.astype(sub["xv"].dtype)
            if valid is not None:
                sub["xvalid"] = jnp.broadcast_to(
                    valid, (self.sched.n_super,) + valid.shape)
            cache["main"][name] = sub
        return cache

    # ------------------------- paged cache ---------------------------
    def init_paged_cache(self, params, num_slots: int, max_len: int, *,
                         page_size: int = 16,
                         num_pages: Optional[int] = None,
                         kv_dtype=jnp.bfloat16,
                         kernel_counters: bool = False) -> Any:
        """Block-paged decode cache (serve/kv_cache.py): per layer, one
        flat pool of `num_pages` pages of `page_size` K/V rows shared by
        all slots, plus a per-slot page table mapping logical positions
        to pages (-1 = unmapped) and per-slot write indices. Families
        whose every sub-block carries an indexed KV cache only (the
        serving-engine families).

        ``kernel_counters=True`` adds a per-layer ``kcnt`` leaf
        ((num_slots, 3) int32 [stored, silent, dropped] element counts)
        that every paged attention forward overwrites with its
        store-site waste counters (DESIGN.md § Kernel tier); its
        presence is the trace-time enable switch, and the leaf rides
        the decode scan so layers stack automatically."""
        cfg, sch = self.cfg, self.sched
        Hkv, D = cfg.num_kv_heads, cfg.head_dim
        max_pages = -(-max_len // page_size)
        if num_pages is None:
            num_pages = num_slots * max_pages
        main = {}
        for i, typ in enumerate(sch.pattern):
            if typ not in ("dense", "moe"):
                raise ValueError(
                    f"paged cache needs indexed KV in every sub-block; "
                    f"{typ!r} blocks are unsupported")
            sub = {
                "k": jnp.zeros((num_pages, page_size, Hkv, D), kv_dtype),
                "v": jnp.zeros((num_pages, page_size, Hkv, D), kv_dtype),
                "idx": jnp.zeros((num_slots,), jnp.int32),
                "pt": jnp.full((num_slots, max_pages), -1, jnp.int32),
            }
            if kernel_counters:
                sub["kcnt"] = jnp.zeros((num_slots, 3), jnp.int32)
            main[f"b{i}_{typ}"] = sub
        return {"main": _stack_cache(main, sch.n_super)}

    @staticmethod
    def kernel_counters(cache) -> Optional[Dict[str, jax.Array]]:
        """The kernel-tier waste counters of the last paged forward, per
        sub-block name: (n_layers, num_slots, 3) int32 stacked over the
        scanned layers — or None when the cache was built without
        ``kernel_counters=True``."""
        main = cache["main"]
        if isinstance(main, list):
            return None
        out = {name: sub["kcnt"] for name, sub in main.items()
               if "kcnt" in sub}
        return out or None

    @staticmethod
    def cache_is_paged(cache) -> bool:
        main = cache["main"]
        layer0 = main[0] if isinstance(main, list) else main
        return any("pt" in sub for sub in layer0.values())

    def with_page_table(self, cache, pt) -> Any:
        """Return `cache` with every paged KV sub-block's page table
        replaced by `pt` ((num_slots, max_pages) int32, -1 = unmapped)."""
        pt = jnp.asarray(pt, jnp.int32)

        def set_in(tree, n):
            return {name: ({**sub,
                            "pt": jnp.broadcast_to(pt, (n,) + pt.shape)}
                           if "pt" in sub else sub)
                    for name, sub in tree.items()}

        new = dict(cache)
        if isinstance(cache["main"], list):      # decode_unroll layout
            new["main"] = [
                {name: ({**sub, "pt": pt} if "pt" in sub else sub)
                 for name, sub in layer.items()}
                for layer in cache["main"]]
        else:
            new["main"] = set_in(cache["main"], self.sched.n_super)
        return new

    # ------------------------- cache index --------------------------
    def cache_index(self, cache) -> jax.Array:
        """Current write index of the decode cache: scalar, or (B,) when
        the cache has per-slot positions (serving engine)."""
        main = cache["main"]
        layer0 = main[0] if isinstance(main, list) else main
        for sub in layer0.values():
            if "idx" in sub:
                idx = sub["idx"]
                return idx if isinstance(main, list) else idx[0]
        raise ValueError("cache has no indexed KV sub-block")

    def with_cache_index(self, cache, idx) -> Any:
        """Return `cache` with every KV sub-block's write index replaced
        by `idx` (scalar, or (B,) for per-slot serving positions)."""
        idx = jnp.asarray(idx, jnp.int32)

        def set_in(tree, n):
            out = {}
            for name, sub in tree.items():
                if "idx" in sub:
                    sub = {**sub,
                           "idx": jnp.broadcast_to(idx, (n,) + idx.shape)}
                out[name] = sub
            return out

        new = dict(cache)
        if isinstance(cache["main"], list):      # decode_unroll layout
            new["main"] = [
                {name: ({**sub, "idx": idx} if "idx" in sub else sub)
                 for name, sub in layer.items()}
                for layer in cache["main"]]
        else:
            new["main"] = set_in(cache["main"], self.sched.n_super)
        if "tail" in cache:
            new["tail"] = set_in(cache["tail"], len(self.sched.tail))
        return new

    # ------------------------------ prefill --------------------------
    def prefill(self, params, cache, tokens: jax.Array, *,
                lengths: Optional[jax.Array] = None) -> Tuple[jax.Array, Any]:
        """Single-pass batched cache fill: one forward through the
        decode/cache path over the whole prompt instead of `prompt_len`
        sequential decode steps.

        tokens: (B, P) prompt tokens, right-padded when lengths vary;
        lengths: optional (B,) true prompt lengths. Writes K/V for all P
        positions of every row in one call; with `lengths` the cache's
        write index is set per-row so padded tail positions (whose K/V
        are garbage — dead stores by construction) are masked out and
        overwritten as decode advances. Returns (logits (B,P,V), cache).
        Per-position K/V depend only on the causal prefix, so entries
        below each row's true length are exactly the token-by-token
        values (bit-identical on the shared fallback attention path).
        """
        logits, cache = self.decode_step(params, cache, tokens)
        if lengths is not None:
            cache = self.with_cache_index(
                cache, jnp.asarray(lengths, jnp.int32))
        return logits, cache

    # ------------------------- speculative verify --------------------
    def verify(self, params, cache, tokens: jax.Array, *,
               commit: bool = True) -> Tuple[jax.Array, Any]:
        """Width-k speculative verification forward.

        tokens: (B, W) = [last accepted token, draft_1 .. draft_{W-1}]
        at each slot's own cache offset (the engine's per-slot (B,)
        write index). One call yields the logits of all W positions —
        position j attends the committed history plus window rows <= j —
        so the greedy acceptance chain and the bonus token come out of a
        single forward instead of W sequential decode steps.

        commit=True ("overwrite"): all W K/V rows are stored through the
        normal cache path (bounded: rows past the extent drop). Rows
        past the accept point become Def.-1 dead stores — the waste
        `ServingDetectors.rejected_draft_store` measures. commit=False
        ("defer", paged caches only): the pool is untouched and each
        sub-block returns the window K/V as ``win_k``/``win_v``; pair
        with `commit_verify` to scatter only the accepted prefix
        (rollback — the measured waste, eliminated).
        """
        return self.decode_step(params, cache, tokens,
                                spec="overwrite" if commit else "defer")

    def commit_verify(self, cache, start: jax.Array,
                      length: jax.Array) -> Any:
        """Scatter a deferred verify window's accepted prefix into the
        paged pool: rows [0, length[b]) of each sub-block's win_k/win_v
        land at logical positions start[b]+s through the page table
        (length 0 = idle slot, nothing stored). Drops the win_* leaves.
        """
        from repro.kernels import ops
        assert not isinstance(cache["main"], list), \
            "commit_verify expects the scanned (stacked) cache layout"

        def one(sub):
            if "win_k" not in sub:
                return sub
            def upd(pk, pv, wk, wv, pt):
                return ops.paged_update(pk, pv, wk, wv, pt, start,
                                        length=length)
            nk, nv = jax.vmap(upd)(sub["k"], sub["v"], sub["win_k"],
                                   sub["win_v"], sub["pt"])
            out = {n: v for n, v in sub.items()
                   if n not in ("win_k", "win_v")}
            if "kcnt" in sub:
                # kernel tier: the commit scatter is where the rollback
                # path's machine-level stores happen — count them here so
                # rejected_draft_store is exactly 0 (only accepted rows
                # are ever stored).
                def cnt(pk, pv, wk, wv, pt):
                    return ops.paged_store_counts(
                        pk, pv, wk, wv, pt, start, length=length,
                        tol=ops.COUNTER_TOL)
                out["kcnt"] = jax.vmap(cnt)(sub["k"], sub["v"],
                                            sub["win_k"], sub["win_v"],
                                            sub["pt"])
            out["k"], out["v"] = nk, nv
            return out

        new = dict(cache)
        new["main"] = {name: one(sub) for name, sub in cache["main"].items()}
        return new

    def decode_step(self, params, cache, tokens: jax.Array, *,
                    spec: Optional[str] = None) -> Tuple[jax.Array, Any]:
        """One decode step. tokens: (B, S). Returns (logits, new_cache).

        ``spec`` marks a speculative width-k verify forward (see
        `verify`); it only reaches the indexed-KV sub-blocks the serving
        engine drives (dense/moe)."""
        cfg, sch = self.cfg, self.sched
        dt = jnp.dtype(cfg.dtype)
        x = jnp.take(params["embed"], tokens, axis=0).astype(dt)
        x = shard(x, "btd_dec")

        def superblock(x, inp):
            p_layer, c_layer = inp
            new_c = {}
            for i, typ in enumerate(sch.pattern):
                name = f"b{i}_{typ}"
                c = c_layer[name]
                if typ == "dense":
                    x, nc = L.apply_dense_block(p_layer[name], cfg, x,
                                                cache=c, spec=spec)
                elif typ == "moe":
                    blk = p_layer[name]
                    h, nc = L.apply_attention(
                        blk["attn"], cfg,
                        L.apply_rmsnorm(blk["ln1"], x, cfg.norm_eps),
                        cache=c, spec=spec)
                    x = x + h
                    h, _ = M.apply_moe(
                        blk["moe"], cfg,
                        L.apply_rmsnorm(blk["ln2"], x, cfg.norm_eps))
                    x = x + h
                elif typ == "xattn":
                    blk = p_layer[name]
                    h = L.apply_rmsnorm(blk["ln1"], x, cfg.norm_eps)
                    h = self._cached_xattn(blk["xattn"], h, c)
                    x = x + jnp.tanh(blk["gate_attn"].astype(x.dtype)) * h
                    h = L.apply_mlp(blk["mlp"], cfg,
                                    L.apply_rmsnorm(blk["ln2"], x, cfg.norm_eps))
                    x = x + jnp.tanh(blk["gate_mlp"].astype(x.dtype)) * h
                    nc = c
                elif typ == "mamba":
                    x, nc = SSM.apply_mamba(p_layer[name], cfg, x, state=c)
                elif typ == "mlstm":
                    x, nc = XL.apply_mlstm(p_layer[name], cfg, x, state=c)
                elif typ == "slstm":
                    x, nc = XL.apply_slstm(p_layer[name], cfg, x, state=c)
                elif typ == "shared":
                    x, nc = L.apply_dense_block(params["shared"], cfg, x, cache=c)
                elif typ == "encdec":
                    blk = p_layer[name]
                    h, nc = L.apply_attention(
                        blk["attn"], cfg,
                        L.apply_rmsnorm(blk["ln1"], x, cfg.norm_eps), cache=c)
                    x = x + h
                    h = L.apply_rmsnorm(blk["lnx"], x, cfg.norm_eps)
                    h = self._cached_xattn(blk["xattn"], h, c)
                    x = x + h
                    x = x + L.apply_mlp(blk["mlp"], cfg,
                                        L.apply_rmsnorm(blk["ln2"], x, cfg.norm_eps))
                    nc = {**nc, "xk": c["xk"], "xv": c["xv"]}
                    if "xvalid" in c:
                        nc["xvalid"] = c["xvalid"]
                else:
                    raise ValueError(typ)
                new_c[name] = nc
            return x, new_c

        if getattr(self, "decode_unroll", False):
            # unrolled layers over per-layer cache leaves: no scan xs/ys
            # slice-copies; XLA aliases each layer's cache in place
            new_main = []
            for li in range(sch.n_super):
                p_l = jax.tree_util.tree_map(lambda a: a[li], params["main"])
                x, nc = superblock(x, (p_l, cache["main"][li]))
                new_main.append(nc)
        else:
            x, new_main = jax.lax.scan(superblock, x,
                                       (params["main"], cache["main"]))
        new_cache = {"main": new_main}
        if sch.tail:
            def tailblk(x, inp):
                p, c = inp
                y, nc = SSM.apply_mamba(p, cfg, x, state=c)
                return y, nc
            x, new_tail = jax.lax.scan(tailblk, x, (params["tail"], cache["tail"]))
            new_cache["tail"] = new_tail

        x = L.apply_rmsnorm(params["final_norm"], x, cfg.norm_eps)
        w = self.head_weight(params)
        logits = jnp.einsum("bsd,vd->bsv", x, w.astype(dt))
        return _mask_pad_vocab(logits, cfg), new_cache

    def _cached_xattn(self, p_attn, x, c):
        """Cross-attention against precomputed cached KV."""
        cfg = self.cfg
        B, S, _ = x.shape
        H, D = cfg.num_heads, cfg.head_dim
        q = (x @ p_attn["wq"]["w"].astype(x.dtype)).reshape(B, S, H, D)
        if cfg.qk_norm:
            q = L.apply_rmsnorm(p_attn["q_norm"], q, cfg.norm_eps)
        from repro.serve.flash_decode import (cross_attention_sharded,
                                              decode_shard_plan)
        from repro.sharding.ctx import current_sharder
        sharder = current_sharder()
        plan = decode_shard_plan(sharder, B, c["xk"].shape[1])
        if plan is not None and "xvalid" not in c:
            b_ax, s_ax = plan
            out = cross_attention_sharded(
                q, c["xk"], c["xv"], mesh=sharder.mesh,
                batch_axes=b_ax, seq_axes=s_ax)
        else:
            # length-masked cross-attn (bucketed encoder prefill): keys
            # past each row's true source length never contribute
            out = L.attention(q, c["xk"].astype(x.dtype),
                              c["xv"].astype(x.dtype), causal=False,
                              kv_valid=c.get("xvalid"))
        out = out.reshape(B, S, H * D)
        return out @ p_attn["wo"]["w"].astype(x.dtype)
