"""Mamba2 block (State Space Duality form).

Training/prefill uses the chunked SSD algorithm: quadratic attention-like
computation *within* chunks (MXU einsums) + a tiny recurrence *across*
chunks — the TPU-idiomatic adaptation of the CUDA selective-scan kernel
(matmuls on the MXU instead of warp-level scans). Decode is the exact O(1)
recurrence. Both paths are validated against each other in tests.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import params as P
from repro.sharding.ctx import shard


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    nheads = d_inner // s.head_dim
    return d_inner, nheads, s.state_dim, s.head_dim, s.conv_width


def decl_mamba(cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    d_inner, H, N, Pd, W = _dims(cfg)
    conv_ch = d_inner + 2 * N
    return {
        "ln": P.norm(d),
        # in_proj -> [z(d_inner), x(d_inner), B(N), C(N), dt(H)]
        "in_proj": P.linear(d, 2 * d_inner + 2 * N + H, "embed", "ssm_inner"),
        "conv_w": P.ParamDecl((W, conv_ch), (None, "ssm_inner"), "normal",
                              1.0 / math.sqrt(W)),
        "conv_b": P.ParamDecl((conv_ch,), ("ssm_inner",), "zeros"),
        "A_log": P.ParamDecl((H,), (None,), "zeros"),
        "D": P.ParamDecl((H,), (None,), "ones"),
        "dt_bias": P.ParamDecl((H,), (None,), "zeros"),
        "gate_norm": P.norm(d_inner, "ssm_inner"),
        "out_proj": P.linear(d_inner, d, "ssm_inner", "embed"),
    }


def _segsum(x: jax.Array) -> jax.Array:
    """x: (..., T) log-decays -> (..., T, T) lower-tri cumulative sums."""
    T = x.shape[-1]
    c = jnp.cumsum(x, axis=-1)
    seg = c[..., :, None] - c[..., None, :]
    # iota comparison, not jnp.tril(ones): tril's diagonal shift lowers
    # as `iota + 0`, an identity add per mask element (tier-0
    # silent_store, ssm.py)
    mask = jnp.arange(T)[:, None] >= jnp.arange(T)[None, :]
    return jnp.where(mask, seg, -jnp.inf)


def _ssd_chunked(xh, dt, A, Bm, Cm, chunk: int):
    """Chunked SSD scan.

    xh: (B,S,H,P) value heads; dt: (B,S,H) softplus'd step; A: (H,) < 0;
    Bm/Cm: (B,S,N) input/output mats (single group). Returns (B,S,H,P),
    final_state (B,H,N,P).
    """
    with jax.named_scope("ssd_vmem"):
        return _ssd_chunked_impl(xh, dt, A, Bm, Cm, chunk)


def _ssd_chunked_impl(xh, dt, A, Bm, Cm, chunk: int):
    Bsz, S, H, Pd = xh.shape
    N = Bm.shape[-1]
    nc = S // chunk
    f32 = jnp.float32

    xc = xh.reshape(Bsz, nc, chunk, H, Pd).astype(f32)
    dtc = dt.reshape(Bsz, nc, chunk, H).astype(f32)
    Bc = Bm.reshape(Bsz, nc, chunk, N).astype(f32)
    Cc = Cm.reshape(Bsz, nc, chunk, N).astype(f32)

    dA = dtc * A.astype(f32)                                  # (B,nc,Q,H) log-decay
    dAc = jnp.cumsum(dA, axis=2)                              # within-chunk cumsum
    dAend = dAc[:, :, -1:]                                    # (B,nc,1,H)

    # 1) intra-chunk (quadratic within chunk): L = exp(segsum(dA))
    L = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2)))            # (B,nc,H,Q,Q)
    scores = jnp.einsum("bczn,bcln->bczl", Cc, Bc)            # (B,nc,Q,Q)
    M = scores[:, :, None] * L                                # (B,nc,H,Q,Q)
    xdt = xc * dtc[..., None]                                 # dt-weighted input
    y_diag = jnp.einsum("bchzl,bclhp->bczhp", M, xdt)

    # 2) chunk states: decay-to-end weighted outer products B (x dt)
    decay_states = jnp.exp(dAend - dAc)                       # (B,nc,Q,H)
    states = jnp.einsum("bcln,bclh,bclhp->bchnp",
                        Bc, decay_states * dtc, xc)           # (B,nc,H,N,P)

    # 3) inter-chunk recurrence (tiny scan over nc chunks)
    chunk_decay = jnp.exp(dAend[:, :, 0])                     # (B,nc,H)

    def step(h, inp):
        s_c, g_c = inp                                        # (B,H,N,P), (B,H)
        h_new = h * g_c[..., None, None] + s_c
        return h_new, h                                       # emit state *before* chunk

    h0 = jnp.zeros((Bsz, H, N, Pd), f32)
    hT, h_prevs = jax.lax.scan(
        step, h0, (states.transpose(1, 0, 2, 3, 4),
                   chunk_decay.transpose(1, 0, 2)))
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)                # (B,nc,H,N,P)

    # 4) inter-chunk output: C_t decayed against previous chunk state
    out_decay = jnp.exp(dAc)                                  # (B,nc,Q,H)
    y_off = jnp.einsum("bczn,bczh,bchnp->bczhp", Cc, out_decay, h_prevs)

    y = (y_diag + y_off).reshape(Bsz, S, H, Pd)
    return y, hT


def _causal_conv(x, w, b, state: Optional[jax.Array] = None):
    """Depthwise causal conv. x: (B,S,ch), w: (W,ch). state: (B,W-1,ch)."""
    W = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)                    # (B, S+W-1, ch)
    # reduce, not builtin sum(): sum() seeds with literal 0, emitting a
    # full-(B,S,ch) zero-add per layer (tier-0 silent_store, ssm.py)
    taps = [xp[:, i:i + x.shape[1]] * w[i] for i in range(W)]
    out = functools.reduce(jnp.add, taps) + b
    new_state = xp[:, x.shape[1]:]                            # last W-1 inputs
    return out, new_state


def apply_mamba(p, cfg: ModelConfig, x: jax.Array, *,
                state: Optional[Dict[str, jax.Array]] = None,
                ) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Mamba2 block. state={'ssm': (B,H,N,P), 'conv': (B,W-1,ch)} for decode."""
    d_inner, H, N, Pd, W = _dims(cfg)
    s = cfg.ssm
    B_, S, _ = x.shape
    dt_model = x.dtype

    h = x
    from repro.models.layers import apply_rmsnorm
    h = apply_rmsnorm(p["ln"], h, cfg.norm_eps)
    zxbcdt = h @ p["in_proj"]["w"].astype(dt_model)
    z, xs, Bm, Cm, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + N, 2 * d_inner + 2 * N],
        axis=-1)

    conv_in = jnp.concatenate([xs, Bm, Cm], axis=-1)
    conv_state = None if state is None else state["conv"]
    conv_out, new_conv = _causal_conv(conv_in, p["conv_w"].astype(dt_model),
                                      p["conv_b"].astype(dt_model), conv_state)
    conv_out = jax.nn.silu(conv_out)
    xs, Bm, Cm = jnp.split(conv_out, [d_inner, d_inner + N], axis=-1)

    # softplus as max(x,0)+log1p(exp(-|x|)), not jax.nn.softplus: that
    # routes through logaddexp(x, 0), whose lowering carries an identity
    # add and sub against literal 0 over the full dt tensor (tier-0
    # silent_store, ssm.py). Same stabilized value.
    dt = dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32)
    dt = jnp.maximum(dt, 0.0) + jnp.log1p(jnp.exp(-jnp.abs(dt)))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))              # (H,) negative
    xh = xs.reshape(B_, S, H, Pd)
    xh = shard(xh, "bshp")

    if state is None:
        # pad S to a chunk multiple
        Q = min(s.chunk_size, S)
        S_pad = -(-S // Q) * Q
        if S_pad != S:
            padlen = S_pad - S
            xh_p = jnp.pad(xh, ((0, 0), (0, padlen), (0, 0), (0, 0)))
            dt_p = jnp.pad(dt, ((0, 0), (0, padlen), (0, 0)))
            Bm_p = jnp.pad(Bm, ((0, 0), (0, padlen), (0, 0)))
            Cm_p = jnp.pad(Cm, ((0, 0), (0, padlen), (0, 0)))
        else:
            xh_p, dt_p, Bm_p, Cm_p = xh, dt, Bm, Cm
        y, hT = _ssd_chunked(xh_p, dt_p, A, Bm_p, Cm_p, Q)
        y = y[:, :S]
        out_state = {"ssm": hT, "conv": new_conv}
    else:
        # recurrent decode: h' = exp(dt*A) h + dt * B (outer) x ; y = C . h
        hs = state["ssm"].astype(jnp.float32)                 # (B,H,N,P)
        ys = []
        for t in range(S):                                    # S==1 for decode
            dt_t = dt[:, t]       # slice once: dt feeds both dA and upd
            dA = jnp.exp(dt_t * A)                            # (B,H)
            upd = jnp.einsum("bn,bh,bhp->bhnp", Bm[:, t].astype(jnp.float32),
                             dt_t, xh[:, t].astype(jnp.float32))
            hs = hs * dA[..., None, None] + upd
            ys.append(jnp.einsum("bn,bhnp->bhp", Cm[:, t].astype(jnp.float32), hs))
        y = jnp.stack(ys, axis=1)                             # (B,S,H,P)
        out_state = {"ssm": hs, "conv": new_conv}

    y = y + p["D"].astype(jnp.float32)[None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B_, S, d_inner).astype(dt_model)
    # gated RMSNorm (mamba2): norm(y * silu(z))
    y = y * jax.nn.silu(z)
    y = apply_rmsnorm(p["gate_norm"], y, cfg.norm_eps)
    out = y @ p["out_proj"]["w"].astype(dt_model)
    return x + shard(out, "btd"), out_state


def init_mamba_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    d_inner, H, N, Pd, W = _dims(cfg)
    return {
        "ssm": jnp.zeros((batch, H, N, Pd), jnp.float32),
        "conv": jnp.zeros((batch, W - 1, d_inner + 2 * N), dtype),
    }
