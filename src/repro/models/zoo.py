"""Model zoo facade: build models from configs; analytic parameter counts."""
from __future__ import annotations

from typing import Optional

import jax

from repro.configs.base import ModelConfig
from repro.models import params as P
from repro.models.lm import LM


def build_model(cfg: ModelConfig) -> LM:
    return LM(cfg)


def count_params_analytic(cfg: ModelConfig, active_only: bool = False) -> int:
    """Parameter count derived from the actual declaration tree.

    active_only: for MoE, count only experts_per_token of num_experts routed
    experts (plus everything else) — the N_active used for MODEL_FLOPS.
    """
    model = LM(cfg)
    decl = model.decl()
    total = P.count_tree(decl)
    if active_only and cfg.moe is not None:
        m = cfg.moe
        # routed expert params per layer (up+gate+down)
        per_expert = (2 * cfg.d_model * m.expert_d_ff +
                      m.expert_d_ff * cfg.d_model)
        inactive = (m.num_experts - m.experts_per_token) * per_expert
        total -= inactive * cfg.num_layers
    return int(total)


def model_flops_per_token(cfg: ModelConfig) -> float:
    """MODEL_FLOPS/token = 6*N (dense) or 6*N_active (MoE), N excluding the
    embedding table (standard convention) plus explicit attention flops are
    NOT included here — this is the §Roofline 'useful flops' convention."""
    n = count_params_analytic(cfg, active_only=True)
    n -= cfg.padded_vocab * cfg.d_model      # embedding gather is not a matmul
    return 6.0 * n
