"""Mixture-of-Experts layer: token-choice top-k routing with capacity.

Two dispatch paths share one routing front-end (`_route`):

* ``dispatch="scatter"`` (default): capacity-mask scatter with
  ``mode="drop"``. Only routed rows of the (B, E, C, d) expert buffer
  are written (dropped tokens target the out-of-bounds slot C and are
  discarded by the scatter), so the dead-expert-store fraction of the
  dispatch buffer is 0 by construction, and the O(B·S·E·C·d) one-hot
  dispatch/combine einsums disappear entirely. Combine is a
  ``mode="fill"`` gather weighted by the kept gates.
* ``dispatch="einsum"``: the GShard/Switch one-hot einsum dispatch kept
  as the A/B reference. It materializes every (e, c) row — rows no
  token routed to are written as zeros and never read non-trivially:
  Def.-1 dead stores, which is exactly what the zoo matrix flags
  (`dispatch_stats` below measures the fraction).

Equivalence (measured, tests/test_moe_dispatch.py): for
experts_per_token == 1 the forward outputs and expert-weight grads are
bit-identical in float32 (empty dispatch rows are +0.0 either way and
single-contributor sums add only exact zeros). For K >= 2 the combine
contracts over k where the einsum contracts over (e, c), so XLA's
FMA/lane accumulation order differs and outputs agree to ~1 ulp
(<= 1e-6 relative in float32) rather than bitwise; grads likewise.

Dispatch is *row-local*: capacity slots are assigned per batch row (cumsum
over the sequence dim only), so no cross-batch communication is induced by
the routing bookkeeping itself; expert parallelism comes from sharding the
expert dim of the (b, e, c, d) dispatch tensor (all-to-all inserted by
GSPMD when experts live on the "model" axis).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import params as P
from repro.sharding.ctx import shard


def decl_moe(cfg: ModelConfig) -> Dict[str, Any]:
    m = cfg.moe
    d, f, e = cfg.d_model, m.expert_d_ff, m.num_experts
    decl = {
        "router": P.ParamDecl((d, e), ("embed", None), "normal", 0.02),
        "w_up": P.ParamDecl((e, d, f), ("experts", "embed", "ffn"),
                            "normal", 1.0 / math.sqrt(d)),
        "w_gate": P.ParamDecl((e, d, f), ("experts", "embed", "ffn"),
                              "normal", 1.0 / math.sqrt(d)),
        "w_down": P.ParamDecl((e, f, d), ("experts", "ffn", "embed"),
                              "normal", 1.0 / math.sqrt(f)),
    }
    if m.shared_expert:
        decl["shared"] = {
            "up": P.linear(d, f, "embed", "ffn"),
            "gate": P.linear(d, f, "embed", "ffn"),
            "down": P.linear(f, d, "ffn", "embed"),
        }
    return decl


GROUP = 256  # tokens per dispatch group; keeps the (g,E,C) tensors small


def capacity(cfg: ModelConfig, group: int) -> int:
    m = cfg.moe
    c = int(math.ceil(m.experts_per_token * group * m.capacity_factor
                      / m.num_experts))
    # lane-align capacity for TPU-friendly (e, c) tiles
    return max(8, -(-c // 8) * 8)


def _route(p, cfg: ModelConfig, x: jax.Array):
    """Routing front-end shared by both dispatch paths.

    x: (B, S, d) grouped tokens. Returns (gate_idx, gate_keep, pos_in_e,
    keep, C, aux): expert choice + capacity slot per (row, token, k),
    the kept (renormalized, capacity-masked) gates, and the Switch
    load-balance auxiliary loss.
    """
    m = cfg.moe
    B, S = x.shape[:2]
    E, K = m.num_experts, m.experts_per_token
    C = capacity(cfg, S)

    logits = (x.astype(jnp.float32) @ p["router"].astype(jnp.float32))  # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)

    gate_vals, gate_idx = jax.lax.top_k(probs, K)                        # (B,S,K)
    gate_vals = gate_vals / jnp.clip(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)                # renorm

    # Load-balance auxiliary loss (Switch): E * sum(mean_prob * mean_assign)
    assign1 = jax.nn.one_hot(gate_idx[..., 0], E, dtype=jnp.float32)
    aux = E * jnp.mean(jnp.mean(probs, axis=(0, 1)) *
                       jnp.mean(assign1, axis=(0, 1))) * m.aux_loss_coef

    # Capacity slots per (row, expert): position of each token in its expert
    # queue, kth choices processed in priority order.
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.int32)                # (B,S,K,E)
    # priority: all k=0 choices first, then k=1, ... (GShard policy)
    flat = onehot.transpose(0, 2, 1, 3).reshape(B, K * S, E)             # (B,KS,E)
    pos = jnp.cumsum(flat, axis=1) - flat                                # (B,KS,E)
    pos = pos.reshape(B, K, S, E).transpose(0, 2, 1, 3)                  # (B,S,K,E)
    pos_in_e = jnp.sum(pos * onehot, axis=-1)                            # (B,S,K)
    keep = pos_in_e < C                                                  # dropped beyond capacity

    gate_keep = gate_vals * keep.astype(jnp.float32)                     # (B,S,K)
    return gate_idx, gate_keep, pos_in_e, keep, C, aux


def _expert_ffn(p, xin: jax.Array, dt) -> jax.Array:
    """(B, E, C, d) -> (B, E, C, d) gated-silu expert FFN."""
    up = jnp.einsum("becd,edf->becf", xin, p["w_up"].astype(dt))
    gt = jnp.einsum("becd,edf->becf", xin, p["w_gate"].astype(dt))
    h = jax.nn.silu(gt) * up
    h = shard(h, "becf")
    eout = jnp.einsum("becf,efd->becd", h, p["w_down"].astype(dt))
    return shard(eout, "becd")


def apply_moe(p, cfg: ModelConfig, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out, aux_loss).

    Tokens are regrouped to (n_groups, GROUP, d); capacity is per-group
    (GShard): routing bookkeeping (cumsum) never crosses a group, so the
    dispatch tensors stay O(tokens * E * C/GROUP) and shard cleanly.
    """
    m = cfg.moe
    Bo, So, d = x.shape
    E = m.num_experts
    tokens = Bo * So
    G = min(GROUP, tokens)
    x = x.reshape(tokens // G, G, d)
    B, S = x.shape[:2]
    dt = x.dtype

    gate_idx, gate_keep, pos_in_e, keep, C, aux = _route(p, cfg, x)

    if m.dispatch == "einsum":
        # Reference path: one-hot dispatch/combine einsums. Every row of
        # the (B,E,C,d) buffer is materialized; the unrouted rows are the
        # dead expert stores the matrix driver flags (dispatch_stats).
        onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)          # (B,S,K,E)
        slot_oh = jax.nn.one_hot(jnp.where(keep, pos_in_e, C), C + 1,
                                 dtype=jnp.float32)[..., :C]             # (B,S,K,C)
        disp = jnp.einsum("bske,bskc->bsec", onehot, slot_oh)
        comb = jnp.einsum("bske,bskc,bsk->bsec", onehot, slot_oh, gate_keep)

        xin = jnp.einsum("bsec,bsd->becd", disp.astype(dt), x)           # (B,E,C,d)
        xin = shard(xin, "becd")
        eout = _expert_ffn(p, xin, dt)
        out = jnp.einsum("bsec,becd->bsd", comb.astype(dt), eout)        # (B,S,d)
    else:
        # Masked scatter dispatch: routed tokens land in their exact
        # (expert, slot); dropped tokens target slot C, which is out of
        # bounds for the C-slot buffer and discarded by mode="drop". The
        # (b, e, slot<C) triples are unique by construction (top_k experts
        # are distinct per token, cumsum slots are distinct per expert),
        # so the scatter is deterministic and writes only routed rows —
        # no dead expert stores, and no O(S·E·C) dispatch einsum.
        K = m.experts_per_token
        slot = jnp.where(keep, pos_in_e, C)                              # (B,S,K)
        b_idx = jnp.broadcast_to(jnp.arange(B)[:, None, None], (B, S, K))
        xk = jnp.broadcast_to(x[:, :, None, :], (B, S, K, d))
        xin = jnp.zeros((B, E, C, d), dt).at[b_idx, gate_idx, slot].set(
            xk, mode="drop")
        xin = shard(xin, "becd")
        eout = _expert_ffn(p, xin, dt)
        # Combine: gather each token's expert outputs back (dropped slots
        # read as 0 via mode="fill") and weight by the kept gates.
        eg = eout.at[b_idx, gate_idx, slot].get(mode="fill", fill_value=0)
        out = jnp.einsum("bsk,bskd->bsd", gate_keep.astype(dt), eg)      # (B,S,d)

    if m.shared_expert:
        sh = p["shared"]
        hs = jax.nn.silu(x @ sh["gate"]["w"].astype(dt)) * (x @ sh["up"]["w"].astype(dt))
        out = out + hs @ sh["down"]["w"].astype(dt)

    out = out.reshape(Bo, So, d)
    return shard(out, "btd"), aux.astype(jnp.float32)


def dispatch_stats(p, cfg: ModelConfig, x: jax.Array) -> Dict[str, Any]:
    """Measure the dead-expert-store waste of the dispatch buffer.

    Runs the routing front-end on real activations and counts (expert,
    slot) rows of the (B, E, C, d) dispatch buffer. Under
    ``dispatch="einsum"`` every row is stored (the dispatch einsum
    materializes the full buffer), so unrouted rows are Def.-1 dead
    stores; under ``dispatch="scatter"`` only routed rows are ever
    written, so the dead fraction is exactly 0. Returned bytes use the
    activation dtype's itemsize x d_model per row.
    """
    m = cfg.moe
    Bo, So, d = x.shape
    tokens = Bo * So
    G = min(GROUP, tokens)
    xg = x.reshape(tokens // G, G, d)
    B, S = xg.shape[:2]
    _, _, _, keep, C, _ = _route(p, cfg, xg)

    rows_total = B * m.num_experts * C
    rows_routed = int(jnp.sum(keep.astype(jnp.int32)))
    row_bytes = d * jnp.dtype(x.dtype).itemsize
    stored = rows_total if m.dispatch == "einsum" else rows_routed
    dead = stored - rows_routed
    return {
        "dispatch": m.dispatch,
        "rows_total": rows_total,
        "rows_routed": rows_routed,
        "rows_stored": stored,
        "dead_rows": dead,
        "dead_bytes": dead * row_bytes,
        "stored_bytes": stored * row_bytes,
        "dead_fraction": (dead / stored) if stored else 0.0,
    }
