"""Mixture-of-Experts layer: token-choice top-k routing with capacity,
GShard/Switch-style einsum dispatch (MXU-friendly, GSPMD-shardable).

Dispatch is *row-local*: capacity slots are assigned per batch row (cumsum
over the sequence dim only), so no cross-batch communication is induced by
the routing bookkeeping itself; expert parallelism comes from sharding the
expert dim of the (b, e, c, d) dispatch tensor (all-to-all inserted by
GSPMD when experts live on the "model" axis).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import params as P
from repro.sharding.ctx import shard


def decl_moe(cfg: ModelConfig) -> Dict[str, Any]:
    m = cfg.moe
    d, f, e = cfg.d_model, m.expert_d_ff, m.num_experts
    decl = {
        "router": P.ParamDecl((d, e), ("embed", None), "normal", 0.02),
        "w_up": P.ParamDecl((e, d, f), ("experts", "embed", "ffn"),
                            "normal", 1.0 / math.sqrt(d)),
        "w_gate": P.ParamDecl((e, d, f), ("experts", "embed", "ffn"),
                              "normal", 1.0 / math.sqrt(d)),
        "w_down": P.ParamDecl((e, f, d), ("experts", "ffn", "embed"),
                              "normal", 1.0 / math.sqrt(f)),
    }
    if m.shared_expert:
        decl["shared"] = {
            "up": P.linear(d, f, "embed", "ffn"),
            "gate": P.linear(d, f, "embed", "ffn"),
            "down": P.linear(f, d, "ffn", "embed"),
        }
    return decl


GROUP = 256  # tokens per dispatch group; keeps the (g,E,C) tensors small


def capacity(cfg: ModelConfig, group: int) -> int:
    m = cfg.moe
    c = int(math.ceil(m.experts_per_token * group * m.capacity_factor
                      / m.num_experts))
    # lane-align capacity for TPU-friendly (e, c) tiles
    return max(8, -(-c // 8) * 8)


def apply_moe(p, cfg: ModelConfig, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out, aux_loss).

    Tokens are regrouped to (n_groups, GROUP, d); capacity is per-group
    (GShard): routing bookkeeping (cumsum) never crosses a group, so the
    dispatch tensors stay O(tokens * E * C/GROUP) and shard cleanly.
    """
    m = cfg.moe
    Bo, So, d = x.shape
    E, K = m.num_experts, m.experts_per_token
    tokens = Bo * So
    G = min(GROUP, tokens)
    x = x.reshape(tokens // G, G, d)
    B, S = x.shape[:2]
    C = capacity(cfg, G)
    dt = x.dtype

    logits = (x.astype(jnp.float32) @ p["router"].astype(jnp.float32))  # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)

    gate_vals, gate_idx = jax.lax.top_k(probs, K)                        # (B,S,K)
    gate_vals = gate_vals / jnp.clip(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)                # renorm

    # Load-balance auxiliary loss (Switch): E * sum(mean_prob * mean_assign)
    assign1 = jax.nn.one_hot(gate_idx[..., 0], E, dtype=jnp.float32)
    aux = E * jnp.mean(jnp.mean(probs, axis=(0, 1)) *
                       jnp.mean(assign1, axis=(0, 1))) * m.aux_loss_coef

    # Capacity slots per (row, expert): position of each token in its expert
    # queue, kth choices processed in priority order.
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.int32)                # (B,S,K,E)
    # priority: all k=0 choices first, then k=1, ... (GShard policy)
    flat = onehot.transpose(0, 2, 1, 3).reshape(B, K * S, E)             # (B,KS,E)
    pos = jnp.cumsum(flat, axis=1) - flat                                # (B,KS,E)
    pos = pos.reshape(B, K, S, E).transpose(0, 2, 1, 3)                  # (B,S,K,E)
    pos_in_e = jnp.sum(pos * onehot, axis=-1)                            # (B,S,K)
    keep = pos_in_e < C                                                  # dropped beyond capacity

    gate_keep = gate_vals * keep.astype(jnp.float32)                     # (B,S,K)
    # dispatch (B,S,E,C) one-hot; combine = dispatch * gate
    slot_oh = jax.nn.one_hot(jnp.where(keep, pos_in_e, C), C + 1,
                             dtype=jnp.float32)[..., :C]                 # (B,S,K,C)
    disp = jnp.einsum("bske,bskc->bsec", onehot.astype(jnp.float32), slot_oh)
    comb = jnp.einsum("bske,bskc,bsk->bsec", onehot.astype(jnp.float32),
                      slot_oh, gate_keep)

    xin = jnp.einsum("bsec,bsd->becd", disp.astype(dt), x)               # (B,E,C,d)
    xin = shard(xin, "becd")
    up = jnp.einsum("becd,edf->becf", xin, p["w_up"].astype(dt))
    gt = jnp.einsum("becd,edf->becf", xin, p["w_gate"].astype(dt))
    h = jax.nn.silu(gt) * up
    h = shard(h, "becf")
    eout = jnp.einsum("becf,efd->becd", h, p["w_down"].astype(dt))       # (B,E,C,d)
    eout = shard(eout, "becd")
    out = jnp.einsum("bsec,becd->bsd", comb.astype(dt), eout)            # (B,S,d)

    if m.shared_expert:
        sh = p["shared"]
        hs = jax.nn.silu(x @ sh["gate"]["w"].astype(dt)) * (x @ sh["up"]["w"].astype(dt))
        out = out + hs @ sh["down"]["w"].astype(dt)

    out = out.reshape(Bo, So, d)
    return shard(out, "btd"), aux.astype(jnp.float32)
