"""Background-prefetching data pipeline over any iterator."""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional


class Prefetcher:
    """Runs the upstream iterator on a thread; keeps `depth` batches hot."""

    def __init__(self, it: Iterator, depth: int = 2):
        self.it = it
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self.err: Optional[BaseException] = None
        self._stop = threading.Event()
        self.thread = threading.Thread(target=self._run, daemon=True)
        self.thread.start()

    def _run(self):
        try:
            for item in self.it:
                if self._stop.is_set():
                    return
                self.q.put(item)
        except BaseException as e:  # surfaced on next __next__
            self.err = e
        finally:
            self.q.put(None)

    def __iter__(self):
        return self

    def __next__(self):
        item = self.q.get()
        if item is None:
            if self.err is not None:
                raise self.err
            raise StopIteration
        return item

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
