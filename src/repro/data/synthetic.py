"""Deterministic synthetic LM data: batches are a pure function of
(seed, step, host), so elastic restarts replay the exact stream with zero
coordination — the data-side half of the fault-tolerance story.

The token stream is a mixture of Zipfian unigrams and short copied motifs
(so models actually have something learnable at smoke scale).
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


def batch_at(cfg: ModelConfig, batch: int, seq: int, *, seed: int,
             step: int, host: int = 0, num_hosts: int = 1) -> Dict[str, np.ndarray]:
    """The per-host slice of the global batch at `step`."""
    assert batch % num_hosts == 0
    local = batch // num_hosts
    rng = np.random.Generator(np.random.Philox(
        key=seed, counter=[step, host, 0, 0]))
    V = cfg.vocab_size
    # zipf-ish unigram mixture
    ranks = np.arange(1, V + 1)
    probs = 1.0 / ranks
    probs /= probs.sum()
    toks = rng.choice(V, size=(local, seq + 1), p=probs).astype(np.int32)
    # plant copyable motifs: repeat a short window later in the sequence
    if seq >= 64:
        w = 16
        src = rng.integers(0, seq // 2 - w, size=local)
        dst = rng.integers(seq // 2, seq - w, size=local)
        for i in range(local):
            toks[i, dst[i]:dst[i] + w] = toks[i, src[i]:src[i] + w]
    out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    if cfg.family == "vlm":
        out["img"] = rng.standard_normal(
            (local, cfg.num_image_tokens, cfg.d_model)).astype(np.float32)
    if cfg.family == "audio":
        out["frames"] = rng.standard_normal(
            (local, min(seq, cfg.encoder_frames), cfg.d_model)).astype(np.float32)
    return out


def frame_lengths(cfg: ModelConfig, batch: int, *, seed: int,
                  step: int = 0) -> np.ndarray:
    """Per-request true encoder frame counts for the audio family:
    seeded, in [max(1, F//8), F//2] where F = cfg.encoder_frames.
    Whisper-style capacity windows (30 s) are sized for the longest
    admissible clip; typical utterances fill a fraction of that, so
    padding every request to capacity F is the prefill_padding waste
    the bucketed serve path (launch/serve.py) eliminates."""
    F = cfg.encoder_frames
    rng = np.random.Generator(np.random.Philox(
        key=seed, counter=[step, 0, 1, 0]))
    lo = max(1, F // 8)
    hi = max(lo + 1, F // 2)
    return rng.integers(lo, hi + 1, size=batch).astype(np.int32)


def stream(cfg: ModelConfig, batch: int, seq: int, *, seed: int = 0,
           start_step: int = 0, host: int = 0,
           num_hosts: int = 1) -> Iterator[Dict[str, np.ndarray]]:
    step = start_step
    while True:
        yield batch_at(cfg, batch, seq, seed=seed, step=step, host=host,
                       num_hosts=num_hosts)
        step += 1
