"""Static waste lint driver: tier-0 jaxpr analysis over the model zoo.

Traces the train step, decode step / engine tick, and prefill of each
config in ``configs/registry.py`` ABSTRACTLY (ShapeDtypeStruct in,
jaxpr out — no parameter allocation, no compile, no device) and runs
``core/jaxpr_lint.py`` over the closed jaxprs. Findings merge into one
tier-0 :class:`WasteProfile` and export as SARIF for CI annotation.

Baseline workflow (CI ``lint-zoo`` job):

    # fail only on NEW findings vs the committed waiver baseline
    python -m repro.launch.lint --all-configs \
        --baseline lint_baseline.json --sarif-out lint.sarif

    # intentionally accept the current findings (reviewed!)
    python -m repro.launch.lint --all-configs \
        --baseline lint_baseline.json --update-baseline

A waiver entry records the finding's stable fingerprint (sha over the
§5.6 key kind|tier|C1|C2 — contexts use file BASENAMES, so baselines
are machine-portable) plus human-readable provenance and a note field
for the review rationale.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.configs.base import TrainConfig
from repro.core.findings import WasteProfile, merge_profiles
from repro.core.jaxpr_lint import lint_fn
from repro.core.report import dump_json
from repro.core.sarif import finding_fingerprint, write_sarif
from repro.models.zoo import build_model
from repro.serve.decode import (make_engine_prefill, make_engine_tick,
                                make_serve_step)
from repro.serve.engine import ENGINE_FAMILIES
from repro.train import state as TS
from repro.train.step import make_train_step

BASELINE_VERSION = 1


def _sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _train_batch(cfg, batch: int, seq: int) -> Dict[str, Any]:
    """Abstract batch matching data/synthetic.batch_at's leaves."""
    out = {"tokens": _sds((batch, seq), jnp.int32),
           "labels": _sds((batch, seq), jnp.int32)}
    if cfg.family == "vlm":
        out["img"] = _sds((batch, cfg.num_image_tokens, cfg.d_model),
                          jnp.float32)
    if cfg.family == "audio":
        out["frames"] = _sds((batch, min(seq, cfg.encoder_frames),
                              cfg.d_model), jnp.float32)
    return out


def _abstract_cache(model, params, batch: int, max_len: int):
    """Decode cache shapes without allocating (init_cache under
    eval_shape; cross-KV families get abstract img/frames)."""
    cfg = model.cfg
    kw: Dict[str, Any] = {}
    if cfg.family == "vlm":
        kw["img"] = _sds((batch, cfg.num_image_tokens, cfg.d_model),
                         jnp.float32)
    if cfg.family == "audio":
        kw["frames"] = _sds((batch, cfg.encoder_frames, cfg.d_model),
                            jnp.float32)
    fn = lambda p, kw2: model.init_cache(p, batch, max_len, **kw2)
    return jax.eval_shape(fn, params, kw)


def lint_config(arch: str, *, smoke: bool = True, batch: int = 2,
                seq: int = 32, max_len: int = 48,
                subjects: Tuple[str, ...] = ("train", "decode", "prefill"),
                verbose: bool = False) -> List[WasteProfile]:
    """Lint one zoo config's step functions; one profile per subject."""
    cfg = registry.get_config(arch)
    if smoke:
        cfg = cfg.smoke()
    model = build_model(cfg)
    profiles: List[WasteProfile] = []

    def note(msg):
        if verbose:
            print(f"[lint]   {msg}", flush=True)

    if "train" in subjects:
        tc = TrainConfig(learning_rate=1e-3, total_steps=10, warmup_steps=1)
        step_fn = make_train_step(model, tc, None)
        state = TS.abstract(model)
        profiles.append(lint_fn(step_fn, state, _train_batch(cfg, batch, seq),
                                subject=f"{arch}:train_step"))
        note(f"train_step: {len(profiles[-1].findings)} findings")

    params = model.abstract_params()
    # decode subjects get the decode-path param view: encoder/cross-KV
    # leaves only feed init_cache, and as decode invars they'd lint as
    # dead_param (they ARE dead there — the fix is to not pass them)
    dparams = model.decode_params(params)
    engine = cfg.family in ENGINE_FAMILIES

    if "decode" in subjects:
        cache = _abstract_cache(model, params, batch, max_len)
        if engine:
            tick = make_engine_tick(model)
            prof = lint_fn(tick, dparams, cache,
                           _sds((batch, 1), jnp.int32),
                           _sds((batch,), jnp.bool_),
                           subject=f"{arch}:engine_tick")
        else:
            step = make_serve_step(model)
            prof = lint_fn(step, dparams, cache,
                           _sds((batch, 1), jnp.int32),
                           subject=f"{arch}:decode_step")
        profiles.append(prof)
        note(f"decode: {len(prof.findings)} findings")

    if "prefill" in subjects:
        P = min(16, max_len - 1)
        cache = _abstract_cache(model, params, batch, max_len)
        if engine:
            pf = make_engine_prefill(model)
            prof = lint_fn(pf, dparams, cache,
                           _sds((batch, P), jnp.int32),
                           _sds((batch,), jnp.bool_),
                           _sds((batch,), jnp.int32),
                           _sds((batch,), jnp.int32),
                           _sds((batch, 1), jnp.int32),
                           subject=f"{arch}:engine_prefill")
        else:
            fn = lambda p, c, t: model.prefill(p, c, t)
            prof = lint_fn(fn, dparams, cache, _sds((batch, P), jnp.int32),
                           subject=f"{arch}:prefill")
        profiles.append(prof)
        note(f"prefill: {len(prof.findings)} findings")
    return profiles


# ---------------------------------------------------------------------
# waiver baseline
# ---------------------------------------------------------------------
def load_baseline(path: str) -> Dict[str, Dict[str, Any]]:
    """fingerprint -> waiver entry. Missing file = empty baseline."""
    if not os.path.exists(path):
        return {}
    with open(path) as fh:
        doc = json.load(fh)
    return {w["fingerprint"]: w for w in doc.get("waivers", [])}


def baseline_doc(profile: WasteProfile) -> Dict[str, Any]:
    waivers = []
    for f in sorted(profile.findings,
                    key=lambda f: (f.kind, f.tier, f.c1, f.c2)):
        waivers.append({
            "fingerprint": finding_fingerprint(f),
            "kind": f.kind,
            "tier": f.tier,
            "subject": f.meta.get("subject", ""),
            "c1": list(f.c1),
            "c2": list(f.c2),
            "bytes": f.bytes,
            "note": f.meta.get("rule", ""),
        })
    return {"version": BASELINE_VERSION, "waivers": waivers}


def split_new(profile: WasteProfile, waived: Dict[str, Dict[str, Any]]):
    """Partition findings into (new, waived-hit) by stable fingerprint."""
    new, hit = [], []
    for f in profile.findings:
        (hit if finding_fingerprint(f) in waived else new).append(f)
    return new, hit


# ---------------------------------------------------------------------
def run(archs: List[str], *, smoke: bool = True,
        subjects: Tuple[str, ...] = ("train", "decode", "prefill"),
        sarif_out: Optional[str] = None,
        profile_out: Optional[str] = None,
        baseline: Optional[str] = None,
        update_baseline: bool = False,
        verbose: bool = False) -> Tuple[WasteProfile, int]:
    """Lint archs; returns (merged tier-0 profile, exit code)."""
    profiles: List[WasteProfile] = []
    for arch in archs:
        print(f"[lint] {arch} ...", flush=True)
        try:
            profiles.extend(lint_config(arch, smoke=smoke,
                                        subjects=subjects, verbose=verbose))
        except Exception as e:                      # pragma: no cover
            print(f"[lint] {arch} FAILED to trace: {e!r}", file=sys.stderr)
            raise
    merged = merge_profiles(profiles)
    merged.meta.setdefault("subjects", ",".join(subjects))

    print(f"[lint] {len(archs)} configs, {len(merged.findings)} findings, "
          f"fractions {merged.fractions()}")
    for f in merged.top(20):
        where = (f"{os.path.basename(str(f.meta.get('file', '?')))}:"
                 f"{f.meta.get('line', 0)}" if "file" in f.meta
                 else f.meta.get("path", "-"))
        print(f"  {f.kind:16s} {f.bytes / 1e3:10.1f} KB x{f.count:<4d} "
              f"{f.meta.get('subject', '?'):40s} {where}")

    if sarif_out:
        root = os.getcwd()
        write_sarif(merged, sarif_out, src_root=root)
        print(f"[lint] SARIF written to {sarif_out}")
    if profile_out:
        dump_json(merged, profile_out)
        print(f"[lint] waste profile written to {profile_out}")

    code = 0
    if baseline and update_baseline:
        with open(baseline, "w") as fh:
            json.dump(baseline_doc(merged), fh, indent=2, sort_keys=True)
            fh.write("\n")
        print(f"[lint] baseline updated: {baseline} "
              f"({len(merged.findings)} waivers)")
    elif baseline:
        waived = load_baseline(baseline)
        new, hit = split_new(merged, waived)
        print(f"[lint] baseline {baseline}: {len(hit)} waived, "
              f"{len(new)} NEW")
        if new:
            print("[lint] new findings (fail):")
            for f in sorted(new, key=lambda f: -f.bytes):
                print(f"  {finding_fingerprint(f)[:12]} {f.kind:16s} "
                      f"{f.meta.get('subject', '?')} :: "
                      f"{f.meta.get('rule', f.meta.get('path', ''))}")
            code = 1
    return merged, code


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="Tier-0 static jaxpr waste lint over the model zoo")
    g = ap.add_mutually_exclusive_group(required=True)
    g.add_argument("--config", choices=registry.ARCH_IDS,
                   help="lint one zoo config")
    g.add_argument("--all-configs", action="store_true",
                   help="lint every config in the registry")
    ap.add_argument("--full-size", action="store_true",
                    help="lint at full config size (default: .smoke())")
    ap.add_argument("--subjects", default="train,decode,prefill",
                    help="comma list from {train,decode,prefill}")
    ap.add_argument("--sarif-out", default=None,
                    help="write findings as SARIF 2.1.0")
    ap.add_argument("--profile-out", default=None,
                    help="write the tier-0 WasteProfile as JSON")
    ap.add_argument("--baseline", default=None,
                    help="waiver baseline JSON; NEW findings exit 1")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite --baseline from current findings")
    ap.add_argument("-v", "--verbose", action="store_true")
    a = ap.parse_args(argv)
    archs = registry.ARCH_IDS if a.all_configs else [a.config]
    subjects = tuple(s for s in a.subjects.split(",") if s)
    _, code = run(archs, smoke=not a.full_size, subjects=subjects,
                  sarif_out=a.sarif_out, profile_out=a.profile_out,
                  baseline=a.baseline, update_baseline=a.update_baseline,
                  verbose=a.verbose)
    return code


if __name__ == "__main__":
    sys.exit(main())
