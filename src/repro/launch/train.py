"""End-to-end training driver.

Integrates every substrate: config registry -> model zoo -> synthetic data
(+prefetch) -> pjit'd mixed-precision train step -> checkpointing (atomic,
async) -> fault monitor -> JXPerf-JAX Tier-3 detectors (--profile) and a
Tier-2 HLO waste report of the compiled step (--waste-report).

CPU smoke:  PYTHONPATH=src python -m repro.launch.train \
                --arch qwen3-1.7b --smoke --steps 20
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import registry
from repro.configs.base import ProfilerConfig, TrainConfig
from repro.core.detectors import TrainingDetectors
from repro.core.findings import merge_profiles
from repro.core.hlo_waste import analyze_waste
from repro.core.objects import ObjectRegistry
from repro.core.replicas import ReplicaDetector
from repro.core.report import dump_json
from repro.core.sarif import write_sarif
from repro.data.pipeline import Prefetcher
from repro.data.synthetic import stream
from repro.launch.mesh import make_host_mesh
from repro.models.zoo import build_model
from repro.runtime.fault import FleetMonitor
from repro.sharding.rules import make_strategy
from repro.train import state as TS
from repro.train.step import make_train_step


def run(arch: str, *, smoke: bool = True, steps: int = 50, batch: int = 8,
        seq: int = 128, lr: float = 3e-4, ckpt_dir: str = None,
        ckpt_every: int = 25, profile: bool = False,
        waste_report: bool = False, resume: bool = False,
        microbatches: int = 1, remat: str = "none", seed: int = 0,
        log_every: int = 10, strategy: str = None, total_steps: int = None,
        profile_out: str = None, sarif_out: str = None,
        objects: bool = False):
    cfg = registry.get_config(arch)
    if smoke:
        cfg = cfg.smoke()
    model = build_model(cfg)
    # total_steps fixes the LR schedule horizon independently of how many
    # steps this invocation runs (checkpoint/restart determinism)
    horizon = total_steps or steps
    tc = TrainConfig(learning_rate=lr, total_steps=horizon,
                     warmup_steps=max(horizon // 10, 1),
                     microbatches=microbatches, remat=remat, seed=seed)

    mesh = None
    strat = None
    if strategy:
        mesh = make_host_mesh() if len(jax.devices()) == 1 else None
        if mesh is not None:
            strat = make_strategy(strategy, mesh)

    step_fn = make_train_step(model, tc, strat)
    # Tier-3 detectors hold pre-step params across the call -> no donation
    donate = () if profile else (0,)
    jit_step = jax.jit(step_fn, donate_argnums=donate)

    obj_registry = ObjectRegistry() if objects else None
    state = TS.create(model, jax.random.PRNGKey(seed),
                      registry=obj_registry)
    obj_scan = None
    if obj_registry is not None:
        # scan AT INIT: the moments are all bit-identical zeros here —
        # the replica_opt_state lazy-materialize finding in its purest
        # form (post-training they diverge and the story is gone)
        obj_scan = ReplicaDetector(obj_registry).scan()
        print(f"[train] object scan: {len(obj_registry)} live objects, "
              f"{len(obj_scan.findings)} replica groups, "
              f"{sum(f.bytes for f in obj_scan.findings):.0f} "
              f"duplicate bytes")
    start_step = 0
    ckpt = Checkpointer(ckpt_dir) if ckpt_dir else None
    if ckpt and resume and ckpt.latest_step() is not None:
        state = ckpt.restore(TS.abstract(model))
        start_step = int(state.step)
        print(f"[train] resumed from step {start_step}")

    detectors = TrainingDetectors(ProfilerConfig(enabled=True)) if profile else None
    monitor = FleetMonitor(hosts=[0], dead_after=3600.0)

    data = Prefetcher(stream(cfg, batch, seq, seed=seed, start_step=start_step))

    tier2_profile = None
    if waste_report:
        b0 = next(iter(data))
        lowered = jit_step.lower(state, {k: jnp.asarray(v) for k, v in b0.items()})
        rep = analyze_waste(lowered.compile().as_text())
        print(rep.summary())
        tier2_profile = rep.profile

    losses = []
    t_start = time.time()
    for step in range(start_step, steps):
        b = next(data)
        batch_dev = {k: jnp.asarray(v) for k, v in b.items()}
        if detectors:
            detectors.on_batch(step, b)
            params_before = state.params
        t0 = time.time()
        state, metrics = jit_step(state, batch_dev)
        loss = float(metrics["loss"])
        losses.append(loss)
        monitor.heartbeat(0, time.time() - t0)
        if detectors:
            detectors.on_step(step, params_before, state.params)
        if ckpt and (step + 1) % ckpt_every == 0:
            ckpt.save_async(step + 1, state)
        if (step + 1) % log_every == 0 or step == start_step:
            print(f"[train] step {step+1:5d} loss {loss:8.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"gnorm {float(metrics['grad_norm']):.3f}", flush=True)
        plan = monitor.plan()
        if plan["action"] == "abort":
            raise RuntimeError(plan["reason"])
    if ckpt:
        ckpt.save(steps, state)
        ckpt.wait()
    data.close()
    dt = time.time() - t_start
    print(f"[train] done: {steps - start_step} steps in {dt:.1f}s; "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    # one merged WasteProfile across tiers (DESIGN.md §2): Tier-3 step
    # findings + Tier-2 compiled-step findings coalesce into one report
    parts = [p for p in (detectors.report if detectors else None,
                         tier2_profile, obj_scan) if p is not None]
    profile_merged = merge_profiles(parts) if parts else None
    if profile_merged is not None:
        print(profile_merged.render(top_k=5))
        if profile_out:
            dump_json(profile_merged, profile_out)
            print(f"[train] waste profile written to {profile_out}")
        if sarif_out:
            write_sarif(profile_merged, sarif_out, src_root=os.getcwd())
            print(f"[train] SARIF findings written to {sarif_out}")
    return losses, profile_merged


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=registry.ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--profile", action="store_true")
    ap.add_argument("--waste-report", action="store_true")
    ap.add_argument("--objects", action="store_true",
                    help="register params/opt state in the object "
                         "registry and run the replica scan at init")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="none")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--profile-out", default=None,
                    help="write the merged waste profile as JSON")
    ap.add_argument("--sarif-out", default=None,
                    help="write the merged waste profile as SARIF 2.1.0")
    a = ap.parse_args()
    run(a.arch, smoke=a.smoke, steps=a.steps, batch=a.batch, seq=a.seq,
        lr=a.lr, ckpt_dir=a.ckpt_dir, ckpt_every=a.ckpt_every,
        profile=a.profile, waste_report=a.waste_report, resume=a.resume,
        microbatches=a.microbatches, remat=a.remat, seed=a.seed,
        profile_out=a.profile_out, sarif_out=a.sarif_out,
        objects=a.objects)


if __name__ == "__main__":
    main()
