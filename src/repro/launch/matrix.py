"""Zoo-wide waste matrix: profile every registry config, rank by
redundancy fraction.

For each ``configs/registry.all_cells()`` cell (arch x assigned shape,
gated by ``cell_applicable``) the driver runs the profiler stack the
cell's kind calls for and merges the per-cell profiles via the paper's
§5.6 associative merge:

  train cells   — tier-0 static lint of the train step + tier-3
                  ``TrainingDetectors`` over real (toy-sized) train
                  steps + the MoE dead-expert-store probe
                  (``models.moe.dispatch_stats``) for MoE families;
  prefill cells — tier-0 prefill lint + the serve run's padding
                  accounting (prompt-bucket padding on the engine
                  families, encoder-frame padding on encoder-decoder);
  decode cells  — tier-0 decode lint + tier-3 ``ServingDetectors`` from
                  the same serve run (long_500k decode cells rerun the
                  serve loop at a longer toy extent).

The report (``--out matrix_report.json``) ranks ⟨config, tier, site⟩
by redundancy fraction (Eq. 1: flagged/checked — the *Redundant Loads*
cross-workload indicator) then waste bytes; ``--sarif-out`` exports the
merged findings and ``--leaderboard-out`` writes the markdown table.
Everything is seeded and wall-clock-free, so two runs of the same tree
produce byte-identical rankings.

CI gate (zoo-matrix job):

    python -m repro.launch.matrix --toy \
        --configs granite-moe-3b-a800m,zamba2-1.2b,whisper-large-v3 \
        --out matrix_report.json --sarif-out matrix.sarif \
        --max-moe-dead-expert-fraction 0.0

exits nonzero if any applicable cell errors or an MoE cell's
dead-expert-store fraction regresses above the post-fix value (the
scatter dispatch stores only routed rows, so the fraction is 0).
"""
from __future__ import annotations

import argparse
import dataclasses
import inspect
import json
import os
import sys
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.configs.base import ProfilerConfig, TrainConfig
from repro.core.detectors import ServingDetectors, TrainingDetectors
from repro.core.findings import Finding, WasteProfile, merge_profiles
from repro.core.sarif import write_sarif
from repro.data.synthetic import batch_at, frame_lengths
from repro.launch import lint as lint_mod
from repro.launch import serve as serve_mod
from repro.models import moe as MOE
from repro.models.zoo import build_model
from repro.serve.engine import ENGINE_FAMILIES, Request, ServeEngine
from repro.train import state as TS
from repro.train.step import make_train_step

SCHEMA = 1

# Toy dims per shape kind: the assigned shapes (4k train, 32k prefill,
# 500k decode) scaled to CI-runnable extents while keeping every cell
# distinct. "long" is the long_500k decode cell's longer toy extent.
_DIMS = {
    True: {   # --toy
        "train": dict(batch=2, seq=32, steps=2),
        "serve": dict(batch=4, prompt=16, gen=8),
        "long": dict(batch=2, prompt=8, gen=16),
    },
    False: {  # full-ish (still smoke configs; real shapes need real HW)
        "train": dict(batch=4, seq=64, steps=3),
        "serve": dict(batch=4, prompt=32, gen=16),
        "long": dict(batch=2, prompt=16, gen=32),
    },
}


def _site(f: Finding) -> str:
    """file.py:line when provenance carries it, else the C1 tail."""
    if "file" in f.meta:
        return (f"{os.path.basename(str(f.meta['file']))}:"
                f"{int(f.meta.get('line', 0) or 0)}")
    path = f.meta.get("path")
    if path:
        return str(path)
    return "|".join(f.c1[-2:]) if f.c1 else f.kind


def _moe_probe(arch: str, cfg, params, *, batch: int, seq: int,
               seed: int) -> WasteProfile:
    """Tier-3 dead-expert-store accounting of the MoE dispatch buffer.

    Routes the embedded token batch through layer 0's router (the
    routing front-end is dispatch-independent) and bills the (E, C)
    buffer rows the configured dispatch stores but no token was routed
    to — the full buffer under "einsum", exactly the routed rows under
    "scatter" (dead fraction 0 by construction)."""
    def find_moe(tree):
        if isinstance(tree, dict):
            for k, v in tree.items():
                if k == "moe":
                    return v
                r = find_moe(v)
                if r is not None:
                    return r
        return None

    prof = WasteProfile(tier=3)
    stacked = find_moe(params)
    if stacked is None:
        return prof
    pm = jax.tree_util.tree_map(lambda a: a[0], stacked)
    data = batch_at(cfg, batch, seq, seed=seed, step=0)
    x = jnp.take(params["embed"], jnp.asarray(data["tokens"]),
                 axis=0).astype(jnp.float32)
    st = MOE.dispatch_stats(pm, cfg, x)
    prof.checked["dead_expert_store"] = int(st["rows_stored"])
    prof.flagged["dead_expert_store"] = int(st["dead_rows"])
    if st["dead_rows"]:
        prof.add(Finding(
            kind="dead_expert_store", tier=3,
            c1=("models.moe:apply_moe",), c2=(f"{arch}:train_step",),
            count=int(st["dead_rows"]), bytes=float(st["dead_bytes"]),
            fraction=float(st["dead_fraction"]),
            meta={"file": inspect.getsourcefile(MOE),
                  "line": inspect.getsourcelines(MOE.apply_moe)[1],
                  "dispatch": st["dispatch"],
                  "rows_total": int(st["rows_total"]),
                  "rows_routed": int(st["rows_routed"]),
                  "rule": "unrouted rows of the (B,E,C,d) dispatch "
                          "buffer are stored and never read (Def. 1); "
                          "fix: moe.dispatch='scatter'"}))
    return prof


def _train_profiles(arch: str, cfg, model, *, seed: int,
                    dims: Dict[str, int]) -> List[WasteProfile]:
    tc = TrainConfig(learning_rate=1e-3, total_steps=dims["steps"],
                     warmup_steps=1, seed=seed)
    jit_step = jax.jit(make_train_step(model, tc, None))
    state = TS.create(model, jax.random.PRNGKey(seed))
    det = TrainingDetectors(ProfilerConfig(enabled=True, seed=seed))
    for step in range(dims["steps"]):
        b = batch_at(cfg, dims["batch"], dims["seq"], seed=seed, step=step)
        det.on_batch(step, b)
        params_before = state.params
        state, _ = jit_step(state, {k: jnp.asarray(v) for k, v in b.items()})
        det.on_step(step, params_before, state.params)
    profs = [det.report]
    if cfg.moe is not None:
        profs.append(_moe_probe(arch, cfg, state.params,
                                batch=dims["batch"], seq=dims["seq"],
                                seed=seed))
    return profs


def _serve_profiles(arch: str, cfg, model, params, *, seed: int,
                    dims: Dict[str, int],
                    bucket_frames: bool) -> Dict[str, WasteProfile]:
    """One serve run -> {"prefill": padding profile, "decode": tier-3}."""
    batch, prompt, gen = dims["batch"], dims["prompt"], dims["gen"]
    data = batch_at(cfg, batch, prompt, seed=seed, step=0)
    prompts = np.asarray(data["tokens"])
    if cfg.family in ENGINE_FAMILIES:
        det = ServingDetectors(ProfilerConfig(enabled=True, seed=seed))
        eng = ServeEngine(model, params, num_slots=batch,
                          max_len=prompt + gen + 1, detectors=det,
                          kv_dtype=jnp.float32)
        # varied true prompt lengths so the engine's pow2 bucketing has
        # real padding to account (uniform lengths would hide it)
        rng = np.random.Generator(np.random.Philox(
            key=seed, counter=[0, 0, 2, 0]))
        lens = rng.integers(max(2, prompt // 2), prompt + 1, size=batch)
        for b in range(batch):
            eng.submit(Request(rid=f"r{b}",
                               tokens=prompts[b][:int(lens[b])],
                               max_new_tokens=gen))
        eng.run()
        return {"prefill": serve_mod.padding_waste_profile(eng.stats),
                "decode": det.report}
    kw = {}
    lens_f = None
    if cfg.family == "vlm":
        kw["img"] = jnp.asarray(data["img"])
    if cfg.family == "audio":
        kw["frames"] = jnp.asarray(data["frames"])
        lens_f = frame_lengths(cfg, batch, seed=seed)
    _, _, _, _, enc_stats = serve_mod._run_legacy(
        cfg, model, params, jnp.asarray(prompts), gen, kw,
        frame_lengths=lens_f, bucket_frames=bucket_frames)
    out = {"prefill": WasteProfile(tier=2), "decode": WasteProfile(tier=3)}
    if enc_stats is not None:
        out["prefill"] = serve_mod.encoder_padding_profile(enc_stats)
    return out


def _finding_row(arch: str, shape: str, f: Finding) -> Dict[str, Any]:
    return {"arch": arch, "shape": shape, "tier": f.tier, "kind": f.kind,
            "site": _site(f), "fraction": round(float(f.fraction), 6),
            "bytes": float(f.bytes), "count": int(f.count)}


def run_cells(configs: List[str], *, toy: bool = True, seed: int = 0,
              moe_dispatch: Optional[str] = None,
              bucket_frames: bool = True,
              shapes: Optional[List[str]] = None,
              verbose: bool = True) -> Dict[str, Any]:
    """Profile every applicable (config x shape) cell; build the report."""
    dims = _DIMS[toy]
    shape_list = [s for s in registry.SHAPES
                  if shapes is None or s.name in shapes]
    cells: List[Dict[str, Any]] = []
    profiles: List[WasteProfile] = []
    for arch in configs:
        cfg = registry.get_config(arch)
        # smoke-reduce for runnability; cell applicability is decided on
        # the FULL config (subquadratic-ness etc. is an arch property)
        full_cfg = cfg
        cfg = cfg.smoke()
        if moe_dispatch is not None and cfg.moe is not None:
            cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
                cfg.moe, dispatch=moe_dispatch))
        model = None
        params = None
        lint_by_subject: Dict[str, WasteProfile] = {}
        serve_cache: Dict[str, Dict[str, WasteProfile]] = {}

        def ensure_model():
            nonlocal model, params
            if model is None:
                model = build_model(cfg)
                params = model.init(jax.random.PRNGKey(seed))
            return model, params

        def tier0(subject: str) -> WasteProfile:
            if subject not in lint_by_subject:
                (prof,) = lint_mod.lint_config(arch, smoke=True,
                                               subjects=(subject,))
                lint_by_subject[subject] = prof
            return lint_by_subject[subject]

        for shape in shape_list:
            ok, why = registry.cell_applicable(full_cfg, shape)
            cell: Dict[str, Any] = {
                "arch": arch, "shape": shape.name, "kind": shape.kind,
                "applicable": ok, "reason": why, "error": None,
                "fractions": {}, "waste_bytes": 0.0, "findings": [],
            }
            if not ok:
                cells.append(cell)
                continue
            if verbose:
                print(f"[matrix] {arch} x {shape.name} ...", flush=True)
            try:
                if shape.kind == "train":
                    ensure_model()
                    profs = [tier0("train")] + _train_profiles(
                        arch, cfg, model, seed=seed, dims=dims["train"])
                elif shape.kind == "prefill":
                    ensure_model()
                    key = "serve"
                    if key not in serve_cache:
                        serve_cache[key] = _serve_profiles(
                            arch, cfg, model, params, seed=seed,
                            dims=dims["serve"],
                            bucket_frames=bucket_frames)
                    profs = [tier0("prefill"), serve_cache[key]["prefill"]]
                else:  # decode
                    ensure_model()
                    key = "long" if shape.name == "long_500k" else "serve"
                    if key not in serve_cache:
                        serve_cache[key] = _serve_profiles(
                            arch, cfg, model, params, seed=seed,
                            dims=dims[key], bucket_frames=bucket_frames)
                    profs = [tier0("decode"), serve_cache[key]["decode"]]
                merged = merge_profiles(profs)
            except Exception as e:  # noqa: BLE001 — cell isolation
                cell["error"] = f"{type(e).__name__}: {e}"
                cells.append(cell)
                continue
            cell["fractions"] = {k: round(float(v), 6)
                                 for k, v in sorted(merged.fractions().items())}
            cell["waste_bytes"] = float(sum(f.bytes
                                            for f in merged.findings))
            cell["findings"] = sorted(
                (_finding_row(arch, shape.name, f)
                 for f in merged.findings),
                key=lambda r: (-r["fraction"], -r["bytes"], r["kind"],
                               r["tier"], r["site"]))
            profiles.append(merged)
            cells.append(cell)

    ranking = sorted(
        (row for c in cells for row in c["findings"]),
        key=lambda r: (-r["fraction"], -r["bytes"], r["arch"], r["shape"],
                       r["kind"], r["tier"], r["site"]))
    report = {
        "schema": SCHEMA, "seed": seed, "toy": toy,
        "moe_dispatch": moe_dispatch or "config-default",
        "bucket_frames": bucket_frames,
        "configs": list(configs),
        "cells": cells,
        "ranking": ranking,
    }
    merged_all = merge_profiles(profiles) if profiles else WasteProfile()
    return {"report": report, "profile": merged_all}


def leaderboard(report: Dict[str, Any], top_k: int = 15) -> str:
    lines = [
        "| # | config | shape | tier | kind | site | fraction | waste |",
        "|---|--------|-------|------|------|------|----------|-------|",
    ]
    for i, r in enumerate(report["ranking"][:top_k], 1):
        waste = (f"{r['bytes'] / 1e6:.2f} MB" if r["bytes"] >= 1e6
                 else f"{r['bytes'] / 1e3:.1f} KB" if r["bytes"] >= 1e3
                 else f"{r['bytes']:.0f} B")
        lines.append(f"| {i} | {r['arch']} | {r['shape']} | {r['tier']} | "
                     f"{r['kind']} | {r['site']} | {r['fraction']:.3f} | "
                     f"{waste} |")
    if not report["ranking"]:
        lines.append("| - | (no findings) | | | | | | |")
    return "\n".join(lines)


def _gate_failures(report: Dict[str, Any],
                   max_moe_dead: Optional[float]) -> List[str]:
    fails = []
    for c in report["cells"]:
        if c["applicable"] and c["error"]:
            fails.append(f"{c['arch']} x {c['shape']}: {c['error']}")
    if max_moe_dead is not None:
        for c in report["cells"]:
            frac = c["fractions"].get("dead_expert_store")
            if frac is not None and frac > max_moe_dead:
                fails.append(
                    f"{c['arch']} x {c['shape']}: dead_expert_store "
                    f"fraction {frac} > {max_moe_dead} (MoE dispatch "
                    f"regression — scatter mode stores no dead rows)")
    return fails


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="Zoo-wide waste matrix: profile every registry "
                    "config cell and rank by redundancy fraction")
    ap.add_argument("--toy", action="store_true",
                    help="CI-sized cell dims (smoke configs either way)")
    ap.add_argument("--configs", default=None,
                    help="comma list of arch ids (default: whole registry)")
    ap.add_argument("--shapes", default=None,
                    help="comma list of shape names (default: all four)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="matrix_report.json",
                    help="machine-readable matrix report")
    ap.add_argument("--sarif-out", default=None,
                    help="merged findings as SARIF 2.1.0")
    ap.add_argument("--leaderboard-out", default=None,
                    help="write the markdown leaderboard to a file")
    ap.add_argument("--moe-dispatch", default=None,
                    choices=("scatter", "einsum"),
                    help="override MoE dispatch for before/after cells "
                         "(default: config default = scatter)")
    ap.add_argument("--bucket-frames", default="on", choices=("on", "off"),
                    help="audio serving: bucketed encoder extent (the "
                         "fix) vs capacity padding (the baseline)")
    ap.add_argument("--max-moe-dead-expert-fraction", type=float,
                    default=None,
                    help="fail if any cell's dead_expert_store fraction "
                         "exceeds this (CI regression gate; post-fix "
                         "value is 0.0)")
    ap.add_argument("--top-k", type=int, default=15)
    a = ap.parse_args(argv)

    configs = ([s for s in a.configs.split(",") if s] if a.configs
               else list(registry.ARCH_IDS))
    for arch in configs:
        if arch not in registry.ARCH_IDS:
            ap.error(f"unknown config {arch!r}")

    shapes = [s for s in a.shapes.split(",") if s] if a.shapes else None
    if shapes:
        known = {s.name for s in registry.SHAPES}
        for s in shapes:
            if s not in known:
                ap.error(f"unknown shape {s!r} (known: {sorted(known)})")
    res = run_cells(configs, toy=a.toy, seed=a.seed,
                    moe_dispatch=a.moe_dispatch,
                    bucket_frames=a.bucket_frames == "on",
                    shapes=shapes)
    report = res["report"]

    with open(a.out, "w") as fh:
        json.dump(report, fh, indent=2, sort_keys=True)
        fh.write("\n")
    print(f"[matrix] report written to {a.out}")
    if a.sarif_out:
        write_sarif(res["profile"], a.sarif_out, src_root=os.getcwd())
        print(f"[matrix] SARIF written to {a.sarif_out}")

    ran = sum(1 for c in report["cells"]
              if c["applicable"] and not c["error"])
    skipped = sum(1 for c in report["cells"] if not c["applicable"])
    errored = sum(1 for c in report["cells"]
                  if c["applicable"] and c["error"])
    print(f"[matrix] {len(report['cells'])} cells: {ran} profiled, "
          f"{skipped} skipped (inapplicable), {errored} errored")
    board = leaderboard(report, a.top_k)
    print(board)
    if a.leaderboard_out:
        with open(a.leaderboard_out, "w") as fh:
            fh.write(f"# Zoo waste matrix leaderboard\n\n{board}\n")
        print(f"[matrix] leaderboard written to {a.leaderboard_out}")

    fails = _gate_failures(report, a.max_moe_dead_expert_fraction)
    for msg in fails:
        print(f"[matrix] FAIL: {msg}", file=sys.stderr)
    return 1 if fails else 0


if __name__ == "__main__":
    sys.exit(main())
