import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --- everything below may import jax -----------------------------------
import argparse          # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402
from pathlib import Path  # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as PS  # noqa: E402

from repro.configs import registry                    # noqa: E402
from repro.configs.base import SHAPES_BY_NAME         # noqa: E402
from repro.launch import roofline as RL               # noqa: E402
from repro.launch import specs as SP                  # noqa: E402
from repro.launch.mesh import make_production_mesh    # noqa: E402
from repro.models.zoo import build_model, model_flops_per_token  # noqa: E402
from repro.serve.decode import make_serve_step, make_prefill_step  # noqa: E402
from repro.sharding.rules import make_strategy        # noqa: E402
from repro.train import state as TS                   # noqa: E402
from repro.train.step import make_train_step          # noqa: E402
from repro.configs.base import TrainConfig            # noqa: E402

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

# bf16 TP-16 weights above this no longer fit a v5e chip alongside the KV
# cache -> serve weight-gathered (DESIGN.md §4).
_SERVE_WG_BYTES = 12e9


def _mem_summary(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    if ma is None:
        return {}
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "generated_code_size_in_bytes",
            "alias_size_in_bytes")
    out = {}
    for k in keys:
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    if out:
        out["total_per_device"] = (out.get("argument_size_in_bytes", 0)
                                   + out.get("temp_size_in_bytes", 0)
                                   + out.get("output_size_in_bytes", 0)
                                   - out.get("alias_size_in_bytes", 0))
    return out


def _named(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, PS))


def run_cell(arch: str, shape_name: str, multi_pod: bool, strategy_name: str,
             remat: str = "full", decode_unroll: bool = False) -> dict:
    cfg = registry.get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    ok, why = registry.cell_applicable(cfg, shape)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "strategy": strategy_name, "remat": remat}
    if not ok:
        rec.update(status="skipped", reason=why)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    model = build_model(cfg)
    dp = ("pod", "data") if multi_pod else ("data",)
    t0 = time.time()

    if shape.kind == "train":
        strat = make_strategy(strategy_name, mesh)
        tc = TrainConfig(remat=remat)
        step = make_train_step(model, tc, strat)
        state_specs = TS.state_specs(model, strat)
        state_abs = TS.abstract(model)
        batch_abs = SP.batch_specs(cfg, shape)
        bd = strat.batch_axes
        batch_specs = jax.tree_util.tree_map(
            lambda x: PS(bd, *([None] * (len(x.shape) - 1))), batch_abs)
        jitted = jax.jit(
            step,
            in_shardings=(_named(mesh, state_specs), _named(mesh, batch_specs)),
            out_shardings=(_named(mesh, state_specs), None),
            donate_argnums=(0,))
        with mesh:
            lowered = jitted.lower(state_abs, batch_abs)
            compiled = lowered.compile()
        tokens = shape.tokens
        mf = model_flops_per_token(cfg) * tokens * 3.0  # fwd+bwd = 3x fwd matmul flops... see note
        # NOTE: 6*N*D already counts fwd+bwd (2N fwd + 4N bwd per token);
        # so model_flops = 6*N per token exactly:
        mf = model_flops_per_token(cfg) * tokens
    elif shape.kind == "prefill":
        strat = make_strategy(strategy_name if strategy_name != "tp_serve"
                              else "dp_tp", mesh)
        pstep = make_prefill_step(model, strat)
        params_abs = model.abstract_params(jnp.bfloat16)
        p_specs = strat.param_specs(model)
        batch_abs = SP.batch_specs(cfg, shape)
        bd = strat.batch_axes
        batch_specs = jax.tree_util.tree_map(
            lambda x: PS(bd, *([None] * (len(x.shape) - 1))), batch_abs)
        jitted = jax.jit(pstep, in_shardings=(
            _named(mesh, p_specs), _named(mesh, batch_specs)))
        with mesh:
            lowered = jitted.lower(params_abs, batch_abs)
            compiled = lowered.compile()
        # fwd only: 2N of the 6N convention
        mf = model_flops_per_token(cfg) / 3.0 * shape.tokens
    else:  # decode
        params_bytes = 2 * model_flops_per_token(cfg) / 6.0
        wg = (params_bytes / mesh.shape["model"]) > _SERVE_WG_BYTES
        strat = make_strategy("tp_serve", mesh, weight_gathered=wg)
        rec["weight_gathered"] = bool(wg)
        if decode_unroll:
            model.decode_unroll = True
            rec["decode_unroll"] = True
        sstep = make_serve_step(model, strat)
        params_abs, cache_abs, tok_abs = SP.decode_inputs(model, cfg, shape)
        p_specs = strat.param_specs(model)
        c_specs = strat.cache_specs(cache_abs, shape.global_batch)
        import numpy as _np
        dpn = int(_np.prod([mesh.shape[a] for a in dp]))
        tok_spec = PS(dp, None) if shape.global_batch % dpn == 0 else PS()
        jitted = jax.jit(
            sstep,
            in_shardings=(_named(mesh, p_specs), _named(mesh, c_specs),
                          NamedSharding(mesh, tok_spec)),
            out_shardings=(NamedSharding(mesh, tok_spec),
                           _named(mesh, c_specs)),
            donate_argnums=(1,))
        with mesh:
            lowered = jitted.lower(params_abs, cache_abs, tok_abs)
            compiled = lowered.compile()
        # one token per sequence; fwd-only flops
        mf = model_flops_per_token(cfg) / 3.0 * shape.global_batch
        # decode ideal: every weight byte + cache byte read once
        cache_bytes = sum(
            int(_np.prod(l.shape)) * l.dtype.itemsize
            for l in jax.tree_util.tree_leaves(cache_abs))
        rec["min_bytes_global"] = params_bytes + cache_bytes

    compile_s = time.time() - t0
    xla_cost = compiled.cost_analysis()
    if isinstance(xla_cost, list):  # older jax returns [dict]
        xla_cost = xla_cost[0]
    mem = _mem_summary(compiled)
    kib = RL.ideal_kernel_bytes(cfg, shape) if shape.kind != "decode" else 0.0
    terms = RL.analyze_compiled(compiled, chips, mf,
                                kernel_ideal_bytes_global=kib,
                                min_bytes_global=rec.get("min_bytes_global", 0.0))
    rec.update(status="ok", compile_s=round(compile_s, 1), memory=mem,
               xla_flops_per_device=float(xla_cost.get("flops", 0.0)),
               **terms)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description="multi-pod dry-run driver")
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--strategy", default="dp_tp",
                    help="train/prefill strategy (decode always tp_serve)")
    ap.add_argument("--remat", default="full")
    ap.add_argument("--decode-unroll", action="store_true")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = registry.ARCH_IDS if args.arch == "all" else args.arch.split(",")
    shapes = (list(SHAPES_BY_NAME) if args.shape == "all"
              else args.shape.split(","))
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    OUT_DIR.mkdir(parents=True, exist_ok=True)
    results = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                name = f"{arch}_{shape}_{'multi' if mp else 'single'}_{args.tag}.json"
                path = OUT_DIR / name
                if path.exists() and not args.force:
                    print(f"[skip existing] {name}", flush=True)
                    continue
                print(f"[dryrun] {arch} x {shape} x "
                      f"{'2x16x16' if mp else '16x16'} ({args.strategy})",
                      flush=True)
                try:
                    rec = run_cell(arch, shape, mp, args.strategy, args.remat,
                                   args.decode_unroll)
                except Exception as e:  # record failures — they are bugs
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if mp else "16x16",
                           "strategy": args.strategy, "status": "error",
                           "error": f"{type(e).__name__}: {e}",
                           "trace": traceback.format_exc()[-2000:]}
                rec["tag"] = args.tag
                path.write_text(json.dumps(rec, indent=2))
                status = rec.get("status")
                extra = (f" dominant={rec.get('dominant')} "
                         f"rf={rec.get('roofline_fraction', 0):.3f} "
                         f"compile={rec.get('compile_s')}s"
                         if status == "ok" else rec.get("reason") or rec.get("error", ""))
                print(f"  -> {status} {extra}", flush=True)
                results.append(rec)
    n_ok = sum(r.get("status") == "ok" for r in results)
    print(f"done: {n_ok} ok / {len(results)} attempted", flush=True)


if __name__ == "__main__":
    main()
