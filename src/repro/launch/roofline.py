"""Roofline-term derivation from compiled dry-run artifacts.

compute    = HLO_FLOPs / (chips * PEAK_FLOPS)
memory     = HLO_bytes / (chips * HBM_BW)
collective = wire_bytes / (chips * ICI_BW)

``compiled.cost_analysis()`` on a GSPMD executable reports *per-device*
flops/bytes (the partitioned module); we report both per-device and global
conventions. Collective bytes are not in cost_analysis: we parse the
compiled HLO text and sum per-op wire bytes with the standard ring-model
factors (all-gather/reduce-scatter: (n-1)/n of the full payload per device;
all-reduce: 2x that; all-to-all: (n-1)/n; collective-permute: full payload).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional

# v5e-class hardware constants (per brief)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLL_RE = re.compile(
    r"=\s*(?:\([^)]*\)|[a-z0-9_]+\[[^\]]*\][^ ]*)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([0-9, ]+)\}")
_GROUPS_SHAPE_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    ops: List[Dict] = field(default_factory=list)

    @property
    def wire_bytes(self) -> float:
        return sum(o["wire_bytes"] for o in self.ops)

    def by_kind(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for o in self.ops:
            out[o["kind"]] = out.get(o["kind"], 0.0) + o["wire_bytes"]
        return out


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Per-device wire bytes of every collective in a compiled HLO module."""
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(1)
        # result type(s): text before the '=' holds the result shape
        lhs = line.split("=", 1)[0]
        result_bytes = _shape_bytes(lhs)
        if result_bytes == 0:
            result_bytes = _shape_bytes(line.split("=", 1)[1].split("(")[0])

        # participant count
        n = 1
        g = _GROUPS_SHAPE_RE.search(line)
        if g:
            n = int(g.group(2))
        else:
            g = _GROUPS_RE.search(line)
            if g:
                n = len([x for x in g.group(1).split(",") if x.strip()])
        if n <= 1:
            continue
        frac = (n - 1) / n
        if kind == "all-gather":
            wire = result_bytes * frac          # result = gathered payload
        elif kind == "all-reduce":
            wire = 2.0 * result_bytes * frac    # rs + ag ring
        elif kind == "reduce-scatter":
            wire = result_bytes * (n - 1)       # operand=(n*result), (n-1)/n of it
        elif kind == "all-to-all":
            wire = result_bytes * frac
        else:  # collective-permute
            wire = result_bytes
        stats.ops.append({"kind": kind, "bytes": result_bytes,
                          "participants": n, "wire_bytes": wire})
    return stats


# named_scope regions that run inside Pallas kernels on the TPU target —
# their XLA-emulation HBM traffic is replaced by the analytic kernel-ideal
# traffic from ideal_kernel_bytes().
KERNEL_SCOPES = ("flashattn_vmem", "ssd_vmem", "mlstm_vmem")


def analyze_compiled(compiled, chips: int,
                     model_flops: Optional[float] = None,
                     kernel_ideal_bytes_global: float = 0.0,
                     min_bytes_global: float = 0.0) -> Dict:
    """Full roofline record from a compiled executable, using the
    trip-count-correct HLO cost model (repro.core.hlo_cost).

    The memory term uses the kernel-adjusted accounting: HBM traffic of
    ops inside KERNEL_SCOPES is zeroed (on TPU they run in VMEM inside the
    Pallas kernels) and replaced by the analytic ideal traffic."""
    from repro.core.hlo_cost import HloCostModel
    txt = compiled.as_text()
    cm = HloCostModel(txt, scope_zero_hbm=KERNEL_SCOPES)
    c = cm.total()
    hbm_adj = c.hbm_bytes + kernel_ideal_bytes_global / max(chips, 1)
    terms = roofline_terms({"flops": c.flops, "bytes accessed": hbm_adj},
                           c.coll_wire_bytes, chips, model_flops,
                           min_bytes_global)
    # also record the raw (XLA-attention-in-HBM) memory term for reference
    raw = HloCostModel(txt).total()
    terms["hbm_bytes_raw_per_device"] = raw.hbm_bytes
    terms["t_memory_raw_s"] = raw.hbm_bytes / HBM_BW
    terms["collectives"] = dict(c.coll_by_kind)
    terms["num_collectives"] = c.coll_count
    terms["transcendentals"] = c.transcendentals
    return terms


def ideal_kernel_bytes(cfg, shape) -> float:
    """GLOBAL ideal HBM bytes of the Pallas-kernel regions per step.

    flash attention: q,k,v reads + out write per invocation; mamba SSD /
    mLSTM chunked: ~4 passes over the (B,S,d_inner) working set. Training
    multiplies by ~4.5 (fwd + remat recompute + flash backward reads/writes);
    prefill by 1. Decode cells never lower the flash path (ref attention is
    linear in cache length), so no adjustment applies; the paged-KV decode
    kernels have their own page-granular model
    (``ideal_paged_attention_bytes``).
    """
    B, S = shape.global_batch, shape.seq_len
    D, Hq, Hkv = cfg.head_dim, cfg.num_heads, cfg.num_kv_heads
    bt = 2.0                                   # bf16 activations
    train = shape.kind == "train"
    passes = 4.5 if train else 1.0

    def attn(nlayers, sq, skv):
        fwd = B * (sq * Hq + 2 * skv * Hkv + sq * Hq) * D * bt
        return nlayers * passes * fwd

    total = 0.0
    fam = cfg.family
    if fam in ("dense", "moe"):
        total += attn(cfg.num_layers, S, S)
    elif fam == "vlm":
        p = cfg.cross_attn_period
        total += attn(cfg.num_layers - cfg.num_layers // p, S, S)
        total += attn(cfg.num_layers // p, S, cfg.num_image_tokens)
    elif fam == "audio":
        F = S                                   # stub frames = seq_len
        total += attn(cfg.encoder_layers, F, F)
        total += attn(cfg.num_layers, S, S)     # decoder self
        total += attn(cfg.num_layers, S, F)     # decoder cross
    elif fam == "hybrid":
        n_attn = cfg.num_layers // cfg.attn_period
        total += attn(n_attn, S, S)
        d_inner = cfg.ssm.expand * cfg.d_model
        total += cfg.num_layers * passes * 4 * B * S * d_inner * bt
    elif fam == "ssm":
        d_in = int(cfg.d_model * cfg.xlstm.proj_factor_mlstm)
        total += cfg.num_layers * passes * 4 * B * S * d_in * bt
    return total


def ideal_paged_attention_bytes(*, batch: int, q_len: int,
                                mapped_pages: int, max_pages: int,
                                page_size: int, num_heads: int,
                                num_kv_heads: int, head_dim: int,
                                kv_bytes: float = 2.0,
                                act_bytes: float = 2.0,
                                materialize: bool = False) -> float:
    """Ideal HBM bytes of ONE paged-attention forward (one layer).

    The paged layout's minimal traffic is page-granular: the kernel
    reads the page table (4 bytes/entry over every slot's table), then
    each MAPPED page of K and V exactly once at full page granularity
    (a partially-filled last page still moves page_size rows — that is
    the paged gather's real cost model, and what the in-kernel gather
    of kernels/paged_attention.py does), plus the q read, the new K/V
    row writes and the output write.

    ``materialize=True`` models the reference composition
    (``paged_update -> paged_gather -> attention_ref``) instead: on top
    of the kernel traffic it WRITES the (batch, max_pages*page_size)
    logical K/V view to HBM and reads it back — the gather
    materialization the Pallas kernel eliminates. The ratio of the two
    is the modeled paged-decode speedup reported by
    ``benchmarks/kernels.py`` (CPU wall time cannot show the HBM
    effect; the byte model can, honestly labeled).

    mapped_pages: total mapped page-table entries across the batch
    (page-granular occupancy, NOT token count); max_pages: per-slot
    table length M.
    """
    Hq, Hkv, D = num_heads, num_kv_heads, head_dim
    pt_read = batch * max_pages * 4.0
    kv_read = mapped_pages * page_size * Hkv * D * 2.0 * kv_bytes
    q_read = batch * q_len * Hq * D * act_bytes
    new_write = batch * q_len * Hkv * D * 2.0 * kv_bytes
    out_write = batch * q_len * Hq * D * act_bytes
    total = pt_read + kv_read + q_read + new_write + out_write
    if materialize:
        # the logical view is dense over the FULL table extent (unmapped
        # entries gather clipped garbage that the validity mask hides):
        # one write of the view, one read back by the attention
        view = batch * max_pages * page_size * Hkv * D * 2.0 * kv_bytes
        total += 2.0 * view
    return total


def roofline_terms(cost: Dict[str, float], wire_bytes_per_dev: float,
                   chips: int, model_flops: Optional[float] = None,
                   min_bytes_global: float = 0.0) -> Dict:
    """cost: flops / bytes-accessed dict (per-device). min_bytes_global:
    unavoidable HBM traffic (weights + KV cache for decode) — sets the
    memory leg of the ideal-time roofline."""
    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = wire_bytes_per_dev / ICI_BW
    dominant = max((("compute", t_compute), ("memory", t_memory),
                    ("collective", t_coll)), key=lambda kv: kv[1])[0]
    out = {
        "flops_per_device": flops_dev,
        "bytes_per_device": bytes_dev,
        "wire_bytes_per_device": wire_bytes_per_dev,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "chips": chips,
        "flops_global": flops_dev * chips,
    }
    if model_flops:
        out["model_flops"] = model_flops
        out["useful_flops_ratio"] = model_flops / max(flops_dev * chips, 1.0)
        t_star = max(t_compute, t_memory, t_coll)
        ideal = max(model_flops / (chips * PEAK_FLOPS),
                    min_bytes_global / (chips * HBM_BW))
        out["t_ideal_s"] = ideal
        out["roofline_fraction"] = ideal / t_star if t_star > 0 else 0.0
    return out
