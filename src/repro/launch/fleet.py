"""Fleet serving driver: N `ServeEngine` replicas behind the
prefix-aware router, under a trace-driven load (DESIGN.md § Fleet tier).

CPU smoke:  PYTHONPATH=src python -m repro.launch.fleet \
                --arch qwen3-1.7b --smoke --replicas 2 --policy prefix \
                --compare --check-single

Reports p50/p99 TTFT and TPOT, per-replica queue depth, prefix-hit
fraction, eviction/preemption/backpressure counts, and the fleet-level
``fleet_silent_prefix_load`` Def.-3 bytes the routing policy did (or
did not) avoid. ``--compare`` replays the SAME trace under random
routing so the acceptance story is measurable on one line;
``--check-single`` replays it through one big single engine and asserts
greedy outputs are bit-identical to the fleet's. ``--profile`` attaches
per-replica serve detectors and merges every member's `WasteProfile`
into one fleet profile (`core.findings.merge_fleet`) for
``--profile-out``/``--sarif-out``.

Every fleet in one invocation shares a `serve.decode.StepCache`, so
replicas (and compared policies) dispatch literally the same compiled
steps — one compile per step shape for the whole process, and A/B
latency numbers that differ only by routing. Each measured policy runs
the trace twice on fresh fleets and reports the second (warm) run.
"""
from __future__ import annotations

import argparse
import os

import jax
import numpy as np

from repro.configs import registry
from repro.configs.base import ProfilerConfig
from repro.core.detectors import ServingDetectors
from repro.core.findings import merge_fleet
from repro.core.objects import ObjectRegistry, register_tree
from repro.core.replicas import ReplicaDetector, cross_replica_bytes
from repro.core.report import dump_json
from repro.core.sarif import write_sarif
from repro.models.zoo import build_model
from repro.serve.decode import StepCache
from repro.serve.engine import Request, ServeEngine
from repro.serve.router import FleetRouter
from repro.serve.workload import Trace, make_trace

# Default smoke workload: spaced poisson arrivals with a long shared
# prefix. Spacing keeps owner-side queueing out of the picture, so the
# comparison isolates what routing controls: who re-pays the prefix.
DEF = dict(replicas=2, slots=2, page_size=8, requests=12,
           prompt_len=48, prefix_len=40, gen=4, dup_rate=0.8,
           arrival="poisson", rate=0.3, burst_size=2, burst_gap=2)


def _build_fleet(model, params, *, replicas, slots, max_len, page_size,
                 num_pages, policy, seed, step_cache, profile,
                 obj_registry=None, content_dedup=False):
    if num_pages is None:
        # the engine's own default (slots x max pages) leaves zero
        # headroom for prefix pins: every admission would immediately
        # evict what the last one published. Two extra slots' worth
        # keeps hot prefixes resident; tests shrink it deliberately to
        # exercise the pressure/preemption paths.
        num_pages = (slots + 2) * (-(-max_len // page_size))
    engines, dets = [], []
    for i in range(replicas):
        det = ServingDetectors(ProfilerConfig(enabled=True, seed=seed + i)) \
            if profile else None
        dets.append(det)
        if obj_registry is not None:
            # one logical weight copy per replica: exactly the layout a
            # multi-host fleet materializes, and what the replica
            # detector reports as dedupable cross-replica params
            register_tree(obj_registry, f"replica{i}/params", params)
        engines.append(ServeEngine(
            model, params, num_slots=slots, max_len=max_len,
            kv_layout="paged", page_size=page_size, num_pages=num_pages,
            detectors=det, step_cache=step_cache,
            registry=obj_registry, owner=f"replica{i}",
            content_dedup=content_dedup))
    return FleetRouter(engines, policy=policy, seed=seed,
                       content_dedup=content_dedup), dets


def _run_policy(model, params, trace, *, policy, replicas, slots, max_len,
                page_size, num_pages, seed, step_cache, profile=False,
                obj_registry=None, content_dedup=False):
    """Warmup pass + measured pass on fresh fleets (shared compiles).

    The object registry only attaches to the MEASURED fleet: a warmup
    fleet's prefix-index pins outlive its run, and its registered pages
    would pollute the replica scan with a dead fleet's objects."""
    for measured in (False, True):
        fleet, dets = _build_fleet(
            model, params, replicas=replicas, slots=slots, max_len=max_len,
            page_size=page_size, num_pages=num_pages, policy=policy,
            seed=seed, step_cache=step_cache,
            profile=profile and measured,
            obj_registry=obj_registry if measured else None,
            content_dedup=content_dedup)
        fleet.submit_trace(trace)
        fleet.run()
        fleet.check()
    return fleet, dets


def _single_engine_outputs(model, params, trace, *, slots, max_len,
                           page_size, step_cache):
    """The whole trace through ONE engine (arrival order preserved) —
    the bit-identity oracle for the fleet's greedy outputs."""
    eng = ServeEngine(model, params, num_slots=slots, max_len=max_len,
                      kv_layout="paged", page_size=page_size,
                      step_cache=step_cache)
    for treq in sorted(trace.requests, key=lambda r: r.arrival):
        eng.submit(Request(rid=treq.rid, tokens=np.asarray(treq.tokens),
                           max_new_tokens=treq.max_new_tokens))
    eng.run()
    return {rid: list(r.generated) for rid, r in eng.finished.items()}


def _print_summary(tag, fleet):
    lat = fleet.latency_summary()
    ms = lambda k: lat.get(k, 0.0) * 1e3  # noqa: E731
    print(f"[fleet:{tag}] TTFT p50 {ms('ttft_p50'):.1f} ms / "
          f"p99 {ms('ttft_p99'):.1f} ms | TPOT p50 {ms('tpot_p50'):.2f} ms "
          f"/ p99 {ms('tpot_p99'):.2f} ms")
    q = ", ".join(f"r{d['replica']}: mean {d['mean_depth']:.1f} "
                  f"max {d['max_depth']}" for d in fleet.queue_summary())
    print(f"[fleet:{tag}] queue depth {q}")
    s = fleet.stats
    print(f"[fleet:{tag}] dispatched {s['dispatched']} | "
          f"prefix routes {s['prefix_routes']} "
          f"(cross-replica prefix routes: "
          f"{s['cross_replica_prefix_routes']}) | "
          f"fallback {s['fallback_routes']} | "
          f"backpressure ticks {s['backpressure_ticks']}")
    print(f"[fleet:{tag}] prefix-hit fraction "
          f"{fleet.prefix_hit_fraction():.2f} | global evictions "
          f"{s['global_evictions']} | preemption-evicted pages "
          f"{s['preemption_evicted_pages']} | fleet silent-prefix-load "
          f"{fleet.fleet_waste_bytes():.0f} bytes")
    return lat


def run(arch: str, *, smoke: bool = True, replicas: int = DEF["replicas"],
        slots: int = DEF["slots"], policy: str = "prefix",
        page_size: int = DEF["page_size"], num_pages: int = None,
        requests: int = DEF["requests"],
        prompt_len: int = DEF["prompt_len"],
        prefix_len: int = DEF["prefix_len"], gen: int = DEF["gen"],
        dup_rate: float = DEF["dup_rate"], arrival: str = DEF["arrival"],
        rate: float = DEF["rate"], burst_size: int = DEF["burst_size"],
        burst_gap: int = DEF["burst_gap"], seed: int = 0,
        trace_in: str = None, trace_out: str = None,
        compare: bool = False, check_single: bool = False,
        profile: bool = False, profile_out: str = None,
        sarif_out: str = None, objects: bool = False,
        dedup: bool = False):
    cfg = registry.get_config(arch)
    if smoke:
        cfg = cfg.smoke()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))

    if trace_in:
        trace = Trace.load(trace_in)
        print(f"[fleet] replaying trace {trace_in} "
              f"({len(trace)} requests, dup {trace.dup_fraction():.2f})")
    else:
        trace = make_trace(
            n_requests=requests, vocab_size=cfg.vocab_size, seed=seed,
            arrival=arrival, rate=rate, burst_size=burst_size,
            burst_gap=burst_gap, prompt_len=(prompt_len, prompt_len),
            gen_len=(gen, gen), dup_rate=dup_rate, n_prefixes=1,
            prefix_len=prefix_len)
    if trace_out:
        trace.save(trace_out)
        print(f"[fleet] trace written to {trace_out}")

    max_len = trace.max_prompt_len + trace.max_new_tokens + 1
    step_cache = StepCache(model)
    kw = dict(replicas=replicas, slots=slots, max_len=max_len,
              page_size=page_size, num_pages=num_pages, seed=seed,
              step_cache=step_cache)

    obj_registry = ObjectRegistry() if objects else None
    fleet, dets = _run_policy(model, params, trace, policy=policy,
                              profile=profile, obj_registry=obj_registry,
                              content_dedup=dedup, **kw)
    print(f"[fleet] {arch}: {len(trace)} requests over {replicas} "
          f"replicas x {slots} slots [policy={policy}]"
          + (" [content-dedup]" if dedup else ""))
    lat = _print_summary(policy, fleet)

    scan = None
    if objects:
        scan = ReplicaDetector(obj_registry).scan()
        dup_bytes = sum(f.bytes for f in scan.findings)
        kv_x = cross_replica_bytes(scan, "replica_kv_page")
        deferrals = sum(e.stats["dedup_deferred"] for e in fleet.engines)
        print(f"[fleet] object registry: {len(obj_registry)} live objects"
              f" ({obj_registry.nbytes_live():.0f} bytes)")
        print(f"[fleet] replica findings: {len(scan.findings)} groups, "
              f"{dup_bytes:.0f} duplicate bytes | cross-replica kv "
              f"replica bytes: {kv_x:.0f}")
        print(f"[fleet] dedup deferrals: {deferrals} | content-dedup "
              f"routes: {fleet.stats['content_dedup_routes']}")
        print(scan.render(top_k=5, by="object"))

    if compare:
        other = "random" if policy != "random" else "prefix"
        fleet2, _ = _run_policy(model, params, trace, policy=other, **kw)
        lat2 = _print_summary(other, fleet2)
        better_ttft = lat.get("ttft_p99", 0) < lat2.get("ttft_p99", 0)
        better_waste = fleet.fleet_waste_bytes() < fleet2.fleet_waste_bytes()
        print(f"[fleet] {policy} beats {other} on p99 TTFT: {better_ttft} "
              f"({lat.get('ttft_p99', 0)*1e3:.1f} vs "
              f"{lat2.get('ttft_p99', 0)*1e3:.1f} ms) | on fleet "
              f"silent-prefix-load bytes: {better_waste} "
              f"({fleet.fleet_waste_bytes():.0f} vs "
              f"{fleet2.fleet_waste_bytes():.0f})")

    if check_single:
        single = _single_engine_outputs(
            model, params, trace, slots=replicas * slots, max_len=max_len,
            page_size=page_size, step_cache=step_cache)
        ours = {rid: list(r.generated) for rid, r in fleet.finished.items()}
        identical = ours == single
        print(f"[fleet] bit-identical to single-engine: {identical}")
        assert identical, \
            "fleet greedy outputs diverged from the single-engine run"

    merged = None
    if profile:
        members = {f"replica{i}": d.combined()
                   for i, d in enumerate(dets) if d is not None}
        members["router"] = fleet.profile
        if scan is not None:
            members["objects"] = scan
        merged = merge_fleet(members)
        print(merged.render(top_k=3))
        if profile_out:
            dump_json(merged, profile_out)
            print(f"[fleet] waste profile written to {profile_out}")
        if sarif_out:
            write_sarif(merged, sarif_out, src_root=os.getcwd())
            print(f"[fleet] SARIF findings written to {sarif_out}")
    return fleet, merged


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=registry.ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--replicas", type=int, default=DEF["replicas"])
    ap.add_argument("--slots", type=int, default=DEF["slots"],
                    help="decode slots per replica")
    ap.add_argument("--policy", default="prefix",
                    choices=("prefix", "least", "random"))
    ap.add_argument("--page-size", type=int, default=DEF["page_size"])
    ap.add_argument("--num-pages", type=int, default=None,
                    help="pages per replica pool (default: slots x "
                         "max pages per slot)")
    ap.add_argument("--requests", type=int, default=DEF["requests"])
    ap.add_argument("--prompt-len", type=int, default=DEF["prompt_len"])
    ap.add_argument("--prefix-len", type=int, default=DEF["prefix_len"])
    ap.add_argument("--gen", type=int, default=DEF["gen"])
    ap.add_argument("--dup-rate", type=float, default=DEF["dup_rate"])
    ap.add_argument("--arrival", default=DEF["arrival"],
                    choices=("poisson", "bursty", "uniform"))
    ap.add_argument("--rate", type=float, default=DEF["rate"],
                    help="poisson/uniform arrivals per scheduler tick")
    ap.add_argument("--burst-size", type=int, default=DEF["burst_size"])
    ap.add_argument("--burst-gap", type=int, default=DEF["burst_gap"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace-in", default=None,
                    help="replay a saved trace JSON instead of generating")
    ap.add_argument("--trace-out", default=None,
                    help="save the generated trace JSON")
    ap.add_argument("--compare", action="store_true",
                    help="replay the same trace under the opposite "
                         "routing policy and print the comparison")
    ap.add_argument("--check-single", action="store_true",
                    help="assert greedy outputs are bit-identical to a "
                         "single-engine run of the same trace")
    ap.add_argument("--profile", action="store_true")
    ap.add_argument("--profile-out", default=None)
    ap.add_argument("--sarif-out", default=None)
    ap.add_argument("--objects", action="store_true",
                    help="attach the object registry and run the "
                         "OJXPerf replica scan after the trace drains")
    ap.add_argument("--dedup", action="store_true",
                    help="content-addressed dedup of same-burst "
                         "duplicate prefixes (router + engine)")
    a = ap.parse_args()
    run(a.arch, smoke=a.smoke, replicas=a.replicas, slots=a.slots,
        policy=a.policy, page_size=a.page_size, num_pages=a.num_pages,
        requests=a.requests, prompt_len=a.prompt_len,
        prefix_len=a.prefix_len, gen=a.gen, dup_rate=a.dup_rate,
        arrival=a.arrival, rate=a.rate, burst_size=a.burst_size,
        burst_gap=a.burst_gap, seed=a.seed, trace_in=a.trace_in,
        trace_out=a.trace_out, compare=a.compare,
        check_single=a.check_single, profile=a.profile,
        profile_out=a.profile_out, sarif_out=a.sarif_out,
        objects=a.objects, dedup=a.dedup)


if __name__ == "__main__":
    main()
