"""Production mesh builders.

Functions, not module-level constants, so importing never touches jax
device state. The dry-run launcher sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
import to obtain the placeholder devices.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh for CPU smoke/examples (data=1, model=1)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_elastic_mesh(num_devices: int):
    """Best-effort (data, model) mesh from a surviving device count —
    used by the elastic-restart path (repro.checkpoint.elastic)."""
    model = 16
    while model > 1 and num_devices % model:
        model //= 2
    return jax.make_mesh((num_devices // model, model), ("data", "model"))
