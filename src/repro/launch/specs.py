"""Abstract input specs (ShapeDtypeStruct) per (arch x shape) cell.

The shape-stand-ins follow the assignment: [audio]/[vlm] backbones receive
precomputed frame/patch embeddings here (the modality frontend is a stub).
No device memory is allocated by anything in this module.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig


def batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Training/prefill batch: tokens + labels (+ modality stubs)."""
    B, S = shape.global_batch, shape.seq_len
    sd = jax.ShapeDtypeStruct
    batch = {"tokens": sd((B, S), jnp.int32)}
    if shape.kind == "train":
        batch["labels"] = sd((B, S), jnp.int32)
    if cfg.family == "vlm":
        batch["img"] = sd((B, cfg.num_image_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.family == "audio":
        batch["frames"] = sd((B, encoder_len(cfg, shape), cfg.d_model),
                             jnp.bfloat16)
    return batch


def encoder_len(cfg: ModelConfig, shape: ShapeConfig) -> int:
    """Stub frame count for the audio backbone at a given shape cell."""
    return shape.seq_len


def decode_inputs(model, cfg: ModelConfig, shape: ShapeConfig,
                  kv_dtype=jnp.bfloat16):
    """(params, cache, tokens) abstract triple for serve_step lowering."""
    B, S = shape.global_batch, shape.seq_len
    params = model.abstract_params(jnp.bfloat16)
    kw = {}
    if cfg.family == "vlm":
        kw["img"] = jax.ShapeDtypeStruct((B, cfg.num_image_tokens, cfg.d_model),
                                         jnp.bfloat16)
    if cfg.family == "audio":
        kw["frames"] = jax.ShapeDtypeStruct((B, encoder_len(cfg, shape),
                                             cfg.d_model), jnp.bfloat16)
    cache = jax.eval_shape(
        lambda p, kws: model.init_cache(p, B, S, kv_dtype=kv_dtype, **kws),
        params, kw)
    tokens = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    return params, cache, tokens
