"""Serving driver: continuous-batching engine with batched prefill,
KV-cache waste detectors, and honest prefill-vs-decode accounting.

CPU smoke:  PYTHONPATH=src python -m repro.launch.serve \
                --arch qwen3-1.7b --smoke --batch 4 --prompt-len 32 --gen 16

Dense/MoE families run on `serve.engine.ServeEngine` (single-pass
batched prefill + per-slot decode positions + slot recycling); families
without an indexed KV cache in every block (hybrid/ssm/vlm/audio) fall
back to the legacy token-loop, with prefill and decode still timed
separately.

``--kv paged`` switches the engine to the block-paged KV heap
(serve/kv_cache.py): refcounted pages + copy-on-write prefix reuse,
eliminating exactly the waste the detectors flag in dense mode —
idle-slot dead/silent KV stores and silent prefix loads.

``--spec on`` adds speculative decoding (serve/spec.py): a host-side
drafter proposes up to ``--spec-k`` tokens per tick and ONE width-(k+1)
verify forward accepts the greedy-consistent prefix, so outputs stay
bit-identical to plain decode while live slots emit up to k+1 tokens
per tick. Rejected drafts are Def.-1 dead KV stores — measured by the
``rejected_draft_store`` detector site, and eliminated in the paged
layout by ``--spec-rollback on`` (the commit stops at the accept
point). ``--draft oracle`` runs a plain pass first and replays its
continuations (accept-rate 1.0 — the mechanism's upper bound and a live
bit-identity assertion).
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.configs.base import ProfilerConfig
from repro.core.detectors import ServingDetectors
from repro.core.findings import Finding, WasteProfile, merge_profiles
from repro.core.hlo_waste import analyze_waste
from repro.core.interpreter import profile_fn
from repro.core.report import dump_json
from repro.core.sarif import write_sarif
from repro.data.synthetic import batch_at
from repro.models.zoo import build_model
from repro.serve.decode import make_serve_step
from repro.serve.engine import ENGINE_FAMILIES, Request, ServeEngine
from repro.serve.spec import make_drafter


def padding_waste_profile(stats) -> WasteProfile:
    """Tier-2-style padding-waste finding from the engine's accounting:
    `_bucket`'s power-of-two prompt padding silently burns prefill
    compute on garbage positions (checked = all prefill positions
    swept, flagged = the padded ones)."""
    prof = WasteProfile(tier=2)
    padded = int(stats.get("padded_prefill_tokens", 0))
    useful = int(stats.get("prefill_computed_tokens", 0))
    prof.checked["prefill_padding"] = padded + useful
    prof.flagged["prefill_padding"] = padded
    if padded:
        prof.add(Finding(
            kind="prefill_padding", tier=2,
            c1=("serve.engine:_bucket",), c2=("serve.engine:prefill",),
            count=int(stats.get("prefills", 0)),
            fraction=padded / max(padded + useful, 1),
            meta={"padded_tokens": padded, "computed_tokens": useful}))
    return prof


def _run_engine(cfg, model, params, prompts, gen, seed, profile,
                kv="dense", page_size=16, spec=False, spec_k=4,
                draft="ngram", spec_rollback=True, obj_registry=None):
    batch, prompt_len = prompts.shape
    max_len = prompt_len + gen + 1

    def build_and_run(drafter, det, reg=None):
        eng = ServeEngine(model, params, num_slots=batch, max_len=max_len,
                          detectors=det, kv_dtype=jnp.float32,
                          kv_layout=kv, page_size=page_size,
                          drafter=drafter, spec_k=spec_k,
                          spec_rollback=spec_rollback,
                          registry=reg, owner="serve")
        for b in range(batch):
            eng.submit(Request(rid=f"r{b}", tokens=np.asarray(prompts[b]),
                               max_new_tokens=gen))
        eng.run()
        out = np.stack(
            [np.asarray(eng.finished[f"r{b}"].generated[:gen], np.int32)
             for b in range(batch)])
        return eng, out

    drafter = None
    plain_out = None
    if spec:
        if draft == "oracle":
            # harvest the plain greedy continuations first; the replay
            # drafter then proposes exactly them (accept-rate 1.0) —
            # the upper bound of the verify/rollback machinery, and a
            # live bit-identity check of the acceptance rule
            _, plain_out = build_and_run(None, None)
            seqs = [np.concatenate([np.asarray(prompts[b]), plain_out[b]])
                    for b in range(batch)]
            drafter = make_drafter("oracle", sequences=seqs)
        else:
            drafter = make_drafter(draft, model=model, params=params)
    det = ServingDetectors(ProfilerConfig(enabled=True, seed=seed)) \
        if profile else None
    # only the measured engine registers objects: the oracle's plain
    # pre-pass would otherwise leave a dead engine's pages in the scan
    eng, out = build_and_run(drafter, det, obj_registry)
    if plain_out is not None:
        assert np.array_equal(out, plain_out), \
            "speculative outputs diverged from plain greedy decode"
    tp = eng.throughput()
    tier3 = det.report if det is not None else None
    tier2_subject = eng.lowered_tick() if profile else None
    return jnp.asarray(out), tp, tier3, tier2_subject, eng.stats


def _bucket_pow2(n: int, cap: int, lo: int = 8) -> int:
    """Smallest power-of-two >= n (engine `_bucket` policy), capped."""
    b = lo
    while b < n:
        b *= 2
    return min(b, cap)


def encoder_padding_profile(stats) -> WasteProfile:
    """Tier-2 padding-waste finding for encoder-decoder serving: frames
    padded to the run extent burn encoder prefill compute and cross-KV
    bytes on garbage rows (checked = all frame rows swept, flagged =
    the padded ones). Bucketing the extent (``--bucket-frames``) is the
    fix this finding's bytes measure."""
    prof = WasteProfile(tier=2)
    padded = int(stats.get("padded_frames", 0))
    true = int(stats.get("true_frames", 0))
    prof.checked["prefill_padding"] = padded + true
    prof.flagged["prefill_padding"] = padded
    if padded:
        prof.add(Finding(
            kind="prefill_padding", tier=2,
            c1=("launch.serve:_run_legacy",), c2=("models.lm:encode",),
            count=1, bytes=float(stats.get("padded_bytes", 0)),
            fraction=padded / max(padded + true, 1),
            meta={"padded_frames": padded, "true_frames": true,
                  "frames_run": int(stats.get("frames_run", 0)),
                  "frames_capacity": int(stats.get("frames_capacity", 0))}))
    return prof


def _prep_frames(cfg, model, kw, frame_lengths, bucket_frames):
    """Right-pad audio frames to the run extent and account the padding.

    Baseline: every request runs at the full capacity extent (the
    frames buffer as generated). Bucketed: the extent shrinks to the
    power-of-two bucket of the batch's longest true length. Rows past
    each true length are zeroed and masked (kv_valid through the
    encoder, xvalid through cross-attention), so greedy outputs are
    identical in both modes — only the padded bytes differ."""
    frames = np.asarray(kw["frames"])
    B, cap = frames.shape[:2]
    lens = np.minimum(np.asarray(frame_lengths, np.int32), cap)
    F_run = cap if not bucket_frames \
        else _bucket_pow2(int(lens.max()), cap)
    mask = np.arange(cap)[None, :] < lens[:, None]
    frames = np.where(mask[..., None], frames, 0.0)[:, :F_run]
    kw = {**kw, "frames": jnp.asarray(frames),
          "frame_lengths": jnp.asarray(lens)}
    true = int(lens.sum())
    padded = B * F_run - true
    itemsize = 4  # float32 frames and kv_dtype below
    # a padded frame row costs its embedding row plus the per-layer
    # cross-K/V rows precomputed from it
    row = cfg.d_model * itemsize
    kv_row = model.sched.n_super * 2 * cfg.num_kv_heads * cfg.head_dim \
        * itemsize
    stats = {"frames_capacity": cap, "frames_run": F_run,
             "true_frames": true, "padded_frames": padded,
             "padded_bytes": padded * (row + kv_row)}
    return kw, stats


def _run_legacy(cfg, model, params, prompts, gen, kw, *,
                frame_lengths=None, bucket_frames=False):
    """Token-loop driver for families without an indexed KV cache."""
    batch, prompt_len = prompts.shape
    max_len = prompt_len + gen + 1
    stats = None
    if cfg.family == "audio" and frame_lengths is not None:
        kw, stats = _prep_frames(cfg, model, kw, frame_lengths,
                                 bucket_frames)
    cache = model.init_cache(params, batch, max_len,
                             kv_dtype=jnp.float32, **kw)
    # init_cache needs the full tree (cross-KV precompute); the decode
    # loop gets the decode-path view so the jitted step carries no dead
    # encoder/cross-KV invars (tier-0 dead_param, whisper/vision)
    params = model.decode_params(params)
    serve_step = jax.jit(make_serve_step(model), donate_argnums=(1,))

    t0 = time.perf_counter()
    for t in range(prompt_len):
        nxt, cache = serve_step(params, cache, prompts[:, t:t + 1])
    nxt.block_until_ready()
    t_prefill = time.perf_counter() - t0

    t0 = time.perf_counter()
    generated = [nxt]
    for _ in range(gen - 1):
        nxt, cache = serve_step(params, cache, generated[-1])
        generated.append(nxt)
    nxt.block_until_ready()
    t_decode = time.perf_counter() - t0

    out = jnp.concatenate(generated, axis=1)
    tp = {"prefill_tok_s": batch * prompt_len / max(t_prefill, 1e-9),
          "decode_tok_s": batch * gen / max(t_decode, 1e-9)}
    lowered = serve_step.lower(params, cache, generated[-1])
    return out, tp, cache, lowered, stats


def run(arch: str, *, smoke: bool = True, batch: int = 4,
        prompt_len: int = 32, gen: int = 16, seed: int = 0,
        profile: bool = False, profile_out: str = None,
        sarif_out: str = None,
        kv: str = "dense", page_size: int = 16,
        spec: bool = False, spec_k: int = 4, draft: str = "ngram",
        spec_rollback: bool = True, objects: bool = False,
        bucket_frames: bool = True):
    cfg = registry.get_config(arch)
    if smoke:
        cfg = cfg.smoke()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    obj_registry = None
    if objects:
        from repro.core.objects import ObjectRegistry, register_tree
        obj_registry = ObjectRegistry()
        register_tree(obj_registry, "serve/params", params)

    data = batch_at(cfg, batch, prompt_len, seed=seed, step=0)
    prompts = jnp.asarray(data["tokens"])
    kw = {}
    if cfg.family == "vlm":
        kw["img"] = jnp.asarray(data["img"])
    if cfg.family == "audio":
        kw["frames"] = jnp.asarray(data["frames"])

    tier3 = None
    stats = None
    enc_stats = None
    if cfg.family in ENGINE_FAMILIES:
        out, tp, tier3, tier2_subject, stats = _run_engine(
            cfg, model, params, prompts, gen, seed, profile,
            kv=kv, page_size=page_size, spec=spec, spec_k=spec_k,
            draft=draft, spec_rollback=spec_rollback,
            obj_registry=obj_registry)
    else:
        if kv != "dense":
            raise ValueError(f"--kv paged needs the engine families "
                             f"{ENGINE_FAMILIES}, not {cfg.family!r}")
        if spec:
            raise ValueError(f"--spec needs the engine families "
                             f"{ENGINE_FAMILIES}, not {cfg.family!r}")
        lens = None
        if cfg.family == "audio":
            from repro.data.synthetic import frame_lengths
            lens = frame_lengths(cfg, batch, seed=seed)
        out, tp, _, tier2_subject, enc_stats = _run_legacy(
            cfg, model, params, prompts, gen, kw,
            frame_lengths=lens, bucket_frames=bucket_frames)
        if enc_stats is not None:
            print(f"[serve] encoder frames: extent {enc_stats['frames_run']}"
                  f"/{enc_stats['frames_capacity']} "
                  f"({'bucketed' if bucket_frames else 'capacity'}), "
                  f"{enc_stats['true_frames']} true + "
                  f"{enc_stats['padded_frames']} padded rows "
                  f"({enc_stats['padded_bytes']} padded bytes)")

    # prompt tokens are NOT generated tokens: report the two rates
    # separately (a single blended tok/s overstates decode by counting
    # teacher-forced prefill pushes at the same rate)
    print(f"[serve] {arch}: {batch} seqs, prompt {prompt_len} + gen {gen} "
          f"[kv={kv}] | prefill {tp['prefill_tok_s']:.0f} tok/s, "
          f"decode {tp['decode_tok_s']:.0f} tok/s (live slots)")
    if stats is not None:
        print(f"[serve] prefix hits: {stats['prefix_hits']} "
              f"({stats['prefix_hit_tokens']} tokens served from cache), "
              f"computed {stats['prefill_computed_tokens']} of "
              f"{stats['prefill_tokens']} prompt tokens, "
              f"padded waste {stats['padded_prefill_tokens']} tokens, "
              f"pages freed {stats['pages_freed']}")
    if spec and stats is not None:
        mode = "rollback" if (spec_rollback and kv == "paged") \
            else "overwrite"
        print(f"[serve] spec[{draft},{mode}]: accepted drafts: "
              f"{stats['draft_accepted']} of {stats['draft_proposed']} "
              f"proposed (accept rate {tp.get('accept_rate', 0.0):.2f}) | "
              f"draft {tp.get('draft_tok_s', 0.0):.0f} tok/s, "
              f"verify {tp.get('verify_tok_s', 0.0):.0f} tok/s over "
              f"{stats['spec_ticks']} verify ticks")
    print("[serve] sample continuation:", np.asarray(out[0])[:12])

    obj_scan = None
    if obj_registry is not None:
        from repro.core.replicas import ReplicaDetector
        obj_scan = ReplicaDetector(obj_registry).scan()
        print(f"[serve] object scan: {len(obj_registry)} live objects, "
              f"{len(obj_scan.findings)} replica groups, "
              f"{sum(f.bytes for f in obj_scan.findings):.0f} "
              f"duplicate bytes")
        print(obj_scan.render(top_k=5, by="object"))

    if profile:
        # one merged WasteProfile for the serving path (DESIGN.md §2):
        # Tier-3 serve detectors on the live engine, Tier-2 on the
        # compiled decode step + the engine's padding accounting, Tier-1
        # (trace→replay) on a single-token decode microstep
        tier2 = analyze_waste(tier2_subject.compile().as_text()).profile
        pc = ProfilerConfig(enabled=True, period=5000, seed=seed)
        cache1 = model.init_cache(params, batch, prompt_len + gen + 1,
                                  kv_dtype=jnp.float32, **kw)
        dparams = model.decode_params(params)
        tok1 = out[:, -1:]
        tier1 = profile_fn(
            lambda tok: make_serve_step(model)(dparams, cache1, tok)[0],
            tok1, cfg=pc, epochs=2)
        profs = [tier1, tier2] + ([tier3] if tier3 is not None else [])
        if stats is not None:
            profs.append(padding_waste_profile(stats))
        if enc_stats is not None:
            profs.append(encoder_padding_profile(enc_stats))
        if obj_scan is not None:
            profs.append(obj_scan)
        merged = merge_profiles(profs)
        print(merged.render(top_k=3))
        if profile_out:
            dump_json(merged, profile_out)
            print(f"[serve] waste profile written to {profile_out}")
        if sarif_out:
            write_sarif(merged, sarif_out, src_root=os.getcwd())
            print(f"[serve] SARIF findings written to {sarif_out}")
    else:
        merged = None
    # same contract as launch.train.run: (result, merged profile or None)
    return out, merged


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=registry.ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--kv", default="dense", choices=("dense", "paged"))
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--spec", default="off", choices=("on", "off"),
                    help="speculative decoding (draft + width-k verify)")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="max draft tokens per verify window")
    ap.add_argument("--draft", default="ngram",
                    choices=("ngram", "oracle", "lm"),
                    help="drafter: self-speculative n-gram lookup, the "
                         "replay oracle (runs a plain pass first; "
                         "accept-rate 1.0), or the model drafting for "
                         "itself")
    ap.add_argument("--spec-rollback", default="on", choices=("on", "off"),
                    help="paged only: roll the commit back to the accept "
                         "point instead of storing rejected draft rows")
    ap.add_argument("--profile", action="store_true")
    ap.add_argument("--profile-out", default=None)
    ap.add_argument("--sarif-out", default=None,
                    help="write the merged waste profile as SARIF 2.1.0")
    ap.add_argument("--objects", action="store_true",
                    help="register params + KV pages in the object "
                         "registry and run the replica scan")
    ap.add_argument("--bucket-frames", default="on", choices=("on", "off"),
                    help="audio family: run the encoder at the "
                         "power-of-two bucket of the batch's longest "
                         "true frame length instead of always padding "
                         "to cfg.encoder_frames (outputs identical; "
                         "prefill_padding bytes drop)")
    a = ap.parse_args()
    run(a.arch, smoke=a.smoke, batch=a.batch, prompt_len=a.prompt_len,
        gen=a.gen, profile=a.profile, profile_out=a.profile_out,
        sarif_out=a.sarif_out,
        kv=a.kv, page_size=a.page_size, spec=a.spec == "on",
        spec_k=a.spec_k, draft=a.draft,
        spec_rollback=a.spec_rollback == "on", objects=a.objects,
        bucket_frames=a.bucket_frames == "on")


if __name__ == "__main__":
    main()
