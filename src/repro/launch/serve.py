"""Batched serving driver: prefill + greedy decode with KV cache.

CPU smoke:  PYTHONPATH=src python -m repro.launch.serve \
                --arch qwen3-1.7b --smoke --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.configs.base import ProfilerConfig
from repro.core.findings import merge_profiles
from repro.core.hlo_waste import analyze_waste
from repro.core.interpreter import profile_fn
from repro.core.report import dump_json
from repro.data.synthetic import batch_at
from repro.models.zoo import build_model
from repro.serve.decode import make_serve_step


def run(arch: str, *, smoke: bool = True, batch: int = 4,
        prompt_len: int = 32, gen: int = 16, seed: int = 0,
        profile: bool = False, profile_out: str = None):
    cfg = registry.get_config(arch)
    if smoke:
        cfg = cfg.smoke()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))

    data = batch_at(cfg, batch, prompt_len, seed=seed, step=0)
    prompts = jnp.asarray(data["tokens"])
    kw = {}
    if cfg.family == "vlm":
        kw["img"] = jnp.asarray(data["img"])
    if cfg.family == "audio":
        kw["frames"] = jnp.asarray(data["frames"])

    max_len = prompt_len + gen + 1
    cache = model.init_cache(params, batch, max_len, kv_dtype=jnp.float32, **kw)

    serve_step = jax.jit(make_serve_step(model), donate_argnums=(1,))

    # teacher-forced prefill through the decode path (exercises the cache)
    t0 = time.time()
    tok = prompts[:, :1]
    for t in range(prompt_len):
        nxt, cache = serve_step(params, cache, prompts[:, t:t + 1])
    generated = [nxt]
    for _ in range(gen - 1):
        nxt, cache = serve_step(params, cache, generated[-1])
        generated.append(nxt)
    out = jnp.concatenate(generated, axis=1)
    dt = time.time() - t0
    tps = batch * (prompt_len + gen) / dt
    print(f"[serve] {arch}: {batch} seqs, prompt {prompt_len} + gen {gen} "
          f"in {dt:.2f}s ({tps:.0f} tok/s)")
    print("[serve] sample continuation:", np.asarray(out[0])[:12])

    if profile:
        # one merged WasteProfile for the serving path (DESIGN.md §2):
        # Tier-2 on the compiled decode step, Tier-1 (trace→replay) on a
        # single-token decode microstep
        lowered = serve_step.lower(params, cache, generated[-1])
        tier2 = analyze_waste(lowered.compile().as_text()).profile
        pc = ProfilerConfig(enabled=True, period=5000, seed=seed)
        tier1 = profile_fn(
            lambda tok: make_serve_step(model)(params, cache, tok)[0],
            generated[-1], cfg=pc, epochs=2)
        merged = merge_profiles([tier1, tier2])
        print(merged.render(top_k=3))
        if profile_out:
            dump_json(merged, profile_out)
            print(f"[serve] waste profile written to {profile_out}")
    else:
        merged = None
    # same contract as launch.train.run: (result, merged profile or None)
    return out, merged


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=registry.ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--profile", action="store_true")
    ap.add_argument("--profile-out", default=None)
    a = ap.parse_args()
    run(a.arch, smoke=a.smoke, batch=a.batch, prompt_len=a.prompt_len,
        gen=a.gen, profile=a.profile, profile_out=a.profile_out)


if __name__ == "__main__":
    main()
