"""starcoder2-7b [dense] — GQA, RoPE, plain-GELU MLP [arXiv:2402.19173]."""
from repro.configs.base import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-7b",
        family="dense",
        num_layers=32,
        d_model=4608,
        num_heads=36,
        num_kv_heads=4,
        head_dim=128,
        d_ff=18432,
        vocab_size=49152,
        gated_mlp=False,           # starcoder2 uses a plain MLP (gelu)
        rope_theta=1e5,
    )
