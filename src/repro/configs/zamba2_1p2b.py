"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared attention block every 6
layers [arXiv:2411.15242]."""
from repro.configs.base import ModelConfig, SSMConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b",
        family="hybrid",
        num_layers=38,
        d_model=2048,
        num_heads=32,
        num_kv_heads=32,
        head_dim=64,
        d_ff=8192,
        vocab_size=32000,
        ssm=SSMConfig(state_dim=64, expand=2, head_dim=64, chunk_size=256),
        attn_period=6,            # every 6th block: shared attention+MLP
        subquadratic=True,        # decode state is O(1)/token except periodic attn
    )
