"""whisper-large-v3 [audio] — enc-dec backbone; conv frontend STUBBED
(input_specs provides precomputed frame embeddings) [arXiv:2212.04356]."""
from repro.configs.base import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3",
        family="audio",
        num_layers=32,             # decoder layers
        encoder_layers=32,
        d_model=1280,
        num_heads=20,
        num_kv_heads=20,           # MHA
        head_dim=64,
        d_ff=5120,
        vocab_size=51866,
        gated_mlp=False,           # whisper uses plain-GELU MLP
        rope_theta=1e4,            # backbone positional: rope stand-in
        encoder_frames=1500,
    )
