"""xlstm-1.3b [ssm] — sLSTM + mLSTM blocks (7:1), no separate FFN (d_ff=0)
[arXiv:2405.04517]."""
from repro.configs.base import ModelConfig, XLSTMConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-1.3b",
        family="ssm",
        num_layers=48,
        d_model=2048,
        num_heads=4,
        num_kv_heads=4,
        head_dim=512,
        d_ff=0,                   # per assignment: block-internal expansion only
        vocab_size=50304,
        xlstm=XLSTMConfig(slstm_period=8, chunk_size=256),
        subquadratic=True,
    )
