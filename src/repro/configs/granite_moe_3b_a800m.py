"""granite-moe-3b-a800m [moe] — 40 experts top-8, tiny expert FFN
[hf:ibm-granite/granite-3.0 family]."""
from repro.configs.base import ModelConfig, MoEConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m",
        family="moe",
        num_layers=32,
        d_model=1536,
        num_heads=24,
        num_kv_heads=8,
        head_dim=64,
        d_ff=512,
        vocab_size=49155,
        rope_theta=1e4,
        moe=MoEConfig(
            num_experts=40,
            experts_per_token=8,
            expert_d_ff=512,
        ),
    )
