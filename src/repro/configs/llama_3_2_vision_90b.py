"""llama-3.2-vision-90b [vlm] — LM backbone with cross-attn image layers
every 5th layer; patch embeddings stubbed [hf:meta-llama/Llama-3.2-Vision]."""
from repro.configs.base import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-90b",
        family="vlm",
        num_layers=100,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=28672,
        vocab_size=128256,
        rope_theta=5e5,
        cross_attn_period=5,     # 20 cross-attention layers out of 100
        num_image_tokens=1024,
    )
