"""Config dataclasses for models, shapes, meshes and training.

Every assigned architecture gets one module in this package exporting
``get_config() -> ModelConfig`` with the exact published numbers. Reduced
("smoke") variants are derived mechanically via ``ModelConfig.smoke()`` so
CPU tests exercise the same code paths as the full dry-run configs.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    experts_per_token: int
    # d_ff of each expert (the ModelConfig.d_ff field for MoE archs).
    expert_d_ff: int
    # llama4-style always-on shared expert (same d_ff as routed experts).
    shared_expert: bool = False
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    # Load-balancing auxiliary loss coefficient (Switch/GShard style).
    aux_loss_coef: float = 0.01
    # "scatter": capacity-mask scatter dispatch (mode=drop) — only routed
    # rows of the (B,E,C,d) expert buffer are ever written, so the
    # dead-expert-store fraction is 0 by construction. "einsum": the
    # GShard one-hot dispatch/combine einsums, kept as the A/B reference
    # (materializes every buffer row; unrouted rows are Def.-1 dead
    # stores).
    dispatch: str = "scatter"


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2-style state-space block config."""
    state_dim: int = 64
    conv_width: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk_size: int = 256


@dataclass(frozen=True)
class XLSTMConfig:
    """xLSTM block schedule: mostly mLSTM with sLSTM every `slstm_period`."""
    slstm_period: int = 8      # every 8th block is sLSTM, rest mLSTM
    proj_factor_mlstm: float = 2.0
    proj_factor_slstm: float = 1.3333333
    chunk_size: int = 256      # chunkwise-parallel mLSTM chunk length


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | vlm | audio | hybrid | ssm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    qk_norm: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # Gated (SwiGLU) vs plain-GELU MLP. starcoder2 uses plain; most use gated.
    gated_mlp: bool = True
    # --- MoE ---
    moe: Optional[MoEConfig] = None
    # --- VLM: every `cross_attn_period`-th decoder layer is cross-attention
    # to stubbed patch embeddings (0 = none).
    cross_attn_period: int = 0
    num_image_tokens: int = 1024
    # --- audio (enc-dec): encoder depth; frontend stubbed to frame embeds.
    encoder_layers: int = 0
    encoder_frames: int = 1500
    # --- hybrid (zamba2): mamba2 blocks + shared attention every N blocks.
    ssm: Optional[SSMConfig] = None
    attn_period: int = 0        # 0 = no interleaved shared-attn block
    # --- ssm family (xlstm) ---
    xlstm: Optional[XLSTMConfig] = None
    # Whether full (quadratic) attention is used anywhere => long_500k skip.
    subquadratic: bool = False
    dtype: str = "bfloat16"
    param_dtype: str = "float32"

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    # ------------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 (MXU lane alignment + clean
        16-way sharding). Embedding rows beyond vocab_size are never
        selected; decode masks padded logits to -inf."""
        return -(-self.vocab_size // 256) * 256

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    def num_params(self) -> int:
        """Analytic parameter count (matches init_params leaf sizes)."""
        from repro.models.zoo import count_params_analytic
        return count_params_analytic(self)

    def active_params(self) -> int:
        from repro.models.zoo import count_params_analytic
        return count_params_analytic(self, active_only=True)

    # ------------------------------------------------------------------
    def smoke(self) -> "ModelConfig":
        """Mechanically reduced config for CPU smoke tests.

        Preserves the block schedule structure (moe/hybrid/vlm/encdec
        periods) while shrinking width/depth/vocab.
        """
        period = 1
        if self.attn_period:
            period = max(period, self.attn_period)
        if self.cross_attn_period:
            period = max(period, self.cross_attn_period)
        if self.xlstm is not None:
            period = max(period, self.xlstm.slstm_period)
        layers = max(2, 2 * period)
        kw = dict(
            name=self.name + "-smoke",
            num_layers=layers,
            d_model=64,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads < self.num_heads else 4,
            head_dim=16,
            d_ff=128,
            vocab_size=256,
        )
        if self.moe is not None:
            kw["moe"] = dataclasses.replace(
                self.moe, num_experts=4,
                experts_per_token=min(2, self.moe.experts_per_token),
                expert_d_ff=64)
            kw["d_ff"] = 64
        if self.ssm is not None:
            kw["ssm"] = dataclasses.replace(
                self.ssm, state_dim=16, head_dim=16, chunk_size=8)
        if self.xlstm is not None:
            kw["xlstm"] = dataclasses.replace(self.xlstm, chunk_size=8)
        if self.encoder_layers:
            kw["encoder_layers"] = 2
            kw["encoder_frames"] = 16
        if self.cross_attn_period:
            kw["num_image_tokens"] = 8
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                  # "train" | "prefill" | "decode"

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


# The four assigned LM shapes (identical sets for all 10 archs).
SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4096, 256, "train"),
    ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    ShapeConfig("decode_32k", 32768, 128, "decode"),
    ShapeConfig("long_500k", 524288, 1, "decode"),
)
SHAPES_BY_NAME = {s.name: s for s in SHAPES}


@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    # microbatches for gradient accumulation (1 = no accumulation)
    microbatches: int = 1
    # activation checkpointing policy: none | dots | full
    remat: str = "dots"
    seed: int = 0
    # gradient compression for cross-pod ("pod" axis) reduction
    grad_compression: str = "none"   # none | int8_ef
    z_loss: float = 0.0


@dataclass(frozen=True)
class ProfilerConfig:
    """JXPerf-JAX configuration (the paper's knobs)."""
    enabled: bool = False
    # Tier-1 sampling period: one sample every `period` memory events.
    period: int = 5000
    # number of software watchpoint slots (paper: 4 debug registers)
    num_watchpoints: int = 4
    # FP approximate-equality tolerance (paper default: 1%)
    fp_tolerance: float = 0.01
    detect: Tuple[str, ...] = ("dead_store", "silent_store", "silent_load")
    # Tier-3 silent-data-load LRU window: max batch-content digests kept
    # (bounds detector memory over arbitrarily long runs)
    batch_hash_window: int = 4096
    seed: int = 0
