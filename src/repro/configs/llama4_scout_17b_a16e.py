"""llama4-scout-17b-a16e [moe] — 16 experts top-1 + shared expert
[hf:meta-llama/Llama-4-Scout-17B-16E]."""
from repro.configs.base import ModelConfig, MoEConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-17b-a16e",
        family="moe",
        num_layers=48,
        d_model=5120,
        num_heads=40,
        num_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab_size=202048,
        rope_theta=5e5,
        moe=MoEConfig(
            num_experts=16,
            experts_per_token=1,
            expert_d_ff=8192,
            shared_expert=True,
        ),
    )
