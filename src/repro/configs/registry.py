"""Architecture registry: ``--arch <id>`` resolution.

All 10 assigned architectures plus small paper-suite configs used by the
profiler benchmarks.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import ModelConfig, SHAPES, SHAPES_BY_NAME, ShapeConfig

_ARCH_MODULES: Dict[str, str] = {
    "starcoder2-7b": "repro.configs.starcoder2_7b",
    "qwen3-14b": "repro.configs.qwen3_14b",
    "qwen3-1.7b": "repro.configs.qwen3_1p7b",
    "granite-20b": "repro.configs.granite_20b",
    "llama4-scout-17b-a16e": "repro.configs.llama4_scout_17b_a16e",
    "granite-moe-3b-a800m": "repro.configs.granite_moe_3b_a800m",
    "llama-3.2-vision-90b": "repro.configs.llama_3_2_vision_90b",
    "whisper-large-v3": "repro.configs.whisper_large_v3",
    "zamba2-1.2b": "repro.configs.zamba2_1p2b",
    "xlstm-1.3b": "repro.configs.xlstm_1p3b",
}

ARCH_IDS: List[str] = list(_ARCH_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(_ARCH_MODULES[arch])
    return mod.get_config()


def get_shape(name: str) -> ShapeConfig:
    return SHAPES_BY_NAME[name]


def cell_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether an (arch x shape) cell runs, else the documented skip reason."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "full quadratic attention: 500k decode skipped per assignment"
    return True, ""


def all_cells():
    """Yield (arch_id, ModelConfig, ShapeConfig, applicable, reason)."""
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES:
            ok, why = cell_applicable(cfg, shape)
            yield arch, cfg, shape, ok, why


# --- tiny "paper suite" configs for the profiler's own benchmarks --------
def paper_suite() -> Dict[str, ModelConfig]:
    """Small models standing in for DaCapo/ScalaBench as profiling subjects."""
    out = {}
    for arch in ("qwen3-1.7b", "granite-moe-3b-a800m", "zamba2-1.2b",
                 "xlstm-1.3b", "whisper-large-v3"):
        cfg = get_config(arch).smoke()
        out[cfg.name] = cfg
    return out
