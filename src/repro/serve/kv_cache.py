"""Block-paged KV cache with copy-on-write prefix reuse (DESIGN.md §2,
serving tier).

This is the optimization the serve-side detectors point at: dense
per-slot cache rows make every idle tick a dead/silent KV store (Defs.
1-2) and every duplicated prompt prefix a silent prefix load (Def. 3).
The paged heap eliminates both:

  * the KV pool is a flat array of fixed-size **pages**; a free-list
    allocator hands pages to slots and a per-slot **page table** maps
    logical token positions to pages, so idle/finished slots simply own
    no pages past their extent and write nothing (the scatter drops
    out-of-table stores);
  * pages are **refcounted**: a prefix another request already computed
    is mapped into the new slot's table instead of recomputed (the
    Def.-3 finding becomes a cache hit), and a partially reused page is
    **copied-on-write** so the borrower's suffix never mutates the
    donor's K/V;
  * a **content-digest prefix index** (LRU-bounded, pinning its pages
    via refcounts) matches a new prompt's longest cached prefix at
    power-of-two and page-boundary granularities.

Host-side bookkeeping lives here (allocator, page tables, prefix
index); the device-side pool layout and gather/scatter live in
`models/lm.py` (`init_paged_cache`) + `kernels/ref.py`
(`paged_update`/`paged_gather`) + `serve/flash_decode.py` (sharded
paged decode). `ServeEngine(kv_layout="paged")` drives it.
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class PoolExhausted(RuntimeError):
    """No free pages left even after evicting every prefix-index pin.

    `freed` carries the pages that pressure-eviction DID release before
    giving up, so the caller can still disarm stale watchpoints on them."""

    def __init__(self, msg: str, freed: Optional[List[int]] = None):
        super().__init__(msg)
        self.freed: List[int] = freed or []


# ----------------------------------------------------------------------
# Free-list page allocator with refcounts
# ----------------------------------------------------------------------
class PageAllocator:
    """Fixed pool of `num_pages` pages; O(1) alloc/free; refcounted.

    A page's refcount is the number of holders: slots mapping it in
    their page table plus prefix-index entries pinning it. `alloc`
    returns pages at refcount 1 (the caller is the first holder);
    sharing bumps it via `incref`; `decref` returns the pages that
    reached zero (freed back to the list).

    With an `ObjectRegistry` attached (core/objects.py) every alloc
    registers the page as a live ``kv_page`` object — provenance is THIS
    allocator's alloc site, the one frame a developer can act on — and
    the zero-refcount free retires it, so replica scans only ever see
    pages some holder still maps. The engine installs `page_bytes` /
    `page_reader` after it builds the device pool (the allocator cannot
    size or read pages it does not own)."""

    def __init__(self, num_pages: int, *, registry=None, owner: str = "kv",
                 page_bytes: int = 0, page_reader=None):
        assert num_pages >= 1
        self.num_pages = num_pages
        self.refcount = np.zeros(num_pages, np.int32)
        self._free: List[int] = list(range(num_pages - 1, -1, -1))
        self.registry = registry
        self.owner = owner
        self.page_bytes = page_bytes
        self.page_reader = page_reader
        self._oids: Dict[int, int] = {}    # page -> registry oid (live)

    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def used_count(self) -> int:
        return self.num_pages - len(self._free)

    def alloc(self, n: int) -> List[int]:
        if n > len(self._free):
            raise PoolExhausted(
                f"need {n} pages, {len(self._free)} free of {self.num_pages}")
        out = [self._free.pop() for _ in range(n)]
        for p in out:
            assert self.refcount[p] == 0, f"free page {p} had refs"
            self.refcount[p] = 1
            if self.registry is not None:
                rd = self.page_reader
                rec = self.registry.register(
                    f"{self.owner}/page{p}", "kv_page", self.page_bytes,
                    reader=(lambda p=p, rd=rd: rd(p))
                    if rd is not None else None)
                self._oids[p] = rec.oid
        return out

    def incref(self, pages: Sequence[int]) -> None:
        for p in pages:
            assert self.refcount[p] > 0, f"incref on free page {p}"
            self.refcount[p] += 1

    def decref(self, pages: Sequence[int]) -> List[int]:
        """Drop one reference per page; returns pages freed (now refless)."""
        freed: List[int] = []
        for p in pages:
            assert self.refcount[p] > 0, f"double free of page {p}"
            self.refcount[p] -= 1
            if self.refcount[p] == 0:
                self._free.append(int(p))
                freed.append(int(p))
                if self.registry is not None:
                    oid = self._oids.pop(int(p), None)
                    if oid is not None:
                        self.registry.release(oid)
        return freed

    def check(self) -> None:
        """Invariants: free list and refcounts partition the pool."""
        free = set(self._free)
        assert len(free) == len(self._free), "duplicate page in free list"
        for p in range(self.num_pages):
            if p in free:
                assert self.refcount[p] == 0, f"free page {p} has refs"
            else:
                assert self.refcount[p] > 0, f"leaked page {p} (no refs)"


# ----------------------------------------------------------------------
# Content-digest prefix index
# ----------------------------------------------------------------------
def _digest(tokens: np.ndarray) -> str:
    arr = np.ascontiguousarray(tokens)
    return hashlib.blake2b(arr.tobytes(), digest_size=8).hexdigest()


def prefix_candidates(n: int, page_size: int) -> List[int]:
    """Prefix lengths worth indexing for an n-token prompt: the power-of-
    two ladder shared with `ServingDetectors` (what the detector calls a
    duplicate, the cache can reuse), page boundaries (whole-page reuse
    needs no copy), and the full prompt; ascending."""
    from repro.core.detectors import PREFIX_POW2
    cands = {p for p in PREFIX_POW2 if p < n}
    cands.update(range(page_size, n, page_size))
    cands.add(n)
    return sorted(cands)


@dataclass
class _Entry:
    length: int
    pages: Tuple[int, ...]     # pages covering [0, ceil(length/page_size))


class PrefixIndex:
    """digest(prompt[:L]) -> pages holding that prefix's K/V.

    Entries pin their pages through the allocator so a donor's prefix
    survives the donor's slot; the index is LRU-bounded and evicts under
    pool pressure (unpinning frees pages only when no live slot still
    maps them)."""

    def __init__(self, allocator: PageAllocator, page_size: int,
                 window: int = 32):
        self.alloc = allocator
        self.page_size = page_size
        self.window = max(1, window)
        self._entries: "OrderedDict[str, _Entry]" = OrderedDict()
        # refcount of registered entry LENGTHS: a donor's full prompt can
        # end mid-bucket (neither a pow2 nor a page boundary), where the
        # candidate ladder alone would never probe it — the OJXPerf
        # "different granularity boundaries" gap. `probe_lengths` adds
        # every registered length as a final partial-boundary probe.
        self._lengths: Dict[int, int] = {}

    def __len__(self) -> int:
        return len(self._entries)

    @staticmethod
    def _key(length: int, tokens: np.ndarray) -> str:
        return f"{length}:{_digest(tokens[:length])}"

    def probe_lengths(self, n: int) -> List[int]:
        """Prefix lengths `match` probes for an n-token prompt: the
        pow2+page candidate ladder PLUS every length some entry was
        actually registered at (bounded by the LRU window), so a prefix
        ending mid-bucket still dedups."""
        cands = set(prefix_candidates(n, self.page_size))
        cands.update(L for L in self._lengths if L < n)
        return sorted(cands)

    def match(self, tokens: np.ndarray) -> Tuple[int, Tuple[int, ...]]:
        """Longest indexed prefix of `tokens`: (length, pages) or (0, ())."""
        tokens = np.asarray(tokens)
        best_len, best_pages = 0, ()
        for cand in self.probe_lengths(tokens.size):
            key = self._key(cand, tokens)
            e = self._entries.get(key)
            if e is not None and cand > best_len:
                best_len, best_pages = e.length, e.pages
                self._entries.move_to_end(key)
        return best_len, best_pages

    def lookup(self, tokens: np.ndarray,
               length: int) -> Optional[Tuple[int, ...]]:
        """Pages of the exact-length entry for tokens[:length], or None
        (no LRU touch). The fleet's global tier mirrors local entries
        through this instead of reaching into the table."""
        e = self._entries.get(self._key(length, np.asarray(tokens)))
        return e.pages if e is not None else None

    def register(self, tokens: np.ndarray,
                 pages: Sequence[int]) -> List[int]:
        """Index the prompt's prefixes against the slot's page row.

        `pages` is the slot's table row covering [0, tokens.size).
        Returns pages freed by LRU eviction (window overflow)."""
        tokens = np.asarray(tokens)
        ps = self.page_size
        freed: List[int] = []
        for cand in prefix_candidates(tokens.size, ps):
            key = self._key(cand, tokens)
            if key in self._entries:
                self._entries.move_to_end(key)
                continue
            need = -(-cand // ps)            # ceil: pages covering [0,cand)
            if need > len(pages):
                continue
            pinned = tuple(int(p) for p in pages[:need])
            self.alloc.incref(pinned)
            self._entries[key] = _Entry(cand, pinned)
            self._lengths[cand] = self._lengths.get(cand, 0) + 1
            while len(self._entries) > self.window:
                freed += self.evict_one() or []
        return freed

    def evict_one(self, prefer_freeing: bool = False) -> Optional[List[int]]:
        """Unpin one entry; returns pages freed, or None when empty.

        Plain LRU by default (window bounding). Under pool pressure
        (`prefer_freeing`), the LRU-oldest entry that would actually
        release a page (one of its pins is the page's last reference)
        goes first — evicting a live donor's entry frees nothing and
        only destroys reuse potential. Falls back to plain LRU when no
        entry frees directly (multi-entry pins can cascade)."""
        if not self._entries:
            return None
        key = next(iter(self._entries))
        if prefer_freeing:
            for k, e in self._entries.items():
                if any(self.alloc.refcount[p] == 1 for p in e.pages):
                    key = k
                    break
        e = self._entries.pop(key)
        self._lengths[e.length] -= 1
        if not self._lengths[e.length]:
            del self._lengths[e.length]
        return self.alloc.decref(e.pages)

    def clear(self) -> List[int]:
        freed: List[int] = []
        while self._entries:
            freed += self.evict_one() or []
        return freed


# ----------------------------------------------------------------------
# The paged KV heap: allocator + per-slot tables + prefix index
# ----------------------------------------------------------------------
@dataclass
class AdmitPlan:
    """One admission's paging decisions (host side, pre-prefill)."""
    reuse_len: int                      # cached-prefix tokens mapped in
    row: List[int]                      # the slot's new page-table row
    cow: List[Tuple[int, int]] = field(default_factory=list)  # (src, dst)
    freed: List[int] = field(default_factory=list)  # evicted under pressure
    # COW source pages temporarily pinned by this admission — the caller
    # MUST release() them once the device-side page copy has consumed
    # their contents (the pin keeps eviction/realloc off the source)
    cow_pins: List[int] = field(default_factory=list)


class PagedKV:
    """Host-side manager of the paged serving heap for one engine.

    The device pool (`models.lm.LM.init_paged_cache`) holds
    `num_pages × page_size` K/V rows per layer; this class owns which
    page belongs to whom: the free list, refcounts, each slot's page
    table (mirrored to the device via `LM.with_page_table`), and the
    prefix index that turns duplicated prompts into page mappings."""

    def __init__(self, num_slots: int, page_size: int, num_pages: int,
                 max_pages_per_slot: int, prefix_window: int = 32,
                 registry=None, owner: str = "kv"):
        self.num_slots = num_slots
        self.page_size = page_size
        self.num_pages = num_pages
        self.max_pages_per_slot = max_pages_per_slot
        self.alloc = PageAllocator(num_pages, registry=registry,
                                   owner=owner)
        self.index = PrefixIndex(self.alloc, page_size, prefix_window)
        self.pt = np.full((num_slots, max_pages_per_slot), -1, np.int32)

    # ------------------------------------------------------------------
    def admit(self, slot: int, tokens: np.ndarray, budget: int,
              hint: Optional[Tuple[int, Tuple[int, ...]]] = None
              ) -> AdmitPlan:
        """Map a new request into `slot`: longest cached prefix shared
        page-for-page, a partially reused page copied-on-write, fresh
        pages for the rest of [0, len(tokens)+budget).

        `budget` is the request's generation allowance; pages covering
        prompt+budget are allocated up front so decode never faults.
        Raises PoolExhausted when eviction cannot free enough pages.

        `hint` is a (length, pages) prefix mapping from the fleet's
        global prefix tier (serve/global_prefix.py): pages of THIS pool
        holding tokens[:length], leased (incref'd) by the router at
        dispatch so they stay live and immutable even if the local LRU
        index has since forgotten the entry. Used when it beats the
        local match; ignored when stale (an unreferenced page means the
        lease protocol was violated, so that is asserted, not risked)."""
        tokens = np.asarray(tokens)
        L = int(tokens.size)
        ps = self.page_size
        assert np.all(self.pt[slot] < 0), f"slot {slot} still mapped"

        match_len, donor = self.index.match(tokens)
        if hint is not None:
            h_len, h_pages = int(hint[0]), tuple(int(p) for p in hint[1])
            h_len = min(h_len, L)
            if h_len > match_len and h_len <= len(h_pages) * ps:
                assert all(self.alloc.refcount[p] > 0 for p in h_pages), \
                    "global-prefix hint maps an unreferenced page"
                match_len, donor = h_len, h_pages
        # the last prompt position is always recomputed: its logits seed
        # the continuation and hidden states are not cached
        reuse = min(match_len, L - 1)
        n_full = reuse // ps
        shared = [int(p) for p in donor[:n_full]]
        partial = reuse % ps
        cow_src = int(donor[n_full]) if partial else None

        need_pos = min(L + max(budget, 1), self.max_pages_per_slot * ps)
        n_need = -(-need_pos // ps)
        if n_need > self.num_pages:
            raise ValueError(
                f"request needs {n_need} pages but the pool holds only "
                f"{self.num_pages}; raise num_pages or page_size")
        n_new = n_need - n_full            # COW page (if any) + fresh pages

        # pin the matched pages BEFORE evicting/allocating: the pressure
        # loop below may evict the very entry just matched, and without
        # these references the allocator would hand the donor's pages
        # back as "fresh" — double-mapping them into this slot's table
        # and letting the COW copy clobber the shared prefix
        self.alloc.incref(shared)
        cow_pins = [cow_src] if partial else []
        self.alloc.incref(cow_pins)

        freed: List[int] = []
        while self.alloc.free_count < n_new:
            fr = self.index.evict_one(prefer_freeing=True)
            if fr is None:
                # undo the pins; entries evicted above may have been the
                # pages' last other holders, so this can free them too
                freed += self.alloc.decref(shared)
                freed += self.alloc.decref(cow_pins)
                raise PoolExhausted(
                    f"slot {slot} needs {n_new} pages, "
                    f"{self.alloc.free_count} free, prefix index empty",
                    freed)
            freed += fr
        new_pages = self.alloc.alloc(n_new)

        cow = [(cow_src, new_pages[0])] if partial else []
        row = shared + new_pages
        self.pt[slot, :] = -1
        self.pt[slot, :len(row)] = row
        return AdmitPlan(reuse, row, cow, freed, cow_pins)

    def release(self, pages: Sequence[int]) -> List[int]:
        """Drop temporary pins (AdmitPlan.cow_pins, once the device copy
        has read the source pages); returns pages actually freed."""
        return self.alloc.decref(pages)

    def register_prefix(self, slot: int, tokens: np.ndarray) -> List[int]:
        """After prefill: index this prompt's prefixes for future reuse.
        Returns pages freed by LRU eviction."""
        row = [int(p) for p in self.pt[slot] if p >= 0]
        return self.index.register(tokens, row)

    def free_slot(self, slot: int) -> List[int]:
        """Recycle: unmap the slot's pages; returns pages actually freed
        (shared/pinned pages survive their other holders)."""
        row = [int(p) for p in self.pt[slot] if p >= 0]
        self.pt[slot, :] = -1
        return self.alloc.decref(row)

    def slot_extent(self, slot: int) -> int:
        """Number of logical positions the slot's page table maps (its
        writable extent). Speculative verify windows are capped to it so
        an ACCEPTED draft can never land on an unmapped position; pages
        cover prompt+budget up front, so only rejected/padding rows ever
        reach past it (and those drop)."""
        return int((self.pt[slot] >= 0).sum()) * self.page_size

    def site(self, slot: int, pos: int) -> Tuple[int, int]:
        """(page, offset) of a logical token position, or (-1, off)."""
        page_i, off = divmod(int(pos), self.page_size)
        if not (0 <= page_i < self.max_pages_per_slot):
            return -1, off
        return int(self.pt[slot, page_i]), off

    def check(self, extra_holders: Optional[Dict[int, int]] = None) -> None:
        """Cross-structure invariants (property tests drive this).

        `extra_holders` maps page -> reference count held by parties
        outside this heap (the fleet's global prefix tier pins and
        in-flight dispatch leases), so the audit stays exact when the
        pool is shared across the replica group."""
        self.alloc.check()
        refs: Dict[int, int] = dict(extra_holders or {})
        for b in range(self.num_slots):
            for p in self.pt[b]:
                if p >= 0:
                    refs[int(p)] = refs.get(int(p), 0) + 1
        for e in self.index._entries.values():
            for p in e.pages:
                refs[int(p)] = refs.get(int(p), 0) + 1
        for p in range(self.num_pages):
            assert self.alloc.refcount[p] == refs.get(p, 0), \
                f"page {p}: refcount {self.alloc.refcount[p]} != " \
                f"holders {refs.get(p, 0)}"


# ----------------------------------------------------------------------
# Device-side page copy (COW) over every paged KV sub-block
# ----------------------------------------------------------------------
def make_page_copy():
    """jit-able (cache, src, dst) -> cache with pool[dst] = pool[src] in
    every layer of every paged KV sub-block (a pure cache-tree
    transform). `src`/`dst` are equal-length int32 page-id vectors;
    entries with dst == num_pages are dropped (padding, so one compiled
    shape serves any COW count ≤ batch)."""

    def copy(cache, src, dst):
        def one(tree):
            out = {}
            for name, sub in tree.items():
                if "pt" in sub:
                    sub = dict(sub)
                    for key in ("k", "v"):
                        pool = sub[key]        # (L, P, page, Hkv, D)
                        rows = jnp.take(
                            pool, jnp.clip(src, 0, pool.shape[1] - 1),
                            axis=1)
                        sub[key] = pool.at[:, dst].set(rows, mode="drop")
                out[name] = sub
            return out

        new = dict(cache)
        new["main"] = one(cache["main"])
        return new
    return copy
