"""Multi-replica fleet router with prefix-aware placement
(DESIGN.md § Fleet tier).

`FleetRouter` spreads a request stream over N in-process `ServeEngine`
replicas, each with its own paged pool and slot budget. Three routing
policies:

  * ``prefix`` — consult the fleet's `GlobalPrefixIndex`: a request
    whose prompt has a globally resident prefix of at least
    `min_route_len` tokens routes to the OWNING replica (taking a
    refcount lease on the pages so they survive until admission), where
    `PagedKV.admit` maps them in instead of re-prefilling. Falls back
    to least-loaded placement when there is no useful match or the
    owner is saturated.
  * ``least`` — least-loaded (queue depth + live slots), the classic
    baseline.
  * ``random`` — uniform over replicas with capacity; the honest
    strawman prefix routing must beat.

Admission control and backpressure: each replica accepts at most
`max_inflight` requests (queued + live); when every replica is
saturated, dispatch stops for the tick and the backlog waits (counted
in ``stats["backpressure"]``). Pool pressure inside a replica
(`admit_deferred` growing) triggers preemption-safe relief: the router
evicts that replica's OWN global-prefix pins (`evict_for`) — never
another replica's, and never a page a live slot or lease still holds —
so the deferred admission can retry next tick.

The router is also a measurement instrument: per-request TTFT/TPOT wall
times, per-replica queue depths, and a fleet-level Tier-3
`WasteProfile` charging ``fleet_silent_prefix_load`` bytes whenever a
request re-prefilled a prefix that was resident on SOME replica at
dispatch time (Def. 3 at fleet scale — the redundancy the prefix policy
exists to eliminate; random routing pays it on every misroute).
"""
from __future__ import annotations

import time
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.core.findings import WasteProfile
from repro.serve.engine import MonotonicStats, Request, ServeEngine
from repro.serve.kv_cache import _digest
from repro.serve.global_prefix import GlobalPrefixIndex
from repro.serve.workload import Trace, TraceRequest

POLICIES = ("prefix", "least", "random")


class FleetRouter:
    """Route a request stream over N `ServeEngine` replicas."""

    def __init__(self, engines: List[ServeEngine], *,
                 policy: str = "prefix", seed: int = 0,
                 min_route_len: int = 8,
                 max_inflight: Optional[int] = None,
                 global_window: int = 64,
                 content_dedup: bool = False):
        assert engines, "a fleet needs at least one replica"
        if policy not in POLICIES:
            raise ValueError(f"policy must be one of {POLICIES}")
        self.engines = list(engines)
        self.policy = policy
        self.min_route_len = min_route_len
        # default inflight cap: a queue as deep as the slot count keeps
        # prefill groups full without unbounded per-replica pile-up
        self.max_inflight = (max_inflight if max_inflight is not None
                             else 2 * max(e.num_slots for e in engines))
        self._rng = np.random.RandomState(seed)
        paged = all(e.kv is not None for e in engines)
        self.gpi: Optional[GlobalPrefixIndex] = None
        if paged:
            self.gpi = GlobalPrefixIndex(
                dict(enumerate(self.engines)),
                page_size=engines[0].kv.page_size, window=global_window)
        elif policy == "prefix":
            raise ValueError(
                "policy='prefix' needs every replica on kv_layout='paged'")
        self.backlog: Deque[TraceRequest] = deque()
        self._inflight: Dict[str, Tuple[int, Request]] = {}
        self.finished: Dict[str, Request] = {}
        self.tick = 0
        # per-request measurement: wall stamps + dispatch-time routing
        # facts (expected global reuse, owner) for waste attribution
        self.metrics: Dict[str, Dict] = {}
        self.stats = MonotonicStats(
            {"dispatched": 0, "prefix_routes": 0,
             "cross_replica_prefix_routes": 0, "fallback_routes": 0,
             "backpressure_ticks": 0, "backpressure_requests": 0,
             "preemption_evicted_pages": 0, "global_evictions": 0,
             "content_dedup_routes": 0})
        # content-addressed dedup of DISPATCHED-but-unpublished prefixes
        # (OJXPerf replica fix, fleet side): the global prefix tier only
        # knows a prefix after its owner admits+publishes, so two
        # same-burst duplicates route independently and each replica
        # computes its own bit-identical pages. With `content_dedup` the
        # router keys every in-flight request's page-aligned prefix
        # digests to its replica and sends later duplicates THERE, where
        # the engine's own same-burst defer (engine.content_dedup) turns
        # them into PrefixIndex hits on the leader's pages.
        self.content_dedup = bool(content_dedup) and paged
        self._inflight_digests: Dict[str, Tuple[int, int]] = {}
        self._rid_digests: Dict[str, List[str]] = {}
        # fleet-level Def.-3 accounting (tier 3: runtime-observed)
        self.profile = WasteProfile(tier=3)
        self.queue_depths: List[List[int]] = [[] for _ in self.engines]
        self._deferred_seen = [0] * len(self.engines)

    # ------------------------------------------------------------------
    def submit(self, treq: TraceRequest) -> None:
        self.backlog.append(treq)

    def submit_trace(self, trace: Trace) -> None:
        for treq in sorted(trace.requests, key=lambda r: r.arrival):
            self.submit(treq)

    @property
    def pending(self) -> int:
        return len(self.backlog) + len(self._inflight)

    def _load(self, i: int) -> int:
        e = self.engines[i]
        return e.queue_depth + e.live_slots

    def _has_capacity(self, i: int) -> bool:
        return self.engines[i].pending < self.max_inflight

    def _least_loaded(self) -> Optional[int]:
        avail = [i for i in range(len(self.engines))
                 if self._has_capacity(i)]
        if not avail:
            return None
        return min(avail, key=lambda i: (self._load(i), i))

    # ------------------------------------------------------------------
    def _route(self, treq: TraceRequest) -> Optional[Tuple[int, Optional[tuple]]]:
        """(replica, prefix_hint) or None when every replica is full.

        The dispatch-time global match is recorded in `metrics` for ALL
        policies — measurement must not depend on whether the policy
        acts on it, or the waste comparison between policies is rigged."""
        L = int(treq.tokens.size)
        g_len, owner, key = 0, None, None
        if self.gpi is not None:
            m = self.gpi.match(treq.tokens)
            if m is not None:
                key, entry = m
                g_len = min(entry.length, L - 1)
                owner = entry.replica
        met = self.metrics.setdefault(treq.rid, {})
        met["global_match_len"] = g_len
        met["owner"] = owner

        fallback = self._least_loaded()
        if (self.policy == "prefix" and key is not None
                and g_len >= self.min_route_len
                and owner is not None and self._has_capacity(owner)):
            lease = self.gpi.lease(key, treq.rid)
            if lease is not None:
                self.stats["prefix_routes"] += 1
                if fallback is not None and fallback != owner:
                    # the prefix overrode load-based placement: the
                    # routing decision crossed replicas through the
                    # global tier (the CI fleet-smoke asserts >= 1)
                    self.stats["cross_replica_prefix_routes"] += 1
                return owner, lease
        if self.content_dedup:
            hit = self._dedup_match(treq)
            if hit is not None and self._has_capacity(hit):
                # an in-flight request on `hit` shares a page-aligned
                # prefix: co-locate so the leader's pages get shared
                # instead of recomputed into cross-replica replicas
                self.stats["content_dedup_routes"] += 1
                return hit, None
        if fallback is None:
            return None
        if self.policy == "random":
            avail = [i for i in range(len(self.engines))
                     if self._has_capacity(i)]
            return int(self._rng.choice(avail)), None
        self.stats["fallback_routes"] += self.policy == "prefix"
        return fallback, None

    def _prefix_keys(self, tokens: np.ndarray) -> List[str]:
        """Page-aligned prefix digest keys of a prompt (same key space
        the engine's same-burst defer uses)."""
        ps = self.engines[0].kv.page_size
        toks = np.asarray(tokens)
        return [f"{m}:{_digest(toks[:m])}"
                for m in range(ps, int(toks.size), ps)]

    def _dedup_match(self, treq: TraceRequest) -> Optional[int]:
        """Replica holding an in-flight request that shares this
        prompt's longest page-aligned prefix (>= min_route_len)."""
        best_len, best = 0, None
        ps = self.engines[0].kv.page_size
        for m, key in zip(range(ps, int(treq.tokens.size), ps),
                          self._prefix_keys(treq.tokens)):
            hit = self._inflight_digests.get(key)
            if hit is not None and m > best_len:
                best_len, best = m, hit[0]
        return best if best_len >= self.min_route_len else None

    def _note_inflight(self, treq: TraceRequest, replica: int) -> None:
        keys = self._prefix_keys(treq.tokens)
        self._rid_digests[treq.rid] = keys
        for key in keys:
            cur = self._inflight_digests.get(key)
            # first dispatcher of a prefix stays its owner; later
            # holders only bump the count that keeps the key alive
            self._inflight_digests[key] = ((replica, 1) if cur is None
                                           else (cur[0], cur[1] + 1))

    def _dispatch(self) -> None:
        blocked = False
        while self.backlog and self.backlog[0].arrival <= self.tick:
            treq = self.backlog[0]
            met = self.metrics.setdefault(treq.rid, {})
            met.setdefault("t_due", time.perf_counter())
            choice = self._route(treq)
            if choice is None:
                # fleet saturated: the head request waits (FIFO — no
                # overtaking, so TTFT percentiles stay honest)
                self.stats["backpressure_requests"] += 1
                blocked = True
                break
            self.backlog.popleft()
            replica, hint = choice
            if self.content_dedup:
                self._note_inflight(treq, replica)
            req = Request(rid=treq.rid, tokens=np.asarray(treq.tokens),
                          max_new_tokens=treq.max_new_tokens,
                          arrival=0, prefix_hint=hint)
            self.engines[replica].submit(req)
            self._inflight[treq.rid] = (replica, req)
            met["replica"] = replica
            self.stats["dispatched"] += 1
        if blocked:
            self.stats["backpressure_ticks"] += 1

    # ------------------------------------------------------------------
    def _relieve_pressure(self, i: int) -> None:
        """A replica deferred an admission under pool pressure: evict
        ITS global-prefix pins until a slot's worth of pages freed (or
        none of its entries remain). Other replicas' pins — and every
        outstanding lease — are untouchable, so a pinned remote prefix
        can never be freed by another pool's pressure."""
        if self.gpi is None:
            return
        want = self.engines[i].kv.max_pages_per_slot
        freed = self.gpi.evict_for(i, want)
        self.stats["preemption_evicted_pages"] += freed

    def _account_admission(self, rid: str, req: Request) -> None:
        """Fleet Def.-3: the request re-prefilled `waste` tokens whose
        K/V was resident on some replica at dispatch time."""
        met = self.metrics[rid]
        g = int(met.get("global_match_len", 0))
        if self.gpi is not None:
            self.gpi.note_admitted(rid)
            self.gpi.publish(met["replica"], req.tokens)
        # admitted + published: the global tier now covers this prompt's
        # prefixes, so the in-flight digest window closes
        for key in self._rid_digests.pop(rid, ()):
            owner_n = self._inflight_digests.get(key)
            if owner_n is not None:
                replica_i, n = owner_n
                if n <= 1:
                    del self._inflight_digests[key]
                else:
                    self._inflight_digests[key] = (replica_i, n - 1)
        if g <= 0:
            return
        waste = max(0, g - int(req.reuse_len))
        self.profile.observe("fleet_silent_prefix_load", waste > 0)
        if waste:
            owner, chosen = met.get("owner"), met["replica"]
            self.profile.add_pair(
                "fleet_silent_prefix_load", 3,
                c1=("serve.global_prefix:resident", f"replica{owner}"),
                c2=("serve.router:dispatch", f"replica{chosen}"),
                nbytes=float(waste * req.tokens.dtype.itemsize),
                tokens=waste, rid=rid)
            self.profile.bump_total("fleet_silent_prefix_tokens", waste)

    def step(self) -> None:
        """One fleet tick: dispatch due requests, step every replica
        with work, then stamp timings / publish prefixes / account
        fleet-level waste and relieve pool pressure."""
        self._dispatch()
        for i, eng in enumerate(self.engines):
            self.queue_depths[i].append(eng.queue_depth)
            if eng.pending:
                eng.step()
            deferred = eng.stats["admit_deferred"]
            if deferred > self._deferred_seen[i]:
                self._deferred_seen[i] = deferred
                self._relieve_pressure(i)
        now = time.perf_counter()
        for rid in list(self._inflight):
            replica, req = self._inflight[rid]
            met = self.metrics[rid]
            if req.prefill_step >= 0 and "t_admit" not in met:
                met["t_admit"] = now
                self._account_admission(rid, req)
            if req.generated and "t_first" not in met:
                met["t_first"] = now
            if req.done:
                met["t_done"] = now
                met["n_generated"] = len(req.generated)
                self.finished[rid] = req
                del self._inflight[rid]
        if self.gpi is not None:
            self.stats["global_evictions"] = max(
                self.stats["global_evictions"], self.gpi.stats["evicted"])
        self.tick += 1

    def run(self, max_ticks: int = 100_000) -> Dict[str, Request]:
        ticks = 0
        while self.pending and ticks < max_ticks:
            self.step()
            ticks += 1
        assert not self.pending, \
            f"fleet did not drain in {max_ticks} ticks " \
            f"({len(self.backlog)} backlogged, {len(self._inflight)} live)"
        return self.finished

    # ---------------------------- reporting ---------------------------
    def latency_summary(self) -> Dict[str, float]:
        """p50/p99 TTFT (due -> first token) and TPOT (per-token decode
        time after the first), seconds, over finished requests."""
        ttft = [m["t_first"] - m["t_due"] for m in self.metrics.values()
                if "t_first" in m and "t_due" in m]
        tpot = [(m["t_done"] - m["t_first"]) / (m["n_generated"] - 1)
                for m in self.metrics.values()
                if "t_done" in m and m.get("n_generated", 0) >= 2]
        out: Dict[str, float] = {}
        if ttft:
            out["ttft_p50"] = float(np.percentile(ttft, 50))
            out["ttft_p99"] = float(np.percentile(ttft, 99))
        if tpot:
            out["tpot_p50"] = float(np.percentile(tpot, 50))
            out["tpot_p99"] = float(np.percentile(tpot, 99))
        return out

    def queue_summary(self) -> List[Dict[str, float]]:
        return [{"replica": i,
                 "mean_depth": float(np.mean(d)) if d else 0.0,
                 "max_depth": int(max(d)) if d else 0}
                for i, d in enumerate(self.queue_depths)]

    def prefix_hit_fraction(self) -> float:
        hit = sum(e.stats["prefix_hit_tokens"] for e in self.engines)
        tot = sum(e.stats["prefill_tokens"] for e in self.engines)
        return hit / tot if tot else 0.0

    def fleet_waste_bytes(self) -> float:
        """Total fleet-level silent-prefix-load bytes this run charged."""
        return sum(f.bytes for f in self.profile.findings
                   if f.kind == "fleet_silent_prefix_load")

    def check(self) -> None:
        """Fleet-wide refcount audit: every replica's pool must balance
        against its local holders PLUS the global tier's pins/leases,
        and no global entry may reach a freed page."""
        if self.gpi is None:
            return
        self.gpi.check()
        for i, eng in enumerate(self.engines):
            eng.kv.check(extra_holders=self.gpi.holders(i))
