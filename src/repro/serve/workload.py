"""Trace-driven serving workload generator (DESIGN.md § Fleet tier).

Production serving is judged under traffic, not single batches: arrival
bursts, mixed prompt lengths, and — the fleet router's whole reason to
exist — duplicated prefixes (system prompts, few-shot headers) arriving
interleaved across the replica group. This module generates such traces
**seeded and replayable**: the same seed yields the same byte-identical
trace, and a trace round-trips through JSON so a measured run can be
re-measured on another revision or another routing policy.

Trace schema (version 1):

    {"version": 1,
     "meta":    {generator knobs, seed, ...},
     "requests": [{"rid": str, "arrival": int (scheduler tick),
                   "tokens": [int, ...], "max_new_tokens": int,
                   "prefix_id": int | null}, ...]}

`prefix_id` names which shared-prefix pool the prompt was drawn from
(null = unique prompt) — consumers use it to report hit-rate honesty,
the engines never see it.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

ARRIVALS = ("poisson", "bursty", "uniform")


@dataclass
class TraceRequest:
    rid: str
    arrival: int                       # scheduler tick the request lands
    tokens: np.ndarray                 # (L,) int32 prompt
    max_new_tokens: int
    prefix_id: Optional[int] = None    # shared-prefix pool id, if any

    def to_dict(self) -> Dict:
        return {"rid": self.rid, "arrival": int(self.arrival),
                "tokens": [int(t) for t in self.tokens],
                "max_new_tokens": int(self.max_new_tokens),
                "prefix_id": self.prefix_id}

    @classmethod
    def from_dict(cls, d: Dict) -> "TraceRequest":
        return cls(rid=str(d["rid"]), arrival=int(d["arrival"]),
                   tokens=np.asarray(d["tokens"], np.int32),
                   max_new_tokens=int(d["max_new_tokens"]),
                   prefix_id=d.get("prefix_id"))


@dataclass
class Trace:
    requests: List[TraceRequest]
    meta: Dict = field(default_factory=dict)

    def __len__(self) -> int:
        return len(self.requests)

    @property
    def max_prompt_len(self) -> int:
        return max(int(r.tokens.size) for r in self.requests)

    @property
    def max_new_tokens(self) -> int:
        return max(int(r.max_new_tokens) for r in self.requests)

    def dup_fraction(self) -> float:
        """Fraction of requests drawn from a shared-prefix pool."""
        if not self.requests:
            return 0.0
        return sum(r.prefix_id is not None
                   for r in self.requests) / len(self.requests)

    def to_dict(self) -> Dict:
        return {"version": 1, "meta": dict(self.meta),
                "requests": [r.to_dict() for r in self.requests]}

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    @classmethod
    def from_dict(cls, d: Dict) -> "Trace":
        assert int(d.get("version", 1)) == 1, "unknown trace version"
        return cls(requests=[TraceRequest.from_dict(r)
                             for r in d.get("requests", [])],
                   meta=dict(d.get("meta", {})))

    @classmethod
    def from_json(cls, s: str) -> "Trace":
        return cls.from_dict(json.loads(s))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json(indent=1))
            f.write("\n")

    @classmethod
    def load(cls, path: str) -> "Trace":
        with open(path) as f:
            return cls.from_json(f.read())


def _arrival_ticks(rng: np.random.RandomState, n: int, arrival: str,
                   rate: float, burst_size: int, burst_gap: int
                   ) -> List[int]:
    """Arrival tick per request, non-decreasing.

    poisson: exponential inter-arrivals at `rate` requests/tick
    (rounded to ticks); bursty: groups of `burst_size` land on the same
    tick, groups `burst_gap` ticks apart; uniform: one request every
    round(1/rate) ticks."""
    if arrival == "poisson":
        gaps = rng.exponential(1.0 / max(rate, 1e-9), size=n)
        return np.floor(np.cumsum(gaps)).astype(int).tolist()
    if arrival == "bursty":
        return [(i // max(burst_size, 1)) * max(burst_gap, 1)
                for i in range(n)]
    if arrival == "uniform":
        step = max(int(round(1.0 / max(rate, 1e-9))), 1)
        return [i * step for i in range(n)]
    raise ValueError(f"arrival must be one of {ARRIVALS}, got {arrival!r}")


def make_trace(*, n_requests: int, vocab_size: int, seed: int = 0,
               arrival: str = "poisson", rate: float = 1.0,
               burst_size: int = 4, burst_gap: int = 4,
               prompt_len: "tuple[int, int]" = (16, 48),
               gen_len: "tuple[int, int]" = (4, 16),
               dup_rate: float = 0.5, n_prefixes: int = 2,
               prefix_len: int = 24) -> Trace:
    """Seeded, replayable request trace.

    With probability `dup_rate` a prompt starts with one of `n_prefixes`
    shared prefixes of `prefix_len` tokens (drawn once per trace) and
    continues with a unique suffix; otherwise it is fully unique.
    Prompt/generation lengths are uniform over the inclusive ranges.
    The same knobs + seed always produce the same trace."""
    assert n_requests >= 1 and vocab_size > 1
    lo, hi = prompt_len
    assert 2 <= lo <= hi
    rng = np.random.RandomState(seed)
    pools = [rng.randint(0, vocab_size, size=prefix_len).astype(np.int32)
             for _ in range(max(n_prefixes, 1))]
    arrivals = _arrival_ticks(rng, n_requests, arrival, rate,
                              burst_size, burst_gap)
    reqs: List[TraceRequest] = []
    for i in range(n_requests):
        L = int(rng.randint(lo, hi + 1))
        dup = bool(rng.rand() < dup_rate)
        if dup:
            pid = int(rng.randint(len(pools)))
            head = pools[pid][:min(prefix_len, L - 1)]
            tail = rng.randint(0, vocab_size,
                               size=L - head.size).astype(np.int32)
            toks = np.concatenate([head, tail])
        else:
            pid = None
            toks = rng.randint(0, vocab_size, size=L).astype(np.int32)
        g = int(rng.randint(gen_len[0], gen_len[1] + 1))
        reqs.append(TraceRequest(rid=f"t{i}", arrival=int(arrivals[i]),
                                 tokens=toks, max_new_tokens=g,
                                 prefix_id=pid))
    meta = {"seed": seed, "arrival": arrival, "rate": rate,
            "burst_size": burst_size, "burst_gap": burst_gap,
            "prompt_len": list(prompt_len), "gen_len": list(gen_len),
            "dup_rate": dup_rate, "n_prefixes": n_prefixes,
            "prefix_len": prefix_len, "n_requests": n_requests,
            "vocab_size": vocab_size}
    return Trace(requests=reqs, meta=meta)


def duplicated_prefix_trace(*, n_requests: int, vocab_size: int,
                            seed: int = 0, prompt_len: int = 32,
                            prefix_len: int = 24, gen: int = 8,
                            burst_size: int = 2, burst_gap: int = 2
                            ) -> Trace:
    """The fleet acceptance workload: heavily duplicated prefixes in
    staggered bursts — the traffic shape where prefix-aware routing
    must beat random placement on TTFT and fleet Def.-3 bytes."""
    return make_trace(n_requests=n_requests, vocab_size=vocab_size,
                      seed=seed, arrival="bursty", burst_size=burst_size,
                      burst_gap=burst_gap,
                      prompt_len=(prompt_len, prompt_len),
                      gen_len=(gen, gen), dup_rate=0.8, n_prefixes=1,
                      prefix_len=prefix_len)
