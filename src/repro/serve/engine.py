"""Continuous-batching serving engine (DESIGN.md §2, serving tier).

Production-shaped serving over a fixed-size decode batch:

  * **Batched prefill** — a request's whole prompt fills its KV-cache row
    in ONE jitted `model.prefill` call (not `prompt_len` sequential
    decode steps). Admission groups waiting requests into one padded
    prefill; non-admitted rows are merged back untouched.
  * **Per-slot positions** — the cache write index is a (B,) vector, so
    every slot sits at its own sequence offset: requests arrive, finish
    (EOS / max-new-tokens) and recycle their slot independently while
    the batch keeps stepping.
  * **Honest accounting** — prefill and decode token counts/times are
    tracked separately, and decode throughput is measured over *live*
    slots only (idle slots still burn compute; that is the point).
  * **Waste detection** — the decode batch writes K/V for every slot
    every tick whether or not it serves a request. With
    `core.detectors.ServingDetectors` attached, idle-slot writes trap as
    dead/silent KV stores and duplicate prompt prefixes as silent prefix
    loads, all in the unified WasteProfile.

The engine needs every sub-block of the architecture to carry an indexed
KV cache, so it supports the "dense" and "moe" families; other families
are served by the legacy token-loop in `launch/serve.py`.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.detectors import ServingDetectors, SlotWrite

ENGINE_FAMILIES = ("dense", "moe")


@dataclass
class Request:
    """One serving request: prompt in, greedy continuation out."""
    rid: str
    tokens: np.ndarray                 # (L,) int32 prompt
    max_new_tokens: int = 16
    arrival: int = 0                   # earliest engine step for admission
    # filled by the engine:
    generated: List[int] = field(default_factory=list)
    prefill_step: int = -1
    finish_step: int = -1

    @property
    def done(self) -> bool:
        return self.finish_step >= 0


def _bucket(n: int, lo: int = 8) -> int:
    """Pad prompt groups to power-of-two lengths: bounded jit cache."""
    p = lo
    while p < n:
        p *= 2
    return p


class ServeEngine:
    """Fixed-size decode batch + waiting queue + slot recycling."""

    def __init__(self, model, params, *, num_slots: int = 4,
                 max_len: int = 128, eos_id: Optional[int] = None,
                 detectors: Optional[ServingDetectors] = None,
                 kv_dtype=jnp.float32):
        if model.cfg.family not in ENGINE_FAMILIES:
            raise ValueError(
                f"ServeEngine needs an indexed KV cache in every block; "
                f"family {model.cfg.family!r} is served by the legacy "
                f"token-loop driver")
        self.model = model
        self.params = params
        self.num_slots = num_slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.detectors = detectors

        cache = model.init_cache(params, num_slots, max_len,
                                 kv_dtype=kv_dtype)
        self.cache = model.with_cache_index(
            cache, jnp.zeros((num_slots,), jnp.int32))
        self.tokens = jnp.zeros((num_slots, 1), jnp.int32)

        self.slots: List[Optional[Request]] = [None] * num_slots
        self._lengths = np.zeros(num_slots, np.int64)  # host mirror of idx
        self._queue: Deque[Request] = deque()
        self.finished: Dict[str, Request] = {}
        self.step_no = 0
        self.stats = {"prefill_tokens": 0, "decode_tokens": 0,
                      "prefill_s": 0.0, "decode_s": 0.0, "ticks": 0,
                      "prefills": 0}

        self._tick_fn = jax.jit(self._make_tick())
        self._prefill_fn = jax.jit(self._make_prefill())

        # detector geometry: the KV sub-blocks of one scanned superblock
        main = self.cache["main"]
        self._kv_names = [n for n, sub in main.items() if "k" in sub]
        if detectors is not None:
            site = sum(
                2 * int(np.prod(main[n]["k"].shape[3:]))
                * main[n]["k"].dtype.itemsize
                for n in self._kv_names)
            detectors.bind(num_layers=model.sched.n_super, site_bytes=site)
            self._peek_fn = jax.jit(self._make_peek())

    # ---------------------------- jitted steps ------------------------
    def _make_tick(self):
        model = self.model

        def tick(params, cache, tokens, active):
            idx0 = model.cache_index(cache)            # (B,)
            logits, new_cache = model.decode_step(params, cache, tokens)
            nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            nxt = jnp.where(active[:, None], nxt[:, None], tokens)
            # idle slots freeze token AND write index: every tick rewrites
            # the same K/V site with the same value — the serving-tier
            # dead/silent store the detectors trap on
            new_cache = model.with_cache_index(
                new_cache, jnp.where(active, idx0 + 1, idx0))
            return nxt, new_cache
        return tick

    def _make_prefill(self):
        model = self.model

        def prefill(params, cache, toks, admit, lengths, prev_tokens):
            B = toks.shape[0]
            idx0 = model.cache_index(cache)
            fresh = model.with_cache_index(
                cache, jnp.zeros((B,), jnp.int32))
            logits, filled = model.prefill(params, fresh, toks)

            def sel(n, o):
                m = admit.reshape((1, -1) + (1,) * (n.ndim - 2))
                return jnp.where(m, n, o)
            merged = jax.tree_util.tree_map(sel, filled, cache)
            merged = model.with_cache_index(
                merged, jnp.where(admit, lengths, idx0))
            first = jnp.argmax(
                logits[jnp.arange(B), lengths - 1], axis=-1).astype(jnp.int32)
            toks_out = jnp.where(admit[:, None], first[:, None], prev_tokens)
            return toks_out, merged
        return prefill

    def _make_peek(self):
        names = self._kv_names

        def peek(cache, layer, slot, pos):
            outs = []
            for name in names:
                sub = cache["main"][name]
                outs.append(sub["k"][layer, slot, pos].reshape(-1))
                outs.append(sub["v"][layer, slot, pos].reshape(-1))
            return jnp.concatenate(outs).astype(jnp.float32)
        return peek

    def _peek(self, layer: int, slot: int, pos: int) -> np.ndarray:
        return np.asarray(self._peek_fn(self.cache, layer, slot, pos))

    # ------------------------------ schedule ---------------------------
    def submit(self, req: Request) -> None:
        assert req.tokens.ndim == 1 and req.tokens.size >= 1
        assert req.tokens.size < self.max_len, "prompt exceeds cache"
        assert req.max_new_tokens >= 1
        self._queue.append(req)

    @property
    def pending(self) -> int:
        return len(self._queue) + sum(r is not None for r in self.slots)

    def _accept_token(self, slot: int, req: Request, tok: int) -> None:
        req.generated.append(int(tok))
        limit = min(req.max_new_tokens,
                    self.max_len - req.tokens.size)
        if ((self.eos_id is not None and tok == self.eos_id)
                or len(req.generated) >= limit):
            req.finish_step = self.step_no
            self.finished[req.rid] = req
            self.slots[slot] = None        # recycle: slot idles until reuse
            if self.detectors is not None:
                self.detectors.on_finish(self.step_no, slot, req.rid)

    def _admit(self) -> None:
        free = [b for b, r in enumerate(self.slots) if r is None]
        group: List[Request] = []
        while free[len(group):] and self._queue \
                and self._queue[0].arrival <= self.step_no:
            group.append(self._queue.popleft())
        if not group:
            return
        B = self.num_slots
        # power-of-two padding for a bounded jit cache, capped at the
        # cache extent (prompts are < max_len by submit's contract)
        P = min(_bucket(max(r.tokens.size for r in group)), self.max_len)
        toks = np.zeros((B, P), np.int32)
        admit = np.zeros(B, bool)
        lengths = np.ones(B, np.int32)
        taken = free[:len(group)]
        for b, req in zip(taken, group):
            L = req.tokens.size
            toks[b, :L] = req.tokens
            admit[b] = True
            lengths[b] = L
            if self.detectors is not None:
                # the prefill store sweeps the full padded extent [0, P)
                self.detectors.on_admit(self.step_no, b, req.rid,
                                        req.tokens, padded_len=P)
            self.slots[b] = req
            self._lengths[b] = L
            req.prefill_step = self.step_no

        t0 = time.perf_counter()
        toks_out, self.cache = self._prefill_fn(
            self.params, self.cache, jnp.asarray(toks),
            jnp.asarray(admit), jnp.asarray(lengths), self.tokens)
        toks_out.block_until_ready()
        self.stats["prefill_s"] += time.perf_counter() - t0
        self.stats["prefill_tokens"] += int(sum(r.tokens.size
                                                for r in group))
        self.stats["prefills"] += 1
        self.tokens = toks_out
        host = np.asarray(toks_out)[:, 0]
        for b, req in zip(taken, group):
            self._accept_token(b, req, host[b])

    def _decode_tick(self) -> None:
        active = np.array([r is not None for r in self.slots])
        write_pos = self._lengths.copy()   # the position each slot writes
        t0 = time.perf_counter()
        nxt, self.cache = self._tick_fn(self.params, self.cache,
                                        self.tokens, jnp.asarray(active))
        nxt.block_until_ready()
        self.stats["decode_s"] += time.perf_counter() - t0
        self.stats["decode_tokens"] += int(active.sum())
        self.stats["ticks"] += 1
        self.tokens = nxt
        self._lengths[active] += 1
        host = np.asarray(nxt)[:, 0]
        slots_now = list(self.slots)
        for b, req in enumerate(slots_now):
            if req is not None:
                self._accept_token(b, req, host[b])
        if self.detectors is not None:
            writes = [SlotWrite(b, req.rid if req is not None else None,
                                req is not None, int(write_pos[b]))
                      for b, req in enumerate(slots_now)]
            self.detectors.on_step(self.step_no, writes, self._peek)

    def step(self) -> None:
        """One scheduler step: admit into free slots, then one decode
        tick over the whole batch."""
        self._admit()
        self._decode_tick()
        self.step_no += 1

    def run(self, max_steps: int = 100_000) -> Dict[str, Request]:
        """Drive until every submitted request has finished."""
        steps = 0
        while self.pending and steps < max_steps:
            self.step()
            steps += 1
        return self.finished

    # ---------------------------- reporting ----------------------------
    def throughput(self) -> Dict[str, float]:
        s = self.stats
        return {
            "prefill_tok_s": (s["prefill_tokens"] / s["prefill_s"]
                              if s["prefill_s"] else 0.0),
            "decode_tok_s": (s["decode_tokens"] / s["decode_s"]
                             if s["decode_s"] else 0.0),
        }

    def lowered_tick(self):
        """Lowered decode tick (Tier-2 HLO waste analysis subject)."""
        active = jnp.ones((self.num_slots,), bool)
        return self._tick_fn.lower(self.params, self.cache, self.tokens,
                                   active)
