"""Continuous-batching serving engine (DESIGN.md §2, serving tier).

Production-shaped serving over a fixed-size decode batch:

  * **Batched prefill** — a request's whole prompt fills its KV-cache row
    in ONE jitted `model.prefill` call (not `prompt_len` sequential
    decode steps). Admission groups waiting requests into one padded
    prefill; non-admitted rows are merged back untouched.
  * **Per-slot positions** — the cache write index is a (B,) vector, so
    every slot sits at its own sequence offset: requests arrive, finish
    (EOS / max-new-tokens) and recycle their slot independently while
    the batch keeps stepping.
  * **Honest accounting** — prefill and decode token counts/times are
    tracked separately, decode throughput is measured over *live* slots
    only, and the padded (wasted) prefill tokens burned by power-of-two
    prompt bucketing are counted in `stats`.
  * **Waste detection → elimination** — in the default dense layout the
    decode batch writes K/V for every slot every tick whether or not it
    serves a request, and every duplicated prompt prefix is recomputed;
    `core.detectors.ServingDetectors` traps exactly that waste. With
    ``kv_layout="paged"`` the engine ELIMINATES it (serve/kv_cache.py):
    the cache becomes a refcounted page pool with per-slot page tables,
    idle/finished slots write nothing past their page-table extent
    (Def.-1/2 stores gone), recycling frees pages instead of rewriting
    rows, and a content-digest prefix index maps a duplicated prefix's
    pages into the new slot (copy-on-write for partial pages) instead of
    re-paying its K/V compute (the Def.-3 finding becomes a cache hit).

  * **Speculative decoding** — pass a ``drafter`` (serve/spec.py) and
    every decode tick becomes draft→verify→accept: the drafter proposes
    up to ``spec_k`` tokens per live slot, ONE width-(k+1) verify
    forward (`serve.decode.make_engine_verify` over `LM.verify`) scores
    them, and the greedy-consistent prefix plus a bonus token are
    emitted — outputs bit-identical to plain decode, up to k+1 tokens
    per tick. Rejected drafts are Def.-1 dead KV stores
    (`ServingDetectors.rejected_draft_store`); with
    ``spec_rollback=True`` on the paged layout the commit stops at the
    accept point (`LM.commit_verify`) and they never reach the pool.

The jitted tick/prefill come from `serve.decode`'s step factories
(sharding-context aware, so the engine composes with `tp_serve`). The
engine needs every sub-block to carry an indexed KV cache, so it
supports the "dense" and "moe" families; other families are served by
the legacy token-loop in `launch/serve.py`.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.detectors import ServingDetectors, SlotWrite, VerifyWrite
from repro.serve.decode import (make_engine_prefill, make_engine_tick,
                                make_engine_verify)
from repro.serve.kv_cache import (PagedKV, PoolExhausted, _digest,
                                  make_page_copy)

ENGINE_FAMILIES = ("dense", "moe")
KV_LAYOUTS = ("dense", "paged")


@dataclass
class Request:
    """One serving request: prompt in, greedy continuation out."""
    rid: str
    tokens: np.ndarray                 # (L,) int32 prompt
    max_new_tokens: int = 16
    arrival: int = 0                   # earliest engine step for admission
    # (length, pages) of this prompt's prefix in THIS replica's pool,
    # leased by the fleet router from the global prefix tier at dispatch
    # (serve/global_prefix.py); the engine consumes it at admission and
    # releases the lease
    prefix_hint: Optional[Any] = None
    # filled by the engine:
    generated: List[int] = field(default_factory=list)
    prefill_step: int = -1
    finish_step: int = -1
    reuse_len: int = 0                 # cached-prefix tokens mapped in

    @property
    def done(self) -> bool:
        return self.finish_step >= 0


class MonotonicStats(dict):
    """Engine counters that can only grow.

    The fleet aggregator (serve/router.py, benchmarks) reads periodic
    snapshots and sums per-replica DELTAS, so a counter that ever
    decreased — e.g. zeroed during a recycle sweep between generations —
    silently undercounts fleet totals (`padded_prefill_tokens` across
    generations was the reported symptom). Decrements now raise instead
    of corrupting downstream accounting; `dict(stats)` snapshots keep
    working."""

    def __setitem__(self, key, value):
        cur = self.get(key)
        if (cur is not None and isinstance(cur, (int, float))
                and isinstance(value, (int, float)) and value < cur):
            raise ValueError(
                f"engine stat {key!r} may not decrease ({cur} -> {value}); "
                f"fleet aggregation reads monotonic snapshots")
        super().__setitem__(key, value)


def _bucket(n: int, lo: int = 8) -> int:
    """Pad prompt groups to power-of-two lengths: bounded jit cache."""
    p = lo
    while p < n:
        p *= 2
    return p


class ServeEngine:
    """Fixed-size decode batch + waiting queue + slot recycling."""

    def __init__(self, model, params, *, num_slots: int = 4,
                 max_len: int = 128, eos_id: Optional[int] = None,
                 detectors: Optional[ServingDetectors] = None,
                 kv_dtype=jnp.float32, kv_layout: str = "dense",
                 page_size: int = 16, num_pages: Optional[int] = None,
                 prefix_window: int = 32, strategy=None,
                 drafter=None, spec_k: int = 4,
                 spec_rollback: bool = True,
                 kernel_counters: bool = False,
                 step_cache=None,
                 registry=None, owner: str = "engine",
                 content_dedup: bool = False):
        if model.cfg.family not in ENGINE_FAMILIES:
            raise ValueError(
                f"ServeEngine needs an indexed KV cache in every block; "
                f"family {model.cfg.family!r} is served by the legacy "
                f"token-loop driver")
        if kv_layout not in KV_LAYOUTS:
            raise ValueError(f"kv_layout must be one of {KV_LAYOUTS}")
        self.model = model
        self.params = params
        self.num_slots = num_slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.detectors = detectors
        self.kv_layout = kv_layout
        self.paged = kv_layout == "paged"
        # speculative decoding: a drafter proposes up to spec_k tokens
        # per tick; one width-(k+1) verify forward accepts the greedy-
        # consistent prefix (outputs stay bit-identical to plain decode)
        self.drafter = drafter
        self.spec = drafter is not None
        if self.spec:
            assert spec_k >= 1, "spec_k must be >= 1 when drafting"
        self.spec_k = spec_k
        # rollback (paged only): rejected draft rows never reach the KV
        # pool; dense always overwrites (the measured waste, kept)
        self.spec_rollback = bool(spec_rollback) and self.paged
        # kernel tier: in-kernel store-site waste counters (paged layout
        # only — the counters ride the paged store path)
        if kernel_counters and not self.paged:
            raise ValueError("kernel_counters needs kv_layout='paged'")
        self.kernel_counters = bool(kernel_counters)
        # object tier (DESIGN.md § Object tier): every allocated page
        # registers as a live kv_page object under this engine's owner
        # name, so the fleet's ReplicaDetector can content-hash pools
        # across replicas
        self.registry = registry
        self.owner = owner
        # same-burst content dedup: an admission group member whose
        # page-aligned prefix duplicates an earlier member's is deferred
        # one tick, so the leader's register_prefix turns the duplicate
        # into an ordinary PrefixIndex hit (see _admit)
        self.content_dedup = bool(content_dedup) and self.paged

        if self.paged:
            max_pages = -(-max_len // page_size)
            if num_pages is None:
                num_pages = num_slots * max_pages
            self.kv = PagedKV(num_slots, page_size, num_pages, max_pages,
                              prefix_window=prefix_window,
                              registry=registry, owner=f"{owner}/kv")
            cache = model.init_paged_cache(
                params, num_slots, max_len, page_size=page_size,
                num_pages=num_pages, kv_dtype=kv_dtype,
                kernel_counters=self.kernel_counters)
            if registry is not None:
                # the allocator registers pages; it needs the pool's
                # per-page byte size and a live-content reader, both
                # only known once the device cache exists
                a = self.kv.alloc
                a.page_bytes = sum(
                    (sub[key].nbytes // num_pages)
                    for sub in cache["main"].values() if "pt" in sub
                    for key in ("k", "v"))
                a.page_reader = self._read_page
            self._copy_fn = (step_cache.get("page_copy")
                             if step_cache is not None
                             else jax.jit(make_page_copy()))
        else:
            self.kv = None
            cache = model.init_cache(params, num_slots, max_len,
                                     kv_dtype=kv_dtype)
        self.cache = model.with_cache_index(
            cache, jnp.zeros((num_slots,), jnp.int32))
        self.tokens = jnp.zeros((num_slots, 1), jnp.int32)
        if self.spec and registry is not None:
            # the drafter's corpus is the engine's long-lived draft
            # window: replicas that served the same traffic hold
            # bit-identical copies (replica_draft_window)
            registry.register(
                f"{owner}/draft/window", "draft_window",
                num_slots * (self.spec_k + 1) * 4,
                reader=self._read_draft_window)

        self.slots: List[Optional[Request]] = [None] * num_slots
        self._lengths = np.zeros(num_slots, np.int64)  # host mirror of idx
        self._queue: Deque[Request] = deque()
        self.finished: Dict[str, Request] = {}
        self.step_no = 0
        self.stats = MonotonicStats(
            {"prefill_tokens": 0, "decode_tokens": 0,
             "prefill_s": 0.0, "decode_s": 0.0, "ticks": 0,
             "prefills": 0,
             # prompt tokens actually pushed through the model
             # (< prefill_tokens when prefixes hit the cache)
             "prefill_computed_tokens": 0,
             # padded-garbage positions the bucketed prefill
             # burned (whole-batch sweep minus useful suffixes)
             "padded_prefill_tokens": 0,
             "prefix_hits": 0, "prefix_hit_tokens": 0,
             "cow_copies": 0, "pages_freed": 0,
             # admissions pushed back by pool pressure (the router's
             # preemption signal: it frees global-prefix pins and the
             # deferred request retries next tick)
             "admit_deferred": 0,
             # admissions pushed back ONE tick by content dedup so a
             # same-burst duplicate prefix admits as an index hit
             # instead of being recomputed into replica pages
             "dedup_deferred": 0,
             # speculative decode accounting
             "spec_ticks": 0, "draft_proposed": 0,
             "draft_accepted": 0, "draft_s": 0.0,
             "verify_s": 0.0, "verified_positions": 0})

        if step_cache is not None:
            assert step_cache.model is model, \
                "step_cache was built for a different model"
            self._tick_fn = step_cache.get("tick", paged=self.paged)
            self._prefill_fn = step_cache.get("prefill", paged=self.paged)
            self._verify_fn = step_cache.get(
                "verify", paged=self.paged,
                rollback=self.spec_rollback) if self.spec else None
        else:
            self._tick_fn = jax.jit(
                make_engine_tick(model, strategy, paged=self.paged))
            self._prefill_fn = jax.jit(
                make_engine_prefill(model, strategy, paged=self.paged))
            self._verify_fn = jax.jit(make_engine_verify(
                model, strategy, paged=self.paged,
                rollback=self.spec_rollback)) if self.spec else None

        # detector geometry: the KV sub-blocks of one scanned superblock
        main = self.cache["main"]
        self._kv_names = [n for n, sub in main.items() if "k" in sub]
        if detectors is not None:
            site = sum(
                2 * int(np.prod(main[n]["k"].shape[3:]))
                * main[n]["k"].dtype.itemsize
                for n in self._kv_names)
            detectors.bind(
                num_layers=model.sched.n_super, site_bytes=site,
                paged=self.paged,
                kv_itemsize=main[self._kv_names[0]]["k"].dtype.itemsize,
                row_elems={n: 2 * int(np.prod(main[n]["k"].shape[3:]))
                           for n in self._kv_names})
            self._peek_fn = jax.jit(self._make_peek())

    # ---------------------------- jitted steps ------------------------
    def _make_peek(self):
        names = self._kv_names

        def peek(cache, layer, page, off):
            # dense layout: (L, B, S, Hkv, D) — page is the slot row;
            # paged layout: (L, P, page_size, Hkv, D) — the pool page.
            outs = []
            for name in names:
                sub = cache["main"][name]
                outs.append(sub["k"][layer, page, off].reshape(-1))
                outs.append(sub["v"][layer, page, off].reshape(-1))
            return jnp.concatenate(outs).astype(jnp.float32)
        return peek

    def _peek(self, layer: int, page: int, off: int) -> np.ndarray:
        return np.asarray(self._peek_fn(self.cache, layer, page, off))

    # --------------------------- object tier ---------------------------
    def _read_page(self, p: int) -> np.ndarray:
        """Live contents of pool page `p` across every paged KV
        sub-block, flat uint8 — the replica detector's content reader
        (reads self.cache at call time, so it tracks the functional
        cache updates)."""
        chunks = []
        for sub in self.cache["main"].values():
            if "pt" not in sub:
                continue
            for key in ("k", "v"):
                a = np.ascontiguousarray(np.asarray(sub[key][:, p]))
                chunks.append(np.frombuffer(a.tobytes(), np.uint8))
        return (np.concatenate(chunks) if chunks
                else np.zeros(0, np.uint8))

    def _read_draft_window(self) -> np.ndarray:
        corpus = (getattr(self.drafter, "_corpus", None)
                  or getattr(self.drafter, "_seqs", None) or [])
        arrs = [np.asarray(a, np.int32).ravel() for a in corpus]
        return (np.concatenate(arrs) if arrs else np.zeros(0, np.int32))

    def _read_kernel_counts(self):
        """The last jitted forward's in-kernel [stored, silent, dropped]
        element counts, per KV sub-block, as (L, B, 3) host arrays —
        or None when the kernel tier is off / unobserved."""
        if not self.kernel_counters or self.detectors is None:
            return None
        counts = self.model.kernel_counters(self.cache)
        if counts is None:
            return None
        return {n: np.asarray(c) for n, c in counts.items()}

    def _emit_kernel_store(self, site: str) -> None:
        counts = self._read_kernel_counts()
        if counts is not None:
            self.detectors.on_kernel_store(self.step_no, site, counts)

    # ------------------------------ schedule ---------------------------
    def submit(self, req: Request) -> None:
        assert req.tokens.ndim == 1 and req.tokens.size >= 1
        assert req.tokens.size < self.max_len, "prompt exceeds cache"
        assert req.max_new_tokens >= 1
        self._queue.append(req)

    @property
    def pending(self) -> int:
        return len(self._queue) + sum(r is not None for r in self.slots)

    @property
    def queue_depth(self) -> int:
        return len(self._queue)

    @property
    def live_slots(self) -> int:
        return sum(r is not None for r in self.slots)

    def _note_freed(self, freed: List[int]) -> None:
        """Every page-freeing path goes through here: count the frees
        AND disarm the detectors' now-stale traps on them."""
        self.stats["pages_freed"] += len(freed)
        if self.detectors is not None and freed:
            self.detectors.on_page_free(freed)

    def note_freed(self, freed: List[int]) -> None:
        """Pages freed by an EXTERNAL holder of this replica's pool —
        the fleet's global prefix tier dropping its pins — still need
        their frees counted and their stale traps disarmed here."""
        self._note_freed([int(p) for p in freed])

    def _accept_token(self, slot: int, req: Request, tok: int) -> None:
        req.generated.append(int(tok))
        limit = min(req.max_new_tokens,
                    self.max_len - req.tokens.size)
        if ((self.eos_id is not None and tok == self.eos_id)
                or len(req.generated) >= limit):
            req.finish_step = self.step_no
            self.finished[req.rid] = req
            self.slots[slot] = None        # recycle: slot idles until reuse
            if self.drafter is not None:
                # self-speculation corpus: a served sequence is future
                # draft material (duplicated traffic drafts itself)
                self.drafter.observe(np.concatenate(
                    [req.tokens, np.asarray(req.generated, np.int32)]))
            if self.paged:
                # recycling frees pages instead of leaving rows to be
                # silently rewritten; prefix-index pins keep shared
                # pages. The device page table is synced lazily at the
                # next _admit: a finished slot's writes are already
                # dropped by the idle index sentinel, and freed pages
                # are only re-mapped by an admission (which pushes the
                # fresh table before its prefill).
                self._note_freed(self.kv.free_slot(slot))
            if self.detectors is not None:
                self.detectors.on_finish(self.step_no, slot, req.rid)

    def _dedup_group(self, group: List[Request]) -> List[Request]:
        """Content-addressed same-burst dedup (OJXPerf replica fix).

        Requests admitted in ONE group share a single prefill and only
        register their prefixes AFTER it, so two same-tick arrivals with
        a common prompt prefix each compute it into their own pages —
        the bit-identical kv_page replicas the detector flags even
        though the PrefixIndex "works". Defer every member whose
        page-aligned prefix digest duplicates an earlier member's beyond
        what the index (or a fleet lease) already covers: next tick the
        leader's register_prefix has landed and the duplicate admits as
        an ordinary prefix hit sharing the leader's pages. Outputs stay
        bit-identical — the follower merely starts one tick later."""
        ps = self.kv.page_size
        keep: List[Request] = []
        deferred: List[Request] = []
        seen: Dict[str, int] = {}      # page-aligned prefix digest key
        for req in group:
            toks = np.asarray(req.tokens)
            keys = [f"{m}:{_digest(toks[:m])}"
                    for m in range(ps, int(toks.size), ps)]
            best = max((m for m, k in zip(
                range(ps, int(toks.size), ps), keys) if k in seen),
                default=0)
            have = self.kv.index.match(toks)[0]
            if req.prefix_hint is not None:
                have = max(have, int(req.prefix_hint[0]))
            if best > have:
                req.arrival = self.step_no + 1
                deferred.append(req)
                self.stats["dedup_deferred"] += 1
            else:
                keep.append(req)
                seen.update((k, 1) for k in keys)
        if deferred:
            self._queue.extendleft(reversed(deferred))
        return keep

    def _admit(self) -> None:
        free = [b for b, r in enumerate(self.slots) if r is None]
        group: List[Request] = []
        while free[len(group):] and self._queue \
                and self._queue[0].arrival <= self.step_no:
            group.append(self._queue.popleft())
        if self.content_dedup and len(group) > 1:
            group = self._dedup_group(group)
        if not group:
            return
        B = self.num_slots
        admit = np.zeros(B, bool)
        starts = np.zeros(B, np.int32)
        lengths = np.ones(B, np.int32)
        taken: List[int] = []
        plans: Dict[int, Any] = {}
        admitted: List[Request] = []
        for b, req in zip(free, group):
            L = req.tokens.size
            if self.paged:
                budget = min(req.max_new_tokens, self.max_len - L)
                try:
                    plan = self.kv.admit(b, req.tokens, budget,
                                         hint=req.prefix_hint)
                except PoolExhausted as e:
                    # pool pressure: defer this (and following) requests;
                    # pages the failed eviction pass DID free still need
                    # their stale traps disarmed. The dispatch lease (if
                    # any) stays held for the retry.
                    self._note_freed(e.freed)
                    self.stats["admit_deferred"] += 1
                    self._queue.extendleft(
                        reversed(group[len(admitted):]))
                    break
                if req.prefix_hint is not None:
                    # admit pinned whatever it mapped; the dispatch-time
                    # lease has done its job
                    self._note_freed(self.kv.release(req.prefix_hint[1]))
                    req.prefix_hint = None
                plans[b] = plan
                starts[b] = plan.reuse_len
                req.reuse_len = plan.reuse_len
                if plan.reuse_len:
                    self.stats["prefix_hits"] += 1
                    self.stats["prefix_hit_tokens"] += plan.reuse_len
                self.stats["cow_copies"] += len(plan.cow)
                self._note_freed(plan.freed)
            admit[b] = True
            lengths[b] = L
            taken.append(b)
            admitted.append(req)
            self.slots[b] = req
            self._lengths[b] = L
            req.prefill_step = self.step_no
        if not admitted:
            return

        # power-of-two padding of the group's (suffix) lengths for a
        # bounded jit cache, capped at the cache extent
        suffixes = [int(lengths[b] - starts[b]) for b in taken]
        P = min(_bucket(max(suffixes)), self.max_len)
        toks = np.zeros((B, P), np.int32)
        for b, req in zip(taken, admitted):
            suf = req.tokens[int(starts[b]):]
            toks[b, :suf.size] = suf
            if self.detectors is not None:
                # dense: the prefill store sweeps the padded extent [0,P)
                # of the slot's row; paged: only freshly-owned pages are
                # written, so there is no stale-row sweep to trap
                self.detectors.on_admit(
                    self.step_no, b, req.rid, req.tokens,
                    padded_len=None if self.paged else P,
                    reuse_len=int(starts[b]))

        if self.paged:
            self.cache = self.model.with_page_table(self.cache, self.kv.pt)
            cows = [c for b in taken for c in plans[b].cow]
            if cows:
                # copy-on-write of partially reused pages, padded to the
                # slot count so one compiled shape serves every group
                src = np.full(B, 0, np.int32)
                dst = np.full(B, self.kv.num_pages, np.int32)  # dropped
                for i, (s, d) in enumerate(cows):
                    src[i], dst[i] = s, d
                self.cache = self._copy_fn(self.cache, jnp.asarray(src),
                                           jnp.asarray(dst))
            # the copy consumed the COW sources (value semantics: this
            # cache already holds the copied rows) — drop their pins
            for b in taken:
                self._note_freed(self.kv.release(plans[b].cow_pins))

        t0 = time.perf_counter()
        toks_out, self.cache = self._prefill_fn(
            self.params, self.cache, jnp.asarray(toks),
            jnp.asarray(admit), jnp.asarray(starts), jnp.asarray(lengths),
            self.tokens)
        toks_out.block_until_ready()
        self.stats["prefill_s"] += time.perf_counter() - t0
        self.stats["prefill_tokens"] += int(sum(r.tokens.size
                                                for r in admitted))
        self.stats["prefill_computed_tokens"] += int(sum(suffixes))
        self.stats["padded_prefill_tokens"] += B * P - int(sum(suffixes))
        self.stats["prefills"] += 1
        self.tokens = toks_out
        self._emit_kernel_store("prefill")
        if self.paged:
            for b, req in zip(taken, admitted):
                self._note_freed(self.kv.register_prefix(b, req.tokens))
        host = np.asarray(toks_out)[:, 0]
        for b, req in zip(taken, admitted):
            self._accept_token(b, req, host[b])

    def _decode_tick(self) -> None:
        if self.spec:
            self._spec_tick()
            return
        active = np.array([r is not None for r in self.slots])
        write_pos = self._lengths.copy()   # the position each slot writes
        t0 = time.perf_counter()
        nxt, self.cache = self._tick_fn(self.params, self.cache,
                                        self.tokens, jnp.asarray(active))
        nxt.block_until_ready()
        self.stats["decode_s"] += time.perf_counter() - t0
        self.stats["decode_tokens"] += int(active.sum())
        self.stats["ticks"] += 1
        self.tokens = nxt
        self._emit_kernel_store("decode")
        self._lengths[active] += 1
        host = np.asarray(nxt)[:, 0]
        slots_now = list(self.slots)
        for b, req in enumerate(slots_now):
            if req is not None:
                self._accept_token(b, req, host[b])
        self._report_tick_writes(slots_now, write_pos)

    def _report_tick_writes(self, slots_now, write_pos) -> None:
        """Tier-3 reporting of one tick's first-position K/V stores."""
        if self.detectors is None:
            return
        writes = []
        for b, req in enumerate(slots_now):
            pos = int(write_pos[b])
            if self.paged:
                # idle slots write NOTHING in the paged layout — the
                # scatter dropped their store, so there is no event;
                # a slot that just finished freed its pages (site
                # lookup comes back unmapped) and is skipped too
                if req is None:
                    continue
                page, off = self.kv.site(b, pos)
                if page < 0:
                    continue
            else:
                page, off = b, pos
            writes.append(SlotWrite(b, req.rid if req is not None
                                    else None, req is not None, pos,
                                    page=page, offset=off))
        self.detectors.on_step(self.step_no, writes, self._peek)

    # ------------------------- speculative tick -----------------------
    def _draft_cap(self, slot: int, req: Request) -> int:
        """Drafts worth proposing for this slot: bounded by spec_k, the
        request's remaining generation allowance (the tick's last token
        is the bonus, so remaining-1 drafts suffice), and — in the paged
        layout — the slot's mapped page-table extent, so an accepted
        draft can never land on an unmapped position."""
        limit = min(req.max_new_tokens, self.max_len - req.tokens.size)
        cap = min(self.spec_k, limit - len(req.generated) - 1)
        pos0 = int(self._lengths[slot])
        if self.paged:
            cap = min(cap, self.kv.slot_extent(slot) - pos0 - 1)
        else:
            cap = min(cap, self.max_len - pos0 - 1)
        return max(0, cap)

    def _spec_tick(self) -> None:
        """One draft→verify→accept step over the whole batch.

        The drafter proposes up to spec_k tokens per live slot (host
        side); ONE width-(k+1) verify forward scores them all; the
        greedy-consistent prefix plus the bonus token are emitted — up
        to spec_k+1 tokens per slot per tick, bit-identical to plain
        decode. With rollback (paged) the rejected rows never reach the
        pool; otherwise they are stored and overwritten — the Def.-1
        dead stores `ServingDetectors.rejected_draft_store` counts."""
        B, W = self.num_slots, self.spec_k + 1
        active = np.array([r is not None for r in self.slots])
        write_pos = self._lengths.copy()
        toks = np.zeros((B, W), np.int32)
        toks[:, 0] = np.asarray(self.tokens)[:, 0]
        dlen = np.zeros(B, np.int32)
        t0 = time.perf_counter()
        for b, req in enumerate(self.slots):
            if req is None:
                continue
            cap = self._draft_cap(b, req)
            if cap <= 0:
                continue
            hist = np.concatenate(
                [req.tokens, np.asarray(req.generated, np.int32)])
            d = np.asarray(self.drafter.propose(hist, cap),
                           np.int32).reshape(-1)[:cap]
            dlen[b] = d.size
            toks[b, 1:1 + d.size] = d
        self.stats["draft_s"] += time.perf_counter() - t0

        t0 = time.perf_counter()
        g, m, nxt, self.cache = self._verify_fn(
            self.params, self.cache, jnp.asarray(toks),
            jnp.asarray(active), jnp.asarray(dlen))
        nxt.block_until_ready()
        dt = time.perf_counter() - t0
        self.stats["verify_s"] += dt
        self.stats["decode_s"] += dt
        self.stats["ticks"] += 1
        self.stats["spec_ticks"] += 1
        self.stats["draft_proposed"] += int(dlen[active].sum())
        self.stats["verified_positions"] += int(active.sum()) * W
        g = np.asarray(g)
        m = np.asarray(m)
        self.stats["draft_accepted"] += int(m[active].sum())
        self.tokens = nxt
        counts = self._read_kernel_counts()
        if counts is not None:
            # overwrite mode: the verify forward's full-window stores;
            # rollback: the commit's accepted-prefix stores (the deferred
            # window stored nothing) — classification against m happens
            # in the detector, measurement stays in-kernel
            self.detectors.on_kernel_verify(self.step_no, counts, m, dlen,
                                            active)
        self._lengths[active] += 1 + m[active]

        slots_now = list(self.slots)
        emitted = 0
        for b, req in enumerate(slots_now):
            if req is None:
                continue
            # emit the accepted chain + bonus; stop at EOS/limit so the
            # output stream is exactly the plain-decode stream
            for j in range(int(m[b]) + 1):
                emitted += 1
                self._accept_token(b, req, int(g[b, j]))
                if req.done:
                    break
        self.stats["decode_tokens"] += emitted

        self._report_tick_writes(slots_now, write_pos)
        if self.detectors is not None:
            entries = []
            for b, req in enumerate(slots_now):
                if req is None or not active[b]:
                    continue
                pos0 = int(write_pos[b])
                # draft rows attributed to the drafter this tick: every
                # PROPOSED row in overwrite mode (so the fraction is
                # exactly 1 - accept-rate), only the accepted prefix
                # under rollback. Overwrite also stores the fixed-width
                # window's padding rows past dlen — dead too, but not
                # the drafter's waste, so they stay out of this site
                n_written = int(m[b]) if self.spec_rollback \
                    else int(dlen[b])
                sites = []
                for j in range(1, n_written + 1):
                    pos = pos0 + j
                    if self.paged:
                        page, off = self.kv.site(b, pos)
                        if page < 0:
                            continue
                    else:
                        if pos >= self.max_len:
                            continue
                        page, off = b, pos
                    sites.append((page, off, j > int(m[b])))
                entries.append(VerifyWrite(b, req.rid, int(m[b]), sites))
            self.detectors.on_verify(self.step_no, entries)

    def step(self) -> None:
        """One scheduler step: admit into free slots, then one decode
        tick over the whole batch."""
        self._admit()
        self._decode_tick()
        self.step_no += 1

    def run(self, max_steps: int = 100_000) -> Dict[str, Request]:
        """Drive until every submitted request has finished."""
        steps = 0
        while self.pending and steps < max_steps:
            self.step()
            steps += 1
        return self.finished

    # ---------------------------- reporting ----------------------------
    def throughput(self) -> Dict[str, float]:
        s = self.stats
        out = {
            "prefill_tok_s": (s["prefill_tokens"] / s["prefill_s"]
                              if s["prefill_s"] else 0.0),
            "decode_tok_s": (s["decode_tokens"] / s["decode_s"]
                             if s["decode_s"] else 0.0),
        }
        if self.spec:
            out["draft_tok_s"] = (s["draft_proposed"] / s["draft_s"]
                                  if s["draft_s"] else 0.0)
            out["verify_tok_s"] = (s["verified_positions"] / s["verify_s"]
                                   if s["verify_s"] else 0.0)
            out["accept_rate"] = (s["draft_accepted"] / s["draft_proposed"]
                                  if s["draft_proposed"] else 0.0)
        return out

    def lowered_tick(self):
        """Lowered decode tick (Tier-2 HLO waste analysis subject)."""
        active = jnp.ones((self.num_slots,), bool)
        return self._tick_fn.lower(self.params, self.cache, self.tokens,
                                   active)
