"""Flash-decoding under shard_map: sequence-chunk-sharded KV cache.

GSPMD's automatic plan for one-token decode against a seq-sharded cache
all-gathers the full K/V per layer (measured: 2 GB/layer/token at qwen3
scale — 56 GB/device/token). The manual plan is textbook flash-decoding:

  * the cache stays sharded in sequence chunks over `seq_axes`;
  * the new token's K/V row is written by the one shard that owns slot
    `idx` (clipped-index DUS — O(1) work, no copies, no gathers);
  * every shard computes partial attention over its chunk with a running
    max/denominator, and partials combine with one tiny pmax+psum.

Works for any head count, any batch, any cache length (incl. 500k), and
is exact (same math as ref.attention_ref).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as PS

from repro.train.fused_xent import shard_map  # version-compat wrapper


def _axis_index(names: Tuple[str, ...], mesh) -> jax.Array:
    idx = jnp.zeros((), jnp.int32)
    for n in names:
        idx = idx * mesh.shape[n] + jax.lax.axis_index(n)
    return idx


def _pallas_mode() -> Tuple[bool, bool]:
    """(use Pallas kernels inside the shard bodies, interpret mode)."""
    from repro.kernels import ops
    return ops._use_pallas(), ops._pallas_interpret()


def _lse_combine(o_l, lse, seq_axes, out_dtype):
    """Flash-decoding cross-shard combine from per-shard normalized
    outputs + log-sum-exp: out = Σ_i e^{lse_i - max} o_i / Σ_i e^{lse_i
    - max}. Idle slots (all lse = -inf) come back zero, no NaNs.
    o_l: (..., D) with lse broadcastable to o_l.shape[:-1]."""
    gm = jax.lax.pmax(lse, seq_axes)
    w = jnp.exp(lse - gm)
    den = jax.lax.psum(w, seq_axes)
    num = jax.lax.psum(o_l.astype(jnp.float32) * w[..., None], seq_axes)
    return (num / jnp.maximum(den, 1e-30)[..., None]).astype(out_dtype)


def decode_attention_sharded(q, k_new, v_new, ck, cv, idx, *, mesh,
                             batch_axes: Tuple[str, ...],
                             seq_axes: Tuple[str, ...]):
    """q: (B,1,Hq,D); k_new/v_new: (B,1,Hkv,D); ck/cv: (B,S,Hkv,D);
    idx: scalar int32 (write position == number of valid tokens so far).
    Returns (out (B,1,Hq,D), new_ck, new_cv)."""
    B, S = ck.shape[0], ck.shape[1]
    Hq, D = q.shape[2], q.shape[3]
    Hkv = ck.shape[2]
    G = Hq // Hkv
    n_seq = int(np.prod([mesh.shape[a] for a in seq_axes]))
    chunk = S // n_seq
    scale = 1.0 / np.sqrt(D)

    b = batch_axes if batch_axes else None
    q_spec = PS(b, None, None, None)
    c_spec = PS(b, seq_axes, None, None)

    def local(q_l, kn, vn, ck_l, cv_l, idx_l):
        f32 = jnp.float32
        off = _axis_index(seq_axes, mesh) * chunk
        lpos = idx_l - off
        in_r = (lpos >= 0) & (lpos < chunk)
        li = jnp.clip(lpos, 0, chunk - 1)
        # write (or harmlessly rewrite) one row
        row_k = jax.lax.dynamic_slice_in_dim(ck_l, li, 1, 1)
        row_v = jax.lax.dynamic_slice_in_dim(cv_l, li, 1, 1)
        row_k = jnp.where(in_r, kn.astype(ck_l.dtype), row_k)
        row_v = jnp.where(in_r, vn.astype(cv_l.dtype), row_v)
        ck_n = jax.lax.dynamic_update_slice_in_dim(ck_l, row_k, li, 1)
        cv_n = jax.lax.dynamic_update_slice_in_dim(cv_l, row_v, li, 1)

        # local partial attention over my chunk
        qg = q_l.reshape(q_l.shape[0], Hkv, G, D)
        s = jnp.einsum("bhgd,bkhd->bhgk", qg, ck_n.astype(q_l.dtype),
                       preferred_element_type=f32) * scale
        pos = off + jnp.arange(chunk)
        valid = pos <= idx_l                       # includes the new token
        s = jnp.where(valid[None, None, None, :], s, -1e30)
        m = jnp.max(s, axis=-1)                    # (b,h,g)
        p = jnp.exp(s - m[..., None])
        l = jnp.sum(p, axis=-1)
        o = jnp.einsum("bhgk,bkhd->bhgd", p, cv_n.astype(q_l.dtype),
                       preferred_element_type=f32)
        # combine across seq shards (flash-decoding reduction)
        gm = jax.lax.pmax(m, seq_axes)
        corr = jnp.exp(m - gm)
        l = jax.lax.psum(l * corr, seq_axes)
        o = jax.lax.psum(o * corr[..., None], seq_axes)
        out = (o / jnp.maximum(l, 1e-30)[..., None]).astype(q_l.dtype)
        return out.reshape(q_l.shape[0], 1, Hq, D), ck_n, cv_n

    fn = shard_map(local, mesh,
                   (q_spec, q_spec, q_spec, c_spec, c_spec, PS()),
                   (q_spec, c_spec, c_spec))
    return fn(q, k_new, v_new, ck, cv, idx)


def decode_paged_attention_sharded(q, k_new, v_new, ck, cv, pt, idx, *,
                                   mesh, batch_axes: Tuple[str, ...],
                                   seq_axes: Tuple[str, ...]):
    """Flash-decoding over a block-paged KV pool (serve/kv_cache.py).

    q: (B,1,Hq,D); k_new/v_new: (B,1,Hkv,D); ck/cv: (P,page,Hkv,D) page
    pool sharded in page chunks over `seq_axes`; pt: (B,M) page table
    (-1 = unmapped); idx: (B,) per-slot write positions (negative =
    idle, store dropped). Each shard scatters the one new row it owns
    through the page table, gathers its locally-owned pages into the
    logical per-slot view under a page-table-aware ownership mask, and
    the partials combine with the same pmax+psum flash reduction as the
    dense path. Returns (out (B,1,Hq,D), new_ck, new_cv)."""
    P, ps = ck.shape[0], ck.shape[1]
    Hq, D = q.shape[2], q.shape[3]
    Hkv = ck.shape[2]
    G = Hq // Hkv
    M = pt.shape[1]
    n_seq = int(np.prod([mesh.shape[a] for a in seq_axes]))
    chunk = P // n_seq                 # pages per shard
    scale = 1.0 / np.sqrt(D)
    use_pallas, interp = _pallas_mode()

    b = batch_axes if batch_axes else None
    q_spec = PS(b, None, None, None)
    pool_spec = PS(seq_axes, None, None, None)
    pt_spec = PS(b, None)
    idx_spec = PS(b)

    def local(q_l, kn, vn, ck_l, cv_l, pt_l, idx_l):
        f32 = jnp.float32
        off = _axis_index(seq_axes, mesh) * chunk
        # -- store: route the new row through the page table; only the
        # shard owning the target page writes (others — and idle slots
        # with negative positions or unmapped pages — drop)
        pi = jnp.floor_divide(idx_l, ps)
        page = jnp.where(
            (pi >= 0) & (pi < M),
            jnp.take_along_axis(pt_l, jnp.clip(pi, 0, M - 1)[:, None],
                                axis=1)[:, 0], -1)
        lp = page - off
        own_w = (page >= 0) & (lp >= 0) & (lp < chunk) & (idx_l >= 0)
        flat = jnp.where(own_w, lp * ps + jnp.remainder(idx_l, ps),
                         chunk * ps)

        def scat(pool, new):
            fp = pool.reshape((chunk * ps,) + pool.shape[2:])
            fp = fp.at[flat].set(new[:, 0].astype(pool.dtype), mode="drop")
            return fp.reshape(pool.shape)
        ck_n = scat(ck_l, kn)
        cv_n = scat(cv_l, vn)

        # -- gather: the slot's logical view from locally-owned pages
        lpt = pt_l - off                              # (B', M)
        owned = (pt_l >= 0) & (lpt >= 0) & (lpt < chunk)

        if use_pallas:
            # Pallas fast path: the decode kernel chases the LOCALIZED
            # page table (-1 on pages this shard does not own) so the
            # logical-view gather never materializes; partials combine
            # with the kernel's per-(slot, head) lse. Counters are
            # polluted by non-owner shards and ignored — the engine's
            # sharded path counts stores host-side (layers._finish).
            from repro.kernels.paged_attention import paged_decode_attention
            o_l, lse, _ = paged_decode_attention(
                q_l, kn, vn, ck_n, cv_n, jnp.where(owned, lpt, -1), idx_l,
                interpret=interp)
            out = _lse_combine(o_l, lse[:, None, :], seq_axes, q_l.dtype)
            return out, ck_n, cv_n

        kg = jnp.take(ck_n, jnp.clip(lpt, 0, chunk - 1), axis=0)
        vg = jnp.take(cv_n, jnp.clip(lpt, 0, chunk - 1), axis=0)
        Bl = pt_l.shape[0]
        kg = kg.reshape(Bl, M * ps, Hkv, D)
        vg = vg.reshape(Bl, M * ps, Hkv, D)
        pos = jnp.arange(M * ps)
        valid = (jnp.repeat(owned, ps, axis=1)
                 & (pos[None, :] <= idx_l[:, None]))  # incl. the new token

        # -- local partial attention + flash-decoding combine
        qg = q_l.reshape(Bl, Hkv, G, D)
        s = jnp.einsum("bhgd,bkhd->bhgk", qg, kg.astype(q_l.dtype),
                       preferred_element_type=f32) * scale
        s = jnp.where(valid[:, None, None, :], s, -1e30)
        m = jnp.max(s, axis=-1)
        p = jnp.exp(s - m[..., None])
        l = jnp.sum(p, axis=-1)
        o = jnp.einsum("bhgk,bkhd->bhgd", p, vg.astype(q_l.dtype),
                       preferred_element_type=f32)
        gm = jax.lax.pmax(m, seq_axes)
        corr = jnp.exp(m - gm)
        l = jax.lax.psum(l * corr, seq_axes)
        o = jax.lax.psum(o * corr[..., None], seq_axes)
        out = (o / jnp.maximum(l, 1e-30)[..., None]).astype(q_l.dtype)
        return out.reshape(Bl, 1, Hq, D), ck_n, cv_n

    fn = shard_map(local, mesh,
                   (q_spec, q_spec, q_spec, pool_spec, pool_spec,
                    pt_spec, idx_spec),
                   (q_spec, pool_spec, pool_spec))
    return fn(q, k_new, v_new, ck, cv, pt, idx)


def verify_paged_attention_sharded(q, k_new, v_new, ck, cv, pt, idx, *,
                                   mesh, batch_axes: Tuple[str, ...],
                                   seq_axes: Tuple[str, ...]):
    """Width-k speculative verify over a block-paged KV pool.

    The width-W generalization of `decode_paged_attention_sharded`
    (LM.verify's sharded fast path): q: (B,W,Hq,D) queries at logical
    positions idx[b]..idx[b]+W-1; k_new/v_new: (B,W,Hkv,D) the window's
    K/V; ck/cv: (P,page,Hkv,D) pool sharded in page chunks over
    `seq_axes`; pt: (B,M) page table; idx: (B,) per-slot window starts
    (negative = idle, stores drop). Each shard scatters the window rows
    whose pages it owns, gathers its owned pages into the logical view,
    masks per QUERY (position idx+i attends pos <= idx+i — the in-window
    causal chain), and partials combine with the same pmax+psum flash
    reduction. Returns (out (B,W,Hq,D), new_ck, new_cv)."""
    P, ps = ck.shape[0], ck.shape[1]
    B, W = q.shape[0], q.shape[1]
    Hq, D = q.shape[2], q.shape[3]
    Hkv = ck.shape[2]
    G = Hq // Hkv
    M = pt.shape[1]
    n_seq = int(np.prod([mesh.shape[a] for a in seq_axes]))
    chunk = P // n_seq                 # pages per shard
    scale = 1.0 / np.sqrt(D)
    use_pallas, interp = _pallas_mode()

    b = batch_axes if batch_axes else None
    q_spec = PS(b, None, None, None)
    pool_spec = PS(seq_axes, None, None, None)
    pt_spec = PS(b, None)
    idx_spec = PS(b)

    def local(q_l, kn, vn, ck_l, cv_l, pt_l, idx_l):
        f32 = jnp.float32
        off = _axis_index(seq_axes, mesh) * chunk
        Bl = pt_l.shape[0]

        if use_pallas:
            # Pallas fast path: the fused window kernel on the LOCALIZED
            # page table does the whole shard body — its store epilogue
            # writes exactly the window rows whose pages this shard owns
            # (store-mode window validity = "target page mapped", which
            # under the localized table means locally owned, so every
            # window row is attended and stored by exactly one shard),
            # its committed-history sweep covers the owned pages, and
            # the per-(slot, head, query) lse drives the cross-shard
            # combine. Counters are ignored here — the engine's sharded
            # path counts stores host-side (layers._finish).
            from repro.kernels.flash_prefill import paged_window_attention
            lpt = pt_l - off
            owned = (pt_l >= 0) & (lpt >= 0) & (lpt < chunk)
            o_l, lse, _, ck_n, cv_n = paged_window_attention(
                q_l, kn, vn, ck_l, cv_l, jnp.where(owned, lpt, -1), idx_l,
                store=True, interpret=interp)
            # lse: (B', Hq, W) -> (B', W, Hq) to match o_l
            out = _lse_combine(o_l, lse.transpose(0, 2, 1), seq_axes,
                               q_l.dtype)
            return out, ck_n, cv_n

        # -- store: route every window row through the page table; only
        # the shard owning the target page writes, everything else drops
        pos = idx_l[:, None] + jnp.arange(W)[None, :]        # (B', W)
        pi = jnp.floor_divide(pos, ps)
        page = jnp.where(
            (pi >= 0) & (pi < M),
            jnp.take_along_axis(pt_l, jnp.clip(pi, 0, M - 1), axis=1), -1)
        lp = page - off
        own_w = (page >= 0) & (lp >= 0) & (lp < chunk) & (pos >= 0)
        flat = jnp.where(own_w, lp * ps + jnp.remainder(pos, ps),
                         chunk * ps)

        def scat(pool, new):
            fp = pool.reshape((chunk * ps,) + pool.shape[2:])
            fp = fp.at[flat.reshape(-1)].set(
                new.reshape((-1,) + new.shape[2:]).astype(pool.dtype),
                mode="drop")
            return fp.reshape(pool.shape)
        ck_n = scat(ck_l, kn)
        cv_n = scat(cv_l, vn)

        # -- gather: the slot's logical view from locally-owned pages
        lpt = pt_l - off                                     # (B', M)
        owned = (pt_l >= 0) & (lpt >= 0) & (lpt < chunk)
        kg = jnp.take(ck_n, jnp.clip(lpt, 0, chunk - 1), axis=0)
        vg = jnp.take(cv_n, jnp.clip(lpt, 0, chunk - 1), axis=0)
        kg = kg.reshape(Bl, M * ps, Hkv, D)
        vg = vg.reshape(Bl, M * ps, Hkv, D)
        kpos = jnp.arange(M * ps)
        # per-query validity: query i at logical pos idx+i sees owned
        # positions <= idx+i (committed history + window rows <= i)
        valid = (jnp.repeat(owned, ps, axis=1)[:, None, :]
                 & (kpos[None, None, :] <= pos[:, :, None]))  # (B',W,Skv)

        # -- local partial attention + flash-decoding combine
        qg = q_l.reshape(Bl, W, Hkv, G, D)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kg.astype(q_l.dtype),
                       preferred_element_type=f32) * scale
        s = jnp.where(valid[:, None, None, :, :], s, -1e30)
        m = jnp.max(s, axis=-1)                              # (b,h,g,q)
        p = jnp.exp(s - m[..., None])
        l = jnp.sum(p, axis=-1)
        o = jnp.einsum("bhgqk,bkhd->bqhgd", p, vg.astype(q_l.dtype),
                       preferred_element_type=f32)
        gm = jax.lax.pmax(m, seq_axes)
        corr = jnp.exp(m - gm)
        l = jax.lax.psum(l * corr, seq_axes)
        o = jax.lax.psum(o * jnp.moveaxis(corr, 3, 1)[..., None],
                         seq_axes)
        lq = jnp.moveaxis(l, 3, 1)                           # (b,q,h,g)
        out = (o / jnp.maximum(lq, 1e-30)[..., None]).astype(q_l.dtype)
        return out.reshape(Bl, W, Hq, D), ck_n, cv_n

    fn = shard_map(local, mesh,
                   (q_spec, q_spec, q_spec, pool_spec, pool_spec,
                    pt_spec, idx_spec),
                   (q_spec, pool_spec, pool_spec))
    return fn(q, k_new, v_new, ck, cv, pt, idx)


def cross_attention_sharded(q, ck, cv, *, mesh, batch_axes, seq_axes):
    """Read-only sharded cross-attention (precomputed KV, e.g. encoder out
    or image tokens). Same combine, no update."""
    B, S = ck.shape[0], ck.shape[1]
    Hq, D = q.shape[2], q.shape[3]
    Hkv = ck.shape[2]
    G = Hq // Hkv
    Sq = q.shape[1]
    n_seq = int(np.prod([mesh.shape[a] for a in seq_axes]))
    scale = 1.0 / np.sqrt(D)
    b = batch_axes if batch_axes else None
    q_spec = PS(b, None, None, None)
    c_spec = PS(b, seq_axes, None, None)

    def local(q_l, ck_l, cv_l):
        f32 = jnp.float32
        qg = q_l.reshape(q_l.shape[0], Sq, Hkv, G, D)
        s = jnp.einsum("bqhgd,bkhd->bqhgk", qg, ck_l.astype(q_l.dtype),
                       preferred_element_type=f32) * scale
        m = jnp.max(s, axis=-1)
        p = jnp.exp(s - m[..., None])
        l = jnp.sum(p, axis=-1)
        o = jnp.einsum("bqhgk,bkhd->bqhgd", p, cv_l.astype(q_l.dtype),
                       preferred_element_type=f32)
        gm = jax.lax.pmax(m, seq_axes)
        corr = jnp.exp(m - gm)
        l = jax.lax.psum(l * corr, seq_axes)
        o = jax.lax.psum(o * corr[..., None], seq_axes)
        out = (o / jnp.maximum(l, 1e-30)[..., None]).astype(q_l.dtype)
        return out.reshape(q_l.shape[0], Sq, Hq, D)

    fn = shard_map(local, mesh, (q_spec, c_spec, c_spec), q_spec)
    return fn(q, ck, cv)


def paged_shard_plan(sharder, batch: int, num_pages: int, page_size: int):
    """Shard plan for a paged pool: pages chunk over 'model' (the dense
    plan's sequence role); batch over dp when divisible. None = run the
    single-device gather/scatter fallback."""
    if sharder is None or "model" not in sharder.mesh.shape:
        return None
    mesh = sharder.mesh
    if num_pages * page_size < 1024 or num_pages % mesh.shape["model"]:
        return None
    dp = ("pod", "data") if "pod" in mesh.shape else ("data",)
    dpn = int(np.prod([mesh.shape[a] for a in dp]))
    return (dp if batch % dpn == 0 else ()), ("model",)


def decode_shard_plan(sharder, batch: int, seq: int):
    """Mirror of TpServe.cache_specs: (batch_axes, seq_axes) or None."""
    if sharder is None or "model" not in sharder.mesh.shape:
        return None
    mesh = sharder.mesh
    dp = ("pod", "data") if "pod" in mesh.shape else ("data",)
    dpn = int(np.prod([mesh.shape[a] for a in dp]))
    if batch % dpn == 0:
        if seq >= 1024 and seq % mesh.shape["model"] == 0:
            return dp, ("model",)
        return None
    full = dp + ("model",)
    n = int(np.prod([mesh.shape[a] for a in full]))
    if seq >= 1024 and seq % n == 0:
        return (), full
    if seq >= 1024 and seq % mesh.shape["model"] == 0:
        return (), ("model",)
    return None
