"""Draft proposers for speculative decoding (DESIGN.md §2, speculative
serving).

Speculative decoding is the canonical serving workload that *deliberately*
manufactures the paper's Def.-1 waste: a cheap drafter guesses the next k
tokens, the target model verifies all k in ONE width-k forward, and every
REJECTED draft token is a KV-cache store that is thrown away — a dead
store by construction. The engine measures that waste with the Tier-3
`rejected_draft_store` site and, in the paged layout, eliminates it by
rolling the commit back to the accept point (`LM.commit_verify`) instead
of overwriting.

Drafters are host-side and pluggable. The engine's contract is tiny:

  propose(history, k) -> np.ndarray   up to k int32 tokens continuing
                                      `history` (prompt + tokens emitted
                                      so far); fewer (or zero) is fine
  observe(tokens)                     optional: learn a finished
                                      request's full sequence

Three drafters ship:

  NGramDrafter   self-speculative prompt lookup: the tail n-gram of the
                 history is matched against the history itself and a
                 bounded corpus of recently served sequences (most
                 recent first); the match's continuation is the draft.
                 Zero extra model compute — duplicated/looping traffic
                 (exactly what the prefix cache already exploits) drafts
                 itself.
  LMDrafter      a draft LM proposes greedily (bucketed prefill + k
                 decode steps). With the target model as its own draft
                 the greedy acceptance rule accepts everything — the
                 equivalence harness the tests lean on.
  ReplayDrafter  oracle over known continuations: the mechanism's upper
                 bound (accept-rate 1.0) for benchmarks and CI smoke.
"""
from __future__ import annotations

from collections import deque
from typing import Deque, Iterable, List, Sequence

import numpy as np


def _as_tokens(x) -> np.ndarray:
    arr = np.asarray(x, np.int32).reshape(-1)
    return arr


def _last_occurrence(seq: np.ndarray, pat: np.ndarray,
                     before: int) -> int:
    """Index AFTER the last occurrence of `pat` in seq[:before] that ends
    strictly before `before`, or -1. (The drafter wants the continuation
    that FOLLOWS the match, so a match flush at the search frontier —
    the pattern matching itself — is useless and excluded via `before`.)

    Byte-level C search (`bytes.rfind` over the int32 buffer, keeping
    only element-aligned hits): the drafter runs on the host inside the
    decode loop, so its lookup must cost microseconds, not a numpy
    sliding-window materialization per tick per slot."""
    n = pat.size
    hi = min(before, seq.size)
    if n == 0 or hi < n:
        return -1
    item = seq.dtype.itemsize
    hay = np.ascontiguousarray(seq[:hi]).tobytes()
    needle = np.ascontiguousarray(pat).tobytes()
    i = hay.rfind(needle)
    while i >= 0 and i % item:
        # unaligned byte hit (a token boundary straddle): keep searching
        # leftward, allowing overlap with the discarded hit
        i = hay.rfind(needle, 0, i + len(needle) - 1)
    if i < 0:
        return -1
    return i // item + n


class NGramDrafter:
    """Prompt-lookup self-speculation over the history + a served corpus.

    For n from `max_n` down to `min_n`, the history's tail n-gram is
    searched in the history itself (excluding the trivial tail match)
    and then in recently observed sequences; the first hit's
    continuation (up to k tokens) is the draft. A duplicated prompt
    whose donor already ran therefore drafts the donor's exact greedy
    continuation — which the verify forward accepts in full.
    """

    def __init__(self, max_n: int = 3, min_n: int = 2,
                 corpus_window: int = 32):
        assert 1 <= min_n <= max_n
        self.max_n = max_n
        self.min_n = min_n
        self._corpus: Deque[np.ndarray] = deque(maxlen=max(1, corpus_window))

    def observe(self, tokens) -> None:
        """Record a served sequence (prompt + continuation) for lookup."""
        toks = _as_tokens(tokens)
        if toks.size:
            self._corpus.appendleft(toks)

    def propose(self, history, k: int) -> np.ndarray:
        hist = _as_tokens(history)
        if k <= 0:
            return np.zeros(0, np.int32)
        for n in range(self.max_n, self.min_n - 1, -1):
            if hist.size < n:
                continue
            pat = hist[-n:]
            # the history itself first (self-speculation), then the
            # corpus most-recent-first; within a sequence the LAST
            # occurrence wins (the freshest context)
            end = _last_occurrence(hist, pat, hist.size - 1)
            if end >= 0 and end < hist.size:
                return hist[end:end + k].copy()
            for seq in self._corpus:
                end = _last_occurrence(seq, pat, seq.size)
                if end == seq.size:
                    # flush at the sequence end: no continuation there,
                    # but an EARLIER occurrence may still have one
                    end = _last_occurrence(seq, pat, seq.size - 1)
                if 0 <= end < seq.size:
                    return seq[end:end + k].copy()
        return np.zeros(0, np.int32)


class ReplayDrafter:
    """Oracle drafter over known full sequences (prompt + continuation).

    `propose` finds the sequence the history is a strict prefix of and
    returns its next k tokens — accept-rate 1.0 when the sequences came
    from the same greedy model. This is the harness that isolates the
    verify/rollback machinery's cost from drafter quality in
    `benchmarks/overhead.py` and the CI serve smoke.
    """

    def __init__(self, sequences: Iterable[Sequence[int]] = ()):
        self._seqs: List[np.ndarray] = [_as_tokens(s) for s in sequences]

    def observe(self, tokens) -> None:
        toks = _as_tokens(tokens)
        if toks.size:
            self._seqs.append(toks)

    def propose(self, history, k: int) -> np.ndarray:
        hist = _as_tokens(history)
        if k <= 0:
            return np.zeros(0, np.int32)
        for seq in self._seqs:
            if seq.size > hist.size and np.array_equal(seq[:hist.size],
                                                       hist):
                return seq[hist.size:hist.size + k].copy()
        return np.zeros(0, np.int32)


class LMDrafter:
    """Greedy draft-LM proposer (the classic two-model speculative setup).

    Host-side and stateless across calls: each proposal prefilling the
    full history into a fresh bucketed cache, then k greedy decode
    steps. Prompt lengths bucket to powers of two so the jit cache stays
    bounded. Using the TARGET model as its own draft gives accept-rate
    1.0 (prefill is bit-identical to the token loop), which the tests
    use to pin the acceptance rule.
    """

    def __init__(self, model, params, max_ctx: int = 512):
        import jax.numpy as jnp
        self.model = model
        self.params = params
        self.max_ctx = max_ctx
        self._kv_dtype = jnp.float32

    def observe(self, tokens) -> None:  # stateless: nothing to learn
        pass

    def propose(self, history, k: int) -> np.ndarray:
        import jax.numpy as jnp
        from repro.serve.engine import _bucket
        hist = _as_tokens(history)
        if k <= 0 or hist.size == 0 or hist.size + k + 1 > self.max_ctx:
            return np.zeros(0, np.int32)
        P = _bucket(hist.size)         # pow2 prompt bucket: bounded jits
        toks = np.zeros((1, P), np.int32)
        toks[0, :hist.size] = hist
        cache = self.model.init_cache(self.params, 1, P + k + 1,
                                      kv_dtype=self._kv_dtype)
        cache = self.model.with_cache_index(cache,
                                            jnp.zeros((1,), jnp.int32))
        lg, cache = self.model.prefill(
            self.params, cache, jnp.asarray(toks),
            lengths=jnp.asarray([hist.size], jnp.int32))
        cur = jnp.argmax(lg[:, hist.size - 1:hist.size], -1).astype(jnp.int32)
        out = [int(cur[0, 0])]
        for _ in range(k - 1):
            lg, cache = self.model.decode_step(self.params, cache, cur)
            cur = jnp.argmax(lg[:, -1:], -1).astype(jnp.int32)
            out.append(int(cur[0, 0]))
        return np.asarray(out, np.int32)


def make_drafter(kind: str, *, model=None, params=None,
                 sequences: Iterable[Sequence[int]] = ()):
    """Drafter factory for drivers (`launch/serve.py --draft ...`)."""
    if kind == "ngram":
        return NGramDrafter()
    if kind == "oracle":
        return ReplayDrafter(sequences)
    if kind == "lm":
        assert model is not None and params is not None
        return LMDrafter(model, params)
    raise ValueError(f"unknown drafter kind {kind!r}")
