"""Serve-step factory: one-token batched decode with sharded KV cache.

With ``tp_serve`` the cache is sequence-chunk sharded over "model": each
shard computes attention over its chunk and XLA decomposes the softmax
reduction into the flash-decoding partial-max/denominator combine. Works
for any head count and any cache length (incl. 500k).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.sharding.ctx import sharding_ctx


def make_serve_step(model, strategy=None, greedy: bool = True):
    sharder = strategy.sharder() if strategy is not None else None

    def serve_step(params, cache, tokens):
        """tokens: (B,1) int32 -> (next_tokens (B,1), new_cache)."""
        with sharding_ctx(sharder):
            logits, new_cache = model.decode_step(params, cache, tokens)
        nxt = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        return nxt, new_cache
    return serve_step


def make_prefill_step(model, strategy=None):
    def prefill_step(params, batch):
        with sharding_ctx(strategy.sharder() if strategy else None):
            logits, _ = model.forward(
                params, batch["tokens"],
                img=batch.get("img"), frames=batch.get("frames"))
        return logits
    return prefill_step
