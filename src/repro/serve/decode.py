"""Serve-step factories: one-token batched decode, batched prefill and
the continuous-batching engine's tick/prefill, all sharded-cache aware.

With ``tp_serve`` the cache is sequence-chunk sharded over "model": each
shard computes attention over its chunk and XLA decomposes the softmax
reduction into the flash-decoding partial-max/denominator combine. Works
for any head count and any cache length (incl. 500k).

Every factory wraps the model call in ``sharding_ctx``, so
``serve.engine.ServeEngine`` composes with distribution strategies
instead of duplicating an unsharded decode step: the engine jits these
factories directly (dense and paged KV layouts alike).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.sharding.ctx import sharding_ctx


def make_serve_step(model, strategy=None, greedy: bool = True):
    sharder = strategy.sharder() if strategy is not None else None

    def serve_step(params, cache, tokens):
        """tokens: (B,1) int32 -> (next_tokens (B,1), new_cache)."""
        with sharding_ctx(sharder):
            logits, new_cache = model.decode_step(params, cache, tokens)
        nxt = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        return nxt, new_cache
    return serve_step


def make_prefill_step(model, strategy=None):
    def prefill_step(params, batch):
        with sharding_ctx(strategy.sharder() if strategy else None):
            logits, _ = model.forward(
                params, batch["tokens"],
                img=batch.get("img"), frames=batch.get("frames"))
        return logits
    return prefill_step


# ----------------------------------------------------------------------
# Continuous-batching engine steps (serve/engine.py jits these)
# ----------------------------------------------------------------------
def make_engine_tick(model, strategy=None, *, paged: bool = False):
    """One decode tick over the whole slot batch.

    Dense layout: idle slots freeze token AND write index, so every tick
    rewrites the same K/V site with the same value — the serving-tier
    dead/silent store the detectors trap on. Paged layout: idle slots'
    write positions drop to a sentinel below the page-table extent, so
    the scatter DROPS their store — the detected waste, eliminated."""
    sharder = strategy.sharder() if strategy is not None else None

    def tick(params, cache, tokens, active):
        idx0 = model.cache_index(cache)            # (B,)
        stepped = cache
        if paged:
            stepped = model.with_cache_index(
                cache, jnp.where(active, idx0, -2))
        with sharding_ctx(sharder):
            logits, new_cache = model.decode_step(params, stepped, tokens)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        nxt = jnp.where(active[:, None], nxt[:, None], tokens)
        new_cache = model.with_cache_index(
            new_cache, jnp.where(active, idx0 + 1, idx0))
        return nxt, new_cache
    return tick


def make_engine_prefill(model, strategy=None, *, paged: bool = False):
    """Grouped admission prefill.

    toks: (B,P) right-padded prompts — full prompts in dense mode, the
    uncached suffixes (prompt minus the reused prefix) in paged mode;
    admit: (B,) bool; start: (B,) cached-prefix lengths (all zero in
    dense mode); lengths: (B,) full prompt lengths; prev_tokens: (B,1)
    tokens of non-admitted rows, passed through untouched.

    Dense: the whole refilled cache is tree-merged back under the admit
    mask. Paged: stores already scatter through each slot's page table
    (non-admitted rows get a sentinel index and write nothing), so no
    merge pass exists — only the write indices are restored."""
    sharder = strategy.sharder() if strategy is not None else None

    def prefill(params, cache, toks, admit, start, lengths, prev_tokens):
        B, P = toks.shape
        idx0 = model.cache_index(cache)
        if paged:
            fresh = model.with_cache_index(
                cache, jnp.where(admit, start, -(P + 1)))
        else:
            fresh = model.with_cache_index(cache, jnp.zeros((B,), jnp.int32))
        with sharding_ctx(sharder):
            logits, filled = model.prefill(params, fresh, toks)
        if not paged:
            def sel(n, o):
                m = admit.reshape((1, -1) + (1,) * (n.ndim - 2))
                return jnp.where(m, n, o)
            filled = jax.tree_util.tree_map(sel, filled, cache)
        merged = model.with_cache_index(
            filled, jnp.where(admit, lengths, idx0))
        sel_pos = jnp.clip(lengths - start - 1, 0, P - 1)
        first = jnp.argmax(
            logits[jnp.arange(B), sel_pos], axis=-1).astype(jnp.int32)
        toks_out = jnp.where(admit[:, None], first[:, None], prev_tokens)
        return toks_out, merged
    return prefill
