"""Serve-step factories: one-token batched decode, batched prefill and
the continuous-batching engine's tick/prefill, all sharded-cache aware.

With ``tp_serve`` the cache is sequence-chunk sharded over "model": each
shard computes attention over its chunk and XLA decomposes the softmax
reduction into the flash-decoding partial-max/denominator combine. Works
for any head count and any cache length (incl. 500k).

Every factory wraps the model call in ``sharding_ctx``, so
``serve.engine.ServeEngine`` composes with distribution strategies
instead of duplicating an unsharded decode step: the engine jits these
factories directly (dense and paged KV layouts alike).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.sharding.ctx import sharding_ctx


class StepCache:
    """Shared jitted engine steps for a replica fleet.

    `jax.jit` caches per function OBJECT, so N `ServeEngine`s built the
    plain way compile the tick/prefill/verify factories N times over —
    pure compile-time waste for replicas serving the same model (they
    already share one weight arena). A fleet builds one StepCache and
    passes it to every `ServeEngine(step_cache=...)`; each distinct
    (kind, paged, rollback) combination compiles once and every replica
    dispatches through the same executable. Also what makes routing-
    policy A/B timing honest: both fleets run literally the same
    compiled code."""

    def __init__(self, model, strategy=None):
        self.model = model
        self.strategy = strategy
        self._fns = {}

    def get(self, kind: str, *, paged: bool = False,
            rollback: bool = False):
        key = (kind, bool(paged), bool(rollback))
        fn = self._fns.get(key)
        if fn is None:
            if kind == "tick":
                fn = jax.jit(make_engine_tick(self.model, self.strategy,
                                              paged=paged))
            elif kind == "prefill":
                fn = jax.jit(make_engine_prefill(self.model, self.strategy,
                                                 paged=paged))
            elif kind == "verify":
                fn = jax.jit(make_engine_verify(self.model, self.strategy,
                                                paged=paged,
                                                rollback=rollback))
            elif kind == "page_copy":
                from repro.serve.kv_cache import make_page_copy
                fn = jax.jit(make_page_copy())
            else:
                raise ValueError(f"unknown step kind {kind!r}")
            self._fns[key] = fn
        return fn


def make_serve_step(model, strategy=None, greedy: bool = True):
    sharder = strategy.sharder() if strategy is not None else None

    def serve_step(params, cache, tokens):
        """tokens: (B,1) int32 -> (next_tokens (B,1), new_cache)."""
        with sharding_ctx(sharder):
            logits, new_cache = model.decode_step(params, cache, tokens)
        nxt = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        return nxt, new_cache
    return serve_step


def make_prefill_step(model, strategy=None):
    def prefill_step(params, batch):
        with sharding_ctx(strategy.sharder() if strategy else None):
            logits, _ = model.forward(
                params, batch["tokens"],
                img=batch.get("img"), frames=batch.get("frames"))
        return logits
    return prefill_step


# ----------------------------------------------------------------------
# Continuous-batching engine steps (serve/engine.py jits these)
# ----------------------------------------------------------------------
def make_engine_tick(model, strategy=None, *, paged: bool = False):
    """One decode tick over the whole slot batch.

    Dense layout: idle slots freeze token AND write index, so every tick
    rewrites the same K/V site with the same value — the serving-tier
    dead/silent store the detectors trap on. Paged layout: idle slots'
    write positions drop to a sentinel below the page-table extent, so
    the scatter DROPS their store — the detected waste, eliminated."""
    sharder = strategy.sharder() if strategy is not None else None

    def tick(params, cache, tokens, active):
        idx0 = model.cache_index(cache)            # (B,)
        stepped = cache
        if paged:
            stepped = model.with_cache_index(
                cache, jnp.where(active, idx0, -2))
        with sharding_ctx(sharder):
            logits, new_cache = model.decode_step(params, stepped, tokens)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        nxt = jnp.where(active[:, None], nxt[:, None], tokens)
        new_cache = model.with_cache_index(
            new_cache, jnp.where(active, idx0 + 1, idx0))
        return nxt, new_cache
    return tick


def make_engine_verify(model, strategy=None, *, paged: bool = False,
                       rollback: bool = False):
    """One speculative verify tick over the whole slot batch.

    tokens: (B, W) = [last accepted token, draft_1 .. draft_{W-1}] per
    slot (width fixed so one compiled shape serves every tick; unused
    draft positions are padding); active: (B,) bool; draft_len: (B,)
    number of REAL drafts in each row (0 = plain decode for that slot).

    Greedy acceptance on-device: draft j+1 is accepted iff it equals the
    verify forward's own greedy token at position j and every earlier
    draft was accepted, so the emitted chain g[:, 0..m] is exactly what
    plain one-token decode would have produced — bit-identical outputs,
    up to W tokens per tick. Returns (g (B,W) greedy tokens, m (B,)
    accepted-draft counts, next_tokens (B,1) = the bonus token g[:, m],
    new_cache with per-slot indices advanced by 1+m).

    rollback=True (paged only): the verify forward defers its K/V
    stores and the accepted prefix is committed in the same jitted call
    (`LM.commit_verify`) — rejected draft rows never reach the pool.
    rollback=False: all W rows are stored and the index rolls back over
    the rejected tail, which the next window overwrites (the Def.-1
    dead stores `rejected_draft_store` counts)."""
    sharder = strategy.sharder() if strategy is not None else None

    def verify(params, cache, tokens, active, draft_len):
        B, W = tokens.shape
        idx0 = model.cache_index(cache)            # (B,)
        stepped = cache
        if paged:
            # idle slots: every window position maps below the page
            # table, so their stores drop (same sentinel idea as tick)
            stepped = model.with_cache_index(
                cache, jnp.where(active, idx0, -(W + 1)))
        with sharding_ctx(sharder):
            logits, new_cache = model.verify(params, stepped, tokens,
                                             commit=not rollback)
        g = jnp.argmax(logits, axis=-1).astype(jnp.int32)       # (B, W)
        ok = ((tokens[:, 1:] == g[:, :-1])
              & (jnp.arange(W - 1)[None, :] < draft_len[:, None]))
        m = jnp.sum(jnp.cumprod(ok.astype(jnp.int32), axis=1), axis=1)
        m = jnp.where(active, m, 0)
        if rollback:
            new_cache = model.commit_verify(
                new_cache, idx0, jnp.where(active, 1 + m, 0))
        nxt = jnp.take_along_axis(g, m[:, None], axis=1)
        nxt = jnp.where(active[:, None], nxt, tokens[:, :1])
        new_cache = model.with_cache_index(
            new_cache, jnp.where(active, idx0 + 1 + m, idx0))
        return g, m, nxt, new_cache
    return verify


def make_engine_prefill(model, strategy=None, *, paged: bool = False):
    """Grouped admission prefill.

    toks: (B,P) right-padded prompts — full prompts in dense mode, the
    uncached suffixes (prompt minus the reused prefix) in paged mode;
    admit: (B,) bool; start: (B,) cached-prefix lengths (all zero in
    dense mode); lengths: (B,) full prompt lengths; prev_tokens: (B,1)
    tokens of non-admitted rows, passed through untouched.

    Dense: the whole refilled cache is tree-merged back under the admit
    mask. Paged: stores already scatter through each slot's page table
    (non-admitted rows get a sentinel index and write nothing), so no
    merge pass exists — only the write indices are restored."""
    sharder = strategy.sharder() if strategy is not None else None

    def prefill(params, cache, toks, admit, start, lengths, prev_tokens):
        B, P = toks.shape
        idx0 = model.cache_index(cache)
        if paged:
            fresh = model.with_cache_index(
                cache, jnp.where(admit, start, -(P + 1)))
        else:
            fresh = model.with_cache_index(cache, jnp.zeros((B,), jnp.int32))
        with sharding_ctx(sharder):
            logits, filled = model.prefill(params, fresh, toks)
        if not paged:
            def sel(n, o):
                m = admit.reshape((1, -1) + (1,) * (n.ndim - 2))
                return jnp.where(m, n, o)
            filled = jax.tree_util.tree_map(sel, filled, cache)
        merged = model.with_cache_index(
            filled, jnp.where(admit, lengths, idx0))
        sel_pos = jnp.clip(lengths - start - 1, 0, P - 1)
        first = jnp.argmax(
            logits[jnp.arange(B), sel_pos], axis=-1).astype(jnp.int32)
        toks_out = jnp.where(admit[:, None], first[:, None], prev_tokens)
        return toks_out, merged
    return prefill
