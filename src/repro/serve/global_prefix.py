"""Global (fleet-wide) prefix-cache tier (DESIGN.md § Fleet tier).

Each `ServeEngine` replica already dedups duplicated prompts locally
through its `PrefixIndex`, but a fleet re-pays a prefix once per replica
it lands on — Def.-3 silent loads measured ACROSS replicas (the
redundancy fraction of "Redundant Loads: A Software Inefficiency
Indicator", applied with OJXPerf's replica-detection framing: the fix
for cross-replica duplicate KV state is routing plus a shared
content-addressed tier).

`GlobalPrefixIndex` is that tier: one content-digest map over the whole
replica group, ``digest(prompt[:L]) -> (replica, pages)``. It never
copies K/V between pools; it records WHERE a prefix is resident so the
router can send the request there, and it pins the pages through the
owning replica's own `PageAllocator` so they survive the donor slot,
local LRU forgetting, and local pool-pressure eviction alike.

Pin/evict ordering protocol (what makes cross-replica reuse
refcount-safe):

  * **publish** — after a replica prefilled a prompt, the router
    publishes it here; the entry increfs the pages it maps (one global
    pin per entry, on top of whatever local holders exist).
  * **lease** — at dispatch the router takes a per-request lease
    (another incref) on the matched entry's pages. The lease — not the
    entry — is what the admitted request consumes, so the entry may be
    evicted between dispatch and admission without ever exposing a
    freed page: pages stay allocated and, by the COW discipline, shared
    pages are never written, so their contents are immutable while any
    reference exists. The engine releases the lease once `PagedKV.admit`
    has pinned what it mapped.
  * **evict** — dropping an entry decrefs through the OWNING replica's
    allocator and reports pages that actually freed back to that engine
    (`ServeEngine.note_freed`) so its stale detector traps disarm.
    Local pressure eviction can therefore never free a globally pinned
    page (the global pin is a holder its allocator counts), and global
    eviction can never free a page a live slot or lease still holds —
    preemption-safe by construction, property-tested in
    tests/test_fleet.py.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.serve.kv_cache import PrefixIndex, prefix_candidates


@dataclass
class GlobalEntry:
    replica: int
    length: int
    pages: Tuple[int, ...]


class GlobalPrefixIndex:
    """digest(prompt[:L]) -> (replica, pages) across the replica group.

    `replicas` maps replica id -> its `ServeEngine`; every engine must
    run the paged KV layout with the same page size. LRU-bounded by
    `window` entries fleet-wide."""

    def __init__(self, replicas: Dict[int, object], page_size: int,
                 window: int = 64):
        for rid, eng in replicas.items():
            assert eng.kv is not None, \
                f"replica {rid} is not paged; the global tier needs " \
                f"kv_layout='paged'"
            assert eng.kv.page_size == page_size, \
                f"replica {rid} page_size {eng.kv.page_size} != {page_size}"
        self.replicas = replicas
        self.page_size = page_size
        self.window = max(1, window)
        self._entries: "OrderedDict[str, GlobalEntry]" = OrderedDict()
        # registered entry lengths (refcounted), same partial-boundary
        # probe fix as the local PrefixIndex: a published prompt can end
        # mid-bucket and must still be probed
        self._lengths: Dict[int, int] = {}
        # outstanding dispatch leases: pages incref'd per routed request
        self._leases: Dict[str, Tuple[int, Tuple[int, ...]]] = {}
        self.stats = {"published": 0, "evicted": 0, "leases": 0}

    def __len__(self) -> int:
        return len(self._entries)

    def _key(self, length: int, tokens: np.ndarray) -> str:
        return PrefixIndex._key(length, tokens)

    # ------------------------------------------------------------------
    def publish(self, replica: int, tokens: np.ndarray) -> None:
        """Mirror this prompt's locally indexed prefix entries (every
        candidate granularity, not just the longest — two prompts that
        share only a SUB-prefix must still meet at the common boundary)
        into the global tier; each entry pins its pages through the
        owning replica's allocator. Idempotent for already-published
        prefixes (LRU touch; first owner wins — routing concentrates
        that traffic there, which is the point)."""
        tokens = np.asarray(tokens)
        kv = self.replicas[replica].kv
        for cand in kv.index.probe_lengths(tokens.size):
            pages = kv.index.lookup(tokens, cand)
            if pages is None:
                continue
            key = self._key(cand, tokens)
            if key in self._entries:
                self._entries.move_to_end(key)
                continue
            pages = tuple(int(p) for p in pages)
            kv.alloc.incref(pages)
            self._entries[key] = GlobalEntry(replica, cand, pages)
            self._lengths[cand] = self._lengths.get(cand, 0) + 1
            self.stats["published"] += 1
            while len(self._entries) > self.window:
                self.evict_one()

    def match(self, tokens: np.ndarray) -> Optional[Tuple[str, GlobalEntry]]:
        """Longest globally resident prefix of `tokens`:
        (key, GlobalEntry) or None. Probes the pow2+page candidate
        ladder plus every registered entry length (partial boundaries
        included)."""
        tokens = np.asarray(tokens)
        cands = set(prefix_candidates(tokens.size, self.page_size))
        cands.update(L for L in self._lengths if L < tokens.size)
        best: Optional[Tuple[str, GlobalEntry]] = None
        for cand in sorted(cands):
            key = self._key(cand, tokens)
            e = self._entries.get(key)
            if e is not None and (best is None or cand > best[1].length):
                best = (key, e)
                self._entries.move_to_end(key)
        return best

    # ------------------------------------------------------------------
    def lease(self, key: str, rid: str) -> Optional[Tuple[int, Tuple[int, ...]]]:
        """Pin an entry's pages for one in-flight request (`rid`); the
        returned (length, pages) becomes the request's `prefix_hint`.
        None if the entry vanished since `match`."""
        e = self._entries.get(key)
        if e is None:
            return None
        self.replicas[e.replica].kv.alloc.incref(e.pages)
        self._leases[rid] = (e.replica, e.pages)
        self.stats["leases"] += 1
        return e.length, e.pages

    def lease_replica(self, rid: str) -> Optional[int]:
        lease = self._leases.get(rid)
        return lease[0] if lease is not None else None

    def drop_lease(self, rid: str) -> None:
        """Release a dispatch lease the ENGINE could not consume (the
        request was cancelled before admission). Leases consumed at
        admission are released by the engine itself via `PagedKV`."""
        lease = self._leases.pop(rid, None)
        if lease is not None:
            replica, pages = lease
            eng = self.replicas[replica]
            eng.note_freed(eng.kv.release(pages))

    def note_admitted(self, rid: str) -> None:
        """The engine consumed (and released) this request's lease."""
        self._leases.pop(rid, None)

    # ------------------------------------------------------------------
    def evict_one(self) -> Optional[Tuple[int, List[int]]]:
        """Drop the LRU entry; decrefs through the owner's allocator and
        disarms the owner's stale traps on pages that actually freed.
        Returns (replica, freed_pages) or None when empty."""
        if not self._entries:
            return None
        key = next(iter(self._entries))
        return self._evict(key)

    def evict_for(self, replica: int, want_pages: int) -> int:
        """Pool pressure on `replica`: drop ITS LRU entries until
        `want_pages` pages came free there or none of its entries
        remain. Returns pages actually freed. Entries owned by other
        replicas are untouched — their pins are not this pool's
        pressure."""
        freed = 0
        while freed < want_pages:
            key = next((k for k, e in self._entries.items()
                        if e.replica == replica), None)
            if key is None:
                break
            freed += len(self._evict(key)[1])
        return freed

    def _evict(self, key: str) -> Tuple[int, List[int]]:
        e = self._entries.pop(key)
        self._lengths[e.length] -= 1
        if not self._lengths[e.length]:
            del self._lengths[e.length]
        eng = self.replicas[e.replica]
        freed = eng.kv.release(e.pages)
        eng.note_freed(freed)
        self.stats["evicted"] += 1
        return e.replica, freed

    # ------------------------------------------------------------------
    def holders(self, replica: int) -> Dict[int, int]:
        """page -> reference count this tier holds on `replica`'s pool
        (entry pins + outstanding dispatch leases) — feeds
        `PagedKV.check(extra_holders=...)` so the fleet-wide refcount
        audit stays exact."""
        out: Dict[int, int] = {}
        for e in self._entries.values():
            if e.replica == replica:
                for p in e.pages:
                    out[p] = out.get(p, 0) + 1
        for r, pages in self._leases.values():
            if r == replica:
                for p in pages:
                    out[p] = out.get(p, 0) + 1
        return out

    def check(self) -> None:
        """No entry or lease may reference a free page: every pinned
        page must show a live refcount in its owner's allocator."""
        for key, e in self._entries.items():
            alloc = self.replicas[e.replica].kv.alloc
            for p in e.pages:
                assert alloc.refcount[p] > 0, \
                    f"global entry {key} maps freed page {p} " \
                    f"on replica {e.replica}"
        for rid, (replica, pages) in self._leases.items():
            alloc = self.replicas[replica].kv.alloc
            for p in pages:
                assert alloc.refcount[p] > 0, \
                    f"lease {rid} maps freed page {p} on replica {replica}"
