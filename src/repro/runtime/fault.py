"""Fault tolerance runtime: heartbeats, straggler detection, restart policy.

On a real multi-host deployment each host runs a ``Heartbeat`` reporter and
rank 0 runs the ``FleetMonitor``; here the same objects are driven by the
single-process launcher and by tests (simulated hosts), which is exactly
the logic that matters — detection thresholds, restart decisions, and the
interaction with the checkpointer — minus the transport.

Policy (DESIGN.md §4):
  * a host missing `dead_after` heartbeats is declared failed -> restore
    from the last checkpoint onto the surviving device set (elastic);
  * a host whose step time exceeds `straggler_factor` x the fleet median
    for `straggler_patience` consecutive steps is flagged (mitigation at
    1000+ nodes: drop from the critical path / re-shard around it);
  * restarts are bounded by `max_restarts` within a sliding window.
"""
from __future__ import annotations

import time
from collections import defaultdict, deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional


@dataclass
class HostState:
    last_beat: float = 0.0
    step_times: deque = field(default_factory=lambda: deque(maxlen=32))
    straggler_streak: int = 0


class FleetMonitor:
    def __init__(self, hosts: List[int], *, dead_after: float = 60.0,
                 straggler_factor: float = 2.0, straggler_patience: int = 3,
                 max_restarts: int = 5, clock=time.monotonic):
        self.clock = clock
        self.dead_after = dead_after
        self.straggler_factor = straggler_factor
        self.straggler_patience = straggler_patience
        self.max_restarts = max_restarts
        self.restarts = 0
        self.hosts: Dict[int, HostState] = {h: HostState() for h in hosts}
        now = clock()
        for st in self.hosts.values():
            st.last_beat = now

    # ------------------------------------------------------------------
    def heartbeat(self, host: int, step_time_s: Optional[float] = None):
        st = self.hosts[host]
        st.last_beat = self.clock()
        if step_time_s is not None:
            st.step_times.append(step_time_s)

    def dead_hosts(self) -> List[int]:
        now = self.clock()
        return [h for h, st in self.hosts.items()
                if now - st.last_beat > self.dead_after]

    def stragglers(self) -> List[int]:
        times = [st.step_times[-1] for st in self.hosts.values()
                 if st.step_times]
        if len(times) < max(2, len(self.hosts) // 2):
            return []
        med = sorted(times)[len(times) // 2]
        out = []
        for h, st in self.hosts.items():
            if st.step_times and st.step_times[-1] > self.straggler_factor * med:
                st.straggler_streak += 1
                if st.straggler_streak >= self.straggler_patience:
                    out.append(h)
            else:
                st.straggler_streak = 0
        return out

    # ------------------------------------------------------------------
    def plan(self) -> Dict[str, object]:
        """Decision for the launcher at this tick."""
        dead = self.dead_hosts()
        if dead:
            if self.restarts >= self.max_restarts:
                return {"action": "abort",
                        "reason": f"restart budget exhausted ({self.restarts})"}
            self.restarts += 1
            survivors = [h for h in self.hosts if h not in dead]
            return {"action": "elastic_restart", "dead": dead,
                    "survivors": survivors}
        strag = self.stragglers()
        if strag:
            return {"action": "mitigate_stragglers", "hosts": strag}
        return {"action": "continue"}

    def remove_hosts(self, hosts: List[int]):
        for h in hosts:
            self.hosts.pop(h, None)
