"""Vocab-parallel fused LM-head + softmax cross-entropy.

Megatron-style: under ``shard_map`` each device computes only its vocab
shard of the logits (never materialized globally, never in f32 globally),
exchanges two (B,S) rowwise statistics (pmax / psum), and the custom vjp
computes dx/dw with shard-local einsums + small psums.

This exists because GSPMD's default plan for the head-matmul backward
all-gathers the full (B,S,V) cotangent (~40 GB/device at qwen3-14b scale).
Fallback: a plain (constrained) implementation when no mesh is active or
the vocab does not divide the model axis.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as PS

try:                                   # jax>=0.6
    from jax import shard_map as _shard_map

    def shard_map(f, mesh, in_specs, out_specs):
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=False)
except ImportError:                    # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, mesh, in_specs, out_specs):
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=False)


def _plain(x, w, labels, z_loss):
    from repro.sharding.ctx import shard
    logits = shard(jnp.einsum("bsd,vd->bsv", x, w.astype(x.dtype)), "btv")
    lf = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(lf, axis=-1, keepdims=True))
    lse = jnp.log(jnp.sum(jnp.exp(lf - m), axis=-1)) + m[..., 0]
    onehot = shard(jax.nn.one_hot(labels, lf.shape[-1], dtype=jnp.bfloat16),
                   "btv")
    ll = jnp.einsum("bsv,bsv->bs", lf, onehot,
                    preferred_element_type=jnp.float32)
    loss = jnp.mean(lse - ll)
    if z_loss:
        loss = loss + z_loss * jnp.mean(jnp.square(lse))
    return loss


def make_fused_xent(mesh, dp_axes: Tuple[str, ...], z_loss: float = 0.0):
    """Returns loss_fn(x, w, labels) -> scalar.

    x: (B,S,d) compute dtype; w: (V,d) param head (vocab-major);
    labels: (B,S) int32.  V must divide the 'model' axis.
    """
    model_ax = "model"
    tp = mesh.shape[model_ax]

    x_spec = PS(dp_axes, None, None)
    w_spec = PS(model_ax, None)
    l_spec = PS(dp_axes, None)

    @jax.custom_vjp
    def fused(x, w, labels):
        return _fwd_value(x, w, labels)

    def _local_fwd(x_l, w_l, lab_l):
        f32 = jnp.float32
        logits = jnp.einsum("bsd,vd->bsv", x_l, w_l.astype(x_l.dtype),
                            preferred_element_type=f32)  # (b,s,v/tp) f32
        m_l = jnp.max(logits, axis=-1)
        m = jax.lax.pmax(m_l, model_ax)                   # (b,s)
        se = jax.lax.psum(jnp.sum(jnp.exp(logits - m[..., None]), axis=-1),
                          model_ax)
        lse = jnp.log(se) + m                             # (b,s)
        v_l = w_l.shape[0]
        v_off = jax.lax.axis_index(model_ax) * v_l
        local_lab = lab_l - v_off
        in_shard = (local_lab >= 0) & (local_lab < v_l)
        idx = jnp.clip(local_lab, 0, v_l - 1)
        ll_l = jnp.take_along_axis(logits, idx[..., None], axis=-1)[..., 0]
        ll = jax.lax.psum(jnp.where(in_shard, ll_l, 0.0), model_ax)
        return logits, lse, ll

    def _fwd_value(x, w, labels):
        def f(x_l, w_l, lab_l):
            _, lse, ll = _local_fwd(x_l, w_l, lab_l)
            ntok = np.prod(lab_l.shape)
            loss = jnp.sum(lse - ll) / ntok
            if z_loss:
                loss = loss + z_loss * jnp.sum(jnp.square(lse)) / ntok
            return jax.lax.pmean(loss, dp_axes)           # replicated scalar
        return shard_map(f, mesh, (x_spec, w_spec, l_spec), PS())(
            x, w, labels)

    def _fwd_rule(x, w, labels):
        return _fwd_value(x, w, labels), (x, w, labels)

    def _bwd_rule(res, g):
        x, w, labels = res

        def f(x_l, w_l, lab_l):
            f32 = jnp.float32
            logits, lse, ll = _local_fwd(x_l, w_l, lab_l)
            p = jnp.exp(logits - lse[..., None])          # softmax local
            v_l = w_l.shape[0]
            v_off = jax.lax.axis_index(model_ax) * v_l
            local_lab = lab_l - v_off
            in_shard = (local_lab >= 0) & (local_lab < v_l)
            onehot_val = jnp.where(in_shard, 1.0, 0.0)
            idx = jnp.clip(local_lab, 0, v_l - 1)
            if z_loss:
                scale = (1.0 + 2.0 * z_loss * lse)[..., None]
            else:
                scale = 1.0
            ntok_global = np.prod(lab_l.shape) * np.prod(
                [mesh.shape[a] for a in dp_axes])
            dl = p * scale
            # subtract onehot at the label slot (only in its shard)
            upd = -onehot_val
            dl = dl.at[jnp.arange(dl.shape[0])[:, None],
                       jnp.arange(dl.shape[1])[None, :], idx].add(upd)
            dl = dl * (g / ntok_global)
            dl = dl.astype(x_l.dtype)
            dx_l = jax.lax.psum(
                jnp.einsum("bsv,vd->bsd", dl, w_l.astype(dl.dtype)), model_ax)
            dw_l = jax.lax.psum(
                jnp.einsum("bsv,bsd->vd", dl, x_l), dp_axes)
            return dx_l.astype(x_l.dtype), dw_l.astype(w.dtype)

        dx, dw = shard_map(f, mesh, (x_spec, w_spec, l_spec),
                           (x_spec, w_spec))(x, w, labels)
        dlab = np.zeros(labels.shape, jax.dtypes.float0)
        return dx, dw, dlab

    fused.defvjp(_fwd_rule, _bwd_rule)
    return fused


def lm_loss(x, w, labels, *, z_loss: float = 0.0, sharder=None):
    """Dispatch: fused vocab-parallel path when a mesh is active, 'model' is
    free (not carrying batch), and the padded vocab divides it; plain
    constrained path otherwise (e.g. fsdp, where batch covers every axis and
    per-device logits are small)."""
    if sharder is not None and "model" in sharder.mesh.shape:
        V = w.shape[0]
        mesh = sharder.mesh
        dp = sharder.batch_axes
        if ("model" not in dp and V % mesh.shape["model"] == 0):
            B, S = labels.shape
            dpn = int(np.prod([mesh.shape[a] for a in dp]))
            if B % dpn == 0:
                fused = make_fused_xent(mesh, dp, z_loss)
                return fused(x, w, labels)
    return _plain(x, w, labels, z_loss)
