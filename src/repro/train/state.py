"""Train state: bf16 compute params + f32 master/moments (ZeRO-1 layout)."""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.optim import adamw


class TrainState(NamedTuple):
    params: Any          # compute dtype (bf16), strategy.param_specs
    master: Any          # f32, fully sharded (opt_specs)
    opt: adamw.AdamWState  # f32, fully sharded
    step: jax.Array      # scalar int32


def create(model, key, compute_dtype=jnp.bfloat16,
           registry=None) -> TrainState:
    """With an object registry (core/objects.py) the compute/master
    trees register as ``param`` objects here and the moments inside
    `adamw.init` — so replica findings carry each tree's real
    allocation site."""
    master = model.init(key, dtype=jnp.float32)
    params = jax.tree_util.tree_map(lambda p: p.astype(compute_dtype), master)
    if registry is not None:
        from repro.core.objects import register_tree
        register_tree(registry, "train/master", master, kind="param")
        register_tree(registry, "train/params", params, kind="param")
    return TrainState(params=params, master=master,
                      opt=adamw.init(master, registry=registry),
                      step=jnp.zeros((), jnp.int32))


def abstract(model, compute_dtype=jnp.bfloat16) -> TrainState:
    """ShapeDtypeStruct state (no allocation) for AOT lowering."""
    master = model.abstract_params(jnp.float32)
    cast = lambda dt: jax.tree_util.tree_map(
        lambda p: jax.ShapeDtypeStruct(p.shape, dt), master)
    return TrainState(params=cast(compute_dtype), master=master,
                      opt=adamw.AdamWState(m=cast(jnp.float32), v=cast(jnp.float32)),
                      step=jax.ShapeDtypeStruct((), jnp.int32))


def state_specs(model, strategy):
    """PartitionSpec tree matching TrainState."""
    import jax.sharding as shd
    p_specs = strategy.param_specs(model)
    o_specs = strategy.opt_specs(model)
    return TrainState(params=p_specs, master=o_specs,
                      opt=adamw.AdamWState(m=o_specs, v=o_specs),
                      step=shd.PartitionSpec())
