"""LM losses. Written GSPMD-friendly: the label log-prob is a one-hot
contraction over the (possibly vocab-sharded) logits dim, so no device ever
materializes a gathered logits tensor."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sharding.ctx import shard


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  z_loss: float = 0.0):
    """logits: (B,S,V); labels: (B,S) int32. Returns (loss, metrics)."""
    lf = shard(logits.astype(jnp.float32), "btv")
    m = jax.lax.stop_gradient(jnp.max(lf, axis=-1, keepdims=True))
    shifted = lf - m
    sumexp = jnp.sum(jnp.exp(shifted), axis=-1)
    lse = jnp.log(sumexp) + m[..., 0]
    # one-hot sharded like the logits, or it replicates (B,S,V) per device
    onehot = shard(jax.nn.one_hot(labels, lf.shape[-1], dtype=jnp.bfloat16),
                   "btv")
    ll = jnp.einsum("bsv,bsv->bs", lf, onehot,
                    preferred_element_type=jnp.float32)
    nll = lse - ll
    loss = jnp.mean(nll)
    metrics = {"nll": loss}
    if z_loss:
        zl = z_loss * jnp.mean(jnp.square(lse))
        loss = loss + zl
        metrics["z_loss"] = zl
    return loss, metrics
