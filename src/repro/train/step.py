"""Train-step factory: pjit'd mixed-precision AdamW step with optional
gradient accumulation, gradient clipping, remat, and int8 gradient
compression on the pod-crossing reduction."""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig
from repro.optim import adamw
from repro.optim.schedule import lr_at
from repro.sharding.ctx import sharding_ctx
from repro.train import state as S
from repro.train.loss import cross_entropy


def _compress_int8_ef(g: jax.Array) -> jax.Array:
    """int8 quantize-dequantize with per-tensor scale (error feedback is
    carried by the optimizer moments; DESIGN.md §4). Models the wire format
    of the cross-pod gradient all-reduce."""
    gf = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-8) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    return (q.astype(jnp.float32) * scale).astype(g.dtype)


def make_train_step(model, tc: TrainConfig, strategy=None):
    """Returns train_step(state, batch) -> (new_state, metrics)."""
    model.remat = tc.remat
    sharder = strategy.sharder() if strategy is not None else None
    # Constrain gradients to the optimizer-state sharding right where they
    # are produced: without this GSPMD all-reduces full replicated f32
    # grads (measured 682 GB/step/device at vision-90b scale) instead of
    # reduce-scattering to the ZeRO shards. §Perf hillclimb A3.
    grad_specs = None
    if strategy is not None:
        gs = strategy.opt_specs(model)
        mesh = strategy.mesh

        def _constrain_grads(grads):
            return jax.tree_util.tree_map(
                lambda g, s: jax.lax.with_sharding_constraint(
                    g, jax.sharding.NamedSharding(mesh, s)),
                grads, gs,
                is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
        grad_specs = _constrain_grads

    def loss_fn(params, batch):
        with sharding_ctx(sharder):
            loss, metrics = model.loss(params, batch, z_loss=tc.z_loss)
        return loss, metrics

    def compute_grads(params, batch):
        if tc.microbatches <= 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            return loss, metrics, grads

        def micro(carry, mb):
            acc, _ = carry
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, mb)
            acc = jax.tree_util.tree_map(jnp.add, acc, grads)
            return (acc, loss), metrics

        k = tc.microbatches
        mbatch = jax.tree_util.tree_map(
            lambda x: x.reshape((k, x.shape[0] // k) + x.shape[1:]), batch)
        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (acc, loss), metrics = jax.lax.scan(micro, (zeros, 0.0), mbatch)
        grads = jax.tree_util.tree_map(lambda g: g / k, acc)
        metrics = jax.tree_util.tree_map(lambda m: m[-1], metrics)
        return loss, metrics, grads

    def train_step(state: S.TrainState, batch: Dict[str, Any]):
        loss, metrics, grads = compute_grads(state.params, batch)
        if grad_specs is not None:
            grads = grad_specs(grads)
        if tc.grad_compression == "int8_ef":
            grads = jax.tree_util.tree_map(_compress_int8_ef, grads)
        grads, gnorm = adamw.clip_by_global_norm(grads, tc.grad_clip)
        lr = lr_at(tc, state.step)
        new_master, new_opt = adamw.update(
            tc, grads, state.opt, state.master, lr, state.step)
        new_params = jax.tree_util.tree_map(
            lambda m, p: m.astype(p.dtype), new_master, state.params)
        new_state = S.TrainState(params=new_params, master=new_master,
                                 opt=new_opt, step=state.step + 1)
        metrics = dict(metrics)
        metrics.update(loss=loss, grad_norm=gnorm, lr=lr)
        return new_state, metrics

    return train_step


def make_eval_step(model, strategy=None):
    """Forward-only step (prefill / eval): batch -> (logits, aux)."""
    sharder = strategy.sharder() if strategy is not None else None

    def eval_step(params, batch):
        with sharding_ctx(sharder):
            logits, aux = model.forward(
                params, batch["tokens"],
                img=batch.get("img"), frames=batch.get("frames"))
        return logits, aux
    return eval_step
