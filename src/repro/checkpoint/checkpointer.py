"""Sharded, atomic, async-capable checkpointing.

Layout: ``<dir>/step_<N>/`` holding one ``.npy`` per pytree leaf plus an
``index.json`` with key-paths, shapes, dtypes and the step. Writes land in
``step_<N>.tmp`` and are renamed atomically, so a crash mid-write never
corrupts the latest checkpoint. ``save_async`` runs the serialization on a
background thread (the train loop only blocks on the previous write).

Restore is mesh-agnostic: leaves are loaded as full arrays and re-placed
with whatever shardings the *current* mesh prescribes — this is the
elastic-restart path (repro.checkpoint.elastic).
"""
from __future__ import annotations

import json
import re
import shutil
import threading
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {jax.tree_util.keystr(k): v for k, v in flat}


def _sanitize(key: str) -> str:
    return re.sub(r"[^A-Za-z0-9_.-]", "_", key)


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------
    def save(self, step: int, tree: Any) -> Path:
        self.wait()
        return self._save_sync(step, jax.device_get(tree))

    def save_async(self, step: int, tree: Any) -> None:
        self.wait()
        host_tree = jax.device_get(tree)      # snapshot before returning
        self._thread = threading.Thread(
            target=self._save_sync, args=(step, host_tree), daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _save_sync(self, step: int, host_tree: Any) -> Path:
        final = self.dir / f"step_{step:08d}"
        tmp = self.dir / f"step_{step:08d}.tmp"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        flat = _flatten(host_tree)
        index: Dict[str, Any] = {"step": step, "leaves": {}}
        for key, val in flat.items():
            arr = np.asarray(val)
            true_dtype = str(arr.dtype)
            if arr.dtype.kind == "V" or "bfloat16" in true_dtype \
                    or "float8" in true_dtype:
                # numpy can't round-trip ml_dtypes: store widened, record
                # the true dtype (bf16->f32 is lossless)
                arr = arr.astype(np.float32)
            fname = _sanitize(key) + ".npy"
            np.save(tmp / fname, arr)
            index["leaves"][key] = {"file": fname, "shape": list(arr.shape),
                                    "dtype": true_dtype}
        (tmp / "index.json").write_text(json.dumps(index))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)                      # atomic publish
        self._gc()
        return final

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # ------------------------------------------------------------------
    def all_steps(self):
        out = []
        for p in self.dir.glob("step_*"):
            if p.suffix == ".tmp" or not (p / "index.json").exists():
                continue
            out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template: Any, step: Optional[int] = None,
                shardings: Any = None) -> Any:
        """Restore into the structure of `template`. If `shardings` is a
        matching tree of NamedSharding, leaves are placed sharded (elastic:
        works for any mesh, not just the one that wrote the checkpoint)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = self.dir / f"step_{step:08d}"
        index = json.loads((path / "index.json").read_text())

        flat_t, treedef = jax.tree_util.tree_flatten_with_path(template)
        shard_flat = (jax.tree_util.tree_flatten(shardings)[0]
                      if shardings is not None else [None] * len(flat_t))
        leaves = []
        for (kp, tmpl), shd in zip(flat_t, shard_flat):
            key = jax.tree_util.keystr(kp)
            meta = index["leaves"].get(key)
            if meta is None:
                raise KeyError(f"checkpoint missing leaf {key}")
            arr = np.load(path / meta["file"])
            if list(arr.shape) != list(tmpl.shape):
                raise ValueError(
                    f"shape mismatch for {key}: ckpt {arr.shape} vs "
                    f"template {tmpl.shape}")
            if shd is not None:
                leaves.append(jax.device_put(arr, shd))
            else:
                leaves.append(jax.numpy.asarray(arr, dtype=tmpl.dtype))
        return jax.tree_util.tree_unflatten(treedef, leaves)
