"""Elastic restart: resume a checkpoint onto a *different* mesh.

Checkpoint leaves are stored as full (unsharded) arrays, so restoring onto
a grown or shrunk device set is just re-placement with the new mesh's
shardings. The only real decision is rebuilding the mesh from however many
devices survived — ``launch.mesh.make_elastic_mesh`` — and recomputing the
strategy's specs against it. Data order is preserved because the synthetic
pipeline is a pure function of (seed, step).
"""
from __future__ import annotations

from typing import Any, Optional

import jax

from repro.checkpoint.checkpointer import Checkpointer
from repro.launch.mesh import make_elastic_mesh
from repro.sharding.rules import make_strategy
from repro.train import state as TS


def resume_elastic(ckpt_dir: str, model, strategy_name: str = "dp_tp",
                   num_devices: Optional[int] = None,
                   step: Optional[int] = None):
    """Returns (mesh, strategy, restored TrainState)."""
    n = num_devices or len(jax.devices())
    mesh = make_elastic_mesh(n)
    strat = make_strategy(strategy_name, mesh)
    specs = TS.state_specs(model, strat)
    shardings = jax.tree_util.tree_map(
        lambda s: jax.sharding.NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    template = TS.abstract(model)
    ckpt = Checkpointer(ckpt_dir)
    with mesh:
        state = ckpt.restore(template, step=step, shardings=shardings)
    return mesh, strat, state
