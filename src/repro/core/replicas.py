"""OJXPerf-style replica-object detection (DESIGN.md § Object tier).

OJXPerf (arXiv 2203.12712) samples object contents and reports
bit-identical *replica* objects — memory a dedup would reclaim. This
port content-hashes every live object in a `core/objects.py` registry
(sampled, chunked digests so the scan stays lightweight at fleet scale)
and emits one tier-5 finding per replica group:

- ``replica_kv_page``: duplicate KV pool pages — the duplicated-prefix
  pages the ``PrefixIndex`` missed, e.g. same-burst admissions whose
  prefixes were not yet registered, or reuse windows cut at mismatched
  page-granularity boundaries. Fix: content-addressed page
  routing/admission (``content_dedup`` on the router + engine).
- ``replica_param``: weight tensors replicated across fleet replicas.
  Fix: a shared weight arena mapped once per host.
- ``replica_opt_state``: bit-identical optimizer-state leaves (e.g.
  freshly zero-initialized moments). Fix: dedup/lazy-materialize.

Every finding carries the duplicate's allocation site (file:line from
the registry) for the SARIF ``physicalLocation``, the member object
keys as its ⟨C1,C2⟩ so §5.6 coalescing works across scans, and a
``meta["fix"]`` naming the dedup. Replica bytes are also billed to the
duplicate objects in the profile's DJXPerf object table.
"""
from __future__ import annotations

import hashlib
from typing import Dict, List, Optional

import numpy as np

from repro.core.findings import TIER_OBJECT, Finding, WasteProfile
from repro.core.objects import ObjectRecord, ObjectRegistry

# object kind -> replica finding kind
REPLICA_KINDS = {
    "kv_page": "replica_kv_page",
    "param": "replica_param",
    "opt_state": "replica_opt_state",
    "draft_window": "replica_draft_window",
}

FIXES = {
    "replica_kv_page": ("content-addressed page dedup: route and admit "
                        "same-content prefixes to the owning replica so "
                        "the PrefixIndex shares one physical page "
                        "(engine/router content_dedup)"),
    "replica_param": ("shared weight arena: map one parameter copy and "
                      "hand every replica a view"),
    "replica_opt_state": ("dedup identical optimizer-state leaves "
                          "(zero-init moments): lazy-materialize on "
                          "first non-zero update"),
    "replica_draft_window": ("share one draft window per replica batch "
                             "instead of per slot"),
}

# digest the whole buffer below this size; sample chunks above it
_FULL_BELOW = 1 << 16
_CHUNK = 4096
_N_STRIDED = 6


def object_digest(arr) -> str:
    """Content digest of one object's bytes, shape/dtype-qualified.

    Small objects hash fully; large ones hash head + tail + strided
    interior chunks (OJXPerf's sampling trade: a replica pair is never
    missed — identical buffers always digest equal — while a collision
    between *different* buffers needs them to agree on every sampled
    chunk AND shape/dtype/nbytes, which the differing suffix pages of
    near-duplicate KV content breaks immediately)."""
    a = np.ascontiguousarray(np.asarray(arr))
    h = hashlib.blake2b(digest_size=12)
    h.update(f"{a.shape}|{a.dtype}|{a.nbytes}".encode())
    buf = a.view(np.uint8).reshape(-1)
    if a.nbytes <= _FULL_BELOW:
        h.update(buf.tobytes())
    else:
        h.update(buf[:_CHUNK].tobytes())
        h.update(buf[-_CHUNK:].tobytes())
        step = max((a.nbytes - 2 * _CHUNK) // (_N_STRIDED + 1), 1)
        for i in range(1, _N_STRIDED + 1):
            off = _CHUNK + i * step
            h.update(buf[off:off + _CHUNK].tobytes())
    return h.hexdigest()


class ReplicaDetector:
    """Scan a registry for bit-identical live objects (per kind)."""

    def __init__(self, registry: ObjectRegistry, *, min_bytes: int = 1):
        self.registry = registry
        self.min_bytes = min_bytes

    def scan(self) -> WasteProfile:
        prof = WasteProfile(tier=TIER_OBJECT)
        for kind, fkind in REPLICA_KINDS.items():
            groups: Dict[str, List[ObjectRecord]] = {}
            for rec in self.registry.live(kind):
                if rec.reader is None or rec.nbytes < self.min_bytes:
                    continue
                buf = np.asarray(rec.reader())
                if kind == "kv_page" and not buf.any():
                    # all-zero KV pages are unwritten budget capacity
                    # (pages cover prompt+gen up front) — not content a
                    # prefix dedup could share, so they are skipped
                    # rather than reported as one giant replica group.
                    # Zero PARAM/OPT leaves stay in: identical zero
                    # moments are the lazy-materialize finding.
                    continue
                prof.observe(fkind, False)  # checked; flag below
                groups.setdefault(object_digest(buf), []).append(rec)
            for digest, members in sorted(groups.items()):
                if len(members) < 2:
                    continue
                members.sort(key=lambda r: r.name)
                canon, dups = members[0], members[1:]
                owners = sorted({r.owner for r in members})
                waste = float(sum(r.nbytes for r in dups))
                # flip the pre-counted observations for the duplicates
                prof.flagged[fkind] = (prof.flagged.get(fkind, 0)
                                       + len(dups))
                prof.add(Finding(
                    kind=fkind, tier=TIER_OBJECT,
                    c1=(canon.object_key,),
                    c2=tuple(r.object_key for r in dups),
                    count=len(dups), bytes=waste,
                    fraction=len(dups) / len(members),
                    meta={"fix": FIXES[fkind],
                          "file": dups[0].file, "line": dups[0].line,
                          "digest": digest,
                          "replicas": owners,
                          "cross_replica": len(owners) > 1}))
                for r in dups:
                    prof.bill_object(r, "replica", r.nbytes)
        prof.bump_total("replica_bytes",
                        sum(f.bytes for f in prof.findings))
        return prof


def cross_replica_bytes(prof: WasteProfile,
                        kind: Optional[str] = None) -> float:
    """Replica bytes whose members span more than one owner — the
    fleet-level dedup opportunity (and the CI gate's 0-after-dedup
    assertion)."""
    return sum(f.bytes for f in prof.findings
               if f.tier == TIER_OBJECT
               and f.meta.get("cross_replica")
               and (kind is None or f.kind == kind))
