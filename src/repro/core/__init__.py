"""JXPerf-JAX: the paper's contribution as a composable module.

Three detection tiers (DESIGN.md §2):
  Tier 1  runtime value profiler      (interpreter.profile_fn)
  Tier 2  compiled-HLO waste analysis (hlo_waste.analyze_waste)
  Tier 3  training-loop detectors     (detectors.TrainingDetectors)
plus the reservoir watchpoint manager (reservoir.ReservoirWatchpoints)
and the trip-count-correct HLO cost model (hlo_cost.HloCostModel).
"""
from repro.core.reservoir import ReservoirWatchpoints, Watchpoint  # noqa: F401
from repro.core.interpreter import JxInterpreter, profile_fn, Report  # noqa: F401
from repro.core.detectors import TrainingDetectors, Tier3Report  # noqa: F401
from repro.core.hlo_waste import analyze_waste, WasteReport  # noqa: F401
from repro.core.hlo_cost import HloCostModel  # noqa: F401
from repro.core.report import merge_reports, render  # noqa: F401
