"""JXPerf-JAX core: the paper's contribution as a composable module.

One measurement substrate (DESIGN.md §2):
  events.py    typed memory-event stream, PMU-style geometric sampler,
               reservoir watchpoints + trap classification (EventEngine),
               trace→replay multi-epoch profiling (EventTrace)
  findings.py  the unified Finding / WasteProfile schema every tier
               emits: mergeable across epochs, shards and tiers;
               lossless JSON round-trip

Three detection tiers on top of it:
  Tier 1  runtime value profiler      (interpreter.profile_fn)
  Tier 2  compiled-HLO waste analysis (hlo_waste.analyze_waste)
  Tier 3  training-loop detectors     (detectors.TrainingDetectors)
plus the reservoir watchpoint manager (reservoir.ReservoirWatchpoints)
and the trip-count-correct HLO cost model (hlo_cost.HloCostModel).
"""
from repro.core.reservoir import ReservoirWatchpoints, Watchpoint  # noqa: F401
from repro.core.events import (EventEngine, EventTrace, GeometricSampler,  # noqa: F401
                               MemEvent, approx_equal, silent_mask)
from repro.core.findings import (Finding, WasteProfile, merge,  # noqa: F401
                                 merge_profiles)
from repro.core.interpreter import JxInterpreter, profile_fn, Report  # noqa: F401
from repro.core.detectors import TrainingDetectors, Tier3Report  # noqa: F401
from repro.core.hlo_waste import analyze_waste, WasteReport  # noqa: F401
from repro.core.hlo_cost import HloCostModel  # noqa: F401
from repro.core.report import (dump_json, load_json, merge_reports,  # noqa: F401
                               merge_shards, render)
