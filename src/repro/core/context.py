"""Calling contexts and ⟨C1,C2⟩ pair bookkeeping (paper §5.5-§5.6).

A context is the full user-code call path of a jaxpr equation
(``source_info`` traceback), ending at the primitive — the analogue of
``packageA.classB.methodC:line -> ... -> String.equals():line``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Tuple

from jax._src import source_info_util


def context_of_eqn(eqn, max_frames: int = 12) -> Tuple[str, ...]:
    """Full calling context for a jaxpr eqn from its source_info."""
    frames = []
    try:
        tb = eqn.source_info.traceback
        for f in source_info_util.user_frames(eqn.source_info):
            frames.append(f"{f.file_name.split('/')[-1]}:{f.start_line}:{f.function_name}")
            if len(frames) >= max_frames:
                break
    except Exception:
        pass
    frames.reverse()                      # outermost -> innermost
    frames.append(str(eqn.primitive.name))
    return tuple(frames)


def fmt_context(ctx: Tuple[str, ...]) -> str:
    return " -> ".join(ctx)


@dataclass
class PairStats:
    count: int = 0
    bytes: float = 0.0


class PairTable:
    """⟨C_watch, C_trap⟩ -> stats, mergeable across shards (§5.6: two pairs
    coalesce iff both contexts match)."""

    def __init__(self):
        self.pairs: Dict[Tuple[Tuple[str, ...], Tuple[str, ...]], PairStats] = {}

    def add(self, c1, c2, nbytes: float) -> None:
        st = self.pairs.setdefault((c1, c2), PairStats())
        st.count += 1
        st.bytes += nbytes

    def merge(self, other: "PairTable") -> "PairTable":
        for k, v in other.pairs.items():
            st = self.pairs.setdefault(k, PairStats())
            st.count += v.count
            st.bytes += v.bytes
        return self

    def top(self, k: int = 10):
        items = sorted(self.pairs.items(), key=lambda kv: -kv[1].bytes)
        return items[:k]

    @property
    def total_bytes(self) -> float:
        return sum(v.bytes for v in self.pairs.values())

    @property
    def total_count(self) -> int:
        return sum(v.count for v in self.pairs.values())
