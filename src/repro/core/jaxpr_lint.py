"""Tier-0: static waste lint over closed jaxprs (DESIGN.md § Static tier).

The earliest point in the pipeline where the paper's waste classes are
visible: the jaxpr of a train step / engine tick / prefill *before* XLA
sees it. Tier 2 (`core/hlo_waste.py`) inspects the optimized HLO, which
is post-CSE/DCE and attributes waste to compiler-mangled op names; here
every equation still carries ``source_info``, so findings point at the
Python ``file:line`` that wrote the waste — the static analogue of
JXPerf's ⟨C1,C2⟩ calling contexts.

Rules, each mapped to a paper definition:

  dead_store      (Def. 1)  a ``dynamic_update_slice``/``scatter`` whose
                            written region is fully overwritten by the
                            next store to the same region before any
                            read, or whose result is never read at all;
  silent_store    (Def. 2)  a store of a value provably equal to what is
                            already resident: scatter/DUS of a slice
                            gathered from the same buffer at the same
                            offsets, and x+0 / x-0 / x*1 / x/1 identity
                            chains (the stored value IS the operand);
  redundant_load  (Def. 3)  the same unmutated buffer gathered/sliced
                            with identical indices more than once within
                            a scope, including across ``scan`` iterations
                            (a loop-invariant gather re-executes every
                            trip);
  dead_param      (Def. 1 at allocation granularity)  jaxpr invars that
                            reach no output and no effectful equation —
                            a buffer marshalled in and never read (dead
                            expert weights in MoE dispatch, unused cache
                            leaves).

Equivalence of index chains is decided by hash-consing value numbers
(``jnp`` index normalization clones ``lt/add/select_n`` chains per use,
so var identity is useless); value numbers flow through ``pjit`` /
``remat`` / ``custom_*`` call boundaries, and scan bodies seed their
const invars as loop-invariant so invariance is derivable per equation.

Findings land in the unified ``WasteProfile`` as ``TIER_STATIC = 0``,
mergeable with tiers 1-4 and exportable as SARIF (`core/sarif.py`).
"""
from __future__ import annotations

import numpy as np
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax

try:
    from jax.extend.core import Literal
except ImportError:  # pragma: no cover
    from jax.core import Literal

from repro.core.context import context_of_eqn
from repro.core.findings import TIER_STATIC, Finding, WasteProfile

# primitives that *store into* a region of an existing buffer
_STORE_PRIMS = ("dynamic_update_slice", "scatter")
# primitives that *load* a region of a buffer
_LOAD_PRIMS = ("gather", "dynamic_slice", "slice")
# control/call primitives walked recursively, never value-numbered
_CONTROL_PRIMS = ("scan", "while", "cond")
_IDENTITY_PRIMS = {"add": 0.0, "sub": 0.0, "mul": 1.0, "div": 1.0}


def _nbytes(aval) -> float:
    try:
        return float(np.prod(aval.shape, dtype=np.float64)
                     * np.dtype(aval.dtype).itemsize)
    except Exception:
        return 0.0


def _src_of(eqn) -> Tuple[Optional[str], int]:
    """Innermost user frame of an eqn: (absolute file path, line)."""
    try:
        from jax._src import source_info_util
        for f in source_info_util.user_frames(eqn.source_info):
            return f.file_name, int(f.start_line)
    except Exception:
        pass
    return None, 0


def _inner_closed_jaxpr(eqn):
    for key in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
        if key in eqn.params:
            return eqn.params[key]
    return None


class _VarInfo:
    """Per-var lint state: value number + loop-invariance in scope."""
    __slots__ = ("vn", "invariant")

    def __init__(self, vn: int, invariant: bool):
        self.vn = vn
        self.invariant = invariant


class _LoadRec:
    __slots__ = ("eqn", "vn", "nbytes", "invariant")

    def __init__(self, eqn, vn, nbytes, invariant):
        self.eqn, self.vn, self.nbytes = eqn, vn, nbytes
        self.invariant = invariant


class JaxprLinter:
    """Walk a ClosedJaxpr and emit a tier-0 :class:`WasteProfile`."""

    def __init__(self, subject: str = "fn"):
        self.subject = subject
        self.profile = WasteProfile(tier=TIER_STATIC)
        self.profile.meta["subject"] = subject
        self._vn_table: Dict[Tuple, int] = {}
        self._next_vn = 0
        # vn -> known scalar constant (literals / broadcast of literal)
        self._const: Dict[int, Any] = {}
        # vn of a load result -> (source vn, index vns, result shape)
        self._load_src: Dict[int, Tuple[int, Tuple[int, ...],
                                        Tuple[int, ...]]] = {}

    # -- value numbering ------------------------------------------------
    def _fresh_vn(self) -> int:
        self._next_vn += 1
        return self._next_vn

    def _fresh_info(self, invariant: bool = True) -> _VarInfo:
        return _VarInfo(self._fresh_vn(), invariant)

    def _vn_of_key(self, key: Tuple) -> int:
        vn = self._vn_table.get(key)
        if vn is None:
            vn = self._fresh_vn()
            self._vn_table[key] = vn
        return vn

    def _lit_info(self, lit: Literal) -> _VarInfo:
        val = np.asarray(lit.val)
        key = ("lit", str(val.dtype), val.shape, val.tobytes())
        vn = self._vn_of_key(key)
        if val.size == 1:
            self._const.setdefault(vn, val.reshape(()).item())
        return _VarInfo(vn, True)

    @staticmethod
    def _params_key(params: Dict[str, Any]) -> str:
        try:
            return repr(sorted(params.items(), key=lambda kv: kv[0]))
        except Exception:
            return repr(sorted(params.keys()))

    # -- findings -------------------------------------------------------
    def _flag(self, kind: str, eqn, *, bytes=0.0, count=1, c2_eqn=None,
              fraction=0.0, **meta) -> None:
        c1 = context_of_eqn(eqn)
        c2 = context_of_eqn(c2_eqn) if c2_eqn is not None else ()
        f, line = _src_of(eqn)
        if f is not None:
            meta.setdefault("file", f)
            meta.setdefault("line", line)
        meta.setdefault("subject", self.subject)
        self.profile.add(Finding(kind=kind, tier=TIER_STATIC, c1=c1, c2=c2,
                                 count=count, bytes=float(bytes),
                                 fraction=fraction, meta=meta))

    def _flag_dead_param(self, label: str, aval, where: str) -> None:
        self.profile.add(Finding(
            kind="dead_param", tier=TIER_STATIC,
            c1=(f"{self.subject}:{label}",), c2=(where,),
            bytes=_nbytes(aval),
            meta={"path": f"{self.subject}:{label}", "subject": self.subject,
                  "shape": str(getattr(aval, "shape", "?")),
                  "rule": "invar reaches no output"}))

    # -- entry ----------------------------------------------------------
    def lint(self, closed, arg_labels: Optional[Sequence[str]] = None
             ) -> WasteProfile:
        jaxpr = closed.jaxpr
        infos = [self._fresh_info(invariant=False)
                 for _ in list(jaxpr.constvars) + list(jaxpr.invars)]
        labels: Dict[Any, str] = {}
        if arg_labels:
            for v, lab in zip(jaxpr.invars, arg_labels):
                labels[v] = lab
        self._walk(jaxpr, infos, mult=1.0, scan_len=None,
                   labels=labels, top=True)
        return self.profile

    # -- the walker -----------------------------------------------------
    def _walk(self, jaxpr, in_infos: List[_VarInfo], *, mult: float,
              scan_len: Optional[int], labels: Dict[Any, str],
              top: bool = False,
              shared_loads: Optional[List[_LoadRec]] = None
              ) -> Tuple[List[_VarInfo], set]:
        """Lint one (sub)jaxpr. Returns (outvar infos, live invar set).

        ``shared_loads``: transparent call boundaries (pjit/remat/
        custom_*) pass their caller's load list so identical loads in
        sibling calls coalesce — ``jnp.take`` nests its gather inside a
        fresh pjit per call site, so per-scope lists would never see the
        duplicate. When set, the dup/loop-invariant epilogue is the
        owner's job, not ours."""
        env: Dict[Any, _VarInfo] = {}
        for v, info in zip(list(jaxpr.constvars) + list(jaxpr.invars),
                           in_infos):
            env[v] = info

        def info_of(v) -> _VarInfo:
            if isinstance(v, Literal):
                return self._lit_info(v)
            return env[v]

        use_count: Dict[Any, int] = {}
        for eqn in jaxpr.eqns:
            for v in eqn.invars:
                if not isinstance(v, Literal):
                    use_count[v] = use_count.get(v, 0) + 1
        outvar_set = {v for v in jaxpr.outvars if not isinstance(v, Literal)}

        producer: Dict[Any, Any] = {}          # var -> producing eqn
        owns_loads = shared_loads is None
        loads: List[_LoadRec] = [] if owns_loads else shared_loads
        store_eqns: List[Any] = []
        dead_stores: set = set()               # id(eqn) flagged dead
        silent_stores: set = set()             # id(eqn) flagged silent

        for eqn in jaxpr.eqns:
            name = eqn.primitive.name
            infos = [info_of(v) for v in eqn.invars]
            inner = _inner_closed_jaxpr(eqn)

            if name in _CONTROL_PRIMS or inner is not None:
                out_infos = self._walk_call(eqn, infos, mult=mult,
                                            scan_len=scan_len,
                                            labels=labels, loads=loads)
            else:
                out_infos = self._number_eqn(eqn, infos)
                self._check_eqn(eqn, infos, out_infos, info_of, producer,
                                use_count, mult=mult, loads=loads,
                                store_eqns=store_eqns,
                                dead_stores=dead_stores,
                                silent_stores=silent_stores,
                                outvar_set=outvar_set)
            for ov, oi in zip(eqn.outvars, out_infos):
                env[ov] = oi
                producer[ov] = eqn

        # ---- liveness (reverse pass) ----------------------------------
        live: set = set(outvar_set)
        for eqn in reversed(jaxpr.eqns):
            if (any(ov in live for ov in eqn.outvars)
                    or bool(getattr(eqn, "effects", ()))):
                for v in eqn.invars:
                    if not isinstance(v, Literal):
                        live.add(v)

        # ---- unused store results -> dead stores ----------------------
        for eqn in store_eqns:
            if id(eqn) in dead_stores:
                continue
            if not any(ov in live for ov in eqn.outvars):
                dead_stores.add(id(eqn))
                upd = eqn.invars[2 if eqn.primitive.name == "scatter"
                                 else 1]
                self._flag("dead_store", eqn,
                           bytes=_nbytes(upd.aval) * mult,
                           count=max(int(mult), 1),
                           rule="store result never read")

        # ---- estimator counters for stores ----------------------------
        for eqn in store_eqns:
            self.profile.observe("dead_store", id(eqn) in dead_stores)
            self.profile.observe("silent_store", id(eqn) in silent_stores)

        # ---- duplicate / loop-invariant loads -------------------------
        if not owns_loads:
            return [info_of(v) for v in jaxpr.outvars], live
        by_vn: Dict[int, List[_LoadRec]] = {}
        for rec in loads:
            by_vn.setdefault(rec.vn, []).append(rec)
        for vn, recs in by_vn.items():
            dup = len(recs) > 1
            loop_inv = (not dup and recs[0].invariant
                        and scan_len is not None and scan_len > 1)
            for j, rec in enumerate(recs):
                self.profile.observe("redundant_load",
                                     (dup and j > 0) or loop_inv)
            if dup:
                extra = sum(r.nbytes for r in recs[1:]) * mult
                self._flag("redundant_load", recs[0].eqn, bytes=extra,
                           count=(len(recs) - 1) * max(int(mult), 1),
                           c2_eqn=recs[1].eqn,
                           rule="same buffer loaded at identical indices "
                                f"{len(recs)}x in one scope")
            elif loop_inv:
                rec = recs[0]
                outer = mult / scan_len
                self._flag("redundant_load", rec.eqn,
                           bytes=rec.nbytes * (scan_len - 1) * outer,
                           count=max(int((scan_len - 1) * outer), 1),
                           fraction=1.0 - 1.0 / scan_len,
                           rule=f"loop-invariant load re-executed by "
                                f"scan[length={scan_len}]")

        # ---- dead invars ----------------------------------------------
        if top:
            for i, v in enumerate(jaxpr.invars):
                self.profile.observe("dead_param", v not in live)
                if v not in live:
                    self._flag_dead_param(labels.get(v, f"arg{i}"), v.aval,
                                          where="top-level jaxpr")
        return [info_of(v) for v in jaxpr.outvars], live

    # -- per-eqn numbering ----------------------------------------------
    def _number_eqn(self, eqn, infos: List[_VarInfo]) -> List[_VarInfo]:
        name = eqn.primitive.name
        invariant = (all(i.invariant for i in infos)
                     and not getattr(eqn, "effects", ()))
        key = (name, self._params_key(eqn.params),
               tuple(i.vn for i in infos))
        if len(eqn.outvars) == 1:
            vns = [self._vn_of_key(key)]
        else:
            vns = [self._vn_of_key(key + ("#out", k))
                   for k in range(len(eqn.outvars))]
        # constant propagation for the silent-identity rule
        if name in ("broadcast_in_dim", "convert_element_type") \
                and infos and infos[0].vn in self._const:
            self._const.setdefault(vns[0], self._const[infos[0].vn])
        return [_VarInfo(vn, invariant) for vn in vns]

    # -- local rules ----------------------------------------------------
    def _check_eqn(self, eqn, infos, out_infos,
                   info_of: Callable[[Any], _VarInfo], producer, use_count,
                   *, mult, loads, store_eqns, dead_stores, silent_stores,
                   outvar_set) -> None:
        name = eqn.primitive.name

        # ---- identity chains: store of a provably-equal value ---------
        if name in _IDENTITY_PRIMS and len(eqn.invars) == 2:
            ident = _IDENTITY_PRIMS[name]
            for xi, ci in ((0, 1), (1, 0)):
                if name in ("sub", "div") and ci == 0:
                    continue       # 0-x / 1/x are not identities
                cval = self._const.get(infos[ci].vn)
                xv = eqn.invars[xi]
                if cval is not None and cval == ident \
                        and not isinstance(xv, Literal) \
                        and tuple(getattr(xv.aval, "shape", ())) \
                        == tuple(eqn.outvars[0].aval.shape):
                    self.profile.observe("silent_store", True)
                    self._flag(
                        "silent_store", eqn,
                        bytes=_nbytes(eqn.outvars[0].aval) * mult,
                        count=max(int(mult), 1),
                        rule=f"identity {name} with {cval!r}: stores a "
                             f"value equal to the resident operand")
                    # the result IS the operand: share its value number
                    out_infos[0].vn = infos[xi].vn
                    return

        # ---- loads ----------------------------------------------------
        if name in _LOAD_PRIMS:
            nb = _nbytes(eqn.outvars[0].aval)
            loads.append(_LoadRec(eqn, out_infos[0].vn, nb,
                                  all(i.invariant for i in infos)))
            src_vn = infos[0].vn
            if name == "slice":    # indices live in params, not operands
                idx_vns: Tuple[int, ...] = (self._vn_of_key(
                    ("slice-idx", self._params_key(eqn.params))),)
            else:
                idx_vns = tuple(i.vn for i in infos[1:])
            self._load_src[out_infos[0].vn] = (
                src_vn, idx_vns, tuple(eqn.outvars[0].aval.shape))
            return

        # ---- stores ---------------------------------------------------
        if name not in _STORE_PRIMS:
            return
        store_eqns.append(eqn)
        if name == "dynamic_update_slice":
            opnd, upd = eqn.invars[0], eqn.invars[1]
            opnd_info, upd_info = infos[0], infos[1]
            idx_vns = tuple(i.vn for i in infos[2:])
        else:                                   # scatter (overwrite mode)
            opnd, upd = eqn.invars[0], eqn.invars[2]
            opnd_info, upd_info = infos[0], infos[2]
            idx_vns = (infos[1].vn,)

        # silent store: the update was gathered from this very buffer at
        # these very offsets (Def. 2, provable statically)
        src = self._load_src.get(upd_info.vn)
        if src is not None:
            src_vn, load_idx_vns, load_shape = src
            if src_vn == opnd_info.vn and load_idx_vns == idx_vns \
                    and load_shape == tuple(upd.aval.shape):
                silent_stores.add(id(eqn))
                self._flag("silent_store", eqn,
                           bytes=_nbytes(upd.aval) * mult,
                           count=max(int(mult), 1),
                           rule="stores the slice it gathered from the "
                                "same offsets (write-back of resident "
                                "value)")

        # dead store: this store overwrites the exact region a previous
        # store (whose result nobody else read) just wrote (Def. 1)
        prev = producer.get(opnd)
        if (prev is not None and prev.primitive.name == name
                and use_count.get(opnd, 0) == 1
                and opnd not in outvar_set
                and id(prev) not in dead_stores):
            if name == "dynamic_update_slice":
                prev_idx = tuple(info_of(v).vn for v in prev.invars[2:])
                prev_upd = prev.invars[1]
            else:
                prev_idx = (info_of(prev.invars[1]).vn,)
                prev_upd = prev.invars[2]
            if prev_idx == idx_vns and tuple(prev_upd.aval.shape) \
                    == tuple(upd.aval.shape):
                dead_stores.add(id(prev))
                self._flag("dead_store", prev,
                           bytes=_nbytes(prev_upd.aval) * mult,
                           count=max(int(mult), 1), c2_eqn=eqn,
                           rule="written region fully overwritten before "
                                "any read")

    # -- call recursion -------------------------------------------------
    def _walk_call(self, eqn, infos: List[_VarInfo], *, mult, scan_len,
                   labels, loads) -> List[_VarInfo]:
        name = eqn.primitive.name
        if name == "scan":
            return self._walk_scan(eqn, infos, mult=mult, labels=labels)
        if name == "while":
            p = eqn.params
            cj, bj = p["cond_jaxpr"], p["body_jaxpr"]
            cn, bn = p["cond_nconsts"], p["body_nconsts"]
            state = [self._fresh_info(invariant=False)
                     for _ in range(len(infos) - cn - bn)]
            self._walk(cj.jaxpr,
                       [self._fresh_info() for _ in cj.jaxpr.constvars]
                       + infos[:cn] + state,
                       mult=mult, scan_len=None, labels={})
            self._walk(bj.jaxpr,
                       [self._fresh_info() for _ in bj.jaxpr.constvars]
                       + infos[cn:cn + bn] + state,
                       mult=mult, scan_len=None, labels={})
            return [self._fresh_info() for _ in eqn.outvars]
        if name == "cond":
            for br in eqn.params["branches"]:
                self._walk(br.jaxpr,
                           [self._fresh_info() for _ in br.jaxpr.constvars]
                           + infos[1:],
                           mult=mult, scan_len=scan_len, labels={})
            return [self._fresh_info() for _ in eqn.outvars]
        # pjit / remat / closed_call / custom_jvp / custom_vjp: value
        # numbers and invariance flow straight through the boundary
        cj = _inner_closed_jaxpr(eqn)
        inner, consts = (cj.jaxpr, cj.consts) if hasattr(cj, "jaxpr") \
            else (cj, [])
        const_infos = [self._fresh_info() for _ in inner.constvars]
        # extra caller operands beyond the inner signature (custom_*
        # bookkeeping args) are dropped positionally from the left
        n = len(inner.invars)
        off = max(len(infos) - n, 0)
        arg_infos = infos[off:]
        arg_infos += [self._fresh_info()
                      for _ in range(n - len(arg_infos))]
        inner_labels = {iv: labels[ov]
                        for iv, ov in zip(inner.invars, eqn.invars[off:])
                        if not isinstance(ov, Literal) and ov in labels}
        outs, _ = self._walk(inner, const_infos + arg_infos, mult=mult,
                             scan_len=scan_len, labels=inner_labels,
                             shared_loads=loads)
        if len(outs) == len(eqn.outvars):
            return outs
        return [self._fresh_info() for _ in eqn.outvars]

    def _walk_scan(self, eqn, infos: List[_VarInfo], *, mult, labels
                   ) -> List[_VarInfo]:
        p = eqn.params
        cj = p["jaxpr"]
        nc, ncar, length = p["num_consts"], p["num_carry"], p["length"]
        body = cj.jaxpr
        const_infos = [self._fresh_info() for _ in body.constvars]
        # consts are loop-invariant BY DEFINITION inside the body; carry
        # and xs change per iteration
        arg_infos = (
            [_VarInfo(i.vn, True) for i in infos[:nc]]
            + [self._fresh_info(invariant=False)
               for _ in range(len(body.invars) - nc)])
        inner_labels = {iv: labels[ov]
                        for iv, ov in zip(body.invars, eqn.invars)
                        if not isinstance(ov, Literal) and ov in labels}
        _, live = self._walk(body, const_infos + arg_infos,
                             mult=mult * max(length, 1),
                             scan_len=length if length > 1 else None,
                             labels=inner_labels)
        # dead scan inputs: a const/xs buffer marshalled into every
        # iteration but never read by the body (the MoE dead-expert case
        # when routing ignores an expert's weights)
        for j, iv in enumerate(body.invars):
            is_carry = nc <= j < nc + ncar
            self.profile.observe("dead_param",
                                 not is_carry and iv not in live)
            if is_carry or iv in live:
                continue
            ov = eqn.invars[j] if j < len(eqn.invars) else None
            lab = inner_labels.get(iv) or (
                labels.get(ov) if ov is not None
                and not isinstance(ov, Literal) else None)
            self._flag_dead_param(
                lab or f"scan arg{j}", iv.aval,
                where=f"scan[length={length}] body "
                      f"({'const' if j < nc else 'xs'} operand unused)")
        return [self._fresh_info() for _ in eqn.outvars]


# ----------------------------------------------------------------------
def lint_jaxpr(closed, *, subject: str = "fn",
               arg_labels: Optional[Sequence[str]] = None) -> WasteProfile:
    """Lint a ClosedJaxpr; returns a tier-0 WasteProfile."""
    return JaxprLinter(subject).lint(closed, arg_labels=arg_labels)


def lint_fn(fn, *args, subject: str = "fn",
            arg_labels: Optional[Sequence[str]] = None) -> WasteProfile:
    """``make_jaxpr`` + lint. ``args`` may be arrays or ShapeDtypeStructs
    (the jaxpr is traced abstractly — no compute, no allocation).

    ``arg_labels`` defaults to the flattened pytree key paths of ``args``
    so dead-parameter findings name the buffer
    (``arg0/main/b0_moe/moe/w_up``) instead of a positional index."""
    closed = jax.make_jaxpr(fn)(*args)
    if arg_labels is None:
        arg_labels = arg_tree_labels(args)
    return lint_jaxpr(closed, subject=subject, arg_labels=arg_labels)


def arg_tree_labels(args) -> List[str]:
    """Flattened key-path labels for a tuple of pytree args (the order
    ``make_jaxpr`` assigns invars)."""
    labels = []
    for i, a in enumerate(args):
        flat = jax.tree_util.tree_flatten_with_path(a)[0]
        for path, _ in flat:
            labels.append(f"arg{i}{jax.tree_util.keystr(path)}"
                          if path else f"arg{i}")
    return labels
