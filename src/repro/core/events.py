"""Typed memory-event substrate shared by every tier (DESIGN.md §2).

The paper's measurement discipline is a pipeline: memory accesses stream
past a PMU-style sampler (geometric inter-sample gaps ≙ period-P PEBS);
sampled accesses arm reservoir-managed software watchpoints; the next
access to a watched location is the trap, classified per Definitions 1-3
with ⟨C1,C2⟩ context-pair attribution. This module is that pipeline,
extracted so Tier-1 (jaxpr interpretation), Tier-3 (training-loop
detectors) and any future detector feed the *same* machinery:

  MemEvent          one load/store over a logical buffer (+ value + ctx)
  EventTrace        a recorded flat event stream (trace→replay profiling:
                    interpret once, replay the trace for epochs 2..N)
  GeometricSampler  the PMU analogue (one sample every ~period events)
  EventEngine       sampler + watchpoints + trap classification, writing
                    into a shared findings.WasteProfile

plus the single approximate-equality helper (symmetric relative
tolerance) used by both the interpreter's scalar compares and the
silent_compare kernels — one definition of "silent" everywhere.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Iterator, List, Optional, Tuple

import numpy as np

from repro.configs.base import ProfilerConfig
from repro.core.findings import WasteProfile
from repro.core.reservoir import ReservoirWatchpoints, Watchpoint

LOAD = "load"
STORE = "store"


# ----------------------------------------------------------------------
# The one "silent" comparison (paper Defs. 2-3, FP tolerance default 1%).
# Symmetric relative tolerance: |a-b| <= tol*max(|a|,|b|). The seed's
# tol*|a| misclassified near-zero stores (a=0 made *any* b non-silent
# while a=eps made huge b silent); max(|a|,|b|) is scale-symmetric.
# ----------------------------------------------------------------------
def silent_mask(a, b, tol: float):
    """Elementwise silent-match mask; jnp/np arrays in, bool array out.
    NaNs are never silent. tol=0 gives exact (integer) equality."""
    import jax.numpy as jnp
    mod = jnp if not isinstance(a, np.ndarray) else np
    if tol == 0.0:
        eq = a == b
    else:
        eq = mod.abs(a - b) <= tol * mod.maximum(mod.abs(a), mod.abs(b))
    return eq & ~mod.isnan(a) & ~mod.isnan(b)


def approx_equal(a, b, tol: float) -> bool:
    """Scalar form of silent_mask — Tier-1's per-element trap compare."""
    a = np.asarray(a)
    b = np.asarray(b)
    if a.dtype.kind in "fc":
        fa, fb = float(np.real(a)), float(np.real(b))
        if np.isnan(fa) or np.isnan(fb):
            return False
        return abs(fa - fb) <= tol * max(abs(fa), abs(fb))
    return bool(a == b)


# ----------------------------------------------------------------------
@dataclass
class MemEvent:
    """One load/store of `nelems` elements at logical address `address`."""
    kind: str                       # LOAD | STORE
    address: int
    nelems: int
    itemsize: int
    values: Optional[np.ndarray]    # full stored/loaded value (by ref)
    ctx: Tuple[str, ...]            # full calling context of the access

    @property
    def nbytes(self) -> int:
        return self.nelems * self.itemsize

    def value_at(self, offset: int):
        """Element at `offset`, or None when the offset lies outside the
        event's value extent. A watchpoint armed at a high offset can trap
        on a shorter event at the same (recycled) address; clamping would
        silently compare the wrong element, so classification must skip —
        and disarm — instead (see EventEngine._check_traps)."""
        if self.values is None:
            return None
        flat = self.values.reshape(-1)
        if offset >= flat.size:
            return None
        return flat[offset]

    def digest(self, size: int = 8) -> str:
        """Content fingerprint (Tier-3 silent-data-load hashing). The only
        MemEvent accessor that materializes the values on the host."""
        arr = np.ascontiguousarray(np.asarray(self.values))
        return hashlib.blake2b(arr.tobytes(), digest_size=size).hexdigest()


class EventTrace:
    """Flat recorded event stream of one profiled epoch.

    Recording happens during the single concrete jaxpr evaluation; replay
    pushes the identical stream through a fresh-epoch EventEngine without
    re-binding a single primitive (values are held by reference)."""

    def __init__(self):
        self.events: List[MemEvent] = []

    def append(self, ev: MemEvent) -> None:
        self.events.append(ev)

    def __len__(self) -> int:
        return len(self.events)

    def __iter__(self) -> Iterator[MemEvent]:
        return iter(self.events)

    @property
    def element_events(self) -> int:
        return sum(ev.nelems for ev in self.events)


# ----------------------------------------------------------------------
class GeometricSampler:
    """PMU-period analogue: i.i.d. geometric gaps with mean `period`.

    `advance(n)` moves past n element-events and returns the offsets of
    the sampled ones (the same arithmetic the seed interpreter inlined)."""

    def __init__(self, period: int, rng: np.random.RandomState):
        self.period = max(1, period)
        self.rng = rng
        # the first gap is drawn lazily at the first advance(), so that
        # construct-then-reset (the engine's epoch 0) costs one draw
        self.next_sample: Optional[int] = None

    def draw_gap(self) -> int:
        return max(1, int(self.rng.geometric(1.0 / self.period)))

    def reset(self) -> None:
        """Epoch boundary: discard the partial gap; a fresh one is drawn
        at the next advance() (the RNG stream continues across epochs)."""
        self.next_sample = None

    def advance(self, n: int) -> List[int]:
        if self.next_sample is None:
            self.next_sample = self.draw_gap()
        hits: List[int] = []
        pos = 0
        remaining = n
        while self.next_sample <= remaining:
            pos += self.next_sample
            hits.append(pos - 1)
            remaining -= self.next_sample
            self.next_sample = self.draw_gap()
        self.next_sample -= remaining
        return hits


# ----------------------------------------------------------------------
class EventEngine:
    """Sampler + reservoir watchpoints + Defs. 1-3 trap classification.

    Feed it MemEvents (live from an interpreter, or replayed from an
    EventTrace); it writes pairs and estimator counters into `profile`."""

    def __init__(self, cfg: Optional[ProfilerConfig] = None, tier: int = 1):
        self.cfg = cfg or ProfilerConfig(enabled=True)
        self.tier = tier
        self.tol = self.cfg.fp_tolerance
        self.detect = set(self.cfg.detect)
        self.rng = np.random.RandomState(self.cfg.seed)
        self.sampler = GeometricSampler(self.cfg.period, self.rng)
        # store-side client selection (dead vs silent) draws from its own
        # stream so it never perturbs the sampler's geometric gaps
        self.client_rng = np.random.RandomState(self.cfg.seed + 0x5EED)
        self._store_clients = tuple(
            c for c in ("dead_store", "silent_store") if c in self.detect)
        self.profile = WasteProfile(tier=tier,
                                    sampling_period=self.sampler.period)
        self.wp = {}
        self.reset_epoch()

    def reset_epoch(self) -> None:
        """GC-epoch semantics: watchpoints never cross an epoch; the
        reservoir restarts from its seed, the sampler draws a fresh gap."""
        self.wp = {
            STORE: ReservoirWatchpoints(self.cfg.num_watchpoints,
                                        self.cfg.seed),
            LOAD: ReservoirWatchpoints(self.cfg.num_watchpoints,
                                       self.cfg.seed + 1),
        }
        self.sampler.reset()

    # ------------------------------------------------------------------
    def on_event(self, ev: MemEvent) -> None:
        if ev.kind == STORE:
            self._on_store(ev)
        else:
            self._on_load(ev)

    def replay(self, trace: EventTrace) -> None:
        """One epoch over a recorded trace (no primitive re-binding)."""
        on_store, on_load = self._on_store, self._on_load
        for ev in trace:
            if ev.kind == STORE:
                on_store(ev)
            else:
                on_load(ev)

    def finalize(self) -> WasteProfile:
        self.profile.watchpoint_stats = {
            k: dict(v.stats) for k, v in self.wp.items()}
        return self.profile

    # ------------------------------------------------------------------
    def _on_store(self, ev: MemEvent) -> None:
        prof = self.profile
        prof.bump_total("store_events", ev.nelems)
        prof.bump_total("store_bytes", ev.nbytes)
        self._check_traps(STORE, ev)
        if not self._store_clients:
            self.sampler.advance(ev.nelems)
            return
        for off in self.sampler.advance(ev.nelems):
            # one-sample-one-watchpoint (paper §5.2): a single PMU sample
            # arms exactly one client, chosen uniformly, so dead- and
            # silent-store detection share the reservoir at the pressure
            # one PMU stream generates instead of doubling it
            client = (self._store_clients[0] if len(self._store_clients) == 1
                      else self._store_clients[
                          self.client_rng.randint(len(self._store_clients))])
            value = None
            if client == "silent_store":
                value = ev.value_at(off)
                if value is None:        # no comparable value at this offset
                    client = "dead_store"
                    if "dead_store" not in self.detect:
                        continue
            self.wp[STORE].on_sample(Watchpoint(
                address=ev.address, offset=off, size=ev.itemsize,
                value=value, context=ev.ctx,
                trap_type="RW_TRAP" if client == "dead_store" else "W_TRAP",
                meta=client))

    def _on_load(self, ev: MemEvent) -> None:
        prof = self.profile
        prof.bump_total("load_events", ev.nelems)
        prof.bump_total("load_bytes", ev.nbytes)
        self._check_traps(LOAD, ev)
        if "silent_load" in self.detect:
            for off in self.sampler.advance(ev.nelems):
                value = ev.value_at(off)
                if value is None:        # no comparable value at this offset
                    continue
                self.wp[LOAD].on_sample(Watchpoint(
                    address=ev.address, offset=off, size=ev.itemsize,
                    value=value, context=ev.ctx,
                    trap_type="RW_TRAP", meta="silent_load"))

    def _check_traps(self, access: str, ev: MemEvent) -> None:
        prof = self.profile
        # Two passes per reservoir, stale disarms FIRST: with several
        # watchpoints tied on one (recycled) address, classification and
        # stale-disarm used to interleave in slot order, so which
        # watchpoints survived the event depended on how earlier slots
        # happened to be filled. Disarming every stale tie up front
        # makes the surviving set — and the profile — a function of the
        # event stream alone.
        store_hits, load_hits = [], []
        for wp in self.wp[STORE].matching(lambda w: w.address == ev.address):
            if wp.offset >= ev.nelems:
                # stale watchpoint: a shorter event at the same (recycled)
                # address means the watched element no longer exists —
                # skip classification entirely and free the slot
                self.wp[STORE].disarm(wp)
            else:
                store_hits.append(wp)
        for wp in self.wp[LOAD].matching(lambda w: w.address == ev.address):
            if wp.offset >= ev.nelems:
                self.wp[LOAD].disarm(wp)
            else:
                load_hits.append(wp)
        for wp in store_hits:
            if wp.meta == "dead_store":
                # Def. 1: store;store with no intervening load is dead
                hit = access == STORE
                prof.observe("dead_store", hit)
                if hit:
                    prof.add_pair("dead_store", self.tier, wp.context,
                                  ev.ctx, wp.size)
                self.wp[STORE].disarm(wp)
            elif wp.meta == "silent_store" and access == STORE:
                cur = ev.value_at(wp.offset)
                if cur is None:          # offset outside the value extent
                    self.wp[STORE].disarm(wp)
                    continue
                # Def. 2: overwrite with the value already there
                hit = approx_equal(wp.value, cur, self.tol)
                prof.observe("silent_store", hit)
                if hit:
                    prof.add_pair("silent_store", self.tier, wp.context,
                                  ev.ctx, wp.size)
                self.wp[STORE].disarm(wp)
        for wp in load_hits:
            if access == LOAD:
                cur = ev.value_at(wp.offset)
                if cur is None:
                    self.wp[LOAD].disarm(wp)
                    continue
                # Def. 3: load of the value already loaded
                hit = approx_equal(wp.value, cur, self.tol)
                prof.observe("silent_load", hit)
                if hit:
                    prof.add_pair("silent_load", self.tier, wp.context,
                                  ev.ctx, wp.size)
            self.wp[LOAD].disarm(wp)
