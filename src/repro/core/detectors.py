"""Tier-3: training-loop waste detectors (DESIGN.md §2) — the production
always-on mode. Watches the *framework's own* memory traffic at step
granularity through the same substrate as Tier-1 (repro.core.events):
parameter/gradient/batch accesses become MemEvents, sampled accesses arm
reservoir watchpoints, and findings land in the unified WasteProfile:

  silent parameter stores — a parameter leaf whose post-optimizer value
      equals its pre-step value within tolerance (frozen/dead subnetwork,
      zero grads): the optimizer "stored the same value" (Def. 2);
  dead gradient stores    — gradient leaves that are (near-)all-zero: the
      backward pass produced bytes nobody needed (Def. 1 flavour);
  silent data loads       — repeated identical batches from the pipeline
      (MemEvent content digest), Def. 3 at the input boundary.

The value comparison runs on-device via the silent_compare Pallas kernel
(2 reads/element — roofline-minimal) using the substrate's single
approximate-equality definition, so the per-step overhead is bounded by
the sampled leaf set, mirroring the paper's 7%-overhead philosophy.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Any, List, Optional, Tuple

import jax
import numpy as np

from repro.configs.base import ProfilerConfig
from repro.core.events import STORE, MemEvent
from repro.core.findings import Finding, WasteProfile
from repro.core.reservoir import ReservoirWatchpoints, Watchpoint
from repro.kernels import ops

# seed-era names: the unified profile/finding replace the ad-hoc pair
Tier3Report = WasteProfile
StepFinding = Finding


def _leaf_paths(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(jax.tree_util.keystr(k), v) for k, v in flat]


def _leaf_event(path: str, leaf) -> MemEvent:
    # metadata comes from the array handle; the leaf itself is held by
    # reference (no device->host transfer unless digest() is called)
    return MemEvent(kind=STORE, address=hash(path) & 0x7FFFFFFF,
                    nelems=int(leaf.size), itemsize=int(leaf.dtype.itemsize),
                    values=leaf, ctx=(path,))


class TrainingDetectors:
    """Attach to a training loop; call on_step each step."""

    def __init__(self, cfg: Optional[ProfilerConfig] = None,
                 leaves_per_step: int = 4):
        self.cfg = cfg or ProfilerConfig(enabled=True)
        self.tol = self.cfg.fp_tolerance
        self.leaves_per_step = leaves_per_step
        self.wp = ReservoirWatchpoints(self.cfg.num_watchpoints,
                                       self.cfg.seed)
        self.rng = np.random.RandomState(self.cfg.seed)
        self.report = WasteProfile(tier=3)
        # bounded LRU of batch-content digests: a long run must not grow
        # memory without limit (window from ProfilerConfig)
        self._batch_hashes: "OrderedDict[str, int]" = OrderedDict()
        self._hash_window = max(1, self.cfg.batch_hash_window)

    def _found(self, step: int, kind: str, path: str,
               frac: float, nbytes: float) -> Finding:
        f = Finding(kind=kind, tier=3, c1=(path,), fraction=frac,
                    step=step, bytes=nbytes, meta={"path": path})
        self.report.add(f)
        return f

    # ------------------------------------------------------------------
    def on_step(self, step: int, params_before, params_after,
                grads=None) -> List[Finding]:
        """Sample leaves; compare watched leaves before/after (Def. 2)."""
        out: List[Finding] = []
        before = dict(_leaf_paths(params_before))
        after = dict(_leaf_paths(params_after))

        # traps: previously armed watchpoints observe this step's store
        for wp in list(self.wp.armed()):
            path = wp.meta
            if path in after:
                frac = float(ops.silent_fraction(before[path], after[path],
                                                 tol=self.tol))
                silent = frac > 0.99
                self.report.observe("silent_param_store", silent)
                if silent:
                    ev = _leaf_event(path, after[path])
                    out.append(self._found(step, "silent_param_store",
                                           path, frac, ev.nbytes))
            self.wp.disarm(wp)

        # arm new watchpoints on sampled leaf-store events (reservoir
        # discipline over the substrate's event type)
        paths = list(after)
        for _ in range(min(self.leaves_per_step, len(paths))):
            p = paths[self.rng.randint(len(paths))]
            ev = _leaf_event(p, after[p])
            self.wp.on_sample(Watchpoint(
                address=ev.address, offset=0, size=ev.itemsize,
                value=None, context=ev.ctx, trap_type="W_TRAP", meta=p))

        # dead gradient stores (value-agnostic: all-zero grad leaves)
        if grads is not None:
            gleaves = _leaf_paths(grads)
            for _ in range(min(self.leaves_per_step, len(gleaves))):
                p, g = gleaves[self.rng.randint(len(gleaves))]
                zero_frac = float(ops.silent_fraction(
                    g, jax.numpy.zeros_like(g), tol=0.0))
                dead = zero_frac > 0.99
                self.report.observe("dead_grad_store", dead)
                if dead:
                    ev = _leaf_event(p, g)
                    out.append(self._found(step, "dead_grad_store", p,
                                           zero_frac, ev.nbytes))
        return out

    # ------------------------------------------------------------------
    def on_batch(self, step: int, batch) -> List[Finding]:
        """Silent data loads: identical batch content re-delivered."""
        out: List[Finding] = []
        for path, leaf in _leaf_paths(batch):
            ev = _leaf_event(path, leaf)
            key = f"{path}:{ev.digest()}"
            dup = key in self._batch_hashes
            self.report.observe("silent_data_load", dup)
            if dup:
                out.append(self._found(step, "silent_data_load", path,
                                       1.0, ev.nbytes))
                self._batch_hashes.move_to_end(key)
            self._batch_hashes[key] = step
            while len(self._batch_hashes) > self._hash_window:
                self._batch_hashes.popitem(last=False)
        return out
