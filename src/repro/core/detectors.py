"""Tier-3: training-loop waste detectors (DESIGN.md §2) — the production
always-on mode. Watches the *framework's own* memory traffic at step
granularity with the same reservoir-sampled watchpoint discipline:

  silent parameter stores — a parameter leaf whose post-optimizer value
      equals its pre-step value within tolerance (frozen/dead subnetwork,
      zero grads): the optimizer "stored the same value" (Def. 2);
  dead gradient stores    — gradient leaves that are (near-)all-zero: the
      backward pass produced bytes nobody needed (Def. 1 flavour);
  silent data loads       — repeated identical batches from the pipeline
      (content hash), Def. 3 at the input boundary.

The value comparison runs on-device via the silent_compare Pallas kernel
(2 reads/element — roofline-minimal), so the per-step overhead is bounded
by the sampled leaf set, mirroring the paper's 7%-overhead philosophy.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.configs.base import ProfilerConfig
from repro.core.reservoir import ReservoirWatchpoints, Watchpoint
from repro.kernels import ops


@dataclass
class StepFinding:
    step: int
    kind: str              # silent_param_store | dead_grad_store | silent_data_load
    path: str
    fraction: float


@dataclass
class Tier3Report:
    findings: List[StepFinding] = field(default_factory=list)
    checked: Dict[str, int] = field(default_factory=dict)
    flagged: Dict[str, int] = field(default_factory=dict)

    def fractions(self) -> Dict[str, float]:
        return {k: self.flagged.get(k, 0) / v
                for k, v in self.checked.items() if v}

    def top(self, k: int = 10) -> List[StepFinding]:
        return sorted(self.findings, key=lambda f: -f.fraction)[:k]


def _leaf_paths(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(jax.tree_util.keystr(k), v) for k, v in flat]


class TrainingDetectors:
    """Attach to a training loop; call on_step each step."""

    def __init__(self, cfg: Optional[ProfilerConfig] = None,
                 leaves_per_step: int = 4):
        self.cfg = cfg or ProfilerConfig(enabled=True)
        self.tol = self.cfg.fp_tolerance
        self.leaves_per_step = leaves_per_step
        self.wp = ReservoirWatchpoints(self.cfg.num_watchpoints,
                                       self.cfg.seed)
        self.rng = np.random.RandomState(self.cfg.seed)
        self.report = Tier3Report()
        self._batch_hashes: Dict[str, int] = {}

    # ------------------------------------------------------------------
    def on_step(self, step: int, params_before, params_after,
                grads=None) -> List[StepFinding]:
        """Sample leaves; compare watched leaves before/after (Def. 2)."""
        out: List[StepFinding] = []
        before = dict(_leaf_paths(params_before))
        after = dict(_leaf_paths(params_after))

        # traps: previously armed watchpoints observe this step's store
        for wp in list(self.wp.armed()):
            path = wp.meta
            if path in after:
                frac = float(ops.silent_fraction(before[path], after[path],
                                                 tol=self.tol))
                self._bump("silent_param_store", frac > 0.99)
                if frac > 0.99:
                    f = StepFinding(step, "silent_param_store", path, frac)
                    self.report.findings.append(f)
                    out.append(f)
            self.wp.disarm(wp)

        # arm new watchpoints on sampled leaves (reservoir discipline)
        paths = list(after)
        for _ in range(min(self.leaves_per_step, len(paths))):
            p = paths[self.rng.randint(len(paths))]
            self.wp.on_sample(Watchpoint(
                address=hash(p) & 0x7FFFFFFF, offset=0, size=4,
                value=None, context=(p,), trap_type="W_TRAP", meta=p))

        # dead gradient stores (value-agnostic: all-zero grad leaves)
        if grads is not None:
            gleaves = _leaf_paths(grads)
            for _ in range(min(self.leaves_per_step, len(gleaves))):
                p, g = gleaves[self.rng.randint(len(gleaves))]
                zero_frac = float(ops.silent_fraction(
                    g, jax.numpy.zeros_like(g), tol=0.0))
                dead = zero_frac > 0.99
                self._bump("dead_grad_store", dead)
                if dead:
                    f = StepFinding(step, "dead_grad_store", p, zero_frac)
                    self.report.findings.append(f)
                    out.append(f)
        return out

    # ------------------------------------------------------------------
    def on_batch(self, step: int, batch) -> List[StepFinding]:
        """Silent data loads: identical batch content re-delivered."""
        out = []
        for path, leaf in _leaf_paths(batch):
            h = hashlib.blake2b(np.asarray(leaf).tobytes(),
                                digest_size=8).hexdigest()
            key = f"{path}:{h}"
            dup = key in self._batch_hashes
            self._bump("silent_data_load", dup)
            if dup:
                f = StepFinding(step, "silent_data_load", path, 1.0)
                self.report.findings.append(f)
                out.append(f)
            self._batch_hashes[key] = step
        return out

    def _bump(self, kind: str, flagged: bool):
        self.report.checked[kind] = self.report.checked.get(kind, 0) + 1
        if flagged:
            self.report.flagged[kind] = self.report.flagged.get(kind, 0) + 1
