"""Tier-3: production always-on waste detectors (DESIGN.md §2). Watches
the *framework's own* memory traffic at step granularity through the same
substrate as Tier-1 (repro.core.events): parameter/gradient/batch/KV-cache
accesses become MemEvents, sampled accesses arm reservoir watchpoints, and
findings land in the unified WasteProfile.

Training loop (``TrainingDetectors``):

  silent parameter stores — a parameter leaf whose post-optimizer value
      equals its pre-step value within tolerance (frozen/dead subnetwork,
      zero grads): the optimizer "stored the same value" (Def. 2);
  dead gradient stores    — gradient leaves that are (near-)all-zero: the
      backward pass produced bytes nobody needed (Def. 1 flavour);
  silent data loads       — repeated identical batches from the pipeline
      (MemEvent content digest), Def. 3 at the input boundary.

Serving loop (``ServingDetectors``, DESIGN.md §2 serving tier): the KV
cache is the serving heap, and the engine's fixed-size decode batch keeps
writing it whether or not a slot serves a live request:

  dead KV stores     — K/V rows written for slots past a request's end
      (idle/finished slots still written every step, or a finished
      request's rows overwritten at recycle without a live read): Def. 1
      at request granularity;
  silent KV stores   — inactive slots rewriting the same K/V site with
      identical values (frozen token + frozen write index), checked via
      silent_compare (Def. 2);
  silent prefix loads — duplicate prompt prefixes by content digest:
      the prefill re-reads (and recomputes K/V for) a prefix another
      request already paid for — a prefix-cache opportunity (Def. 3).

The value comparison runs on-device via the silent_compare Pallas kernel
(2 reads/element — roofline-minimal) using the substrate's single
approximate-equality definition, so the per-step overhead is bounded by
the sampled leaf/site set, mirroring the paper's 7%-overhead philosophy.

Kernel tier (``on_kernel_store`` / ``on_kernel_verify``, DESIGN.md
§ Kernel tier): the serving Pallas kernels measure waste at the machine
store site itself — every paged K/V store epilogue emits per-slot
[stored, silent, dropped] element counts (kernels/paged_attention.py) —
and the engine feeds them here per (layer, store site). Where tier 3
samples sites with watchpoints (Eq. (1) estimator), the kernel tier is
EXHAUSTIVE: every element of every store is counted in-kernel, so the
checked/flagged counters hold exact populations and the fraction
estimator degenerates to the true fraction. Measurement and
classification split: the kernel counts stores without knowing why;
the engine, which knows the accept point, classifies the verify tick's
stored-but-rejected rows (``kernel_rejected_draft_store`` — the
machine-level replication of tier 3's ``rejected_draft_store``:
1 − accept-rate under overwrite, exactly 0 under rollback, where the
kernel provably stored only the accepted prefix).
"""
from __future__ import annotations

import zlib
from collections import OrderedDict
from typing import Any, Callable, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.configs.base import ProfilerConfig
from repro.core.events import LOAD, STORE, MemEvent
from repro.core.findings import Finding, WasteProfile
from repro.core.reservoir import ReservoirWatchpoints, Watchpoint
from repro.kernels import ops

# seed-era names: the unified profile/finding replace the ad-hoc pair
Tier3Report = WasteProfile
StepFinding = Finding

# power-of-two prefix granularities shared by the Def.-3 prefix-load
# detector and the paged prefix cache (serve.kv_cache) — one ladder, so
# what the detector calls a duplicate is exactly what the cache can reuse
PREFIX_POW2 = (8, 16, 32, 64, 128, 256, 512, 1024)


def _leaf_paths(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(jax.tree_util.keystr(k), v) for k, v in flat]


def _leaf_event(path: str, leaf) -> MemEvent:
    # metadata comes from the array handle; the leaf itself is held by
    # reference (no device->host transfer unless digest() is called).
    # crc32, NOT hash(): Python string hashing is salted per process
    # (PYTHONHASHSEED), so hash()-derived addresses made equal-address
    # collisions — and therefore trap/disarm behavior — vary across
    # runs. crc32 is stable, so profiles reproduce.
    return MemEvent(kind=STORE, address=zlib.crc32(path.encode()) & 0x7FFFFFFF,
                    nelems=int(leaf.size), itemsize=int(leaf.dtype.itemsize),
                    values=leaf, ctx=(path,))


class TrainingDetectors:
    """Attach to a training loop; call on_step each step."""

    def __init__(self, cfg: Optional[ProfilerConfig] = None,
                 leaves_per_step: int = 4):
        self.cfg = cfg or ProfilerConfig(enabled=True)
        self.tol = self.cfg.fp_tolerance
        self.leaves_per_step = leaves_per_step
        self.wp = ReservoirWatchpoints(self.cfg.num_watchpoints,
                                       self.cfg.seed)
        self.rng = np.random.RandomState(self.cfg.seed)
        self.report = WasteProfile(tier=3)
        # bounded LRU of batch-content digests: a long run must not grow
        # memory without limit (window from ProfilerConfig)
        self._batch_hashes: "OrderedDict[str, int]" = OrderedDict()
        self._hash_window = max(1, self.cfg.batch_hash_window)

    def _found(self, step: int, kind: str, path: str,
               frac: float, nbytes: float) -> Finding:
        f = Finding(kind=kind, tier=3, c1=(path,), fraction=frac,
                    step=step, bytes=nbytes, meta={"path": path})
        self.report.add(f)
        return f

    # ------------------------------------------------------------------
    def on_step(self, step: int, params_before, params_after,
                grads=None) -> List[Finding]:
        """Sample leaves; compare watched leaves before/after (Def. 2)."""
        out: List[Finding] = []
        before = dict(_leaf_paths(params_before))
        after = dict(_leaf_paths(params_after))

        # traps: previously armed watchpoints observe this step's store
        for wp in list(self.wp.armed()):
            path = wp.meta
            if path in after:
                frac = float(ops.silent_fraction(before[path], after[path],
                                                 tol=self.tol))
                silent = frac > 0.99
                self.report.observe("silent_param_store", silent)
                if silent:
                    ev = _leaf_event(path, after[path])
                    out.append(self._found(step, "silent_param_store",
                                           path, frac, ev.nbytes))
            self.wp.disarm(wp)

        # arm new watchpoints on sampled leaf-store events (reservoir
        # discipline over the substrate's event type)
        paths = list(after)
        for _ in range(min(self.leaves_per_step, len(paths))):
            p = paths[self.rng.randint(len(paths))]
            ev = _leaf_event(p, after[p])
            self.wp.on_sample(Watchpoint(
                address=ev.address, offset=0, size=ev.itemsize,
                value=None, context=ev.ctx, trap_type="W_TRAP", meta=p))

        # dead gradient stores (value-agnostic: all-zero grad leaves)
        if grads is not None:
            gleaves = _leaf_paths(grads)
            for _ in range(min(self.leaves_per_step, len(gleaves))):
                p, g = gleaves[self.rng.randint(len(gleaves))]
                zero_frac = float(ops.silent_fraction(
                    g, jax.numpy.zeros_like(g), tol=0.0))
                dead = zero_frac > 0.99
                self.report.observe("dead_grad_store", dead)
                if dead:
                    ev = _leaf_event(p, g)
                    out.append(self._found(step, "dead_grad_store", p,
                                           zero_frac, ev.nbytes))
        return out

    # ------------------------------------------------------------------
    def on_batch(self, step: int, batch) -> List[Finding]:
        """Silent data loads: identical batch content re-delivered."""
        out: List[Finding] = []
        for path, leaf in _leaf_paths(batch):
            ev = _leaf_event(path, leaf)
            key = f"{path}:{ev.digest()}"
            dup = key in self._batch_hashes
            self.report.observe("silent_data_load", dup)
            if dup:
                out.append(self._found(step, "silent_data_load", path,
                                       1.0, ev.nbytes))
                self._batch_hashes.move_to_end(key)
            self._batch_hashes[key] = step
            while len(self._batch_hashes) > self._hash_window:
                self._batch_hashes.popitem(last=False)
        return out


# ----------------------------------------------------------------------
# Serving tier
# ----------------------------------------------------------------------
class SlotWrite:
    """One decode-batch slot's K/V write in the current engine tick.

    Sites are addressed as (page, offset) so watchpoints survive page
    remapping in the paged KV layout; the dense layout is the degenerate
    case page == slot row, offset == position."""

    __slots__ = ("slot", "rid", "active", "pos", "page", "offset")

    def __init__(self, slot: int, rid: Optional[str], active: bool,
                 pos: int, page: Optional[int] = None,
                 offset: Optional[int] = None):
        self.slot = slot
        self.rid = rid
        self.active = active
        self.pos = pos
        self.page = slot if page is None else page
        self.offset = pos if offset is None else offset


class VerifyWrite:
    """One slot's speculative verify-window K/V stores in one tick.

    `sites` lists the DRAFT rows actually stored this tick, in window
    order, as (page, offset, rejected): rejected rows are Def.-1 dead
    stores (written for a token past the accept point, never read by
    the request, overwritten by the next window). Under rollback the
    engine never stores rejected rows, so every site arrives with
    rejected=False — the fraction collapses to zero, which is exactly
    the detect→optimize claim the acceptance test pins."""

    __slots__ = ("slot", "rid", "accepted", "sites")

    def __init__(self, slot: int, rid: str, accepted: int,
                 sites: Sequence[Tuple[int, int, bool]]):
        self.slot = slot
        self.rid = rid
        self.accepted = accepted
        self.sites = list(sites)


class ServingDetectors:
    """Serve-side Tier-3: KV-cache waste at request granularity.

    Attach to a ``serve.engine.ServeEngine`` (it calls ``bind`` once and
    then ``on_admit`` / ``on_finish`` / ``on_step`` as the schedule
    advances). Watchpoints follow the paper's discipline on the serving
    heap: a sampled K/V *site* (layer, slot, position) arms one reservoir
    watchpoint for one client — dead (value-agnostic RW analogue) or
    silent (holds the written value) — and traps on the next store to
    that site: the idle-slot rewrite of the same position, a recycled
    slot's prefill sweep, or a new occupant's decode reaching the
    position. ⟨C1,C2⟩ is the arming request/layer and the trapping
    request/step.

    Sites are addressed (layer, page, offset) so watchpoints survive the
    paged layout's page remapping: in the dense layout page == slot row
    and offset == position, while in the paged layout
    (serve/kv_cache.py) the engine reports pool pages directly and calls
    ``on_page_free`` when recycling frees them — armed watchpoints on a
    freed page disarm WITHOUT classification, the same out-of-extent
    rule ``EventEngine._check_traps`` applies to stale traps at recycled
    addresses.
    """

    def __init__(self, cfg: Optional[ProfilerConfig] = None,
                 sites_per_step: int = 2):
        self.cfg = cfg or ProfilerConfig(enabled=True)
        self.tol = self.cfg.fp_tolerance
        self.sites_per_step = sites_per_step
        self.wp = ReservoirWatchpoints(self.cfg.num_watchpoints,
                                       self.cfg.seed)
        self.rng = np.random.RandomState(self.cfg.seed)
        self.report = WasteProfile(tier=3)
        # bounded LRU of prompt-prefix digests -> (step, C1 of first load)
        self._prefix_hashes: "OrderedDict[str, Tuple[int, Tuple[str, ...]]]" \
            = OrderedDict()
        self._hash_window = max(1, self.cfg.batch_hash_window)
        self.num_layers = 1
        self.site_bytes = 0
        self.paged = False
        # kernel tier (tier 4): exhaustive in-kernel store-site counters,
        # kept as its own profile so the §5.6 merge composes it with the
        # sampled tier-3 report without mixing estimator populations
        self.kernel = WasteProfile(tier=4)
        self.kv_itemsize = 4
        self.row_elems: dict = {}

    def bind(self, *, num_layers: int, site_bytes: int,
             paged: bool = False, kv_itemsize: int = 4,
             row_elems: Optional[dict] = None) -> None:
        """Engine geometry: layer count, bytes per K/V site, KV layout.

        kv_itemsize / row_elems feed the kernel tier: bytes per stored
        element, and per KV sub-block the K+V element count of ONE
        stored row (2 * Hkv * D) — the unit that converts the kernel's
        element counts back into row counts for classification."""
        self.num_layers = max(1, num_layers)
        self.site_bytes = site_bytes
        self.paged = paged
        self.kv_itemsize = kv_itemsize
        self.row_elems = dict(row_elems or {})

    # -- kernel tier (in-kernel store-site counters) -------------------
    def on_kernel_store(self, step: int, site: str, counts) -> None:
        """Merge one forward's in-kernel waste counters.

        counts: per KV sub-block name, an (L, B, 3) int array of
        [stored, silent, dropped] ELEMENT counts measured at the paged
        store epilogue (L = scanned layers, B = slots). Exhaustive, not
        sampled: checked/flagged hold the full store population.
        ``site`` names the store site (prefill / decode / verify /
        commit) — findings coalesce per (site, sub-block, layer)."""
        isz = self.kv_itemsize
        for name, c in counts.items():
            c = np.asarray(c)
            per_layer = c.sum(axis=1)                      # (L, 3)
            stored = int(per_layer[:, 0].sum())
            silent = int(per_layer[:, 1].sum())
            dropped = int(per_layer[:, 2].sum())
            k = self.kernel
            k.bump_total("kernel_store_elems", stored)
            k.bump_total("kernel_silent_elems", silent)
            k.bump_total("kernel_dropped_elems", dropped)
            k.checked["kernel_silent_store"] = \
                k.checked.get("kernel_silent_store", 0) + stored
            k.flagged["kernel_silent_store"] = \
                k.flagged.get("kernel_silent_store", 0) + silent
            k.checked["kernel_dead_store"] = \
                k.checked.get("kernel_dead_store", 0) + stored + dropped
            k.flagged["kernel_dead_store"] = \
                k.flagged.get("kernel_dead_store", 0) + dropped
            for layer in range(per_layer.shape[0]):
                st, si, dr = (int(x) for x in per_layer[layer])
                if si:
                    k.add_pair("kernel_silent_store", 4,
                               (f"kernel:{site}", name, f"layer:{layer}"),
                               (f"serve.engine:{site}",), si * isz,
                               stored_bytes=st * isz)
                if dr:
                    k.add_pair("kernel_dead_store", 4,
                               (f"kernel:{site}", name, f"layer:{layer}"),
                               (f"serve.engine:{site}",), dr * isz,
                               stored_bytes=st * isz)

    def on_kernel_verify(self, step: int, counts, accepted, draft_len,
                         active) -> None:
        """Classify one verify tick's kernel counters against the accept
        point (measurement in-kernel, classification host-side).

        counts: as in ``on_kernel_store`` — under overwrite these are
        the verify forward's full-window stores, under rollback the
        commit's accepted-prefix stores (the deferred window stored
        nothing). accepted/draft_len/active: (B,) accept counts m, real
        draft counts, live mask. Per slot the kernel-measured stored
        rows are stored_elems / row_elems; rows beyond 1 + m (capped to
        the proposed drafts) are rejected — so the fraction is exactly
        1 − accept-rate when the window was overwritten and exactly 0
        when only the accepted prefix was committed."""
        self.on_kernel_store(step, "verify", counts)
        accepted = np.asarray(accepted)
        draft_len = np.asarray(draft_len)
        active = np.asarray(active)
        k = self.kernel
        for name, c in counts.items():
            re = self.row_elems.get(name)
            if not re:
                continue
            c = np.asarray(c)
            # layers store identically; measure rows from layer 0
            rows_stored = c[0, :, 0] // re                 # (B,)
            for b in range(c.shape[1]):
                if not active[b] or draft_len[b] == 0:
                    continue
                drafts_stored = min(int(draft_len[b]),
                                    max(0, int(rows_stored[b]) - 1))
                rejected = max(0, drafts_stored - int(accepted[b]))
                k.checked["kernel_rejected_draft_store"] = \
                    k.checked.get("kernel_rejected_draft_store", 0) \
                    + int(draft_len[b])
                k.flagged["kernel_rejected_draft_store"] = \
                    k.flagged.get("kernel_rejected_draft_store", 0) \
                    + rejected
                if rejected:
                    k.add_pair(
                        "kernel_rejected_draft_store", 4,
                        ("kernel:verify", name),
                        ("serve.engine:verify",),
                        rejected * re * self.kv_itemsize
                        * c.shape[0],
                        accepted=int(accepted[b]))

    def combined(self) -> WasteProfile:
        """Tier-3 sampled report + tier-4 kernel counters, §5.6-merged."""
        out = WasteProfile()
        out.merge(self.report)
        out.merge(self.kernel)
        return out

    # -- silent prefix loads -------------------------------------------
    @staticmethod
    def _prefix_lengths(n: int) -> List[int]:
        """Power-of-two prefixes (≥8) plus the full prompt, shortest
        first, so shared prefixes of different-length prompts match."""
        out = [p for p in PREFIX_POW2 if p < n]
        out.append(n)
        return out

    def on_admit(self, step: int, slot: int, rid: str,
                 tokens: np.ndarray,
                 padded_len: Optional[int] = None,
                 reuse_len: int = 0) -> List[Finding]:
        """Admission: prefix-digest dedup + recycle traps for the slot.

        padded_len: extent of the prefill's store sweep — the padded
        prompt length, ≥ tokens.size (engines pad admission groups);
        None when the prefill sweeps no stale rows (paged layout).
        reuse_len: prompt positions served from a prefix cache — only a
        duplicated prefix LONGER than this was actually re-loaded and
        re-computed, so shorter duplicates are cache hits, not waste."""
        out: List[Finding] = []
        tokens = np.asarray(tokens)
        swept = max(int(padded_len or 0), tokens.size)
        ctx2 = ("serve.engine:prefill", f"req:{rid}", f"slot:{slot}")

        plens = self._prefix_lengths(tokens.size)
        hit: Optional[Tuple[int, Tuple[str, ...]]] = None
        keys = []
        for plen in plens:
            ev = MemEvent(kind=LOAD, address=slot, nelems=plen,
                          itemsize=int(tokens.dtype.itemsize),
                          values=tokens[:plen], ctx=ctx2)
            key = f"prefix{plen}:{ev.digest()}"
            keys.append(key)
            if key in self._prefix_hashes and plen > reuse_len:
                hit = (plen, self._prefix_hashes[key][1])
        self.report.observe("silent_prefix_load", hit is not None)
        if hit is not None:
            plen, c1 = hit       # longest re-paid duplicated prefix wins
            f = self.report.add_pair(
                "silent_prefix_load", 3, c1, ctx2,
                (plen - reuse_len) * int(tokens.dtype.itemsize),
                prefix_len=plen, reuse_len=reuse_len)
            out.append(f)
        for key in keys:
            if key in self._prefix_hashes:
                self._prefix_hashes.move_to_end(key)
            else:
                self._prefix_hashes[key] = (step, ctx2)
        while len(self._prefix_hashes) > self._hash_window:
            self._prefix_hashes.popitem(last=False)

        # recycle traps (dense layout only): the prefill store sweeps
        # [0, padded_len) of this slot's rows — watched sites in that
        # range are overwritten now (padded-tail positions included:
        # their old value is destroyed by garbage K/V). The old value is
        # gone, so silent-client watchpoints disarm without
        # classification (the substrate's out-of-extent rule);
        # dead-client ones classify: no live read since arming ⇒ dead.
        # In the paged layout the prefill writes only freshly-allocated
        # pages — a recycled slot's old pages were freed (on_page_free
        # disarmed their traps), so there is no stale sweep to scan.
        if not self.paged:
            for wp in list(self.wp.armed()):
                m = wp.meta
                if m["slot"] != slot or m["pos"] >= swept:
                    continue
                if m["client"] == "dead_kv_store":
                    dead = not m["live"]
                    self.report.observe("dead_kv_store", dead)
                    if dead:
                        f = self.report.add_pair("dead_kv_store", 3,
                                                 wp.context, ctx2, wp.size)
                        out.append(f)
                self.wp.disarm(wp)
        return out

    def on_finish(self, step: int, slot: int, rid: str) -> None:
        """Request ended: its armed sites can no longer be live-read."""
        for wp in self.wp.armed():
            if wp.meta["slot"] == slot and wp.meta["rid"] == rid:
                wp.meta["live"] = False

    def on_page_free(self, pages: Sequence[int]) -> None:
        """Paged layout: recycling freed these pool pages. The watched
        values no longer exist, so armed traps on them are STALE — they
        disarm without classification (out-of-extent rule), exactly like
        a shorter event at a recycled address in the substrate."""
        freed = set(int(p) for p in pages)
        if not freed:
            return
        for wp in list(self.wp.armed()):
            if wp.meta.get("page") in freed:
                self.wp.disarm(wp)

    # -- speculative verify (rejected-draft dead stores) ---------------
    def on_verify(self, step: int,
                  entries: Sequence[VerifyWrite]) -> List[Finding]:
        """One engine verify tick's draft-row K/V stores (Def. 1 at the
        speculative-decode site): every proposed-and-stored draft row
        is checked, rows past the accept point are flagged — dead by
        construction
        (the value is never read and the next verify window overwrites
        it). Deterministic accounting, no sampling: the engine already
        knows exactly which rows it stored and where the accept point
        fell, so estimating would only add noise. A rejected row is
        written in EVERY layer of the stack, so its cost is
        site_bytes * num_layers."""
        out: List[Finding] = []
        for e in entries:
            for page, off, rejected in e.sites:
                self.report.observe("rejected_draft_store", rejected)
                if rejected:
                    # derived, not drawn: a shared-RNG draw here would
                    # shift the OTHER detectors' watchpoint sampling
                    # between overwrite and rollback runs at the same
                    # seed, making cross-mode fractions non-comparable
                    layer = (page * 131 + off) % self.num_layers
                    f = self.report.add_pair(
                        "rejected_draft_store", 3,
                        ("serve.spec:draft", f"req:{e.rid}"),
                        ("serve.engine:verify", f"slot:{e.slot}"),
                        self.site_bytes * self.num_layers,
                        layer=layer, page=page, offset=off,
                        accepted=e.accepted)
                    out.append(f)
        return out

    # -- per-tick watchpoints ------------------------------------------
    def on_step(self, step: int, writes: Sequence[SlotWrite],
                peek: Callable[[int, int, int], Any]) -> List[Finding]:
        """One engine decode tick's K/V stores.

        writes: per-slot view of this tick's stores, addressed by
        (page, offset) site — every slot in the dense layout, live slots
        only in the paged layout (idle stores were dropped).
        peek(layer, page, offset) -> the K/V values now at that site.
        """
        out: List[Finding] = []
        by_site = {(w.page, w.offset): w for w in writes}

        for wp in list(self.wp.armed()):
            m = wp.meta
            w = by_site.get((m["page"], m["offset"]))
            if w is None:
                continue                 # no store at the watched site
            ctx2 = (f"serve.engine:step{step}", f"slot:{w.slot}",
                    f"req:{w.rid or 'idle'}")
            if m["client"] == "dead_kv_store":
                # Def. 1 analogue: the armed store was overwritten with no
                # live-request read in between
                dead = not m["live"]
                self.report.observe("dead_kv_store", dead)
                if dead:
                    out.append(self.report.add_pair(
                        "dead_kv_store", 3, wp.context, ctx2, wp.size))
            else:
                # Def. 2 analogue: same site rewritten with the same value
                cur = np.asarray(peek(m["layer"], w.page, w.offset))
                frac = float(ops.silent_fraction(wp.value, cur,
                                                 tol=self.tol))
                silent = frac > 0.99
                self.report.observe("silent_kv_store", silent)
                if silent:
                    out.append(self.report.add_pair(
                        "silent_kv_store", 3, wp.context, ctx2, wp.size))
            self.wp.disarm(wp)

        # arm: sample this tick's written sites; one client per sample
        # (the substrate's one-sample-one-watchpoint discipline)
        k = min(self.sites_per_step, len(writes))
        if k > 0:
            for i in self.rng.choice(len(writes), size=k, replace=False):
                w = writes[int(i)]
                layer = int(self.rng.randint(self.num_layers))
                client = ("dead_kv_store" if self.rng.randint(2) == 0
                          else "silent_kv_store")
                value = None
                if client == "silent_kv_store":
                    value = np.asarray(peek(layer, w.page, w.offset))
                c1 = (f"serve.kv[{layer}]", f"page:{w.page}",
                      f"req:{w.rid or 'idle'}")
                self.wp.on_sample(Watchpoint(
                    address=(layer << 40) | (w.page << 20) | w.offset,
                    offset=w.offset, size=self.site_bytes, value=value,
                    context=c1,
                    trap_type="RW_TRAP" if client == "dead_kv_store"
                    else "W_TRAP",
                    meta={"client": client, "layer": layer,
                          "page": w.page, "offset": w.offset,
                          "slot": w.slot, "pos": w.pos, "rid": w.rid,
                          "live": w.active}))
        return out
