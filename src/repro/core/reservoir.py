"""Reservoir-sampled watchpoint slots (paper §5.2, exact algorithm).

Hardware offers N debug registers (default 4). If every slot is armed when
a new PMU sample arrives, naive policies (replace-oldest, exponential
decay) are biased — the paper's scheme gives every sample a uniform
survival probability with O(1) state:

  * the i-th sample since a slot was last (re)armed replaces that slot
    with probability P = 1/i;
  * a new sample attempts each armed slot (in randomized order) and may
    fail everywhere;
  * whether it succeeds or fails, every armed slot's P is updated;
  * a trap disarms its slot and resets its reservoir probability to 1.0.

``Watchpoint`` is trigger-agnostic: Tier-1 arms it on interpreter memory
events, Tier-3 on parameter/optimizer stores.
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Any, Callable, List, Optional


@dataclass
class Watchpoint:
    address: int                 # logical address (allocator offset)
    offset: int                  # element offset within the buffer
    size: int                    # bytes per element access
    value: Any                   # value observed when armed
    context: Any                 # C1 — full calling context when armed
    trap_type: str               # "W_TRAP" (stores) | "RW_TRAP" (loads+stores)
    meta: Any = None
    # samples seen since this slot was armed (P = 1 / samples_seen)
    samples_seen: int = 1


class ReservoirWatchpoints:
    """N-slot manager with the paper's uniform-survival replacement.

    The reservoir count belongs to the SLOT (samples seen since the slot
    was last free), not to the occupant — the i-th sample since the slot
    freed replaces whatever occupies it with probability 1/i, which is the
    invariant that makes survival uniform (P(any sample survives) = 1/i
    after i samples)."""

    def __init__(self, num_slots: int = 4, seed: int = 0):
        assert num_slots >= 1
        self.num_slots = num_slots
        self.slots: List[Optional[Watchpoint]] = [None] * num_slots
        self.counts: List[int] = [0] * num_slots   # samples since last free
        self.rng = random.Random(seed)
        self.stats = {"armed": 0, "replaced": 0, "rejected": 0, "traps": 0}

    # ------------------------------------------------------------------
    def armed(self) -> List[Watchpoint]:
        return [w for w in self.slots if w is not None]

    def on_sample(self, wp: Watchpoint) -> bool:
        """A PMU sample arrived; try to install `wp`. Returns installed?"""
        # free slot: arm unconditionally (its count restarts at 1)
        for i, s in enumerate(self.slots):
            if s is None:
                self.slots[i] = wp
                self.counts[i] = 1
                for j in range(self.num_slots):   # others age
                    if j != i and self.slots[j] is not None:
                        self.counts[j] += 1
                self.stats["armed"] += 1
                return True
        # all armed: visit slots in randomized order; the (count+1)-th
        # sample replaces slot i with probability 1/(count+1); every slot's
        # count advances whether the attempt succeeded or not (paper §5.2)
        order = list(range(self.num_slots))
        self.rng.shuffle(order)
        installed = False
        for i in order:
            self.counts[i] += 1
            if not installed and self.rng.random() < 1.0 / self.counts[i]:
                self.slots[i] = wp
                self.stats["replaced"] += 1
                installed = True
        if not installed:
            self.stats["rejected"] += 1
        return installed

    # ------------------------------------------------------------------
    def matching(self, pred: Callable[[Watchpoint], bool]) -> List[Watchpoint]:
        return [w for w in self.slots if w is not None and pred(w)]

    def disarm(self, wp: Watchpoint) -> None:
        """Trap handled: free the slot (reservoir P resets to 1.0 — the
        slot count restarts when the next occupant arms)."""
        for i, s in enumerate(self.slots):
            if s is wp:
                self.slots[i] = None
                self.counts[i] = 0
                self.stats["traps"] += 1
                return

    def disarm_all(self) -> None:
        """Epoch boundary (GC analogue: jit-step boundary) — watchpoints
        never survive an epoch because buffer identity is not stable."""
        self.slots = [None] * self.num_slots
        self.counts = [0] * self.num_slots
