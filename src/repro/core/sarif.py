"""SARIF v2.1.0 export for any :class:`WasteProfile` (DESIGN.md § Static
tier, "SARIF contract").

Findings from every tier — static jaxpr lint (0), interpreter (1), HLO
(2), detectors (3), kernel counters (4) — render as code-scanning
annotations: each waste kind becomes a SARIF *rule* carrying its paper
definition as help text, each finding becomes a *result* whose
``physicalLocation`` comes from the finding's provenance (tier-0 records
the Python ``file:line`` of the offending equation; other tiers fall
back to a logical location built from the ⟨C1,C2⟩ contexts).

Contract details tooling relies on:

* ``partialFingerprints["wasteKey/v1"]`` is a sha256 over the §5.6
  coalescing key ``kind|tier|C1|C2`` — byte counts and fractions are
  deliberately excluded, so the fingerprint is stable run-to-run and a
  committed baseline (``lint_baseline.json``) can suppress pre-existing
  findings while new ones still fail CI.
* ``rank`` orders results by wasted bytes (log scale; flops, then
  fraction as fallbacks) so viewers sort the biggest waste first.
* file URIs under ``src_root`` are emitted relative with
  ``uriBaseId: SRCROOT`` so GitHub anchors annotations in the PR diff;
  anything else (stdlib, site-packages) stays absolute.
"""
from __future__ import annotations

import hashlib
import json
import math
import os
from typing import Any, Dict, List, Optional

from repro.core.context import fmt_context
from repro.core.findings import Finding, WasteProfile

SARIF_VERSION = "2.1.0"
SARIF_SCHEMA = ("https://raw.githubusercontent.com/oasis-tcs/sarif-spec/"
                "master/Schemata/sarif-schema-2.1.0.json")
TOOL_NAME = "jxperf-jax"

# Rule registry: waste kind -> (short description, paper-definition help).
# Kinds not listed here still export — a generic rule is synthesized — so
# the exporter accepts ANY WasteProfile, including future tiers' kinds.
_RULES: Dict[str, Dict[str, str]] = {
    "dead_store": {
        "short": "Dead store: a write that is overwritten before any read",
        "help": "Paper Def. 1: two successive stores S1, S2 to memory "
                "location M with no intervening load make S1 dead. "
                "Statically (tier 0): a dynamic_update_slice/scatter "
                "whose written region is fully overwritten before a "
                "read, or whose result is never read at all.",
    },
    "silent_store": {
        "short": "Silent store: rewriting the value already resident",
        "help": "Paper Def. 2: a store S2 writing value V2 to location M "
                "holding V1 is silent iff V1 == V2. Statically (tier 0): "
                "storing a slice gathered from the same buffer at the "
                "same offsets, or an identity chain (x+0, x*1) whose "
                "result provably equals its operand.",
    },
    "silent_load": {
        "short": "Silent load: re-reading an unchanged value",
        "help": "Paper Def. 3: two successive loads L1, L2 from location "
                "M are silent iff they observe the same value with no "
                "intervening store changing it.",
    },
    "redundant_load": {
        "short": "Redundant load: same buffer read at identical indices "
                 "more than once",
        "help": "Paper Def. 3 at the equation level: the same unmutated "
                "buffer gathered/sliced with identical index chains "
                "multiple times in one scope, or a loop-invariant gather "
                "re-executed on every scan iteration.",
    },
    "dead_param": {
        "short": "Dead parameter: a buffer marshalled in but never read",
        "help": "Paper Def. 1 at allocation granularity: a jaxpr invar "
                "that reaches no output and no effectful equation — e.g. "
                "dead expert weights in MoE dispatch, unused cache "
                "leaves. The buffer is allocated, transferred and held "
                "live for nothing.",
    },
    "silent_param_store": {
        "short": "Silent parameter update: optimizer wrote back unchanged "
                 "weights",
        "help": "Paper Def. 2 applied per parameter leaf: the train step "
                "stored a parameter tensor bit-equal (within tolerance) "
                "to its previous value.",
    },
    "dead_grad_store": {
        "short": "Dead gradient store: gradient written then overwritten "
                 "unread",
        "help": "Paper Def. 1 applied to gradient accumulation buffers.",
    },
    "silent_data_load": {
        "short": "Silent data load: an input batch re-read unchanged",
        "help": "Paper Def. 3 applied to input pipelines: the same batch "
                "content loaded repeatedly (duplicate epochs/shards).",
    },
    "redundant_collective": {
        "short": "Redundant collective: identical collective issued twice",
        "help": "Tier-2 HLO analysis: two collectives with identical "
                "operand shapes, replica groups and producer provenance "
                "move the same bytes twice.",
    },
    "recompute": {
        "short": "Recompute: identical expensive op executed twice",
        "help": "Tier-2 HLO analysis: duplicate dot/convolution/large "
                "reduction with identical shapes AND identical operand "
                "producers — the same flops spent twice (CSE miss or "
                "intentional remat; rank tells you if it matters).",
    },
    "reshard_copy": {
        "short": "Reshard copy: large layout/sharding change materialized",
        "help": "Tier-2 HLO analysis: a copy/transpose/all-to-all over "
                "the reshard threshold that only rearranges bytes.",
    },
    "prefill_padding": {
        "short": "Prefill padding burn: tokens computed then masked away",
        "help": "Serve-side: bucket padding in batched prefill computes "
                "attention for positions that are discarded.",
    },
    "rejected_draft_store": {
        "short": "Rejected draft store: KV written for tokens verification "
                 "discarded",
        "help": "Paper Def. 1 in speculative decoding: draft tokens past "
                "the first mismatch still wrote their KV into the cache "
                "(overwrite mode); rollback commits exactly the accepted "
                "rows and drives this to zero.",
    },
    "kernel_silent_store": {
        "short": "Kernel-counted silent store (exact, in-kernel)",
        "help": "Tier 4: the Pallas store epilogue counted stores whose "
                "value equaled the resident value (COUNTER_TOL=0). "
                "Exhaustive population — the fraction is exact.",
    },
    "kernel_dead_store": {
        "short": "Kernel-counted dead store (exact, in-kernel)",
        "help": "Tier 4: in-kernel counters at the store site; writes "
                "dropped or overwritten before any read.",
    },
    "kernel_rejected_draft_store": {
        "short": "Kernel-counted rejected-draft store (exact, in-kernel)",
        "help": "Tier 4: verify-kernel store counters; equals 1-accept "
                "under overwrite and is provably 0 under rollback.",
    },
    "fleet_silent_prefix_load": {
        "short": "Fleet-level silent prefix load: prefix re-prefilled on "
                 "one replica while resident on another",
        "help": "Paper Def. 3 measured across serving replicas (the "
                "redundancy fraction of Su et al.'s Redundant Loads, "
                "OJXPerf's replica-detection framing): at dispatch time "
                "some replica already held this prompt prefix's KV "
                "pages, but the routed replica recomputed them. "
                "Prefix-aware routing through the global prefix tier "
                "(serve/global_prefix.py) turns the finding into a "
                "cross-replica cache hit.",
    },
    "replica_kv_page": {
        "short": "Bit-identical KV pool pages (dedup opportunity)",
        "help": "Object tier (OJXPerf replica detection): content "
                "digests of live KV pages collide across the fleet — "
                "duplicated prefixes the PrefixIndex missed (same-burst "
                "admissions registered after prefill, or reuse cut at "
                "mismatched page-granularity boundaries). The result's "
                "location is the duplicate page's allocation site "
                "(PageAllocator.alloc). Fix: content-addressed page "
                "dedup (content_dedup on router + engine).",
    },
    "replica_param": {
        "short": "Weight tensors replicated across serving replicas",
        "help": "Object tier (OJXPerf replica detection): the same "
                "parameter bytes live once per replica. Fix: a shared "
                "weight arena mapped once per host, replicas get views.",
    },
    "replica_opt_state": {
        "short": "Bit-identical optimizer-state leaves",
        "help": "Object tier (OJXPerf replica detection): optimizer "
                "moments that are byte-equal (typically still "
                "zero-initialized). Fix: dedup or lazy-materialize on "
                "first nonzero update.",
    },
    "replica_draft_window": {
        "short": "Bit-identical speculative draft windows",
        "help": "Object tier (OJXPerf replica detection): per-slot "
                "draft windows holding the same proposal bytes.",
    },
}

_TIER_NAMES = {0: "static jaxpr lint", 1: "interpreter", 2: "HLO",
               3: "detectors", 4: "kernel counters",
               5: "object replicas"}


def finding_fingerprint(f: Finding) -> str:
    """Stable id over the §5.6 coalescing key (kind|tier|C1|C2).

    Excludes counts/bytes/fractions on purpose: the same site found in
    two runs with different magnitudes must collide, so baselines can
    suppress it."""
    raw = "|".join([f.kind, str(f.tier),
                    "\x1f".join(f.c1), "\x1f".join(f.c2)])
    return hashlib.sha256(raw.encode("utf-8")).hexdigest()[:32]


def _rank(f: Finding) -> float:
    """0-100 priority: log-scaled wasted bytes, then flops, then the
    local waste fraction."""
    if f.bytes > 0:
        return round(min(100.0, 10.0 * math.log10(f.bytes + 1.0)), 2)
    if f.flops > 0:
        return round(min(100.0, 8.0 * math.log10(f.flops + 1.0)), 2)
    fr = f.fraction
    if not math.isnan(fr) and fr > 0:
        return round(min(100.0, 50.0 * fr), 2)
    return 1.0


def _fmt_bytes(b: float) -> str:
    if b >= 1e9:
        return f"{b / 1e9:.2f} GB"
    if b >= 1e6:
        return f"{b / 1e6:.2f} MB"
    if b >= 1e3:
        return f"{b / 1e3:.1f} KB"
    return f"{b:.0f} B"


def _message(f: Finding) -> str:
    rule = f.meta.get("rule", "")
    bits = [f"{f.kind} (tier {f.tier}, {_TIER_NAMES.get(f.tier, '?')})"]
    if rule:
        bits.append(rule)
    cost = []
    if f.bytes:
        cost.append(f"{_fmt_bytes(f.bytes)} wasted")
    if f.flops:
        cost.append(f"{f.flops / 1e9:.2f} GFLOP wasted")
    if not math.isnan(f.fraction) and f.fraction > 0:
        cost.append(f"local waste fraction {f.fraction:.0%}")
    if f.count > 1:
        cost.append(f"x{f.count}")
    if cost:
        bits.append(", ".join(cost))
    if f.c1:
        bits.append(f"C1: {fmt_context(f.c1[-3:])}")
    if f.c2:
        bits.append(f"C2: {fmt_context(f.c2[-3:])}")
    return ". ".join(bits)


def _location(f: Finding, src_root: Optional[str]) -> Dict[str, Any]:
    file = f.meta.get("file")
    line = int(f.meta.get("line", 0) or 0)
    if file:
        uri = str(file).replace(os.sep, "/")
        loc: Dict[str, Any] = {"artifactLocation": {"uri": uri}}
        if src_root:
            root = str(src_root).rstrip("/\\")
            rootu = root.replace(os.sep, "/") + "/"
            if uri.startswith(rootu):
                loc["artifactLocation"] = {
                    "uri": uri[len(rootu):], "uriBaseId": "SRCROOT"}
        if line > 0:
            loc["region"] = {"startLine": line}
        return {"physicalLocation": loc}
    # no source file (e.g. dead_param names a buffer, tier-3 names a
    # leaf path): a logical location keeps the result addressable
    name = f.meta.get("path") or fmt_context(f.c1[-2:]) or f.kind
    return {"logicalLocations": [
        {"name": str(name), "kind": "member",
         "fullyQualifiedName": fmt_context(f.c1) or str(name)}]}


def _rule_for(kind: str) -> Dict[str, Any]:
    spec = _RULES.get(kind)
    if spec is None:
        spec = {"short": f"Wasteful memory operation: {kind}",
                "help": "Waste class observed by the JXPerf-JAX profiler "
                        "(see DESIGN.md); no static definition recorded "
                        "for this kind."}
    return {
        "id": kind,
        "name": "".join(w.capitalize() for w in kind.split("_")),
        "shortDescription": {"text": spec["short"]},
        "fullDescription": {"text": spec["help"]},
        "help": {"text": spec["help"]},
        "defaultConfiguration": {"level": "warning"},
    }


def to_sarif(profile: WasteProfile, *,
             src_root: Optional[str] = None,
             tool_version: str = "0") -> Dict[str, Any]:
    """Render a WasteProfile (any tier or merged) as a SARIF 2.1.0 doc."""
    findings = sorted(profile.findings,
                      key=lambda f: (-f.bytes, -f.flops, f.kind,
                                     f.tier, f.c1, f.c2))
    kinds: List[str] = []
    for f in findings:
        if f.kind not in kinds:
            kinds.append(f.kind)
    rule_index = {k: i for i, k in enumerate(kinds)}

    results = []
    for f in findings:
        props: Dict[str, Any] = {
            "tier": f.tier, "count": f.count, "bytes": f.bytes,
            "flops": f.flops, "fraction": (None if math.isnan(f.fraction)
                                           else f.fraction),
        }
        for k in ("subject", "path", "shape"):
            if k in f.meta:
                props[k] = f.meta[k]
        results.append({
            "ruleId": f.kind,
            "ruleIndex": rule_index[f.kind],
            "level": "warning",
            "rank": _rank(f),
            "message": {"text": _message(f)},
            "locations": [_location(f, src_root)],
            "partialFingerprints": {"wasteKey/v1": finding_fingerprint(f)},
            "properties": props,
        })

    run: Dict[str, Any] = {
        "tool": {"driver": {
            "name": TOOL_NAME,
            "informationUri":
                "https://github.com/jxperf/jxperf#readme",
            "version": str(tool_version),
            "rules": [_rule_for(k) for k in kinds],
        }},
        "results": results,
        "columnKind": "utf16CodeUnits",
        "properties": {
            "tiers": list(profile.tiers),
            "fractions": {k: v for k, v in profile.fractions().items()},
            "checked": dict(profile.checked),
            "flagged": dict(profile.flagged),
        },
    }
    if src_root:
        run["originalUriBaseIds"] = {
            "SRCROOT": {"uri": "file://"
                        + str(src_root).replace(os.sep, "/").rstrip("/")
                        + "/"}}
    return {"$schema": SARIF_SCHEMA, "version": SARIF_VERSION,
            "runs": [run]}


def write_sarif(profile: WasteProfile, path: str, *,
                src_root: Optional[str] = None,
                tool_version: str = "0") -> Dict[str, Any]:
    doc = to_sarif(profile, src_root=src_root, tool_version=tool_version)
    with open(path, "w") as fh:
        json.dump(doc, fh, indent=2, sort_keys=True)
        fh.write("\n")
    return doc
