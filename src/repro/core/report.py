"""Post-mortem profile rendering and cross-shard merging (paper §5.6).

Per-device/per-process Tier-1 reports merge with the paper's rule: pairs
coalesce iff both calling contexts match; metrics aggregate.
"""
from __future__ import annotations

from typing import Iterable, List

from repro.core.context import fmt_context
from repro.core.interpreter import Report


def merge_reports(reports: Iterable[Report]) -> Report:
    it = iter(reports)
    first = next(it)
    for r in it:
        first.merge(r)
    return first


def render(report: Report, top_k: int = 5) -> str:
    fr = report.fractions()
    lines: List[str] = []
    lines.append("== JXPerf-JAX Tier-1 profile ==")
    lines.append(f"  sampling period: {report.sampling_period} events")
    lines.append(f"  events: {report.total_store_events:,} stores / "
                 f"{report.total_load_events:,} loads")
    for kind, table in (("dead_store", report.dead_stores),
                        ("silent_store", report.silent_stores),
                        ("silent_load", report.silent_loads)):
        lines.append(f"  F^{kind} = {fr[kind]:.1%} "
                     f"({table.total_count} sampled pairs)")
        for (c1, c2), st in table.top(top_k):
            lines.append(f"    x{st.count:<5d} {fmt_context(c1[-3:])}")
            lines.append(f"           -> {fmt_context(c2[-3:])}")
    return "\n".join(lines)
