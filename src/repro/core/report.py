"""Post-mortem profile rendering and merging (paper §5.6, DESIGN.md §2).

Every tier emits the same findings.WasteProfile, so merging is uniform:
per-device / per-process / per-tier profiles coalesce with the paper's
rule — ⟨C1,C2⟩ pairs merge iff both calling contexts (and kind/tier)
match; estimator counters and totals aggregate. Profiles round-trip
through JSON, so shards can be written per host and merged post-mortem.
"""
from __future__ import annotations

import os
from typing import Iterable

from repro.core.findings import WasteProfile, merge_profiles


def merge_reports(reports: Iterable[WasteProfile]) -> WasteProfile:
    """Mutating left-fold merge (seed API): first profile absorbs the rest."""
    it = iter(reports)
    first = next(it)
    for r in it:
        first.merge(r)
    return first


def merge_shards(reports: Iterable[WasteProfile]) -> WasteProfile:
    """Pure cross-shard merge: inputs untouched, fresh merged profile."""
    return merge_profiles(reports)


def render(report: WasteProfile, top_k: int = 5) -> str:
    return report.render(top_k=top_k)


def dump_json(report: WasteProfile, path: str) -> str:
    """Write the profile to `path` (lossless JSON round-trip). Parent
    directories are created — a long profiled run must not lose its
    profile to a missing output directory at the very end."""
    parent = os.path.dirname(path)
    if parent:
        os.makedirs(parent, exist_ok=True)
    text = report.to_json(indent=2)
    with open(path, "w") as f:
        f.write(text)
    return path


def load_json(path: str) -> WasteProfile:
    with open(path) as f:
        return WasteProfile.from_json(f.read())
