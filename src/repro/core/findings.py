"""Unified waste-finding schema shared by all three tiers (DESIGN.md §2).

One ``Finding`` describes one coalescible waste site: a kind (dead_store,
silent_store, silent_load, silent_param_store, dead_grad_store,
silent_data_load, redundant_collective, recompute, reshard_copy, ...), the
tier that observed it, the paper's ⟨C1,C2⟩ calling-context provenance, and
its cost dimensions (event count, bytes, flops, local waste fraction).

One ``WasteProfile`` is the report type every tier emits: findings plus
the checked/flagged counters behind the sampled fraction estimator
(Eq. (1): F^kind = flagged/checked over a uniform reservoir sample),
event/byte totals, and watchpoint statistics. Profiles merge across
shards, epochs and tiers with the paper's §5.6 rule — findings coalesce
iff (kind, tier, C1, C2) all match; counters and totals add — and
round-trip losslessly through JSON so per-host profiles can be shipped
and aggregated post-mortem.
"""
from __future__ import annotations

import dataclasses
import json
import math
from dataclasses import dataclass, field
from typing import Any, Dict, Iterable, List, Optional, Tuple

from repro.core.context import PairStats, PairTable, fmt_context

SCHEMA_VERSION = 1

# kinds whose fraction estimator is meaningful per-access (Defs. 1-3)
TIER1_KINDS = ("dead_store", "silent_store", "silent_load")

# the static tier (DESIGN.md § Static tier): findings proven on the
# closed jaxpr BEFORE compilation by core/jaxpr_lint.py (dead_store,
# silent_store, redundant_load, dead_param). Checked/flagged counters
# count candidate equations, so Eq. (1) here estimates the fraction of
# store/load SITES that are wasteful rather than dynamic accesses.
TIER_STATIC = 0

# the machine-code attribution tier (DESIGN.md § Kernel tier): findings
# whose counters were measured INSIDE the serving Pallas kernels at the
# store site (kernel_silent_store, kernel_dead_store,
# kernel_rejected_draft_store). Exhaustive populations, so for tier-4
# kinds the Eq. (1) estimator returns the exact fraction, not a sample.
TIER_KERNEL = 4

# the object tier (DESIGN.md § Object tier): DJXPerf-style aggregation
# by allocation (core/objects.py registry) and OJXPerf-style replica
# findings (core/replicas.py) — replica_kv_page / replica_param /
# replica_opt_state, each naming the dedup that eliminates it.
TIER_OBJECT = 5


def _fmax(a: float, b: float) -> float:
    """NaN-robust max: prefer the non-NaN operand (both NaN -> NaN).

    Python's max() is order-dependent under NaN (max(nan, 1) is nan but
    max(1, nan) is 1), which silently broke the §5.6 merge's
    associativity/commutativity for NaN-bearing findings — the merge
    fuzz test pins this."""
    if math.isnan(a):
        return b
    if math.isnan(b):
        return a
    return max(a, b)


@dataclass
class Finding:
    """One coalescible waste site (key = kind, tier, c1, c2)."""
    kind: str
    tier: int
    c1: Tuple[str, ...] = ()
    c2: Tuple[str, ...] = ()
    count: int = 1
    bytes: float = 0.0
    flops: float = 0.0
    # worst observed local fraction (max keeps merge exactly associative)
    fraction: float = 0.0
    step: int = -1
    meta: Dict[str, Any] = field(default_factory=dict)

    @property
    def key(self) -> Tuple:
        return (self.kind, self.tier, self.c1, self.c2)

    @property
    def path(self) -> str:
        """Tier-3 leaf path / generic site label."""
        return self.meta.get("path", fmt_context(self.c1))

    def absorb(self, other: "Finding") -> None:
        assert self.key == other.key
        self.count += other.count
        self.bytes += other.bytes
        self.flops += other.flops
        self.fraction = _fmax(self.fraction, other.fraction)
        self.step = max(self.step, other.step)
        for k, v in other.meta.items():
            self.meta.setdefault(k, v)

    def to_dict(self) -> Dict[str, Any]:
        return {"kind": self.kind, "tier": self.tier,
                "c1": list(self.c1), "c2": list(self.c2),
                "count": self.count, "bytes": self.bytes,
                "flops": self.flops, "fraction": self.fraction,
                "step": self.step, "meta": dict(self.meta)}

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "Finding":
        return cls(kind=d["kind"], tier=int(d["tier"]),
                   c1=tuple(d.get("c1", ())), c2=tuple(d.get("c2", ())),
                   count=int(d.get("count", 1)),
                   bytes=float(d.get("bytes", 0.0)),
                   flops=float(d.get("flops", 0.0)),
                   fraction=float(d.get("fraction", 0.0)),
                   step=int(d.get("step", -1)),
                   meta=dict(d.get("meta", {})))


class WasteProfile:
    """The one report type all tiers emit; mergeable and JSON round-trip."""

    def __init__(self, tier: Optional[int] = None, sampling_period: int = 1):
        self.tiers: List[int] = [tier] if tier is not None else []
        self.sampling_period = sampling_period
        self._index: Dict[Tuple, Finding] = {}
        # sampled fraction estimator state: per kind, how many watched
        # accesses were checked and how many of those were wasteful
        self.checked: Dict[str, int] = {}
        self.flagged: Dict[str, int] = {}
        # event/byte/flop totals ("store_events", "load_bytes", tier-2
        # "recompute_flops", ...) — all additive under merge
        self.totals: Dict[str, float] = {}
        self.watchpoint_stats: Dict[str, Dict[str, int]] = {}
        self.meta: Dict[str, Any] = {}
        # DJXPerf object table: object_key (kind|name|alloc-site, see
        # core/objects.py) -> {"kind","name","site","nbytes","count",
        # "waste": {waste_kind: bytes}}. Any tier can bill waste bytes
        # to an object; rows merge additively (waste/count add, nbytes
        # is a size so merge takes the NaN-robust max) which keeps the
        # §5.6 merge associative and commutative over objects too.
        self.objects: Dict[str, Dict[str, Any]] = {}

    # -- findings ------------------------------------------------------
    @property
    def findings(self) -> List[Finding]:
        return list(self._index.values())

    def add(self, f: Finding) -> Finding:
        """Coalesce `f` into the profile (§5.6 rule); returns the site."""
        cur = self._index.get(f.key)
        if cur is None:
            cur = dataclasses.replace(f, meta=dict(f.meta))
            self._index[cur.key] = cur
        else:
            cur.absorb(f)
        return cur

    def add_pair(self, kind: str, tier: int, c1, c2, nbytes: float,
                 **meta) -> Finding:
        return self.add(Finding(kind=kind, tier=tier, c1=tuple(c1),
                                c2=tuple(c2), bytes=float(nbytes),
                                meta=meta))

    def observe(self, kind: str, flagged: bool) -> None:
        """One watched access was checked against Definitions 1-3."""
        self.checked[kind] = self.checked.get(kind, 0) + 1
        if flagged:
            self.flagged[kind] = self.flagged.get(kind, 0) + 1

    def bump_total(self, key: str, amount: float) -> None:
        self.totals[key] = self.totals.get(key, 0) + amount

    # -- object table (DJXPerf aggregation) ----------------------------
    def bill_object(self, obj, waste_kind: str, nbytes: float,
                    count: int = 1) -> Dict[str, Any]:
        """Bill ``nbytes`` of ``waste_kind`` waste to an object.

        ``obj`` is an ``ObjectRecord`` (core/objects.py) or a row dict
        from another profile's object table; either way the row is keyed
        by the stable object key so repeated bills and cross-profile
        merges coalesce."""
        if isinstance(obj, dict):
            key = obj["key"]
            row = self.objects.setdefault(key, {
                "key": key, "kind": obj["kind"], "name": obj["name"],
                "site": obj["site"], "nbytes": float(obj["nbytes"]),
                "count": 0, "waste": {}})
        else:
            key = obj.object_key
            row = self.objects.setdefault(key, {
                "key": key, "kind": obj.kind, "name": obj.name,
                "site": obj.site, "nbytes": float(obj.nbytes),
                "count": 0, "waste": {}})
        row["nbytes"] = _fmax(row["nbytes"], float(
            obj["nbytes"] if isinstance(obj, dict) else obj.nbytes))
        row["count"] += int(count)
        row["waste"][waste_kind] = (row["waste"].get(waste_kind, 0.0)
                                    + float(nbytes))
        return row

    def top_objects(self, k: int = 10) -> List[Dict[str, Any]]:
        """Object rows by total attributed waste bytes, descending."""
        rows = sorted(self.objects.values(),
                      key=lambda r: (-sum(r["waste"].values()), r["key"]))
        return rows[:k]

    def _absorb_object(self, row: Dict[str, Any]) -> None:
        cur = self.objects.get(row["key"])
        if cur is None:
            self.objects[row["key"]] = {**row, "waste": dict(row["waste"])}
            return
        cur["nbytes"] = _fmax(float(cur["nbytes"]), float(row["nbytes"]))
        cur["count"] += int(row["count"])
        for k, v in row["waste"].items():
            cur["waste"][k] = cur["waste"].get(k, 0.0) + float(v)

    # -- estimators ----------------------------------------------------
    def fractions(self) -> Dict[str, float]:
        # `if v` is a guard, not style: a zero-event kind (cold engine,
        # empty object tier) must drop out of the estimator entirely
        # rather than divide by zero and leak NaN into JSON/SARIF
        out = {k: self.flagged.get(k, 0) / v
               for k, v in self.checked.items() if v}
        for k in TIER1_KINDS:            # always present for tier-1 readers
            if 1 in self.tiers:
                out.setdefault(k, 0.0)
        return out

    def top(self, k: int = 10, kind: Optional[str] = None) -> List[Finding]:
        fs = [f for f in self._index.values()
              if kind is None or f.kind == kind]
        return sorted(fs, key=lambda f: (-f.bytes, -f.flops, -f.fraction,
                                         -f.count))[:k]

    def pair_table(self, kind: str) -> PairTable:
        """⟨C1,C2⟩ view of one kind's findings (seed-Report compatible)."""
        t = PairTable()
        for f in self._index.values():
            if f.kind == kind:
                t.pairs[(f.c1, f.c2)] = PairStats(count=f.count,
                                                  bytes=f.bytes)
        return t

    # seed-era accessors kept so existing tooling reads the new profile
    @property
    def dead_stores(self) -> PairTable:
        return self.pair_table("dead_store")

    @property
    def silent_stores(self) -> PairTable:
        return self.pair_table("silent_store")

    @property
    def silent_loads(self) -> PairTable:
        return self.pair_table("silent_load")

    @property
    def total_store_events(self) -> int:
        return int(self.totals.get("store_events", 0))

    @property
    def total_load_events(self) -> int:
        return int(self.totals.get("load_events", 0))

    @property
    def total_store_bytes(self) -> float:
        return self.totals.get("store_bytes", 0.0)

    @property
    def total_load_bytes(self) -> float:
        return self.totals.get("load_bytes", 0.0)

    # -- merge (cross-epoch, cross-shard, cross-tier) ------------------
    def merge(self, other: "WasteProfile") -> "WasteProfile":
        for t in other.tiers:
            if t not in self.tiers:
                self.tiers.append(t)
        self.tiers.sort()
        self.sampling_period = max(self.sampling_period,
                                   other.sampling_period)
        for f in other._index.values():
            self.add(f)
        for k, v in other.checked.items():
            self.checked[k] = self.checked.get(k, 0) + v
        for k, v in other.flagged.items():
            self.flagged[k] = self.flagged.get(k, 0) + v
        for k, v in other.totals.items():
            self.totals[k] = self.totals.get(k, 0) + v
        for cls, st in other.watchpoint_stats.items():
            mine = self.watchpoint_stats.setdefault(cls, {})
            for k, v in st.items():
                mine[k] = mine.get(k, 0) + v
        for row in other.objects.values():
            self._absorb_object(row)
        for k, v in other.meta.items():
            self.meta.setdefault(k, v)
        return self

    # -- serialization -------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        return {
            "version": SCHEMA_VERSION,
            "tiers": list(self.tiers),
            "sampling_period": self.sampling_period,
            "checked": dict(sorted(self.checked.items())),
            "flagged": dict(sorted(self.flagged.items())),
            "totals": dict(sorted(self.totals.items())),
            "watchpoint_stats": {k: dict(sorted(v.items())) for k, v in
                                 sorted(self.watchpoint_stats.items())},
            "meta": dict(sorted(self.meta.items())),
            "objects": {k: {**row, "waste": dict(sorted(row["waste"].items()))}
                        for k, row in sorted(self.objects.items())},
            "findings": [f.to_dict() for f in
                         sorted(self._index.values(),
                                key=lambda f: (f.kind, f.tier, f.c1, f.c2))],
        }

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "WasteProfile":
        p = cls()
        p.tiers = [int(t) for t in d.get("tiers", [])]
        p.sampling_period = int(d.get("sampling_period", 1))
        p.checked = {k: int(v) for k, v in d.get("checked", {}).items()}
        p.flagged = {k: int(v) for k, v in d.get("flagged", {}).items()}
        p.totals = dict(d.get("totals", {}))
        p.watchpoint_stats = {k: {kk: int(vv) for kk, vv in v.items()}
                              for k, v in d.get("watchpoint_stats",
                                                {}).items()}
        p.meta = dict(d.get("meta", {}))
        for k, row in d.get("objects", {}).items():
            p.objects[k] = {
                "key": row.get("key", k), "kind": row["kind"],
                "name": row["name"], "site": row["site"],
                "nbytes": float(row["nbytes"]),
                "count": int(row.get("count", 0)),
                "waste": {wk: float(wv)
                          for wk, wv in row.get("waste", {}).items()}}
        for fd in d.get("findings", []):
            f = Finding.from_dict(fd)
            p._index[f.key] = f
        return p

    @classmethod
    def from_json(cls, s: str) -> "WasteProfile":
        return cls.from_dict(json.loads(s))

    def __eq__(self, other) -> bool:
        if not isinstance(other, WasteProfile):
            return NotImplemented
        return self.to_dict() == other.to_dict()

    def __repr__(self) -> str:
        return (f"WasteProfile(tiers={self.tiers}, "
                f"findings={len(self._index)}, "
                f"fractions={self.fractions()})")

    # -- rendering -----------------------------------------------------
    def render(self, top_k: int = 5, by: str = "kind") -> str:
        if by == "object":
            return self._render_objects(top_k)
        if by != "kind":
            raise ValueError(f"render(by=...) wants 'kind' or 'object', "
                             f"not {by!r}")
        fr = self.fractions()
        tiers = ",".join(str(t) for t in self.tiers) or "-"
        lines = [f"== JXPerf-JAX waste profile (tiers {tiers}) =="]
        if self.total_store_events or self.total_load_events:
            lines.append(f"  sampling period: {self.sampling_period} events")
            lines.append(f"  events: {self.total_store_events:,} stores / "
                         f"{self.total_load_events:,} loads")
        for kind in TIER1_KINDS:
            if kind not in fr:
                continue
            table = self.pair_table(kind)
            lines.append(f"  F^{kind} = {fr[kind]:.1%} "
                         f"({table.total_count} sampled pairs)")
            for (c1, c2), st in table.top(top_k):
                lines.append(f"    x{st.count:<5d} {fmt_context(c1[-3:])}")
                lines.append(f"           -> {fmt_context(c2[-3:])}")
        for kind in sorted(fr):
            if kind in TIER1_KINDS:
                continue
            lines.append(f"  F^{kind} = {fr[kind]:.1%} "
                         f"({self.flagged.get(kind, 0)}/"
                         f"{self.checked.get(kind, 0)} checked)")
            for f in self.top(top_k, kind=kind):
                cost = (f"{f.bytes / 1e9:.2f} GB" if f.bytes
                        else f"{f.flops / 1e12:.2f} TF" if f.flops
                        else f"{f.fraction:.0%}")
                lines.append(f"    x{f.count:<5d} {cost:>10s}  {f.path}")
        return "\n".join(lines)

    def _render_objects(self, top_k: int) -> str:
        """DJXPerf-style top-objects table: waste billed per allocation,
        ranked by attributed bytes, with the allocation site inline.

        A cold engine legitimately has an empty (or waste-free) object
        table — render zero rows, never a division by an absent
        denominator (object "fractions" are waste/nbytes and nbytes can
        be 0 for lazily-sized objects)."""
        lines = [f"== top objects by attributed waste "
                 f"({len(self.objects)} registered) =="]
        rows = [r for r in self.top_objects(top_k)
                if sum(r["waste"].values()) > 0]
        if not rows:
            lines.append("  (no object-attributed waste)")
            return "\n".join(lines)
        for r in rows:
            waste = sum(r["waste"].values())
            nbytes = r["nbytes"]
            frac = (f"{waste / nbytes:7.1%}"
                    if nbytes and not math.isnan(nbytes) else "      -")
            kinds = ", ".join(f"{k} {v / 1e3:.1f}KB"
                              for k, v in sorted(r["waste"].items()))
            lines.append(f"  {waste / 1e3:10.1f} KB {frac} "
                         f"{r['kind']:13s} {r['name']}")
            lines.append(f"      @ {r['site']}  [{kinds}] x{r['count']}")
        return "\n".join(lines)


def merge(*profiles: WasteProfile) -> WasteProfile:
    """Pure n-way merge: cross-shard, cross-epoch and cross-tier profiles
    coalesce into one report (associative; inputs untouched)."""
    out = WasteProfile()
    for p in profiles:
        out.merge(p)
    return out


def merge_profiles(profiles: Iterable[WasteProfile]) -> WasteProfile:
    return merge(*profiles)


def merge_fleet(profiles: Dict[str, WasteProfile]) -> WasteProfile:
    """§5.6 merge across serving-fleet members (replica engines + the
    router's own fleet-level findings), keyed by member name.

    Findings coalesce exactly as in `merge` — cross-replica sites with
    the same (kind, tier, C1, C2) add up — but replica attribution is
    not lost: ``meta["fleet"]`` records each member's finding count and
    checked/flagged totals, so the fleet report can say which replica
    contributed what without breaking associative coalescing. The
    result round-trips through JSON and SARIF like any profile."""
    out = WasteProfile()
    summary: Dict[str, Dict[str, int]] = {}
    for name in sorted(profiles):
        p = profiles[name]
        out.merge(p)
        summary[name] = {
            "findings": len(p.findings),
            "checked": int(sum(p.checked.values())),
            "flagged": int(sum(p.flagged.values())),
        }
    out.meta["fleet"] = summary
    return out
