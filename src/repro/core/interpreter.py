"""Tier-1 runtime profiler: dead stores / silent stores / silent loads in
an executing JAX program (paper §4-§5, adapted per DESIGN.md §2).

The program's jaxpr is interpreted op by op against a modeled flat address
space: every equation output is a STORE over a buffer placed by a reusing
allocator (buffers free at last use, addresses recycle — the moral
equivalent of the mutable heap JXPerf watches), every operand read is a
LOAD. Memory events stream through the shared event substrate
(repro.core.events): a PMU-style geometric sampler, the paper's reservoir
watchpoints, traps classified per Definitions 1-3 with ⟨C1,C2⟩
attribution into one findings.WasteProfile.

Multi-epoch profiling is trace→replay: the jaxpr is evaluated concretely
ONCE while recording a flat EventTrace (address, extent, value reference,
context per access); epochs 2..N replay that trace through a fresh-epoch
EventEngine. The program is deterministic, so replaying the recorded
stream is event-for-event identical to re-interpreting it — minus the N×
primitive re-binding, which is where all the interpreter time goes
(benchmarks/overhead.py: tier1_replay vs tier1_reinterp). Epoch semantics
are unchanged: each epoch is a GC epoch (watchpoints never cross it),
scan/while/cond/pjit/remat bodies are interpreted recursively with buffer
identity preserved across iterations, so a linear search in a scan traps
exactly like the paper's ``contains()`` case.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional

import jax
import numpy as np
try:
    from jax.extend.core import Literal
except ImportError:  # pragma: no cover
    from jax.core import Literal

from repro.configs.base import ProfilerConfig
from repro.core.context import context_of_eqn
from repro.core.events import (LOAD, STORE, EventEngine, EventTrace,
                               MemEvent)
from repro.core.findings import WasteProfile

# the unified profile IS the Tier-1 report (seed `Report` name kept)
Report = WasteProfile


# ----------------------------------------------------------------------
class Allocator:
    """Flat address space with size-class recycling (heap analogue)."""

    def __init__(self):
        self.next = 0
        self.free_lists: Dict[int, List[int]] = {}

    def alloc(self, nelems: int) -> int:
        fl = self.free_lists.get(nelems)
        if fl:
            return fl.pop()
        addr = self.next
        self.next += max(nelems, 1)
        return addr

    def free(self, addr: int, nelems: int) -> None:
        self.free_lists.setdefault(nelems, []).append(addr)


@dataclass
class Buffer:
    addr: int
    nelems: int
    itemsize: int


_CONTROL_PRIMS = {"scan", "while", "cond"}


def _inner_closed_jaxpr(eqn):
    for key in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
        if key in eqn.params:
            return eqn.params[key]
    return None


class JxInterpreter:
    """Profile fn(*args) and produce a :class:`WasteProfile`."""

    def __init__(self, cfg: Optional[ProfilerConfig] = None):
        self.cfg = cfg or ProfilerConfig(enabled=True)
        self.engine = EventEngine(self.cfg, tier=1)
        self.trace: Optional[EventTrace] = None

    # ------------------------------------------------------------------
    def profile(self, fn, *args, epochs: int = 1,
                replay: bool = True) -> WasteProfile:
        """Profile `epochs` identical executions of fn(*args).

        replay=True (default): interpret once recording an EventTrace,
        then replay it for the remaining epochs. replay=False keeps the
        seed behaviour — full re-interpretation every epoch — and exists
        as the benchmark baseline; both give identical profiles at a
        fixed seed because the replayed stream IS the recorded stream.

        Memory trade: the recorded trace holds every intermediate value
        by reference until profiling ends, so peak host memory is the
        program's *total* intermediate footprint rather than its live
        set. Tier-1 is the offline analysis mode and its subjects are
        deliberately small (DESIGN.md §2); for a memory-constrained
        multi-epoch profile pass replay=False to trade time back.
        """
        closed = jax.make_jaxpr(fn)(*args)
        flat, _ = jax.tree_util.tree_flatten(args)
        flat = [np.asarray(x) for x in flat]
        record = replay and epochs > 1
        for epoch in range(epochs):
            self.alloc = Allocator()
            self.engine.reset_epoch()          # GC-epoch semantics
            if epoch == 0 or not replay:
                self.trace = EventTrace() if record else None
                self._eval_jaxpr(closed.jaxpr, closed.consts, flat, None)
                record = False                 # only the first epoch records
            else:
                self.engine.replay(self.trace)
        return self.engine.finalize()

    # ------------------------------------------------------------------
    def _emit(self, kind: str, buf: Buffer, val: np.ndarray, ctx) -> None:
        ev = MemEvent(kind=kind, address=buf.addr, nelems=buf.nelems,
                      itemsize=buf.itemsize, values=val, ctx=ctx)
        if self.trace is not None:
            self.trace.append(ev)
        self.engine.on_event(ev)

    def _new_buffer(self, val: np.ndarray) -> Buffer:
        return Buffer(self.alloc.alloc(int(val.size)), int(val.size),
                      int(val.dtype.itemsize))

    def _eval_jaxpr(self, jaxpr, consts, args, arg_bufs):
        """Interpret one (sub)jaxpr. arg_bufs: parallel Buffer list for
        `args` (None entries -> fresh input buffers owned by this frame)."""
        env: Dict[Any, np.ndarray] = {}
        bufs: Dict[Any, Buffer] = {}
        owned: List[Buffer] = []

        def read_val(v):
            return np.asarray(v.val) if isinstance(v, Literal) else env[v]

        def read_buf(v):
            return None if isinstance(v, Literal) else bufs.get(v)

        if arg_bufs is None:
            arg_bufs = [None] * len(args)

        for cv, cval in zip(jaxpr.constvars, consts):
            val = np.asarray(cval)
            env[cv] = val
            b = self._new_buffer(val)
            bufs[cv] = b
            owned.append(b)
        for iv, val, b in zip(jaxpr.invars, args, arg_bufs):
            env[iv] = np.asarray(val)
            if b is None:
                b = self._new_buffer(env[iv])
                owned.append(b)
            bufs[iv] = b

        # last-use positions for address recycling within this frame
        last_use: Dict[Any, int] = {}
        for i, eqn in enumerate(jaxpr.eqns):
            for v in eqn.invars:
                if not isinstance(v, Literal):
                    last_use[v] = i
        out_set = {v for v in jaxpr.outvars if not isinstance(v, Literal)}

        for i, eqn in enumerate(jaxpr.eqns):
            ctx = context_of_eqn(eqn)
            invals = [read_val(v) for v in eqn.invars]
            inbufs = [read_buf(v) for v in eqn.invars]
            is_call = (eqn.primitive.name in _CONTROL_PRIMS
                       or _inner_closed_jaxpr(eqn) is not None)
            if not is_call:
                for v, b in zip(eqn.invars, inbufs):
                    if b is not None:
                        self._emit(LOAD, b, read_val(v), ctx)

            outvals = self._run_eqn(eqn, invals, inbufs)
            if not isinstance(outvals, (list, tuple)):
                outvals = [outvals]
            for ov, val in zip(eqn.outvars, outvals):
                val = np.asarray(val)
                env[ov] = val
                b = self._new_buffer(val)
                bufs[ov] = b
                owned.append(b)
                if not is_call:
                    self._emit(STORE, b, val, ctx)

            # recycle frame-local dead buffers
            for v in list(bufs):
                if last_use.get(v, -1) <= i and v not in out_set:
                    b = bufs.pop(v)
                    if b in owned:
                        self.alloc.free(b.addr, b.nelems)
                        owned.remove(b)

        outs = [read_val(v) for v in jaxpr.outvars]
        for b in owned:                        # frame exit: release
            self.alloc.free(b.addr, b.nelems)
        return outs

    # ------------------------------------------------------------------
    def _run_eqn(self, eqn, invals, inbufs):
        prim = eqn.primitive
        name = prim.name
        if name == "scan":
            return self._run_scan(eqn, invals, inbufs)
        if name == "while":
            return self._run_while(eqn, invals, inbufs)
        if name == "cond":
            return self._run_cond(eqn, invals, inbufs)
        inner = _inner_closed_jaxpr(eqn)
        if inner is not None:
            cj = inner
            if hasattr(cj, "jaxpr"):
                return self._eval_jaxpr(cj.jaxpr, cj.consts, invals, inbufs)
            return self._eval_jaxpr(cj, [], invals, inbufs)
        out = prim.bind(*invals, **eqn.params)
        return out if prim.multiple_results else [out]

    def _run_scan(self, eqn, invals, inbufs):
        p = eqn.params
        cj = p["jaxpr"]
        nc, ncar, length = p["num_consts"], p["num_carry"], p["length"]
        consts, cbufs = invals[:nc], inbufs[:nc]
        carry = [np.asarray(x) for x in invals[nc:nc + ncar]]
        xs = invals[nc + ncar:]
        ys_acc: List[List[np.ndarray]] = []
        idxs = (range(length - 1, -1, -1) if p.get("reverse")
                else range(length))
        for t in idxs:
            xt = [np.asarray(x)[t] for x in xs]
            args = list(consts) + carry + xt
            bufs = list(cbufs) + [None] * (ncar + len(xt))
            outs = self._eval_jaxpr(cj.jaxpr, cj.consts, args, bufs)
            carry = [np.asarray(o) for o in outs[:ncar]]
            ys_acc.append(outs[ncar:])
        if p.get("reverse"):
            ys_acc.reverse()
        ys = []
        if ys_acc and ys_acc[0]:
            for j in range(len(ys_acc[0])):
                ys.append(np.stack([np.asarray(step[j]) for step in ys_acc]))
        return list(carry) + ys

    def _run_while(self, eqn, invals, inbufs):
        p = eqn.params
        cj, bj = p["cond_jaxpr"], p["body_jaxpr"]
        cn, bn = p["cond_nconsts"], p["body_nconsts"]
        cconsts, ccb = invals[:cn], inbufs[:cn]
        bconsts, bcb = invals[cn:cn + bn], inbufs[cn:cn + bn]
        state = [np.asarray(x) for x in invals[cn + bn:]]
        iters = 0
        while True:
            pred = self._eval_jaxpr(cj.jaxpr, cj.consts,
                                    list(cconsts) + state,
                                    list(ccb) + [None] * len(state))[0]
            if not bool(np.asarray(pred)):
                break
            state = [np.asarray(o) for o in self._eval_jaxpr(
                bj.jaxpr, bj.consts, list(bconsts) + state,
                list(bcb) + [None] * len(state))]
            iters += 1
            if iters > 100000:
                raise RuntimeError("while loop runaway in interpreter")
        return state

    def _run_cond(self, eqn, invals, inbufs):
        branches = eqn.params["branches"]
        idx = int(np.asarray(invals[0]))
        idx = max(0, min(idx, len(branches) - 1))
        br = branches[idx]
        return self._eval_jaxpr(br.jaxpr, br.consts, invals[1:], inbufs[1:])


def profile_fn(fn, *args, cfg: Optional[ProfilerConfig] = None,
               epochs: int = 1, replay: bool = True) -> WasteProfile:
    """Profile fn(*args) with JXPerf-JAX Tier-1 (trace→replay epochs)."""
    return JxInterpreter(cfg).profile(fn, *args, epochs=epochs,
                                      replay=replay)
