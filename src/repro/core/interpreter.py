"""Tier-1 runtime profiler: dead stores / silent stores / silent loads in
an executing JAX program (paper §4-§5, adapted per DESIGN.md §2).

The program's jaxpr is interpreted op by op against a modeled flat address
space: every equation output is a STORE over a buffer placed by a reusing
allocator (buffers free at last use, addresses recycle — the moral
equivalent of the mutable heap JXPerf watches), every operand read is a
LOAD. Memory events stream past a PMU-style sampler (period P); sampled
events arm software watchpoints managed by the paper's reservoir scheme;
the next access to a watched location is the trap, classified per
Definitions 1-3:

  dead store    S1;S2 stores, no intervening load         (value-agnostic)
  silent store  S2 stores the value S1 stored             (fp tol, def 1%)
  silent load   L2 loads the value L1 loaded

Attribution is a ⟨C1,C2⟩ pair of full calling contexts from jaxpr
source_info. Epochs: each profiled call is one epoch (jit-step boundary ≡
GC epoch: watchpoints never cross it). Scan/while/cond/pjit/remat bodies
are interpreted recursively with buffer identity preserved across
iterations, so a linear search in a scan traps exactly like the paper's
``contains()`` case, and loop-invariant recomputation writes the same
values to the same recycled addresses like the paper's NPB-IS case.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np
try:
    from jax.extend.core import Literal
except ImportError:  # pragma: no cover
    from jax.core import Literal

from repro.configs.base import ProfilerConfig
from repro.core.context import PairTable, context_of_eqn
from repro.core.reservoir import ReservoirWatchpoints, Watchpoint


# ----------------------------------------------------------------------
class Allocator:
    """Flat address space with size-class recycling (heap analogue)."""

    def __init__(self):
        self.next = 0
        self.free_lists: Dict[int, List[int]] = {}

    def alloc(self, nelems: int) -> int:
        fl = self.free_lists.get(nelems)
        if fl:
            return fl.pop()
        addr = self.next
        self.next += max(nelems, 1)
        return addr

    def free(self, addr: int, nelems: int) -> None:
        self.free_lists.setdefault(nelems, []).append(addr)


@dataclass
class Buffer:
    addr: int
    nelems: int
    itemsize: int


@dataclass
class Report:
    dead_stores: PairTable = field(default_factory=PairTable)
    silent_stores: PairTable = field(default_factory=PairTable)
    silent_loads: PairTable = field(default_factory=PairTable)
    not_wasteful: Dict[str, int] = field(default_factory=dict)
    total_store_events: int = 0
    total_load_events: int = 0
    total_store_bytes: float = 0.0
    total_load_bytes: float = 0.0
    sampling_period: int = 1
    watchpoint_stats: Dict[str, Any] = field(default_factory=dict)

    def _frac(self, table: PairTable, kind: str) -> float:
        hits = table.total_count
        misses = self.not_wasteful.get(kind, 0)
        checked = hits + misses
        if not checked:
            return 0.0
        # fraction of *checked* accesses that were wasteful — the sampled
        # estimator of Eq. (1)'s byte fractions (uniform reservoir makes
        # checked accesses an unbiased sample of all accesses)
        return hits / checked

    def fractions(self) -> Dict[str, float]:
        return {
            "dead_store": self._frac(self.dead_stores, "dead_store"),
            "silent_store": self._frac(self.silent_stores, "silent_store"),
            "silent_load": self._frac(self.silent_loads, "silent_load"),
        }

    def merge(self, other: "Report") -> "Report":
        self.dead_stores.merge(other.dead_stores)
        self.silent_stores.merge(other.silent_stores)
        self.silent_loads.merge(other.silent_loads)
        for k, v in other.not_wasteful.items():
            self.not_wasteful[k] = self.not_wasteful.get(k, 0) + v
        self.total_store_events += other.total_store_events
        self.total_load_events += other.total_load_events
        self.total_store_bytes += other.total_store_bytes
        self.total_load_bytes += other.total_load_bytes
        return self


_CONTROL_PRIMS = {"scan", "while", "cond"}


def _inner_closed_jaxpr(eqn):
    for key in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
        if key in eqn.params:
            return eqn.params[key]
    return None


class JxInterpreter:
    """Profile fn(*args) and produce a :class:`Report`."""

    def __init__(self, cfg: Optional[ProfilerConfig] = None):
        self.cfg = cfg or ProfilerConfig(enabled=True)
        self.period = max(1, self.cfg.period)
        self.tol = self.cfg.fp_tolerance
        self.detect = set(self.cfg.detect)
        self.rng = np.random.RandomState(self.cfg.seed)
        self.report = Report(sampling_period=self.period)

    def _reset_epoch(self):
        self.alloc = Allocator()
        self.wp = {
            "store": ReservoirWatchpoints(self.cfg.num_watchpoints, self.cfg.seed),
            "load": ReservoirWatchpoints(self.cfg.num_watchpoints, self.cfg.seed + 1),
        }
        self.next_sample = self._draw_gap()

    def _draw_gap(self) -> int:
        return max(1, int(self.rng.geometric(1.0 / self.period)))

    # ------------------------------------------------------------------
    def profile(self, fn, *args, epochs: int = 1) -> Report:
        closed = jax.make_jaxpr(fn)(*args)
        flat, _ = jax.tree_util.tree_flatten(args)
        flat = [np.asarray(x) for x in flat]
        for _ in range(epochs):
            self._reset_epoch()                    # GC-epoch semantics
            self._eval_jaxpr(closed.jaxpr, closed.consts, flat, None)
        self.report.watchpoint_stats = {
            k: dict(v.stats) for k, v in self.wp.items()}
        return self.report

    # ------------------------------------------------------------------
    def _new_buffer(self, val: np.ndarray) -> Buffer:
        return Buffer(self.alloc.alloc(int(val.size)), int(val.size),
                      int(val.dtype.itemsize))

    def _eval_jaxpr(self, jaxpr, consts, args, arg_bufs):
        """Interpret one (sub)jaxpr. arg_bufs: parallel Buffer list for
        `args` (None entries -> fresh input buffers owned by this frame)."""
        env: Dict[Any, np.ndarray] = {}
        bufs: Dict[Any, Buffer] = {}
        owned: List[Buffer] = []

        def read_val(v):
            return np.asarray(v.val) if isinstance(v, Literal) else env[v]

        def read_buf(v):
            return None if isinstance(v, Literal) else bufs.get(v)

        if arg_bufs is None:
            arg_bufs = [None] * len(args)

        for cv, cval in zip(jaxpr.constvars, consts):
            val = np.asarray(cval)
            env[cv] = val
            b = self._new_buffer(val)
            bufs[cv] = b
            owned.append(b)
        for iv, val, b in zip(jaxpr.invars, args, arg_bufs):
            env[iv] = np.asarray(val)
            if b is None:
                b = self._new_buffer(env[iv])
                owned.append(b)
            bufs[iv] = b

        # last-use positions for address recycling within this frame
        last_use: Dict[Any, int] = {}
        for i, eqn in enumerate(jaxpr.eqns):
            for v in eqn.invars:
                if not isinstance(v, Literal):
                    last_use[v] = i
        out_set = {v for v in jaxpr.outvars if not isinstance(v, Literal)}

        for i, eqn in enumerate(jaxpr.eqns):
            ctx = context_of_eqn(eqn)
            invals = [read_val(v) for v in eqn.invars]
            inbufs = [read_buf(v) for v in eqn.invars]
            is_call = (eqn.primitive.name in _CONTROL_PRIMS
                       or _inner_closed_jaxpr(eqn) is not None)
            if not is_call:
                for v, b in zip(eqn.invars, inbufs):
                    if b is not None:
                        self._load_event(b, read_val(v), ctx)

            outvals = self._run_eqn(eqn, invals, inbufs)
            if not isinstance(outvals, (list, tuple)):
                outvals = [outvals]
            for ov, val in zip(eqn.outvars, outvals):
                val = np.asarray(val)
                env[ov] = val
                b = self._new_buffer(val)
                bufs[ov] = b
                owned.append(b)
                if not is_call:
                    self._store_event(b, val, ctx)

            # recycle frame-local dead buffers
            for v in list(bufs):
                if last_use.get(v, -1) <= i and v not in out_set:
                    b = bufs.pop(v)
                    if b in owned:
                        self.alloc.free(b.addr, b.nelems)
                        owned.remove(b)

        outs = [read_val(v) for v in jaxpr.outvars]
        for b in owned:                        # frame exit: release
            self.alloc.free(b.addr, b.nelems)
        return outs

    # ------------------------------------------------------------------
    def _run_eqn(self, eqn, invals, inbufs):
        prim = eqn.primitive
        name = prim.name
        if name == "scan":
            return self._run_scan(eqn, invals, inbufs)
        if name == "while":
            return self._run_while(eqn, invals, inbufs)
        if name == "cond":
            return self._run_cond(eqn, invals, inbufs)
        inner = _inner_closed_jaxpr(eqn)
        if inner is not None:
            cj = inner
            if hasattr(cj, "jaxpr"):
                return self._eval_jaxpr(cj.jaxpr, cj.consts, invals, inbufs)
            return self._eval_jaxpr(cj, [], invals, inbufs)
        out = prim.bind(*invals, **eqn.params)
        return out if prim.multiple_results else [out]

    def _run_scan(self, eqn, invals, inbufs):
        p = eqn.params
        cj = p["jaxpr"]
        nc, ncar, length = p["num_consts"], p["num_carry"], p["length"]
        consts, cbufs = invals[:nc], inbufs[:nc]
        carry = [np.asarray(x) for x in invals[nc:nc + ncar]]
        xs = invals[nc + ncar:]
        ys_acc: List[List[np.ndarray]] = []
        idxs = (range(length - 1, -1, -1) if p.get("reverse")
                else range(length))
        for t in idxs:
            xt = [np.asarray(x)[t] for x in xs]
            args = list(consts) + carry + xt
            bufs = list(cbufs) + [None] * (ncar + len(xt))
            outs = self._eval_jaxpr(cj.jaxpr, cj.consts, args, bufs)
            carry = [np.asarray(o) for o in outs[:ncar]]
            ys_acc.append(outs[ncar:])
        if p.get("reverse"):
            ys_acc.reverse()
        ys = []
        if ys_acc and ys_acc[0]:
            for j in range(len(ys_acc[0])):
                ys.append(np.stack([np.asarray(step[j]) for step in ys_acc]))
        return list(carry) + ys

    def _run_while(self, eqn, invals, inbufs):
        p = eqn.params
        cj, bj = p["cond_jaxpr"], p["body_jaxpr"]
        cn, bn = p["cond_nconsts"], p["body_nconsts"]
        cconsts, ccb = invals[:cn], inbufs[:cn]
        bconsts, bcb = invals[cn:cn + bn], inbufs[cn:cn + bn]
        state = [np.asarray(x) for x in invals[cn + bn:]]
        iters = 0
        while True:
            pred = self._eval_jaxpr(cj.jaxpr, cj.consts,
                                    list(cconsts) + state,
                                    list(ccb) + [None] * len(state))[0]
            if not bool(np.asarray(pred)):
                break
            state = [np.asarray(o) for o in self._eval_jaxpr(
                bj.jaxpr, bj.consts, list(bconsts) + state,
                list(bcb) + [None] * len(state))]
            iters += 1
            if iters > 100000:
                raise RuntimeError("while loop runaway in interpreter")
        return state

    def _run_cond(self, eqn, invals, inbufs):
        branches = eqn.params["branches"]
        idx = int(np.asarray(invals[0]))
        idx = max(0, min(idx, len(branches) - 1))
        br = branches[idx]
        return self._eval_jaxpr(br.jaxpr, br.consts, invals[1:], inbufs[1:])

    # ------------------------------------------------------------------
    # Memory events
    # ------------------------------------------------------------------
    def _advance(self, n: int) -> List[int]:
        hits = []
        pos = 0
        remaining = n
        while self.next_sample <= remaining:
            pos += self.next_sample
            hits.append(pos - 1)
            remaining -= self.next_sample
            self.next_sample = self._draw_gap()
        self.next_sample -= remaining
        return hits

    @staticmethod
    def _value_at(val: np.ndarray, offset: int):
        flat = val.reshape(-1)
        return flat[min(offset, flat.size - 1)]

    def _equal(self, a, b) -> bool:
        a = np.asarray(a)
        b = np.asarray(b)
        if a.dtype.kind in "fc":
            fa, fb = float(np.real(a)), float(np.real(b))
            if math.isnan(fa) or math.isnan(fb):
                return False
            return abs(fa - fb) <= self.tol * abs(fa)
        return bool(a == b)

    def _store_event(self, buf: Buffer, val: np.ndarray, ctx):
        self.report.total_store_events += buf.nelems
        self.report.total_store_bytes += buf.nelems * buf.itemsize
        self._check_traps("store", buf, val, ctx)
        for off in self._advance(buf.nelems):
            if "dead_store" in self.detect:
                self.wp["store"].on_sample(Watchpoint(
                    address=buf.addr, offset=off, size=buf.itemsize,
                    value=None, context=ctx, trap_type="RW_TRAP",
                    meta="dead_store"))
            if "silent_store" in self.detect:
                self.wp["store"].on_sample(Watchpoint(
                    address=buf.addr, offset=off, size=buf.itemsize,
                    value=self._value_at(val, off), context=ctx,
                    trap_type="W_TRAP", meta="silent_store"))

    def _load_event(self, buf: Buffer, val: np.ndarray, ctx):
        self.report.total_load_events += buf.nelems
        self.report.total_load_bytes += buf.nelems * buf.itemsize
        self._check_traps("load", buf, val, ctx)
        if "silent_load" in self.detect:
            for off in self._advance(buf.nelems):
                self.wp["load"].on_sample(Watchpoint(
                    address=buf.addr, offset=off, size=buf.itemsize,
                    value=self._value_at(val, off), context=ctx,
                    trap_type="RW_TRAP", meta="silent_load"))

    def _check_traps(self, access: str, buf: Buffer, val: np.ndarray, ctx):
        rep = self.report
        for wp in self.wp["store"].matching(
                lambda w: w.address == buf.addr and w.offset < buf.nelems):
            if wp.meta == "dead_store":
                if access == "store":
                    rep.dead_stores.add(wp.context, ctx, wp.size)
                else:
                    rep.not_wasteful["dead_store"] = \
                        rep.not_wasteful.get("dead_store", 0) + 1
                self.wp["store"].disarm(wp)
            elif wp.meta == "silent_store" and access == "store":
                if self._equal(wp.value, self._value_at(val, wp.offset)):
                    rep.silent_stores.add(wp.context, ctx, wp.size)
                else:
                    rep.not_wasteful["silent_store"] = \
                        rep.not_wasteful.get("silent_store", 0) + 1
                self.wp["store"].disarm(wp)
        for wp in self.wp["load"].matching(
                lambda w: w.address == buf.addr and w.offset < buf.nelems):
            if access == "load":
                if self._equal(wp.value, self._value_at(val, wp.offset)):
                    rep.silent_loads.add(wp.context, ctx, wp.size)
                else:
                    rep.not_wasteful["silent_load"] = \
                        rep.not_wasteful.get("silent_load", 0) + 1
            self.wp["load"].disarm(wp)


def profile_fn(fn, *args, cfg: Optional[ProfilerConfig] = None,
               epochs: int = 1) -> Report:
    """Profile fn(*args) with JXPerf-JAX Tier-1."""
    return JxInterpreter(cfg).profile(fn, *args, epochs=epochs)
