"""Tier-2: static waste analysis of compiled HLO (DESIGN.md §2).

The TPU analogue of JXPerf inspecting JITted machine code: we scan the
*optimized, partitioned* HLO of a step for the paper's waste categories:

  silent collective loads  — the same source tensor all-gathered /
                             broadcast more than once without intervening
                             mutation (same operand fingerprint);
  recompute (dead work)    — duplicate op fingerprints (op, operand
                             shapes, result shape) executed more than once
                             (remat-inserted or CSE-missed);
  reshard copies           — large copy/transpose ops inserted by SPMD
                             ("involuntary full rematerialization");
  padding waste            — dots whose operand dims exceed the logical
                             shapes (implicit GSPMD padding).

Built on the trip-count-correct cost model (repro.core.hlo_cost); every
finding carries its effective multiplier and op_name provenance, i.e. the
same two-party attribution discipline as the runtime tiers. Alongside the
detailed per-op lists, the analysis emits the unified
findings.WasteProfile (tier 2), mergeable with Tier-1/Tier-3 profiles.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List

from repro.core.findings import Finding, WasteProfile
from repro.core.hlo_cost import (HloCostModel, _CALL_RE, _COLLECTIVES,
                                 _nbytes)


@dataclass
class WasteReport:
    redundant_collectives: List[Dict] = field(default_factory=list)
    recompute: List[Dict] = field(default_factory=list)
    reshard_copies: List[Dict] = field(default_factory=list)
    totals: Dict[str, float] = field(default_factory=dict)
    # the unified cross-tier view of the same findings (DESIGN.md §2)
    profile: WasteProfile = field(default_factory=lambda: WasteProfile(tier=2))

    def summary(self) -> str:
        out = ["== JXPerf-JAX Tier-2 (compiled HLO waste) =="]
        t = self.totals
        out.append(f"  redundant collective wire bytes/dev: "
                   f"{t.get('redundant_collective_bytes', 0)/1e9:.3f} GB")
        out.append(f"  duplicate-compute flops/dev:          "
                   f"{t.get('recompute_flops', 0)/1e12:.3f} TF")
        out.append(f"  reshard copy bytes/dev:               "
                   f"{t.get('reshard_bytes', 0)/1e9:.3f} GB")
        for r in self.redundant_collectives[:5]:
            out.append(f"  [coll x{r['copies']}] {r['kind']} "
                       f"{r['shape']} wire {r['wire_bytes']/1e9:.2f} GB | {r['op_name'][-60:]}")
        for r in self.recompute[:5]:
            out.append(f"  [dup x{r['copies']}] {r['fingerprint'][:60]} "
                       f"{r['flops']/1e12:.2f} TF")
        for r in self.reshard_copies[:5]:
            out.append(f"  [reshard] {r['op']} {r['shape']} "
                       f"{r['bytes']/1e9:.2f} GB | {r['op_name'][-60:]}")
        return "\n".join(out)


# ops eligible for duplicate-compute detection; `reduce` joins them only
# above _REDUCE_DUP_FLOOR operand bytes (small reductions duplicate all
# over legitimately — epilogues, norms — and cost nothing)
_DUP_OPS = ("dot", "convolution")
_REDUCE_DUP_FLOOR = 1e6

# default reshard-copy size floor (bytes after trip-count multiplier)
RESHARD_THRESHOLD = 64e6


def _op_name_of(inst) -> str:
    m = re.search(r'op_name="([^"]+)"', inst.line)
    return m.group(1) if m else ""


def _operand_provenance(inst, comp) -> str:
    """Who produced each operand: producer op + its op_name metadata.

    Two *different* matmuls with identical shapes (layer A vs layer B)
    have operands produced at different source sites, so their
    provenance strings differ; a true remat/CSE-miss duplicate re-runs
    the same source expression, so provenance matches. Shapes alone
    (the old fingerprint) conflated the two."""
    parts = []
    for o in inst.operands:
        prod = comp.producers.get(o)
        if prod is None:
            parts.append("arg")
        else:
            nm = _op_name_of(prod)
            parts.append(f"{prod.op}@{nm}" if nm else prod.op)
    return ";".join(parts)


def analyze_waste(hlo_text: str, top_k: int = 20,
                  reshard_threshold: float = RESHARD_THRESHOLD
                  ) -> WasteReport:
    cm = HloCostModel(hlo_text)
    mult = cm._multipliers()
    rep = WasteReport()

    # --- redundant collectives: same (kind, operand fingerprint) ---------
    seen: Dict[tuple, List] = defaultdict(list)
    for cname, comp in cm.comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        for inst in comp.insts:
            kind = None
            for k in _COLLECTIVES:
                if inst.op == k or inst.op == k + "-start":
                    kind = k
                    break
            if kind is None:
                continue
            # fingerprint the collected source: operand's producer op+type
            src = inst.operands[0] if inst.operands else ""
            prod = comp.producers.get(src)
            fp = (kind, comp.shapes.get(src, "").split("{")[0],
                  prod.op if prod else "arg")
            c = cm._inst_cost(inst, comp)
            meta = re.search(r'op_name="([^"]+)"', inst.line)
            seen[fp].append({
                "kind": kind, "shape": inst.result_type.split("{")[0],
                "wire_bytes": c.coll_wire_bytes * m, "mult": m,
                "op_name": meta.group(1) if meta else "",
            })
    red_total = 0.0
    for fp, items in seen.items():
        redundant = len(items) > 1 and items[0]["wire_bytes"] > 0
        rep.profile.observe("redundant_collective", redundant)
        if redundant:
            extra = sum(it["wire_bytes"] for it in items[1:])
            red_total += extra
            rep.redundant_collectives.append({
                "kind": fp[0], "shape": items[0]["shape"],
                "copies": len(items), "wire_bytes": extra,
                "op_name": items[0]["op_name"],
            })
            rep.profile.add(Finding(
                kind="redundant_collective", tier=2,
                c1=(items[0]["op_name"] or f"{fp[0]} {items[0]['shape']}",),
                count=len(items), bytes=extra,
                meta={"kind": fp[0], "shape": items[0]["shape"]}))
    rep.redundant_collectives.sort(key=lambda r: -r["wire_bytes"])
    rep.redundant_collectives = rep.redundant_collectives[:top_k]

    # --- duplicate compute (remat / missed CSE) --------------------------
    dup: Dict[str, List] = defaultdict(list)
    for cname, comp in cm.comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        for inst in comp.insts:
            if inst.op not in _DUP_OPS:
                if inst.op != "reduce":
                    continue
                opbytes = sum(_nbytes(comp.shapes.get(o, ""))
                              for o in inst.operands)
                if opbytes * m < _REDUCE_DUP_FLOOR:
                    continue
            opshapes = ",".join(comp.shapes.get(o, "?").split("{")[0]
                                for o in inst.operands)
            # shapes AND operand producer provenance: identical shapes
            # with different producers are different computations, not
            # recompute (the old shapes-only fingerprint false-flagged
            # every same-shaped layer pair)
            prov = _operand_provenance(inst, comp)
            fp = (f"{inst.op} {inst.result_type.split('{')[0]} <- "
                  f"{opshapes} [{prov}]")
            c = cm._inst_cost(inst, comp)
            dup[fp].append(c.flops * m)
    rec_total = 0.0
    for fp, fl in dup.items():
        duplicated = len(fl) > 1
        rep.profile.observe("recompute", duplicated)
        if duplicated:
            extra = sum(sorted(fl)[:-1])
            rec_total += extra
            rep.recompute.append({"fingerprint": fp, "copies": len(fl),
                                  "flops": extra})
            rep.profile.add(Finding(kind="recompute", tier=2, c1=(fp,),
                                    count=len(fl), flops=extra))
    rep.recompute.sort(key=lambda r: -r["flops"])
    rep.recompute = rep.recompute[:top_k]

    # --- reshard copies ---------------------------------------------------
    resh_total = 0.0
    for cname, comp in cm.comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        for inst in comp.insts:
            if inst.op not in ("copy", "transpose"):
                continue
            b = _nbytes(inst.result_type)
            large = b * m >= reshard_threshold
            rep.profile.observe("reshard_copy", large)
            if not large:
                continue
            resh_total += 2 * b * m
            meta = re.search(r'op_name="([^"]+)"', inst.line)
            op_name = meta.group(1) if meta else ""
            rep.reshard_copies.append({
                "op": inst.op, "shape": inst.result_type.split("{")[0],
                "bytes": 2 * b * m, "op_name": op_name})
            rep.profile.add(Finding(
                kind="reshard_copy", tier=2,
                c1=(op_name or f"{inst.op} {inst.result_type.split('{')[0]}",),
                bytes=2 * b * m, meta={"op": inst.op}))
    rep.reshard_copies.sort(key=lambda r: -r["bytes"])
    rep.reshard_copies = rep.reshard_copies[:top_k]

    rep.totals = {
        "redundant_collective_bytes": red_total,
        "recompute_flops": rec_total,
        "reshard_bytes": resh_total,
    }
    for k, v in rep.totals.items():
        rep.profile.bump_total(k, v)
    return rep
