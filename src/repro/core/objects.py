"""DJXPerf-style object registry: allocation-site provenance for every
long-lived buffer in the system (DESIGN.md § Object tier).

JXPerf bills waste to flat addresses; DJXPerf (arXiv 2104.03388) showed
the actionable unit is the *object* — the allocation a developer can
rename, resize or delete. This registry is that mapping for the JAX
port: every KV pool page, parameter tensor, optimizer-state leaf and
speculative draft window registers an :class:`ObjectRecord` carrying

- a stable human-readable name (``replica0/kv/page7``,
  ``params/main.b0_dense.attn.wq.w``),
- its kind (``kv_page`` / ``param`` / ``opt_state`` / ``draft_window``),
- byte size and the **allocation site** (file:line:function of the
  registering caller — ``PageAllocator.alloc``, ``params.init_tree``,
  ``adamw.init``), and
- an optional zero-argument ``reader`` returning the current contents
  as a numpy array, which is what lets `core/replicas.py` content-hash
  live objects without the registry ever holding device buffers.

Tiers 0-4 bill waste bytes to objects through
``WasteProfile.bill_object``; the registry itself is pure bookkeeping
(one dict insert per alloc) so it can stay on in production serving.
"""
from __future__ import annotations

import os
import sys
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

OBJECT_KINDS = ("kv_page", "param", "opt_state", "draft_window")


@dataclass
class ObjectRecord:
    """One registered long-lived buffer with allocation-site provenance."""
    oid: int
    name: str
    kind: str
    nbytes: int
    file: str
    line: int
    func: str
    meta: Dict[str, Any] = field(default_factory=dict)
    reader: Optional[Callable[[], Any]] = None

    @property
    def site(self) -> str:
        """Machine-portable allocation site (file basename, like the
        tier-0 lint contexts)."""
        return f"{os.path.basename(self.file)}:{self.line}"

    @property
    def object_key(self) -> str:
        """Stable string key the WasteProfile object table coalesces on
        (kind|name|alloc-site) — the §5.6 analogue for objects."""
        return f"{self.kind}|{self.name}|{self.site}"

    @property
    def owner(self) -> str:
        """Leading path segment of the name (fleet replica / subsystem)."""
        return self.name.split("/", 1)[0]


class ObjectRegistry:
    """Live-object table. register() captures the caller's file:line as
    the allocation site; release() retires an object (freed page,
    dropped window) so replica scans only see live buffers."""

    def __init__(self) -> None:
        self._records: Dict[int, ObjectRecord] = {}
        self._next_oid = 0

    def __len__(self) -> int:
        return len(self._records)

    def register(self, name: str, kind: str, nbytes: int, *,
                 reader: Optional[Callable[[], Any]] = None,
                 meta: Optional[Dict[str, Any]] = None,
                 depth: int = 1) -> ObjectRecord:
        """Register one object; the allocation site is the caller's
        frame (``depth`` frames up — pass 2 from a helper that registers
        on someone else's behalf)."""
        assert kind in OBJECT_KINDS, kind
        fr = sys._getframe(depth)
        rec = ObjectRecord(oid=self._next_oid, name=name, kind=kind,
                           nbytes=int(nbytes), file=fr.f_code.co_filename,
                           line=fr.f_lineno, func=fr.f_code.co_name,
                           meta=dict(meta or {}), reader=reader)
        self._next_oid += 1
        self._records[rec.oid] = rec
        return rec

    def release(self, oid: int) -> None:
        self._records.pop(oid, None)

    def get(self, oid: int) -> Optional[ObjectRecord]:
        return self._records.get(oid)

    def live(self, kind: Optional[str] = None) -> List[ObjectRecord]:
        recs = [r for r in self._records.values()
                if kind is None or r.kind == kind]
        return sorted(recs, key=lambda r: r.name)

    def nbytes_live(self, kind: Optional[str] = None) -> int:
        return sum(r.nbytes for r in self.live(kind))


def register_tree(registry: Optional[ObjectRegistry], owner: str, tree,
                  *, kind: str = "param",
                  meta: Optional[Dict[str, Any]] = None
                  ) -> List[ObjectRecord]:
    """Register every array leaf of a pytree under ``owner/<path>``.

    Used to attribute one physical tree to a logical owner — e.g. the
    fleet driver registers the (shared, in-process) parameter tree once
    per replica, which is exactly the layout a multi-host fleet would
    materialize; the replica detector then reports those copies as the
    bit-identical weight replicas they would be.
    """
    if registry is None:
        return []
    import jax
    import numpy as np
    out = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        if not hasattr(leaf, "nbytes"):
            continue
        name = f"{owner}/" + jax.tree_util.keystr(path).strip("[]'").replace(
            "']['", ".")
        out.append(registry.register(
            name, kind, int(leaf.nbytes),
            reader=(lambda a=leaf: np.asarray(a)),
            meta=meta, depth=2))
    return out
