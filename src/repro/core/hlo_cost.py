"""HLO-text cost analyzer with correct while-loop trip-count accounting.

XLA's built-in ``compiled.cost_analysis()`` counts each while-loop body
ONCE — for scan-over-layers models that undercounts flops/bytes/collectives
by the layer count (verified empirically on the CPU backend). This module
parses the optimized HLO text into its computation graph and accumulates

    flops          (dot/conv exact from shapes; elementwise ~1/elem)
    hbm_bytes      (operands + results of top-level instructions)
    collectives    (per-op wire bytes with ring-model factors)

multiplying every called computation by its call multiplier
(``known_trip_count`` for while bodies, 1 elsewhere).

It is also the substrate for the Tier-2 JXPerf waste analysis
(repro.core.hlo_waste): the same parsed representation is scanned for
redundant collectives / dead stores / remat recompute.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_ARRAY_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*?)\)\s*->")
_INST_RE = re.compile(r"^(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+)$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALL_RE = re.compile(r"(?:calls|body|to_apply)=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_SHAPE_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "compare", "select", "and", "or", "xor", "not", "power",
    "remainder", "clamp", "sign", "floor", "ceil", "round-nearest-afz",
    "round-nearest-even", "atan2",
}
_TRANSCENDENTAL = {"exponential", "log", "tanh", "rsqrt", "sqrt", "logistic",
                   "expm1", "log-plus-one", "cosine", "sine", "erf", "cbrt"}
_FREE = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
         "after-all", "partition-id", "replica-id", "iota", "opt-barrier"}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _dims(txt: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _ARRAY_RE.findall(txt):
        if dt in _DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _nbytes(txt: str) -> int:
    total = 0
    for dt, dims in _dims(txt):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _nelems(txt: str) -> int:
    total = 0
    for _, dims in _dims(txt):
        n = 1
        for d in dims:
            n *= d
        total += n
    return total


@dataclass
class Inst:
    name: str
    op: str
    result_type: str
    line: str
    operands: List[str]


# ops whose values flow through fused chains without touching HBM on TPU
# (the "fused-ideal" memory model: bytes are only paid at materialization
# points — dot/conv/fusion/collective/reduce/parameter/... — which is the
# roofline-appropriate lower bound and matches Pallas/XLA-TPU fusion).
_LIGHT = (_ELEMENTWISE | _TRANSCENDENTAL |
          {"select", "compare", "convert", "broadcast", "reshape",
           "transpose", "copy", "bitcast", "concatenate", "slice", "pad",
           "reverse", "iota", "exponential", "rng-bit-generator"})


@dataclass
class Computation:
    name: str
    insts: List[Inst] = field(default_factory=list)
    shapes: Dict[str, str] = field(default_factory=dict)   # %name -> type str
    producers: Dict[str, Inst] = field(default_factory=dict)

    _src_memo: Dict[str, Dict[str, int]] = field(default_factory=dict)


@dataclass
class Cost:
    flops: float = 0.0
    transcendentals: float = 0.0
    hbm_bytes: float = 0.0
    coll_wire_bytes: float = 0.0
    coll_by_kind: Dict[str, float] = field(default_factory=dict)
    coll_count: float = 0.0

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.transcendentals += other.transcendentals * mult
        self.hbm_bytes += other.hbm_bytes * mult
        self.coll_wire_bytes += other.coll_wire_bytes * mult
        self.coll_count += other.coll_count * mult
        for k, v in other.coll_by_kind.items():
            self.coll_by_kind[k] = self.coll_by_kind.get(k, 0.0) + v * mult


_OPNAME_RE = re.compile(r"^([a-z][a-z0-9\-]*)\(")


def parse_module(hlo: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for raw in hlo.splitlines():
        line = raw.strip()
        if not line:
            continue
        if cur is None:
            m = _COMP_HDR.match(line)
            if m and line.endswith("{"):
                cur = Computation(m.group(1))
                if raw.startswith("ENTRY") or line.startswith("ENTRY"):
                    entry = cur.name
                # parameters are declared in the header parens
                continue
            continue
        if line == "}" or line.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _INST_RE.match(line)
        if not m:
            continue
        name, rhs = m.groups()
        # result type = prefix of rhs until the op name token
        om = re.search(r"\b([a-z][a-z0-9\-]*)\(", rhs)
        if not om:
            continue
        op = om.group(1)
        result_type = rhs[:om.start()].strip()
        args = rhs[om.end():]
        depth = 1
        j = 0
        for j, ch in enumerate(args):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        operand_str = args[:j]
        operands = re.findall(r"%([\w.\-]+)", operand_str)
        inst = Inst(name, op, result_type, line, operands)
        cur.insts.append(inst)
        cur.shapes[name] = result_type
        cur.producers[name] = inst
    return comps, entry


def _dot_flops(inst: Inst, comp: Computation) -> float:
    out_elems = _nelems(inst.result_type)
    csize = 1
    m = _CONTRACT_RE.search(inst.line)
    if m and inst.operands:
        lhs_type = comp.shapes.get(inst.operands[0], "")
        d = _dims(lhs_type)
        if d:
            dims = d[0][1]
            for idx in (int(i) for i in m.group(1).split(",") if i):
                if idx < len(dims):
                    csize *= dims[idx]
    return 2.0 * out_elems * csize


def _conv_flops(inst: Inst, comp: Computation) -> float:
    out_elems = _nelems(inst.result_type)
    if not inst.operands or len(inst.operands) < 2:
        return 2.0 * out_elems
    k = _dims(comp.shapes.get(inst.operands[1], ""))
    kelems = 1
    if k:
        for d in k[0][1]:
            kelems *= d
        # per output element: kernel spatial x in-channels macs (approx:
        # kernel elems / out-features)
        od = _dims(inst.result_type)
        ofeat = od[0][1][-1] if od and od[0][1] else 1
        kelems = max(kelems // max(ofeat, 1), 1)
    return 2.0 * out_elems * kelems


def _wire(kind: str, result_bytes: int, n: int) -> float:
    if kind == "collective-permute":
        return float(result_bytes)       # full payload, any group size
    if n <= 1:
        return 0.0
    frac = (n - 1) / n
    if kind == "all-gather":
        return result_bytes * frac
    if kind == "all-reduce":
        return 2.0 * result_bytes * frac
    if kind == "reduce-scatter":
        return result_bytes * (n - 1)
    if kind == "all-to-all":
        return result_bytes * frac
    return float(result_bytes)       # collective-permute


def _participants(line: str, default: int) -> int:
    m = _GROUPS_SHAPE_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip()])
    return default


class HloCostModel:
    def __init__(self, hlo_text: str, default_participants: int = 1,
                 scope_zero_hbm: Tuple[str, ...] = ()):
        """scope_zero_hbm: named_scope substrings whose instructions are
        known to run inside a Pallas kernel on the TPU target — their HBM
        traffic is zeroed here and replaced analytically by the caller
        (see launch.roofline.ideal_attention_bytes)."""
        self.comps, self.entry = parse_module(hlo_text)
        self.default_participants = default_participants
        self.scope_zero_hbm = tuple(scope_zero_hbm)
        self._memo: Dict[str, Cost] = {}
        self._light_memo: Dict[str, bool] = {}

    def cost_of(self, comp_name: str) -> Cost:
        if comp_name in self._memo:
            return self._memo[comp_name]
        self._memo[comp_name] = Cost()      # cycle guard
        comp = self.comps.get(comp_name)
        if comp is None:
            return self._memo[comp_name]
        total = Cost()
        for inst in comp.insts:
            total.add(self._inst_cost(inst, comp))
        self._memo[comp_name] = total
        return total

    def _is_light_fusion(self, comp_name: str) -> bool:
        """A fusion whose body is entirely elementwise/data-movement melts
        into its neighbours on TPU (kLoop chains) — treat as fuse-through."""
        if comp_name in self._light_memo:
            return self._light_memo[comp_name]
        comp = self.comps.get(comp_name)
        ok = comp is not None
        if ok:
            for inst in comp.insts:
                if inst.op in _LIGHT or inst.op in _FREE or inst.op == "reduce":
                    continue
                if inst.op in ("fusion", "call"):
                    cal = _CALL_RE.search(inst.line)
                    if cal and self._is_light_fusion(cal.group(1)):
                        continue
                ok = False
                break
        self._light_memo[comp_name] = ok
        return ok

    def _is_slice_fusion(self, comp_name: str) -> bool:
        """Fusion of slices/converts only: window-sized traffic."""
        comp = self.comps.get(comp_name)
        if comp is None:
            return False
        has_slice = False
        for inst in comp.insts:
            if inst.op in ("dynamic-slice",):
                has_slice = True
                continue
            if inst.op in _LIGHT or inst.op in _FREE or inst.op == "reduce":
                continue
            return False
        return has_slice

    def _is_light_inst(self, inst: Inst) -> bool:
        if inst.op in _LIGHT:
            return True
        if inst.op in ("fusion", "call"):
            cal = _CALL_RE.search(inst.line)
            if cal:
                return self._is_light_fusion(cal.group(1))
        return False

    def _sources(self, comp: Computation, name: str,
                 _depth: int = 0) -> Dict[str, int]:
        """Materialized HBM sources feeding symbol `name` (fused-ideal)."""
        if name in comp._src_memo:
            return comp._src_memo[name]
        prod = comp.producers.get(name)
        if prod is None or _depth > 24:
            out = {name: _nbytes(comp.shapes.get(name, ""))}
        elif self._is_light_inst(prod):
            out = {}
            for o in prod.operands:
                out.update(self._sources(comp, o, _depth + 1))
        else:
            out = {name: _nbytes(comp.shapes.get(name, ""))}
        comp._src_memo[name] = out
        return out

    def _read_bytes(self, inst: Inst, comp: Computation) -> int:
        seen: Dict[str, int] = {}
        for o in inst.operands:
            seen.update(self._sources(comp, o))
        return sum(seen.values())

    def _inst_cost(self, inst: Inst, comp: Computation) -> Cost:
        c = self._inst_cost_raw(inst, comp)
        if c.hbm_bytes and self.scope_zero_hbm and \
                any(s in inst.line for s in self.scope_zero_hbm):
            c.hbm_bytes = 0.0
        return c

    def _inst_cost_raw(self, inst: Inst, comp: Computation) -> Cost:
        c = Cost()
        op = inst.op
        rb = _nbytes(inst.result_type)
        if op in _FREE:
            return c
        if op == "while":
            trip = 1
            m = _TRIP_RE.search(inst.line)
            if m:
                trip = int(m.group(1))
            body = _CALL_RE.search(inst.line)
            if body:
                c.add(self.cost_of(body.group(1)), trip)
            cond = _COND_RE.search(inst.line)
            if cond:
                c.add(self.cost_of(cond.group(1)), trip)
            return c
        if op in ("dynamic-slice", "gather"):
            # read + write only the sliced window (result)
            c.hbm_bytes += 2 * rb
            return c
        if op == "dynamic-update-slice":
            # in-place aliasing update: read+write the update operand only
            ub = (_nbytes(comp.shapes.get(inst.operands[1], ""))
                  if len(inst.operands) > 1 else rb)
            c.hbm_bytes += 2 * ub
            return c
        if op in ("call", "fusion", "map", "reduce", "reduce-window",
                  "scatter", "select-and-scatter", "sort", "conditional",
                  "async-start", "custom-call"):
            # fusion containing a dynamic-update-slice (plus only light ops)
            # aliases in place: pay only the update window
            callee0 = _CALL_RE.search(inst.line)
            if op == "fusion" and callee0:
                cal = self.comps.get(callee0.group(1))
                dus = None
                windowed = cal is not None
                if cal:
                    for ci in cal.insts:
                        if ci.op == "dynamic-update-slice":
                            dus = ci
                        elif ci.op not in _LIGHT and ci.op not in _FREE \
                                and ci.op != "dynamic-slice":
                            windowed = False
                            break
                if windowed and dus is not None:
                    ub = (_nbytes(cal.shapes.get(dus.operands[1], ""))
                          if len(dus.operands) > 1 else rb)
                    c.hbm_bytes += 2 * ub
                    c.add(self._fused_flops(callee0.group(1), inst))
                    return c
            if op == "fusion" and callee0 and \
                    self._is_slice_fusion(callee0.group(1)):
                # slice/convert pipelines read+write the window only
                c.hbm_bytes += 2 * rb
                c.add(self._fused_flops(callee0.group(1), inst))
                return c
            if not self._is_light_inst(inst):
                c.hbm_bytes += rb + self._read_bytes(inst, comp)
            callee = _CALL_RE.search(inst.line)
            if callee and callee.group(1) in self.comps:
                c.add(self._fused_flops(callee.group(1), inst))
            if op in ("reduce", "sort", "scatter"):
                c.flops += _nelems(inst.result_type)
            return c
        for kind in _COLLECTIVES:
            if op == kind or op == kind + "-start":
                n = _participants(inst.line, self.default_participants)
                wire = _wire(kind, rb, n)
                c.coll_wire_bytes += wire
                c.coll_by_kind[kind] = c.coll_by_kind.get(kind, 0.0) + wire
                c.coll_count += 1
                c.hbm_bytes += rb
                return c
        if op.endswith("-done") or op.endswith("-update"):
            return c
        if op == "dot":
            c.flops += _dot_flops(inst, comp)
            c.hbm_bytes += rb + self._read_bytes(inst, comp)
            return c
        if op == "convolution":
            c.flops += _conv_flops(inst, comp)
            c.hbm_bytes += rb + self._read_bytes(inst, comp)
            return c
        # top-level elementwise / data movement: fused-ideal — VPU flops
        # count, HBM traffic is attributed to materialization points only.
        if op in _ELEMENTWISE:
            c.flops += _nelems(inst.result_type)
        elif op in _TRANSCENDENTAL:
            c.transcendentals += _nelems(inst.result_type)
            c.flops += _nelems(inst.result_type)
        elif op not in _LIGHT:
            # unknown non-light op: be conservative about memory
            c.hbm_bytes += rb + self._read_bytes(inst, comp)
        return c

    def _fused_flops(self, comp_name: str, call_inst: Inst) -> Cost:
        """flops inside a fused computation (no HBM bytes for internals)."""
        c = Cost()
        comp = self.comps.get(comp_name)
        if comp is None:
            return c
        for inst in comp.insts:
            if inst.op == "dot":
                c.flops += _dot_flops(inst, comp)
            elif inst.op == "convolution":
                c.flops += _conv_flops(inst, comp)
            elif inst.op in _ELEMENTWISE:
                c.flops += _nelems(inst.result_type)
            elif inst.op in _TRANSCENDENTAL:
                n = _nelems(inst.result_type)
                c.flops += n
                c.transcendentals += n
            elif inst.op in ("fusion", "call", "reduce", "map"):
                callee = _CALL_RE.search(inst.line)
                if callee and callee.group(1) != comp_name:
                    c.add(self._fused_flops(callee.group(1), inst))
                if inst.op == "reduce":
                    c.flops += _nelems(inst.result_type)
        return c

    def total(self) -> Cost:
        if self.entry is None:
            return Cost()
        return self.cost_of(self.entry)

    # ------------------------------------------------------------------
    # Attribution: per-instruction costs scaled by effective multiplier
    # (product of trip counts on the call path) — the provenance view the
    # Tier-2 waste analysis consumes.
    # ------------------------------------------------------------------
    def _multipliers(self) -> Dict[str, float]:
        mult: Dict[str, float] = {}
        if self.entry is None:
            return mult
        mult[self.entry] = 1.0
        order = [self.entry]
        seen = {self.entry}
        while order:
            cname = order.pop(0)
            comp = self.comps.get(cname)
            if comp is None:
                continue
            m = mult[cname]
            for inst in comp.insts:
                trip = 1
                if inst.op == "while":
                    t = _TRIP_RE.search(inst.line)
                    trip = int(t.group(1)) if t else 1
                for cm in _CALL_RE.finditer(inst.line):
                    callee = cm.group(1)
                    if callee in self.comps:
                        mult[callee] = mult.get(callee, 0.0) + m * trip
                        if callee not in seen:
                            seen.add(callee)
                            order.append(callee)
                cond = _COND_RE.search(inst.line)
                if cond and cond.group(1) in self.comps:
                    mult[cond.group(1)] = mult.get(cond.group(1), 0.0) + m * trip
        return mult

    def attribute(self):
        """Yield per-instruction cost records with effective multipliers."""
        mult = self._multipliers()
        for cname, comp in self.comps.items():
            m = mult.get(cname, 0.0)
            if m == 0.0:
                continue
            for inst in comp.insts:
                if inst.op in _FREE or inst.op == "while":
                    continue
                c = self._inst_cost(inst, comp)
                if c.flops == 0 and c.hbm_bytes == 0 and c.coll_wire_bytes == 0:
                    continue
                meta = re.search(r'op_name="([^"]+)"', inst.line)
                yield {
                    "computation": cname, "name": inst.name, "op": inst.op,
                    "mult": m, "flops": c.flops * m,
                    "hbm_bytes": c.hbm_bytes * m,
                    "wire_bytes": c.coll_wire_bytes * m,
                    "result_type": inst.result_type.split("{")[0].strip(),
                    "op_name": meta.group(1) if meta else "",
                }

    def top(self, key: str = "flops", k: int = 15):
        recs = list(self.attribute())
        recs.sort(key=lambda r: -r[key])
        return recs[:k]


def analyze(hlo_text: str) -> Cost:
    return HloCostModel(hlo_text).total()
