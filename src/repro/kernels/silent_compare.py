"""Silent-byte comparison kernel (Pallas).

The hot-spot of JXPerf-JAX's Tier-3 detectors: given the before/after value
of a watched buffer (e.g. a parameter before/after an optimizer step), count
how many elements are "silent" — unchanged within the paper's FP tolerance
(Defs. 2-3; tol=0 gives exact equality for integer semantics).

TPU adaptation: the comparison is a pure VPU (8x128 vector) workload; the
kernel tiles both operands into VMEM as (rows, 128) blocks and emits one
partial count per grid step, reduced on-device afterwards. This keeps the
detector's HBM traffic at exactly 2 reads / element, which is the roofline
minimum for this measurement — the software analogue of the paper's "7%
overhead" requirement.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
SUB = 8
BLOCK_ROWS = 256          # (256, 128) f32 tile = 128 KiB/operand in VMEM


def _silent_kernel(a_ref, b_ref, o_ref, *, tol: float):
    from repro.core.events import silent_mask
    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    # the substrate's single silent-match definition (symmetric rel tol,
    # NaN padding never silent) — pure VPU elementwise ops
    eq = silent_mask(a, b, tol)
    o_ref[0, 0] = jnp.sum(eq.astype(jnp.int32))


def silent_compare(a: jax.Array, b: jax.Array, tol: float = 0.01, *,
                   interpret: bool = False) -> jax.Array:
    """Count silent elements (|a-b| <= tol*max(|a|,|b|)). Returns int32."""
    assert a.shape == b.shape, (a.shape, b.shape)
    af = a.reshape(-1)
    bf = b.reshape(-1)
    n = af.shape[0]
    block = BLOCK_ROWS * LANE
    n_pad = pl.cdiv(max(n, 1), block) * block
    if n_pad != n:
        pad = jnp.full((n_pad - n,), jnp.nan, jnp.float32)
        af = jnp.concatenate([af.astype(jnp.float32), pad])
        bf = jnp.concatenate([bf.astype(jnp.float32), pad])
    else:
        af = af.astype(jnp.float32)
        bf = bf.astype(jnp.float32)
    rows = n_pad // LANE
    a2 = af.reshape(rows, LANE)
    b2 = bf.reshape(rows, LANE)
    grid = (rows // BLOCK_ROWS,)

    partial = pl.pallas_call(
        functools.partial(_silent_kernel, tol=tol),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, LANE), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_ROWS, LANE), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((grid[0], 1), jnp.int32),
        interpret=interpret,
    )(a2, b2)
    return jnp.sum(partial)
