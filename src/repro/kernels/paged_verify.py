"""Fused width-(k+1) speculative-verify kernel (Pallas TPU).

A thin mode wrapper over the paged window kernel
(``kernels/flash_prefill.py``): verify pushes the k+1-token draft
window against the paged pool exactly like prefill pushes a prompt
chunk — same in-kernel page-table gather, same store epilogue — the
only degree of freedom is what happens to the pool:

  * ``mode="overwrite"`` (``LM.verify(commit=True)``): all k+1 window
    rows are stored through the page table. Rows past the accepted
    prefix are *rejected draft stores* — the kernel's store-site
    counters measure every stored element, and the engine's kernel-tier
    classification (which knows the acceptance length) attributes the
    rejected fraction: 1 − accept-rate, measured from inside the kernel.
  * ``mode="defer"`` (rollback): the pool is untouched; the kernel only
    computes the spliced-window attention and the counters stay zero.
    The accepted prefix is committed afterwards by ``LM.commit_verify``
    (a counted ``paged_update``), so the kernel-tier
    ``rejected_draft_store`` fraction is exactly 0 — rejected rows
    never become machine-level stores at all.
"""
from __future__ import annotations

import jax

from repro.kernels.flash_prefill import paged_window_attention


def paged_verify_attention(q: jax.Array, k_win: jax.Array, v_win: jax.Array,
                           pool_k: jax.Array, pool_v: jax.Array,
                           pt: jax.Array, idx: jax.Array, *,
                           mode: str = "overwrite",
                           block_q: int = 128,
                           tol: float = 0.0,
                           interpret: bool = False):
    """q/k_win/v_win: (B, k+1, H*, D) at per-slot offsets ``idx``.

    Returns ``(out, lse, counters, new_pool_k, new_pool_v)`` — see
    ``paged_window_attention``; the pools come back unchanged in
    ``defer`` mode."""
    assert mode in ("overwrite", "defer"), mode
    return paged_window_attention(
        q, k_win, v_win, pool_k, pool_v, pt, idx,
        store=(mode == "overwrite"), block_q=block_q, tol=tol,
        interpret=interpret)
