"""Paged-attention decode kernel (Pallas TPU).

One new token per slot attends over its whole paged KV history. The
kernel gathers K/V pages from the pool *inside* the kernel: the page
table and per-slot positions are scalar-prefetched, and each kv grid
step's BlockSpec index map chases ``pt[b, m]`` directly, so the
(B, M*page) logical view the ref path materializes in HBM
(``ref.paged_gather``) never exists. The new token's K/V row is spliced
into its page block in VMEM (the pool scatter itself stays a cheap
O(B*Hkv*D) host-side ``ref.paged_update`` — one row per slot).

Waste counters (the machine-code tier of the detector stack, see
DESIGN.md § Kernel tier): at the splice step — the store site of the
new K/V row — the kernel compares the incoming row against the pool
content it overwrites with ``core.events.silent_mask`` semantics and
emits per-slot element counts [stored, silent, dropped]:

  * stored  — elements whose page-table-mapped store will land;
  * silent  — stored elements equal (within tol) to the old value
              (paper Def. 2 silent stores, counted at the store site);
  * dropped — elements whose target page is unmapped (the store is
              masked off: dead lanes).

Grid iteration order is (B, Hq, M) with the page dim innermost; flash
accumulators live in VMEM scratch across the page sweep. All grid dims
are "arbitrary" (scratch carries state), so revisiting semantics match
interpret mode.

Validated in interpret mode on CPU against the ref composition
``paged_update -> paged_gather -> attention_ref``.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.events import silent_mask
from repro.kernels.flash_attention import online_softmax_step

NEG_INF = -1e30


def _decode_kernel(pt_ref, idx_ref, q_ref, kn_ref, vn_ref, k_ref, v_ref,
                   o_ref, lse_ref, cnt_ref,
                   m_scr, l_scr, acc_scr, cnt_scr, *,
                   scale: float, ps: int, G: int, tol: float):
    b = pl.program_id(0)
    h = pl.program_id(1)
    m = pl.program_id(2)
    nm = pl.num_programs(2)
    idx = idx_ref[b]
    page = pt_ref[b, m]

    @pl.when((h == 0) & (m == 0))
    def _zero_cnt():
        cnt_scr[...] = jnp.zeros_like(cnt_scr)

    @pl.when(m == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    offs = jax.lax.broadcasted_iota(jnp.int32, (ps, 1), 0)
    pos = m * ps + offs                                   # (ps, 1) logical

    live = (idx >= 0) & (page >= 0) & (m * ps <= idx)

    @pl.when(live)
    def _attend():
        q = q_ref[0].astype(jnp.float32)                  # (1, D)
        k = k_ref[0, :, 0].astype(jnp.float32)            # (ps, D)
        v = v_ref[0, :, 0].astype(jnp.float32)
        is_new = pos == idx                               # (ps, 1)
        k = jnp.where(is_new, kn_ref[0].astype(jnp.float32), k)
        v = jnp.where(is_new, vn_ref[0].astype(jnp.float32), v)

        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * scale                                     # (1, ps)
        s = jnp.where(pos.T <= idx, s, NEG_INF)
        online_softmax_step(s, v, m_scr, l_scr, acc_scr)

    # --- store-site counters: the new row lands in page idx // ps ------
    D = q_ref.shape[-1]

    @pl.when((h % G == 0) & (idx >= 0) & (m == idx // ps))
    def _count():
        pdt = k_ref.dtype
        old_k = k_ref[0, :, 0].astype(jnp.float32)        # pre-store content
        old_v = v_ref[0, :, 0].astype(jnp.float32)
        new_k = kn_ref[0].astype(pdt).astype(jnp.float32)
        new_v = vn_ref[0].astype(pdt).astype(jnp.float32)
        row = pos == idx                                  # (ps, 1)
        sil = (jnp.sum(jnp.where(row, silent_mask(old_k, new_k, tol), False),
                       dtype=jnp.int32)
               + jnp.sum(jnp.where(row, silent_mask(old_v, new_v, tol), False),
                         dtype=jnp.int32))
        ok = page >= 0
        cnt_scr[0, 0] += jnp.where(ok, 2 * D, 0)
        cnt_scr[0, 1] += jnp.where(ok, sil, 0)
        cnt_scr[0, 2] += jnp.where(ok, 0, 2 * D)

    cnt_ref[...] = cnt_scr[...]

    @pl.when(m == nm - 1)
    def _fin():
        l = l_scr[...]
        lse_ref[...] = jnp.where(l > 0.0, m_scr[...] + jnp.log(
            jnp.where(l > 0.0, l, 1.0)), NEG_INF)
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


def paged_decode_attention(q: jax.Array, k_new: jax.Array, v_new: jax.Array,
                           pool_k: jax.Array, pool_v: jax.Array,
                           pt: jax.Array, idx: jax.Array, *,
                           tol: float = 0.0,
                           interpret: bool = False):
    """q/k_new/v_new: (B, 1, H*, D); pool: (P, page, Hkv, D); pt: (B, M);
    idx: (B,) per-slot positions (negative = idle slot, attends nothing).

    Returns ``(out, lse, counters)``: out (B, 1, Hq, D); lse (B, Hq)
    per-(slot, head) log-sum-exp for sharded flash combines (NEG_INF
    where nothing was attended); counters (B, 3) int32 — see module doc.

    NOTE: the kernel does not write the pool. Callers scatter the single
    new row with ``ref.paged_update`` (the counters still describe that
    store: they are measured here against pre-store pool content).
    """
    B, S, Hq, D = q.shape
    assert S == 1, "decode kernel is single-token"
    P, ps, Hkv, _ = pool_k.shape
    M = pt.shape[1]
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(D)

    pt = pt.astype(jnp.int32)
    idx = idx.astype(jnp.int32)
    q2 = q.reshape(B, Hq, D)
    # round-trip the new row through the pool dtype: the ref path attends
    # the value the pool actually stores, so the splice must match it bit
    # for bit (e.g. bf16 pools under f32 activations)
    pdt = pool_k.dtype
    kn = k_new.reshape(B, Hkv, D).astype(pdt)
    vn = v_new.reshape(B, Hkv, D).astype(pdt)

    def q_index(b, h, m, pt_ref, idx_ref):
        return (b, h, 0)

    def new_index(b, h, m, pt_ref, idx_ref):
        return (b, h // G, 0)

    def pool_index(b, h, m, pt_ref, idx_ref):
        return (jnp.clip(pt_ref[b, m], 0, P - 1), 0, h // G, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hq, M),
        in_specs=[
            pl.BlockSpec((1, 1, D), q_index),
            pl.BlockSpec((1, 1, D), new_index),
            pl.BlockSpec((1, 1, D), new_index),
            pl.BlockSpec((1, ps, 1, D), pool_index),
            pl.BlockSpec((1, ps, 1, D), pool_index),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, D), q_index),
            pl.BlockSpec((1, 1), lambda b, h, m, *_: (b, h)),
            pl.BlockSpec((1, 3), lambda b, h, m, *_: (b, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),      # running max
            pltpu.VMEM((1, 1), jnp.float32),      # running denom
            pltpu.VMEM((1, D), jnp.float32),      # accumulator
            pltpu.VMEM((1, 3), jnp.int32),        # waste counters
        ],
    )
    out, lse, cnt = pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale, ps=ps, G=G, tol=tol),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, Hq, D), q.dtype),
            jax.ShapeDtypeStruct((B, Hq), jnp.float32),
            jax.ShapeDtypeStruct((B, 3), jnp.int32),
        ],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(pt, idx, q2, kn, vn, pool_k, pool_v)
    return out.reshape(B, 1, Hq, D), lse, cnt
