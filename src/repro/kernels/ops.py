"""jit'd wrappers + backend dispatch for the Pallas kernels.

On TPU the Pallas kernels are used (``REPRO_USE_PALLAS=1`` or automatic);
elsewhere the pure-jnp oracles from ``ref.py`` run — they are the same math
and XLA/GSPMD handles fusion + partitioning. Tests exercise the kernels in
interpret mode against the oracles across shape/dtype sweeps.
"""
from __future__ import annotations

import os
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels import flash_attention as _fa
from repro.kernels import silent_compare as _sc
from repro.kernels import rmsnorm as _rn


def _use_pallas() -> bool:
    env = os.environ.get("REPRO_USE_PALLAS")
    if env is not None:
        return env not in ("0", "false", "")
    return jax.default_backend() == "tpu"


def _pallas_interpret() -> bool:
    return jax.default_backend() != "tpu"


# ----------------------------------------------------------------------
# KV length above which the O(S^2)-memory reference path is replaced by the
# flash (chunked online-softmax, custom-vjp) path.
FLASH_THRESHOLD = 1024


def attention(q, k, v, *, causal: bool = True, q_offset=0,
              kv_len: Optional[jax.Array] = None,
              kv_valid: Optional[jax.Array] = None) -> jax.Array:
    """Model-facing attention entry point (GQA)."""
    sq, skv = q.shape[1], k.shape[1]
    if (kv_len is None and kv_valid is None
            and isinstance(q_offset, int) and q_offset == 0):
        if _use_pallas() and sq >= 8:
            return _fa.flash_attention(q, k, v, causal=causal,
                                       interpret=_pallas_interpret())
        if skv >= FLASH_THRESHOLD:
            from repro.kernels.flash_xla import flash_xla
            return flash_xla(q, k, v, causal, 0)
    return _ref.attention_ref(q, k, v, causal=causal, q_offset=q_offset,
                              kv_len=kv_len, kv_valid=kv_valid)


# paged-KV scatter/gather: pure-jnp (XLA scatter/gather fuse well and
# GSPMD partitions them); re-exported here so model code dispatches
# through one kernel namespace
paged_update = _ref.paged_update
paged_gather = _ref.paged_gather
paged_store_counts = _ref.paged_store_counts

# store-site waste-counter tolerance (kernel tier): exact equality, the
# paper's Def.-2 silent-store semantics for same-dtype overwrites
COUNTER_TOL = 0.0


def paged_decode(q, k_new, v_new, pool_k, pool_v, pt, idx, *,
                 counters: bool = False):
    """One-token paged-attention decode: attend slot history + the new
    K/V row, scatter the row through the page table.

    Returns ``(out, ck, cv, cnt)`` — cnt is the (B, 3) int32 store-site
    waste counter block ([stored, silent, dropped] elements, see
    ``kernels/paged_attention.py``) or None when ``counters=False``.

    Pallas path: the kernel gathers K/V pages in-kernel via the
    scalar-prefetched page table (no logical-view materialization) and
    measures the counters at the store site; only the O(B*Hkv*D)
    single-row scatter runs outside. Ref path: the scatter-gather-mask
    composition from ``ref.py``.
    """
    if _use_pallas():
        from repro.kernels.paged_attention import paged_decode_attention
        out, _, cnt = paged_decode_attention(
            q, k_new, v_new, pool_k, pool_v, pt, idx,
            tol=COUNTER_TOL, interpret=_pallas_interpret())
        ck, cv = _ref.paged_update(pool_k, pool_v, k_new, v_new, pt, idx)
        return out, ck, cv, (cnt if counters else None)
    cnt = None
    if counters:
        cnt = _ref.paged_store_counts(pool_k, pool_v, k_new, v_new, pt, idx,
                                      tol=COUNTER_TOL)
    dt = q.dtype
    ck, cv = _ref.paged_update(pool_k, pool_v, k_new, v_new, pt, idx)
    gk, valid = _ref.paged_gather(ck, pt)
    gv, _ = _ref.paged_gather(cv, pt)
    out = _ref.attention_ref(q, gk.astype(dt), gv.astype(dt), causal=True,
                             q_offset=idx, kv_len=idx + 1, kv_valid=valid)
    return out, ck, cv, cnt


def paged_window(q, k_win, v_win, pool_k, pool_v, pt, idx, *,
                 store: bool = True, counters: bool = False):
    """S-token paged window forward (prefill chunk / width-k verify):
    attend committed history + the in-window causal part, and — store
    mode — write the window rows into the pool through the page table.

    Returns ``(out, ck, cv, cnt)`` like ``paged_decode``; with
    ``store=False`` ("defer"/rollback verify) the pool is untouched and
    cnt is all-zero (no machine-level stores happen).
    """
    if _use_pallas():
        from repro.kernels.flash_prefill import paged_window_attention
        out, _, cnt, ck, cv = paged_window_attention(
            q, k_win, v_win, pool_k, pool_v, pt, idx, store=store,
            tol=COUNTER_TOL, interpret=_pallas_interpret())
        return out, ck, cv, (cnt if counters else None)
    out, ck, cv, cnt = _ref.paged_window_ref(
        q, k_win, v_win, pool_k, pool_v, pt, idx, store=store,
        tol=COUNTER_TOL)
    return out, ck, cv, (cnt if counters else None)


def flash_attention(q, k, v, *, causal: bool = True, interpret=None,
                    block_q: int = 128, block_k: int = 128) -> jax.Array:
    if interpret is None:
        interpret = _pallas_interpret()
    return _fa.flash_attention(q, k, v, causal=causal, interpret=interpret,
                               block_q=block_q, block_k=block_k)


@partial(jax.jit, static_argnames=("tol", "use_pallas"))
def silent_fraction(a, b, tol: float = 0.01, use_pallas: bool = False):
    """Fraction of silent (unchanged within tol) elements between a and b."""
    n = a.size
    if use_pallas:
        cnt = _sc.silent_compare(a, b, tol, interpret=_pallas_interpret())
    else:
        cnt = _ref.silent_compare_ref(a, b, tol)
    return cnt.astype(jnp.float32) / max(n, 1)


def silent_count(a, b, tol: float = 0.01, use_pallas: Optional[bool] = None):
    if use_pallas is None:
        use_pallas = _use_pallas()
    if use_pallas:
        return _sc.silent_compare(a, b, tol, interpret=_pallas_interpret())
    return _ref.silent_compare_ref(a, b, tol)


def rmsnorm(x, scale, eps: float = 1e-5):
    if _use_pallas():
        return _rn.rmsnorm(x, scale, eps, interpret=_pallas_interpret())
    return _ref.rmsnorm_ref(x, scale, eps)
