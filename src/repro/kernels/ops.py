"""jit'd wrappers + backend dispatch for the Pallas kernels.

On TPU the Pallas kernels are used (``REPRO_USE_PALLAS=1`` or automatic);
elsewhere the pure-jnp oracles from ``ref.py`` run — they are the same math
and XLA/GSPMD handles fusion + partitioning. Tests exercise the kernels in
interpret mode against the oracles across shape/dtype sweeps.
"""
from __future__ import annotations

import os
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels import flash_attention as _fa
from repro.kernels import silent_compare as _sc
from repro.kernels import rmsnorm as _rn


def _use_pallas() -> bool:
    env = os.environ.get("REPRO_USE_PALLAS")
    if env is not None:
        return env not in ("0", "false", "")
    return jax.default_backend() == "tpu"


def _pallas_interpret() -> bool:
    return jax.default_backend() != "tpu"


# ----------------------------------------------------------------------
# KV length above which the O(S^2)-memory reference path is replaced by the
# flash (chunked online-softmax, custom-vjp) path.
FLASH_THRESHOLD = 1024


def attention(q, k, v, *, causal: bool = True, q_offset=0,
              kv_len: Optional[jax.Array] = None,
              kv_valid: Optional[jax.Array] = None) -> jax.Array:
    """Model-facing attention entry point (GQA)."""
    sq, skv = q.shape[1], k.shape[1]
    if (kv_len is None and kv_valid is None
            and isinstance(q_offset, int) and q_offset == 0):
        if _use_pallas() and sq >= 8:
            return _fa.flash_attention(q, k, v, causal=causal,
                                       interpret=_pallas_interpret())
        if skv >= FLASH_THRESHOLD:
            from repro.kernels.flash_xla import flash_xla
            return flash_xla(q, k, v, causal, 0)
    return _ref.attention_ref(q, k, v, causal=causal, q_offset=q_offset,
                              kv_len=kv_len, kv_valid=kv_valid)


# paged-KV scatter/gather: pure-jnp (XLA scatter/gather fuse well and
# GSPMD partitions them); re-exported here so model code dispatches
# through one kernel namespace
paged_update = _ref.paged_update
paged_gather = _ref.paged_gather


def flash_attention(q, k, v, *, causal: bool = True, interpret=None,
                    block_q: int = 128, block_k: int = 128) -> jax.Array:
    if interpret is None:
        interpret = _pallas_interpret()
    return _fa.flash_attention(q, k, v, causal=causal, interpret=interpret,
                               block_q=block_q, block_k=block_k)


@partial(jax.jit, static_argnames=("tol", "use_pallas"))
def silent_fraction(a, b, tol: float = 0.01, use_pallas: bool = False):
    """Fraction of silent (unchanged within tol) elements between a and b."""
    n = a.size
    if use_pallas:
        cnt = _sc.silent_compare(a, b, tol, interpret=_pallas_interpret())
    else:
        cnt = _ref.silent_compare_ref(a, b, tol)
    return cnt.astype(jnp.float32) / max(n, 1)


def silent_count(a, b, tol: float = 0.01, use_pallas: Optional[bool] = None):
    if use_pallas is None:
        use_pallas = _use_pallas()
    if use_pallas:
        return _sc.silent_compare(a, b, tol, interpret=_pallas_interpret())
    return _ref.silent_compare_ref(a, b, tol)


def rmsnorm(x, scale, eps: float = 1e-5):
    if _use_pallas():
        return _rn.rmsnorm(x, scale, eps, interpret=_pallas_interpret())
    return _ref.rmsnorm_ref(x, scale, eps)
