"""Pure-jnp oracles for every Pallas kernel.

These are the correctness references (kernel tests assert allclose against
them) AND the default compute path on non-TPU backends — XLA fuses them
well and GSPMD partitions them automatically.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


# ----------------------------------------------------------------------
# Attention (GQA, causal, optional decode length-mask)
# ----------------------------------------------------------------------
def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, q_offset=0,
                  kv_len: Optional[jax.Array] = None,
                  kv_valid: Optional[jax.Array] = None) -> jax.Array:
    """q: (B,Sq,Hq,D), k/v: (B,Skv,Hkv,D) -> (B,Sq,Hq,D). f32 accumulate.

    ``q_offset``/``kv_len`` may be scalars (one decode position for the
    whole batch) or (B,) vectors (per-slot positions — the serving
    engine's continuous-batching cache, where every row sits at its own
    sequence offset). ``kv_valid`` is an optional (B,Skv) gather-validity
    mask: positions of a paged cache's logical view whose page table
    entry is unmapped (see ``paged_gather``) are masked out like
    positions past ``kv_len``.
    """
    B, Sq, Hq, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, G, D)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                        preferred_element_type=jnp.float32)
    scores = scores * (1.0 / jnp.sqrt(D).astype(jnp.float32))

    mask = None
    if causal:
        if isinstance(q_offset, int) and q_offset == 0:
            # training/prefill-from-scratch call: adding the static 0
            # offset would emit a full-(Sq,) identity add against literal
            # 0 (tier-0 silent_store, ref.py) — same (Sq,) qpos either way
            qpos = jnp.arange(Sq)
        else:
            qpos = jnp.asarray(q_offset)[..., None] + jnp.arange(Sq)
        mask = qpos[..., :, None] >= jnp.arange(Skv)   # (Sq,Skv) | (B,Sq,Skv)
    if kv_len is not None:
        lmask = jnp.arange(Skv) < jnp.asarray(kv_len)[..., None]
        lmask = lmask[..., None, :]              # (1,Skv) | (B,1,Skv)
        mask = lmask if mask is None else (mask & lmask)
    if kv_valid is not None:
        vmask = kv_valid[:, None, :]             # (B,1,Skv)
        mask = vmask if mask is None else (mask & vmask)
    if mask is not None:
        bmask = mask[:, None, None] if mask.ndim == 3 else mask[None, None, None]
        scores = jnp.where(bmask, scores, -jnp.inf)

    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v)
    return out.reshape(B, Sq, Hq, D)


# ----------------------------------------------------------------------
# Paged KV cache: page-table scatter (store) and gather (load) between
# the logical per-slot view and the flat page pool (serve/kv_cache.py).
# ----------------------------------------------------------------------
def paged_update(pool_k: jax.Array, pool_v: jax.Array, k_new: jax.Array,
                 v_new: jax.Array, pt: jax.Array,
                 idx: jax.Array,
                 length: Optional[jax.Array] = None) -> tuple:
    """Scatter new K/V rows into a paged pool through the page table.

    pool: (P, page, Hkv, D); k_new/v_new: (B, S, Hkv, D); pt: (B, M)
    page table (-1 = unmapped); idx: (B,) per-slot write positions. Row
    (b, s) lands at logical position idx[b]+s -> page pt[b, pos//page].
    Stores whose position is negative (engine idle-slot sentinel) or
    whose page is unmapped are DROPPED — idle/finished slots write
    nothing past their page-table extent, which is exactly the dead/
    silent-store waste of the dense layout eliminated.

    ``length`` (optional, (B,)): per-slot row budget — rows s >=
    length[b] are dropped too. Speculative rollback commits exactly the
    accepted prefix of a verify window this way (LM.commit_verify), so
    rejected draft rows never reach the pool at all.
    """
    P, ps = pool_k.shape[0], pool_k.shape[1]
    B, S = k_new.shape[0], k_new.shape[1]
    M = pt.shape[1]
    pos = idx[:, None] + jnp.arange(S)[None, :]            # (B,S) logical
    if length is not None:
        pos = jnp.where(jnp.arange(S)[None, :] < length[:, None], pos, -1)
    page_i = jnp.floor_divide(pos, ps)
    page = jnp.where(
        (page_i >= 0) & (page_i < M),
        jnp.take_along_axis(pt, jnp.clip(page_i, 0, M - 1), axis=1), -1)
    flat = jnp.where((page >= 0) & (pos >= 0),
                     page * ps + jnp.remainder(pos, ps), P * ps)

    def scat(pool, new):
        fp = pool.reshape((P * ps,) + pool.shape[2:])
        fp = fp.at[flat].set(new.astype(pool.dtype), mode="drop")
        return fp.reshape(pool.shape)
    return scat(pool_k, k_new), scat(pool_v, v_new)


def paged_store_counts(pool_k: jax.Array, pool_v: jax.Array,
                       k_new: jax.Array, v_new: jax.Array,
                       pt: jax.Array, idx: jax.Array,
                       length: Optional[jax.Array] = None,
                       tol: float = 0.0) -> jax.Array:
    """Waste counters for a ``paged_update`` store, per slot: (B, 3) int32
    ``[stored, silent, dropped]`` element counts over K and V.

    This is the pure-jnp oracle for the in-kernel store-site counters
    (kernel tier, see DESIGN.md): *stored* elements land through the
    page table; *silent* stored elements equal the pool content they
    overwrite within ``core.events.silent_mask`` tolerance (paper Def. 2,
    after the round-trip through the pool dtype); *dropped* elements
    target an unmapped page and are masked off (dead store lanes). Idle
    slots (negative positions) attempt no store and count nothing.
    """
    from repro.core.events import silent_mask
    P, ps = pool_k.shape[0], pool_k.shape[1]
    B, S, Hkv, D = k_new.shape
    M = pt.shape[1]
    pos = idx[:, None] + jnp.arange(S)[None, :]            # (B, S)
    attempted = pos >= 0
    if length is not None:
        attempted = attempted & (jnp.arange(S)[None, :] < length[:, None])
    page_i = jnp.floor_divide(pos, ps)
    page = jnp.where(
        (page_i >= 0) & (page_i < M),
        jnp.take_along_axis(pt, jnp.clip(page_i, 0, M - 1), axis=1), -1)
    landing = attempted & (page >= 0)

    flat = jnp.where(landing, page * ps + jnp.remainder(pos, ps), 0)

    def row_silent(pool, new):
        old = pool.reshape((P * ps,) + pool.shape[2:])[flat]   # (B,S,Hkv,D)
        oldf = old.astype(jnp.float32)
        newf = new.astype(pool.dtype).astype(jnp.float32)
        return jnp.sum(silent_mask(oldf, newf, tol), axis=(2, 3),
                       dtype=jnp.int32)                        # (B, S)

    sil = jnp.where(landing,
                    row_silent(pool_k, k_new) + row_silent(pool_v, v_new), 0)
    stored = jnp.sum(jnp.where(landing, 2 * Hkv * D, 0), axis=1,
                     dtype=jnp.int32)
    silent = jnp.sum(sil, axis=1, dtype=jnp.int32)
    dropped = jnp.sum(jnp.where(attempted & (page < 0), 2 * Hkv * D, 0),
                      axis=1, dtype=jnp.int32)
    return jnp.stack([stored, silent, dropped], axis=1)


def paged_decode_ref(q: jax.Array, k_new: jax.Array, v_new: jax.Array,
                     pool_k: jax.Array, pool_v: jax.Array,
                     pt: jax.Array, idx: jax.Array,
                     tol: float = 0.0) -> tuple:
    """Oracle for the paged-attention decode kernel: the store-then-
    gather-then-mask composition the serving fallback path runs, plus
    the store-site waste counters. Returns (out, ck, cv, counters)."""
    dt = q.dtype
    cnt = paged_store_counts(pool_k, pool_v, k_new, v_new, pt, idx, tol=tol)
    ck, cv = paged_update(pool_k, pool_v, k_new, v_new, pt, idx)
    gk, valid = paged_gather(ck, pt)
    gv, _ = paged_gather(cv, pt)
    out = attention_ref(q, gk.astype(dt), gv.astype(dt), causal=True,
                        q_offset=idx, kv_len=idx + 1, kv_valid=valid)
    return out, ck, cv, cnt


def paged_window_ref(q: jax.Array, k_win: jax.Array, v_win: jax.Array,
                     pool_k: jax.Array, pool_v: jax.Array,
                     pt: jax.Array, idx: jax.Array, *,
                     store: bool = True, tol: float = 0.0) -> tuple:
    """Oracle for the fused paged window kernel (prefill / verify).

    ``store=True`` is the scatter-then-gather composition the overwrite
    paths run (all S window rows stored through the page table, then
    attention over the gathered view); ``store=False`` is the "defer"
    composition (window spliced into the gathered view, pool untouched,
    zero store counters). Returns (out, ck, cv, counters).
    """
    dt = q.dtype
    B, S = q.shape[:2]
    if store:
        cnt = paged_store_counts(pool_k, pool_v, k_win, v_win, pt, idx,
                                 tol=tol)
        ck, cv = paged_update(pool_k, pool_v, k_win, v_win, pt, idx)
        gk, valid = paged_gather(ck, pt)
        gv, _ = paged_gather(cv, pt)
    else:
        cnt = jnp.zeros((B, 3), jnp.int32)
        ck, cv = pool_k, pool_v
        gk, valid = paged_gather(pool_k, pt)
        gv, _ = paged_gather(pool_v, pt)
        ext = gk.shape[1]
        pos = idx[:, None] + jnp.arange(S)[None, :]
        tgt = jnp.where((pos >= 0) & (pos < ext), pos, ext)
        bidx = jnp.arange(B)[:, None]
        gk = gk.at[bidx, tgt].set(k_win.astype(gk.dtype), mode="drop")
        gv = gv.at[bidx, tgt].set(v_win.astype(gv.dtype), mode="drop")
        valid = valid.at[bidx, tgt].set(True, mode="drop")
    out = attention_ref(q, gk.astype(dt), gv.astype(dt), causal=True,
                        q_offset=idx, kv_len=idx + S, kv_valid=valid)
    return out, ck, cv, cnt


def paged_gather(pool: jax.Array, pt: jax.Array) -> tuple:
    """Logical per-slot view of a paged pool: (B, M*page, ...) plus the
    (B, M*page) validity mask (False where the page table is unmapped —
    gathered garbage there must be masked, see attention_ref.kv_valid).
    """
    P, ps = pool.shape[0], pool.shape[1]
    B, M = pt.shape
    g = jnp.take(pool, jnp.clip(pt, 0, P - 1), axis=0)     # (B,M,page,...)
    g = g.reshape((B, M * ps) + pool.shape[2:])
    valid = jnp.repeat(pt >= 0, ps, axis=1)
    return g, valid


# ----------------------------------------------------------------------
# Silent-compare: fraction of "silent" (unchanged) elements between two
# buffers — the detector hot-spot (paper Defs. 2-3 value equality, with
# the paper's FP tolerance semantics; tol=0 => exact).
# ----------------------------------------------------------------------
def silent_compare_ref(a: jax.Array, b: jax.Array, tol: float = 0.01) -> jax.Array:
    """Count elements where b is a 'silent' overwrite of a. Returns int32 count.

    Uses the substrate's single silent-match definition (symmetric relative
    tolerance; NaN padding is never silent)."""
    from repro.core.events import silent_mask
    a = a.astype(jnp.float32).ravel()
    b = b.astype(jnp.float32).ravel()
    return jnp.sum(silent_mask(a, b, tol), dtype=jnp.int32)


# ----------------------------------------------------------------------
# RMSNorm (fused)
# ----------------------------------------------------------------------
def rmsnorm_ref(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)
