"""Pure-jnp oracles for every Pallas kernel.

These are the correctness references (kernel tests assert allclose against
them) AND the default compute path on non-TPU backends — XLA fuses them
well and GSPMD partitions them automatically.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


# ----------------------------------------------------------------------
# Attention (GQA, causal, optional decode length-mask)
# ----------------------------------------------------------------------
def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, q_offset=0,
                  kv_len: Optional[jax.Array] = None,
                  kv_valid: Optional[jax.Array] = None) -> jax.Array:
    """q: (B,Sq,Hq,D), k/v: (B,Skv,Hkv,D) -> (B,Sq,Hq,D). f32 accumulate.

    ``q_offset``/``kv_len`` may be scalars (one decode position for the
    whole batch) or (B,) vectors (per-slot positions — the serving
    engine's continuous-batching cache, where every row sits at its own
    sequence offset). ``kv_valid`` is an optional (B,Skv) gather-validity
    mask: positions of a paged cache's logical view whose page table
    entry is unmapped (see ``paged_gather``) are masked out like
    positions past ``kv_len``.
    """
    B, Sq, Hq, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, G, D)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                        preferred_element_type=jnp.float32)
    scores = scores * (1.0 / jnp.sqrt(D).astype(jnp.float32))

    mask = None
    if causal:
        qpos = jnp.asarray(q_offset)[..., None] + jnp.arange(Sq)
        mask = qpos[..., :, None] >= jnp.arange(Skv)   # (Sq,Skv) | (B,Sq,Skv)
    if kv_len is not None:
        lmask = jnp.arange(Skv) < jnp.asarray(kv_len)[..., None]
        lmask = lmask[..., None, :]              # (1,Skv) | (B,1,Skv)
        mask = lmask if mask is None else (mask & lmask)
    if kv_valid is not None:
        vmask = kv_valid[:, None, :]             # (B,1,Skv)
        mask = vmask if mask is None else (mask & vmask)
    if mask is not None:
        bmask = mask[:, None, None] if mask.ndim == 3 else mask[None, None, None]
        scores = jnp.where(bmask, scores, -jnp.inf)

    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v)
    return out.reshape(B, Sq, Hq, D)


# ----------------------------------------------------------------------
# Paged KV cache: page-table scatter (store) and gather (load) between
# the logical per-slot view and the flat page pool (serve/kv_cache.py).
# ----------------------------------------------------------------------
def paged_update(pool_k: jax.Array, pool_v: jax.Array, k_new: jax.Array,
                 v_new: jax.Array, pt: jax.Array,
                 idx: jax.Array,
                 length: Optional[jax.Array] = None) -> tuple:
    """Scatter new K/V rows into a paged pool through the page table.

    pool: (P, page, Hkv, D); k_new/v_new: (B, S, Hkv, D); pt: (B, M)
    page table (-1 = unmapped); idx: (B,) per-slot write positions. Row
    (b, s) lands at logical position idx[b]+s -> page pt[b, pos//page].
    Stores whose position is negative (engine idle-slot sentinel) or
    whose page is unmapped are DROPPED — idle/finished slots write
    nothing past their page-table extent, which is exactly the dead/
    silent-store waste of the dense layout eliminated.

    ``length`` (optional, (B,)): per-slot row budget — rows s >=
    length[b] are dropped too. Speculative rollback commits exactly the
    accepted prefix of a verify window this way (LM.commit_verify), so
    rejected draft rows never reach the pool at all.
    """
    P, ps = pool_k.shape[0], pool_k.shape[1]
    B, S = k_new.shape[0], k_new.shape[1]
    M = pt.shape[1]
    pos = idx[:, None] + jnp.arange(S)[None, :]            # (B,S) logical
    if length is not None:
        pos = jnp.where(jnp.arange(S)[None, :] < length[:, None], pos, -1)
    page_i = jnp.floor_divide(pos, ps)
    page = jnp.where(
        (page_i >= 0) & (page_i < M),
        jnp.take_along_axis(pt, jnp.clip(page_i, 0, M - 1), axis=1), -1)
    flat = jnp.where((page >= 0) & (pos >= 0),
                     page * ps + jnp.remainder(pos, ps), P * ps)

    def scat(pool, new):
        fp = pool.reshape((P * ps,) + pool.shape[2:])
        fp = fp.at[flat].set(new.astype(pool.dtype), mode="drop")
        return fp.reshape(pool.shape)
    return scat(pool_k, k_new), scat(pool_v, v_new)


def paged_gather(pool: jax.Array, pt: jax.Array) -> tuple:
    """Logical per-slot view of a paged pool: (B, M*page, ...) plus the
    (B, M*page) validity mask (False where the page table is unmapped —
    gathered garbage there must be masked, see attention_ref.kv_valid).
    """
    P, ps = pool.shape[0], pool.shape[1]
    B, M = pt.shape
    g = jnp.take(pool, jnp.clip(pt, 0, P - 1), axis=0)     # (B,M,page,...)
    g = g.reshape((B, M * ps) + pool.shape[2:])
    valid = jnp.repeat(pt >= 0, ps, axis=1)
    return g, valid


# ----------------------------------------------------------------------
# Silent-compare: fraction of "silent" (unchanged) elements between two
# buffers — the detector hot-spot (paper Defs. 2-3 value equality, with
# the paper's FP tolerance semantics; tol=0 => exact).
# ----------------------------------------------------------------------
def silent_compare_ref(a: jax.Array, b: jax.Array, tol: float = 0.01) -> jax.Array:
    """Count elements where b is a 'silent' overwrite of a. Returns int32 count.

    Uses the substrate's single silent-match definition (symmetric relative
    tolerance; NaN padding is never silent)."""
    from repro.core.events import silent_mask
    a = a.astype(jnp.float32).ravel()
    b = b.astype(jnp.float32).ravel()
    return jnp.sum(silent_mask(a, b, tol), dtype=jnp.int32)


# ----------------------------------------------------------------------
# RMSNorm (fused)
# ----------------------------------------------------------------------
def rmsnorm_ref(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)
