"""Pure-jnp oracles for every Pallas kernel.

These are the correctness references (kernel tests assert allclose against
them) AND the default compute path on non-TPU backends — XLA fuses them
well and GSPMD partitions them automatically.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


# ----------------------------------------------------------------------
# Attention (GQA, causal, optional decode length-mask)
# ----------------------------------------------------------------------
def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, q_offset=0,
                  kv_len: Optional[jax.Array] = None) -> jax.Array:
    """q: (B,Sq,Hq,D), k/v: (B,Skv,Hkv,D) -> (B,Sq,Hq,D). f32 accumulate.

    ``q_offset``/``kv_len`` may be scalars (one decode position for the
    whole batch) or (B,) vectors (per-slot positions — the serving
    engine's continuous-batching cache, where every row sits at its own
    sequence offset).
    """
    B, Sq, Hq, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, G, D)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                        preferred_element_type=jnp.float32)
    scores = scores * (1.0 / jnp.sqrt(D).astype(jnp.float32))

    mask = None
    if causal:
        qpos = jnp.asarray(q_offset)[..., None] + jnp.arange(Sq)
        mask = qpos[..., :, None] >= jnp.arange(Skv)   # (Sq,Skv) | (B,Sq,Skv)
    if kv_len is not None:
        lmask = jnp.arange(Skv) < jnp.asarray(kv_len)[..., None]
        lmask = lmask[..., None, :]              # (1,Skv) | (B,1,Skv)
        mask = lmask if mask is None else (mask & lmask)
    if mask is not None:
        bmask = mask[:, None, None] if mask.ndim == 3 else mask[None, None, None]
        scores = jnp.where(bmask, scores, -jnp.inf)

    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v)
    return out.reshape(B, Sq, Hq, D)


# ----------------------------------------------------------------------
# Silent-compare: fraction of "silent" (unchanged) elements between two
# buffers — the detector hot-spot (paper Defs. 2-3 value equality, with
# the paper's FP tolerance semantics; tol=0 => exact).
# ----------------------------------------------------------------------
def silent_compare_ref(a: jax.Array, b: jax.Array, tol: float = 0.01) -> jax.Array:
    """Count elements where b is a 'silent' overwrite of a. Returns int32 count.

    Uses the substrate's single silent-match definition (symmetric relative
    tolerance; NaN padding is never silent)."""
    from repro.core.events import silent_mask
    a = a.astype(jnp.float32).ravel()
    b = b.astype(jnp.float32).ravel()
    return jnp.sum(silent_mask(a, b, tol), dtype=jnp.int32)


# ----------------------------------------------------------------------
# RMSNorm (fused)
# ----------------------------------------------------------------------
def rmsnorm_ref(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)
