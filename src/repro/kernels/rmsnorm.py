"""Fused RMSNorm kernel (Pallas).

One HBM read + one write per element (the unfused XLA path reads x twice:
once for the variance reduction, once for the scale). Rows are tiled into
VMEM as (block_rows, d) blocks; the reduction runs on the VPU in f32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-5, *,
            block_rows: int = 128, interpret: bool = False) -> jax.Array:
    """x: (..., d); scale: (d,)."""
    orig_shape = x.shape
    d = x.shape[-1]
    xf = x.reshape(-1, d)
    n = xf.shape[0]
    block_rows = min(block_rows, max(n, 1))
    n_pad = pl.cdiv(n, block_rows) * block_rows
    if n_pad != n:
        xf = jnp.pad(xf, ((0, n_pad - n), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(n_pad // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, d), x.dtype),
        interpret=interpret,
    )(xf, scale)
    return out[:n].reshape(orig_shape)
