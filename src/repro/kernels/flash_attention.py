"""Blocked causal flash attention for TPU (Pallas).

TPU adaptation notes (vs. the canonical CUDA flash-attention):
  * tiles are BlockSpec'd into VMEM; the (Bq x D) @ (D x Bk) products map
    onto the 128x128 MXU, so block sizes are multiples of 128 where the
    head dim allows;
  * the kv-block loop is the innermost grid dimension; running max /
    denominator / accumulator live in VMEM scratch that persists across the
    innermost grid iterations ("arbitrary" dimension semantics), which is
    the TPU-idiomatic replacement for a CUDA thread-block software loop;
  * GQA is handled in the index_map (q head h reads kv head h // G), so
    no KV replication is materialized in HBM.

Validated in interpret mode on CPU against ``ref.attention_ref``.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def online_softmax_step(s, v, m_scr, l_scr, acc_scr):
    """One flash accumulation step, shared by every attention kernel in
    this package (causal flash, paged decode, paged window).

    ``s``: (rows, cols) masked f32 scores; ``v``: (cols, D) f32 values;
    the three scratch refs are the (rows, 1) running max / denominator
    and the (rows, D) output accumulator, persisted across the innermost
    grid sweep."""
    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, block_q: int, block_k: int,
                  seq_len: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k

    run = True
    if causal:
        # skip fully-masked kv blocks (strictly above the diagonal)
        run = (k_start <= q_start + block_q - 1)

    @pl.when(run if causal else (ki >= 0))
    def _body():
        q = q_ref[0].astype(jnp.float32)            # (block_q, D)
        k = k_ref[0].astype(jnp.float32)            # (block_k, D)
        v = v_ref[0].astype(jnp.float32)            # (block_k, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * scale                                # (block_q, block_k)

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < seq_len
        if causal:
            mask = mask & (qpos >= kpos)
        s = jnp.where(mask, s, NEG_INF)
        online_softmax_step(s, v, m_scr, l_scr, acc_scr)

    @pl.when(ki == nk - 1)
    def _fin():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q: (B, Sq, Hq, D), k/v: (B, Skv, Hkv, D) -> (B, Sq, Hq, D)."""
    B, Sq, Hq, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(D)

    # (B, H, S, D) layout for clean 2D blocks per (b, h) program.
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    block_q = min(block_q, max(Sq, 8))
    block_k = min(block_k, max(Skv, 8))
    # pad seq to block multiples
    Sq_p = pl.cdiv(Sq, block_q) * block_q
    Skv_p = pl.cdiv(Skv, block_k) * block_k
    if Sq_p != Sq:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, Sq_p - Sq), (0, 0)))
    if Skv_p != Skv:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, Skv_p - Skv), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, Skv_p - Skv), (0, 0)))

    grid = (B * Hq, Sq_p // block_q, Skv_p // block_k)

    def q_index(bh, qi, ki):
        return (bh, qi, 0)

    def kv_index(bh, qi, ki):
        h = bh % Hq
        b = bh // Hq
        return (b * Hkv + h // G, ki, 0)

    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          block_q=block_q, block_k=block_k, seq_len=Skv),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, D), q_index),
            pl.BlockSpec((1, block_k, D), kv_index),
            pl.BlockSpec((1, block_k, D), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), q_index),
        out_shape=jax.ShapeDtypeStruct((B * Hq, Sq_p, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),   # running denom l
            pltpu.VMEM((block_q, D), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(qt.reshape(B * Hq, Sq_p, D), kt.reshape(B * Hkv, Skv_p, D),
      vt.reshape(B * Hkv, Skv_p, D))

    out = out.reshape(B, Hq, Sq_p, D)[:, :, :Sq].transpose(0, 2, 1, 3)
    return out
