"""Fused paged window-attention kernel (Pallas TPU): causal flash
prefill with a paged-write epilogue, and width-(k+1) speculative verify.

One kernel serves both serving forwards that push an S-token *window*
at per-slot offsets ``idx`` against a paged KV pool:

  * prefill (``LM.prefill``) — S prompt tokens; the window's K/V rows
    are written straight into the page pool from inside the kernel
    (aliased pool outputs, no host-side scatter);
  * verify (``LM.verify``) — S = k+1 draft tokens; ``store=True`` is
    spec="overwrite" (all rows stored, rejected rows become dead
    stores), ``store=False`` is spec="defer" (rollback: pool untouched,
    the kernel only computes the spliced-window attention).

The committed history is gathered from the pool *inside* the kernel via
the scalar-prefetched page table (no ``paged_gather`` materialization);
the window K/V ride in a separate operand. The innermost grid dim runs
``M`` committed-page steps, one window step, then (store mode)
``Wp`` store-epilogue steps that write the window rows into their pages.

Waste counters ([stored, silent, dropped] per slot, see
``kernels/paged_attention.py`` and DESIGN.md § Kernel tier) are
measured at the store epilogue — the store site — by comparing each
page tile against the rows about to overwrite it with
``core.events.silent_mask`` semantics, *before* the tile is rewritten.

Store semantics: the aliased pool outputs are read-modify-written (see
the in-kernel comment) — input refs of aliased operands are snapshots,
so all epilogue reads go through the output refs, and visits that store
nothing leave their block untouched. Grid dims are declared "arbitrary"
so the sequential-revisit semantics interpret mode tests are the
semantics the TPU pipeline must honor; the COW invariant of
`serve/kv_cache.py` (a page being extended is exclusively mapped; shared
pages are read-only) is what makes the per-slot writes race-free.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.events import silent_mask
from repro.kernels.flash_attention import NEG_INF, online_softmax_step


def _window_kernel(pt_ref, idx_ref, q_ref, kw_ref, vw_ref, wv_ref,
                   k_ref, v_ref,
                   o_ref, lse_ref, cnt_ref, ok_ref, ov_ref,
                   m_scr, l_scr, acc_scr, cnt_scr, *,
                   scale: float, ps: int, G: int, S: int, M: int,
                   block_q: int, store: bool, tol: float):
    b = pl.program_id(0)
    h = pl.program_id(1)
    qi = pl.program_id(2)
    mi = pl.program_id(3)
    idx = idx_ref[b]
    w0 = jnp.maximum(idx, 0) // ps

    @pl.when((h == 0) & (qi == 0) & (mi == 0))
    def _zero_cnt():
        cnt_scr[...] = jnp.zeros_like(cnt_scr)

    @pl.when(mi == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # ---- committed-history page steps -------------------------------
    page = pt_ref[b, jnp.clip(mi, 0, M - 1)]

    @pl.when((mi < M) & (idx >= 1) & (page >= 0) & (mi * ps < idx))
    def _attend_page():
        q = q_ref[0, 0].astype(jnp.float32)               # (bq, D)
        k = k_ref[0, :, 0].astype(jnp.float32)            # (ps, D)
        v = v_ref[0, :, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        kpos = mi * ps + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kpos < idx, s, NEG_INF)
        online_softmax_step(s, v, m_scr, l_scr, acc_scr)

    # ---- window step: in-window causal attention --------------------
    @pl.when((mi == M) & (idx >= 0))
    def _attend_window():
        q = q_ref[0, 0].astype(jnp.float32)               # (bq, D)
        k = kw_ref[0, :, 0].astype(jnp.float32)           # (S, D)
        v = vw_ref[0, :, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        r = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        c = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = (c <= r) & (wv_ref[0][None, :] > 0)
        s = jnp.where(mask, s, NEG_INF)
        online_softmax_step(s, v, m_scr, l_scr, acc_scr)

    @pl.when(mi == M)
    def _fin():
        l = l_scr[...]
        lse_ref[0, 0] = jnp.where(
            l > 0.0, m_scr[...] + jnp.log(jnp.where(l > 0.0, l, 1.0)),
            NEG_INF)[:, 0]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)

    # ---- store epilogue: write window rows into their pages ---------
    #
    # The aliased pool *outputs* are read-modify-written: o-ref reads see
    # the live buffer (the aliased input's value until the page is first
    # written), and visits that store nothing leave the block untouched,
    # so pages shared across slots / revisited across (h, qi) sweeps are
    # never clobbered with stale content. (Input refs of aliased
    # operands are snapshots — they serve only the committed-history
    # attention reads, which never overlap this kernel's stores.)
    if store:
        pdt = ok_ref.dtype

        @pl.when((mi > M) & (idx >= 0))
        def _store():
            j = mi - (M + 1)
            page_i = w0 + j
            entry = pt_ref[b, jnp.clip(page_i, 0, M - 1)]
            page_ok = (page_i < M) & (entry >= 0)

            offs = jax.lax.broadcasted_iota(jnp.int32, (ps, 1), 0)
            sw = page_i * ps + offs - idx                 # window row per off
            sel = (sw >= 0) & (sw < S)
            oh = ((sw == jax.lax.broadcasted_iota(jnp.int32, (ps, S), 1))
                  & sel).astype(jnp.float32)              # one-hot (ps, S)

            def rows(w_ref):
                w = w_ref[0, :, 0].astype(jnp.float32)    # (S, D)
                r = jax.lax.dot_general(
                    oh, w, (((1,), (0,)), ((), ())),
                    preferred_element_type=jnp.float32)
                return r.astype(pdt)                      # exact row copies

            old_k = ok_ref[0, :, 0]
            old_v = ov_ref[0, :, 0]
            write = sel & page_ok
            new_k = jnp.where(write, rows(kw_ref), old_k)
            new_v = jnp.where(write, rows(vw_ref), old_v)
            ok_ref[0, :, 0] = new_k
            ov_ref[0, :, 0] = new_v

            # store-site counters, measured against pre-store content at
            # the first visit of each (kv head, page)
            @pl.when((h % G == 0) & (qi == 0))
            def _count():
                D = old_k.shape[-1]
                n_sel = jnp.sum(sel.astype(jnp.int32))
                sil = (jnp.sum(jnp.where(sel, silent_mask(
                            old_k.astype(jnp.float32),
                            new_k.astype(jnp.float32), tol), False),
                            dtype=jnp.int32)
                       + jnp.sum(jnp.where(sel, silent_mask(
                            old_v.astype(jnp.float32),
                            new_v.astype(jnp.float32), tol), False),
                            dtype=jnp.int32))
                cnt_scr[0, 0] += jnp.where(page_ok, 2 * D * n_sel, 0)
                cnt_scr[0, 1] += jnp.where(page_ok, sil, 0)
                cnt_scr[0, 2] += jnp.where(page_ok, 0, 2 * D * n_sel)

    cnt_ref[...] = cnt_scr[...]


def paged_window_attention(q: jax.Array, k_win: jax.Array, v_win: jax.Array,
                           pool_k: jax.Array, pool_v: jax.Array,
                           pt: jax.Array, idx: jax.Array, *,
                           store: bool = True,
                           block_q: int = 128,
                           tol: float = 0.0,
                           interpret: bool = False):
    """q: (B, S, Hq, D) at per-slot offsets idx (B,); k_win/v_win:
    (B, S, Hkv, D); pool: (P, page, Hkv, D); pt: (B, M).

    Returns ``(out, lse, counters, new_pool_k, new_pool_v)``; with
    ``store=False`` the pools come back unchanged (and are not donated).
    Matches the ref compositions used by ``models.layers.apply_attention``:
    ``paged_update -> paged_gather -> attention_ref`` for store mode, the
    spliced-gather "defer" path otherwise. Idle slots (idx < 0) attend
    nothing and come back zero (the ref path yields NaN there; the
    engine discards both).
    """
    B, S, Hq, D = q.shape
    P, ps, Hkv, _ = pool_k.shape
    M = pt.shape[1]
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(D)
    pdt = pool_k.dtype

    pt = pt.astype(jnp.int32)
    idx = idx.astype(jnp.int32)

    # window validity per mode (page-table reads only — O(B*S) scalars)
    gpos = jnp.maximum(idx, 0)[:, None] + jnp.arange(S)[None, :]   # (B, S)
    if store:
        pg = jnp.floor_divide(gpos, ps)
        entry = jnp.where(pg < M,
                          jnp.take_along_axis(pt, jnp.clip(pg, 0, M - 1),
                                              axis=1), -1)
        wv = (entry >= 0).astype(jnp.int32)
    else:
        wv = (gpos < M * ps).astype(jnp.int32)

    block_q = min(block_q, max(S, 8))
    Sq_p = pl.cdiv(S, block_q) * block_q
    qt = q.transpose(0, 2, 1, 3)                        # (B, Hq, S, D)
    if Sq_p != S:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, Sq_p - S), (0, 0)))
    nq = Sq_p // block_q

    kw = k_win.astype(pdt)
    vw = v_win.astype(pdt)

    Wp = pl.cdiv(S, ps) + 1 if store else 0
    grid = (B, Hq, nq, M + 1 + Wp)

    def q_index(b, h, qi, mi, *_):
        return (b, h, qi, 0)

    def win_index(b, h, qi, mi, *_):
        return (b, 0, h // G, 0)

    def wv_index(b, h, qi, mi, *_):
        return (b, 0)

    def pool_index(b, h, qi, mi, pt_ref, idx_ref):
        w0 = jnp.maximum(idx_ref[b], 0) // ps
        page_i = jnp.where(mi < M, mi, jnp.clip(w0 + mi - M - 1, 0, M - 1))
        return (jnp.clip(pt_ref[b, page_i], 0, P - 1), 0, h // G, 0)

    out_specs = [
        pl.BlockSpec((1, 1, block_q, D), q_index),
        pl.BlockSpec((1, 1, block_q), lambda b, h, qi, mi, *_: (b, h, qi)),
        pl.BlockSpec((1, 3), lambda b, h, qi, mi, *_: (b, 0)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((B, Hq, Sq_p, D), q.dtype),
        jax.ShapeDtypeStruct((B, Hq, Sq_p), jnp.float32),
        jax.ShapeDtypeStruct((B, 3), jnp.int32),
    ]
    kwargs = {}
    if store:
        out_specs += [pl.BlockSpec((1, ps, 1, D), pool_index),
                      pl.BlockSpec((1, ps, 1, D), pool_index)]
        out_shape += [jax.ShapeDtypeStruct(pool_k.shape, pdt),
                      jax.ShapeDtypeStruct(pool_v.shape, pdt)]
        # operand numbering includes the scalar-prefetch args: the pools
        # are inputs 6, 7 of (pt, idx, q, kw, vw, wv, pool_k, pool_v)
        kwargs["input_output_aliases"] = {6: 3, 7: 4}

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), q_index),
            pl.BlockSpec((1, S, 1, D), win_index),
            pl.BlockSpec((1, S, 1, D), win_index),
            pl.BlockSpec((1, S), wv_index),
            pl.BlockSpec((1, ps, 1, D), pool_index),
            pl.BlockSpec((1, ps, 1, D), pool_index),
        ],
        out_specs=out_specs,
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
            pltpu.VMEM((1, 3), jnp.int32),
        ],
    )

    def dummy_store_refs(fn):
        if store:
            return fn
        # store=False has no pool outputs; pad the kernel signature
        def wrapped(pt_ref, idx_ref, q_ref, kw_ref, vw_ref, wv_ref,
                    k_ref, v_ref, o_ref, lse_ref, cnt_ref, *scr):
            return fn(pt_ref, idx_ref, q_ref, kw_ref, vw_ref, wv_ref,
                      k_ref, v_ref, o_ref, lse_ref, cnt_ref, None, None,
                      *scr)
        return wrapped

    kernel = dummy_store_refs(functools.partial(
        _window_kernel, scale=scale, ps=ps, G=G, S=S, M=M,
        block_q=block_q, store=store, tol=tol))

    res = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("arbitrary",) * 4),
        interpret=interpret,
        **kwargs,
    )(pt, idx, qt, kw, vw, wv, pool_k, pool_v)

    if store:
        out, lse, cnt, npk, npv = res
    else:
        out, lse, cnt = res
        npk, npv = pool_k, pool_v
    out = out[:, :, :S].transpose(0, 2, 1, 3)           # (B, S, Hq, D)
    lse = lse[:, :, :S]
    return out, lse, cnt, npk, npv
