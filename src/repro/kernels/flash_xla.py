"""Flash attention in pure JAX with a hand-written custom_vjp.

This is the XLA-lowerable twin of the Pallas kernel (flash_attention.py):
KV-chunked online-softmax forward, recompute-based backward — O(S·D)
residuals (q, k, v, out, lse) instead of O(S²) score materialization. It is
what the dry-run lowers for every train/prefill cell, so memory_analysis
and cost_analysis reflect flash-attention behaviour, and it is the actual
compute path on non-TPU backends. GQA handled by head grouping.

Numerical convention matches ref.attention_ref (f32 accumulation).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

DEFAULT_CHUNK = 512
NEG_INF = -1e30


def _chunks(x: jax.Array, chunk: int, axis: int = 1) -> jax.Array:
    """(B, S, ...) -> (nch, B, chunk, ...) for scanning."""
    B = x.shape[0]
    S = x.shape[axis]
    nch = S // chunk
    xs = x.reshape(x.shape[:axis] + (nch, chunk) + x.shape[axis + 1:])
    return jnp.moveaxis(xs, axis, 0)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_xla(q, k, v, causal: bool = True, q_offset: int = 0,
              chunk: int = DEFAULT_CHUNK):
    out, _ = _fwd_impl(q, k, v, causal, q_offset, chunk)
    return out


def _mask_for(Sq, ck_len, q_offset, kidx, chunk, kv_total, causal):
    qpos = q_offset + jnp.arange(Sq)[:, None]
    kpos = kidx * chunk + jnp.arange(ck_len)[None, :]
    m = kpos < kv_total
    if causal:
        m = m & (qpos >= kpos)
    return m  # (Sq, ck_len)


def _fwd_impl(q, k, v, causal, q_offset, chunk):
  with jax.named_scope("flashattn_vmem"):
    B, Sq, Hq, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    f32 = jnp.float32
    scale = 1.0 / jnp.sqrt(jnp.asarray(D, f32))
    chunk = min(chunk, Skv)
    pad = (-Skv) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qg = q.reshape(B, Sq, Hkv, G, D)

    ks = _chunks(k, chunk)
    vs = _chunks(v, chunk)
    nch = ks.shape[0]

    def body(carry, inp):
        acc, m, l = carry
        kc, vc, kidx = inp
        s = jnp.einsum("bqhgd,bkhd->bqhgk", qg, kc,
                       preferred_element_type=f32) * scale
        msk = _mask_for(Sq, chunk, q_offset, kidx, chunk, Skv, causal)
        s = jnp.where(msk[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bqhgk,bkhd->bqhgd", p, vc, preferred_element_type=f32)
        return (acc, m_new, l), None

    acc0 = jnp.zeros((B, Sq, Hkv, G, D), f32)
    m0 = jnp.full((B, Sq, Hkv, G), NEG_INF, f32)
    l0 = jnp.zeros((B, Sq, Hkv, G), f32)
    (acc, m, l), _ = jax.lax.scan(
        body, (acc0, m0, l0), (ks, vs, jnp.arange(nch)))
    l_safe = jnp.where(l == 0.0, 1.0, l)
    out = (acc / l_safe[..., None]).astype(q.dtype).reshape(B, Sq, Hq, D)
    lse = m + jnp.log(l_safe)
  return out, lse


def _fwd_rule(q, k, v, causal, q_offset, chunk):
    out, lse = _fwd_impl(q, k, v, causal, q_offset, chunk)
    return out, (q, k, v, out, lse)


def _bwd_rule(causal, q_offset, chunk, res, dout):
  with jax.named_scope("flashattn_vmem"):
    q, k, v, out, lse = res
    B, Sq, Hq, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    f32 = jnp.float32
    scale = 1.0 / jnp.sqrt(jnp.asarray(D, f32))
    chunk_ = min(chunk, Skv)
    pad = (-Skv) % chunk_
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else k
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else v

    qg = q.reshape(B, Sq, Hkv, G, D).astype(f32)
    dog = dout.reshape(B, Sq, Hkv, G, D).astype(f32)
    og = out.reshape(B, Sq, Hkv, G, D).astype(f32)
    delta = jnp.sum(dog * og, axis=-1)                      # (B,Sq,Hkv,G)

    ks = _chunks(kp, chunk_)
    vs = _chunks(vp, chunk_)
    nch = ks.shape[0]

    def body(dq, inp):
        kc, vc, kidx = inp
        kcf = kc.astype(f32)
        vcf = vc.astype(f32)
        s = jnp.einsum("bqhgd,bkhd->bqhgk", qg, kcf,
                       preferred_element_type=f32) * scale
        msk = _mask_for(Sq, chunk_, q_offset, kidx, chunk_, Skv, causal)
        s = jnp.where(msk[None, :, None, None, :], s, NEG_INF)
        p = jnp.exp(s - lse[..., None])                     # (B,Sq,Hkv,G,ck)
        dv_c = jnp.einsum("bqhgk,bqhgd->bkhd", p, dog)
        dp = jnp.einsum("bqhgd,bkhd->bqhgk", dog, vcf,
                        preferred_element_type=f32)
        ds = p * (dp - delta[..., None]) * scale
        dq = dq + jnp.einsum("bqhgk,bkhd->bqhgd", ds, kcf,
                             preferred_element_type=f32)
        dk_c = jnp.einsum("bqhgk,bqhgd->bkhd", ds, qg)
        return dq, (dk_c, dv_c)

    dq0 = jnp.zeros((B, Sq, Hkv, G, D), f32)
    dq, (dks, dvs) = jax.lax.scan(body, dq0, (ks, vs, jnp.arange(nch)))
    dk = jnp.moveaxis(dks, 0, 1).reshape(B, nch * chunk_, Hkv, D)[:, :Skv]
    dv = jnp.moveaxis(dvs, 0, 1).reshape(B, nch * chunk_, Hkv, D)[:, :Skv]
  return (dq.reshape(B, Sq, Hq, D).astype(q.dtype),
          dk.astype(k.dtype), dv.astype(v.dtype))


flash_xla.defvjp(_fwd_rule, _bwd_rule)
