"""AdamW with decoupled weight decay, pytree-native, GSPMD-friendly.

State layout (ZeRO-1): the f32 master params and both moments live fully
sharded (see repro.sharding.rules.opt_specs); the bf16 compute params are
re-materialized from the master after each update with the model's own
(strategy-specific) sharding.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


class AdamWState(NamedTuple):
    m: Any
    v: Any


def init(params: Any, registry=None, owner: str = "opt") -> AdamWState:
    """Zero moments. With an `ObjectRegistry` (core/objects.py) every
    moment leaf registers as a live ``opt_state`` object — all
    bit-identical zeros at init, which is exactly the replica-detector
    demo: state that could lazy-materialize on first non-zero update."""
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    state = AdamWState(m=jax.tree_util.tree_map(zeros, params),
                       v=jax.tree_util.tree_map(zeros, params))
    if registry is not None:
        from repro.core.objects import register_tree
        register_tree(registry, f"{owner}/m", state.m, kind="opt_state")
        register_tree(registry, f"{owner}/v", state.v, kind="opt_state")
    return state


def update(tc: TrainConfig, grads: Any, state: AdamWState, master: Any,
           lr: jax.Array, step: jax.Array):
    """Returns (new_master, new_state). All math in f32."""
    b1, b2, eps, wd = tc.b1, tc.b2, tc.eps, tc.weight_decay
    count = step.astype(jnp.float32) + 1.0
    c1 = 1.0 - b1 ** count
    c2 = 1.0 - b2 ** count

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m_new = b1 * m + (1.0 - b1) * g
        v_new = b2 * v + (1.0 - b2) * jnp.square(g)
        mhat = m_new / c1
        vhat = v_new / c2
        delta = mhat / (jnp.sqrt(vhat) + eps) + wd * p
        return p - lr * delta, m_new, v_new

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    flat_p = treedef.flatten_up_to(master)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(m=new_m, v=new_v)


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    # reduce, not builtin sum(): sum() seeds with literal 0, emitting a
    # zero-add equation (tier-0 silent_store finding)
    sq = [jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves]
    return jnp.sqrt(functools.reduce(jnp.add, sq))


def clip_by_global_norm(tree: Any, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), tree), norm
