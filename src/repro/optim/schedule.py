"""Warmup-cosine learning-rate schedule (pure function of step)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import TrainConfig


def lr_at(tc: TrainConfig, step) -> jnp.ndarray:
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(tc.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - tc.warmup_steps) /
                    jnp.maximum(tc.total_steps - tc.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return tc.learning_rate * warm * (0.1 + 0.9 * cos)
