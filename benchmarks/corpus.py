"""Synthetic performance-bug corpus (paper Table 2 analogue).

Twelve inefficiency patterns drawn from the paper's taxonomy, each with
the expected waste kind and (where meaningful) an optimized twin used by
the case studies. Pattern #11 is the *adjacent-location* class the paper
documents as a JXPerf miss (Ant#53637): our buffer-granular watchpoints
DO catch it — a documented improvement of the TPU adaptation
(EXPERIMENTS.md §Paper-validation).
"""
from __future__ import annotations

from typing import Callable, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class Bug(NamedTuple):
    name: str
    kind: str                     # dead_store | silent_store | silent_load
    build: Callable[[], Tuple[Callable, tuple]]
    fixed: Optional[Callable[[], Tuple[Callable, tuple]]] = None
    expect_detected: bool = True
    source: str = ""


def _linear_search():
    arr = jnp.arange(256)
    keys = jnp.arange(48) % 7

    def f(keys, arr):
        def body(c, k):
            return c + jnp.any(arr == k).astype(jnp.int32), None
        out, _ = jax.lax.scan(body, jnp.int32(0), keys)
        return out
    return f, (keys, arr)


def _linear_search_fixed():
    # hash-set analogue: one vectorized membership test
    arr = jnp.arange(256)
    keys = jnp.arange(48) % 7

    def f(keys, arr):
        idx = jnp.searchsorted(arr, keys)          # O(log n) per key
        idx = jnp.clip(idx, 0, arr.shape[0] - 1)
        return (arr[idx] == keys).sum()
    return f, (keys, arr)


def _loop_invariant_pow():
    keys = jnp.arange(24.0)
    x = jnp.linspace(0, 1, 256)

    def f(keys, x):
        def body(c, k):
            r23 = jnp.exp(x * 0.23)          # invariant, recomputed/stored
            return c + r23.sum() * k, None
        out, _ = jax.lax.scan(body, jnp.float32(0), keys)
        return out
    return f, (keys, x)


def _loop_invariant_pow_fixed():
    keys = jnp.arange(24.0)
    x = jnp.linspace(0, 1, 256)

    def f(keys, x):
        r23 = jnp.exp(x * 0.23)              # hoisted + memoized
        s = r23.sum()
        def body(c, k):
            return c + s * k, None
        out, _ = jax.lax.scan(body, jnp.float32(0), keys)
        return out
    return f, (keys, x)


def _dead_intermediates():
    x = jnp.linspace(0, 1, 512)

    def f(x):
        acc = jnp.float32(0)
        w = x
        for i in range(16):
            w = jnp.exp(x) * (i + 1)          # stored, never loaded
            acc = acc + x.sum()
        return acc, w
    return f, (x,)


def _clear_then_overwrite():
    vals = jnp.arange(512.0)

    def f(vals):
        def body(c, v):
            buf = jnp.zeros(128)              # "clear()"
            buf = v * jnp.ones(128)           # fully overwritten, zeros dead
            return c + buf.sum(), None
        out, _ = jax.lax.scan(body, jnp.float32(0), vals[:16])
        return out
    return f, (vals,)


def _repeated_max_scan():
    segs = jnp.sort(jax.random.uniform(jax.random.PRNGKey(0), (256,)))
    qs = jnp.linspace(0, 1, 32)

    def f(qs, segs):
        def body(c, q):
            n = jnp.sum(segs < q)             # full scan per query
            return c + n, None
        out, _ = jax.lax.scan(body, jnp.int32(0), qs)
        return out
    return f, (qs, segs)


def _repeated_max_scan_fixed():
    segs = jnp.sort(jax.random.uniform(jax.random.PRNGKey(0), (256,)))
    qs = jnp.linspace(0, 1, 32)

    def f(qs, segs):
        return jnp.searchsorted(segs, qs).sum()   # sorted early-exit
    return f, (qs, segs)


def _missed_cse():
    x = jnp.linspace(0, 1, 512)

    def f(x):
        a = jnp.tanh(x * 3.0).sum()
        b = jnp.tanh(x * 3.0).sum()          # identical expression
        return a + b
    return f, (x,)


def _dense_reinit():
    idx = jnp.arange(8)

    def f(idx):
        def body(c, i):
            dense = jnp.zeros(1024)           # dense array for sparse data
            dense = dense.at[i].set(1.0)
            return c + dense.sum(), None
        out, _ = jax.lax.scan(body, jnp.float32(0), idx)
        return out
    return f, (idx,)


def _astype_roundtrip():
    x = jnp.linspace(1, 2, 2048, dtype=jnp.float32)

    def f(x):
        y = x
        for _ in range(8):
            y = (y * 2.0) / 2.0                 # value-identical roundtrip
        return y.sum()
    return f, (x,)


def _recompute_softmax():
    logits = jax.random.normal(jax.random.PRNGKey(1), (64,))
    steps = jnp.arange(16)

    def f(steps, logits):
        def body(c, t):
            p = jax.nn.softmax(logits)        # unchanged input every iter
            return c + p[0] * t, None
        out, _ = jax.lax.scan(body, jnp.float32(0), steps)
        return out
    return f, (steps, logits)


def _regather_embedding():
    table = jax.random.normal(jax.random.PRNGKey(2), (128, 16))
    toks = jnp.zeros(32, jnp.int32)           # same row every time

    def f(toks, table):
        def body(c, t):
            row = table[t]                    # same row re-gathered
            return c + row.sum(), None
        out, _ = jax.lax.scan(body, jnp.float32(0), toks)
        return out
    return f, (toks, table)


def _adjacent_shift():
    """Ant#53637 analogue: repeated element SHIFTS — same values move to
    ADJACENT locations. JXPerf's element-granular watchpoints miss this
    class (paper §6); JXPerf-JAX's buffer-granular watchpoints catch it
    (repeated reads of the shifted-but-unchanged container) — a documented
    improvement of the adaptation."""
    x = jnp.arange(64.0)

    def f(x):
        def body(c, _):
            return jnp.roll(c, 1), None       # values move, never repeat in place
        out, _ = jax.lax.scan(body, x, None, length=24)
        return out.sum()
    return f, (x,)


def _zero_accumulate():
    zeros = jnp.zeros(32)
    x = jnp.linspace(0, 1, 256)

    def f(zeros, x):
        def body(c, z):
            return c + z, None                # accumulates nothing
        out, _ = jax.lax.scan(body, x[:32], zeros)
        return out.sum()
    return f, (zeros, x)


CORPUS: List[Bug] = [
    Bug("linear_search_contains", "silent_load", _linear_search,
        _linear_search_fixed, True, "Apache Collections#588 analogue"),
    Bug("loop_invariant_pow", "silent_store", _loop_invariant_pow,
        _loop_invariant_pow_fixed, True, "NPB-3.0 IS analogue"),
    Bug("dead_intermediates", "dead_store", _dead_intermediates, None, True,
        "Dacapo bloat analogue"),
    Bug("clear_then_overwrite", "dead_store", _clear_then_overwrite, None,
        True, "FindBugs Frame.copyFrom analogue"),
    Bug("repeated_segment_scan", "silent_load", _repeated_max_scan,
        _repeated_max_scan_fixed, True, "JFreeChart getExceptionSegmentCount analogue"),
    Bug("missed_cse", "silent_store", _missed_cse, None, True,
        "scimark.fft code-gen analogue"),
    Bug("dense_reinit", "silent_store", _dense_reinit, None, True,
        "dense-array-for-sparse-data analogue"),
    Bug("astype_roundtrip", "silent_store", _astype_roundtrip, None, True,
        "value-identical convert chain"),
    Bug("recompute_softmax", "silent_store", _recompute_softmax, None, True,
        "MemoizeIt-class redundancy"),
    Bug("regather_embedding", "silent_load", _regather_embedding, None, True,
        "cacheable-data analogue"),
    Bug("adjacent_shift", "silent_load", _adjacent_shift, None, True,
        "Ant#53637 analogue — JXPerf misses; buffer-granular JXPerf-JAX detects"),
    Bug("zero_accumulate", "silent_store", _zero_accumulate, None, True,
        "useless value assignment analogue"),
]
