"""Tab. 1 / Fig. 6 analogue: detection overhead vs native execution.

Numbers, honestly separated (DESIGN.md §2):
  * Tier-3 (production mode): % step-time overhead of the detectors on a
    real jitted train step — the analogue of the paper's 7% claim;
  * Tier-1 (analysis mode): interpreter slowdown vs the jitted step at
    several sampling periods — expensive by construction (software
    watchpoints), reported for completeness;
  * Serving: batched prefill vs the seed's token-by-token cache fill,
    the serve-side Tier-3 detectors' overhead on the engine's decode
    loop, and speculative decoding (`serve_spec_*`): decode tok/s of
    draft+verify against plain one-token decode on a repetitive-prompt
    workload, with accept rates reported per drafter.

Every row can also run at toy sizes (``run(toy=True)``) — the CI smoke
(`tests/test_benchmarks.py`) executes the full row set once so a broken
row (the PR-3 `serve_paged_*` bit-rot failure mode) fails loudly
instead of silently vanishing from the report.

Run as a script (``python benchmarks/overhead.py [--toy]``) the row set
is also written to ``benchmarks/BENCH_<git-rev>.json`` with machine
info, so successive revisions leave comparable artifacts;
``benchmarks/bench_diff.py`` diffs two such files inside a noise band
(the CI bench-diff job runs it against the latest committed baseline).
"""
from __future__ import annotations

import dataclasses
import json
import os
import subprocess
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.configs.base import ProfilerConfig, TrainConfig
from repro.core.detectors import ServingDetectors, TrainingDetectors
from repro.core.interpreter import profile_fn
from repro.models.zoo import build_model
from repro.launch.fleet import _run_policy
from repro.serve.decode import StepCache, make_serve_step
from repro.serve.engine import Request, ServeEngine
from repro.serve.spec import NGramDrafter, ReplayDrafter
from repro.serve.workload import make_trace
from repro.train import state as TS
from repro.train.step import make_train_step


def _time(fn, n=5):
    fn()                                    # warmup
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n


def run(toy: bool = False):
    rows = []
    cfg = registry.get_config("qwen3-1.7b").smoke()
    model = build_model(cfg)
    tc = TrainConfig(total_steps=100, warmup_steps=1)
    step = jax.jit(make_train_step(model, tc))
    state = TS.create(model, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 64), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}

    holder = {"state": state}

    def native():
        s, m = step(holder["state"], batch)
        jax.block_until_ready(m["loss"])
        holder["state"] = s
    t_native = _time(native)
    rows.append(("overhead.native_step", t_native * 1e6, "baseline"))

    det = TrainingDetectors(ProfilerConfig(enabled=True), leaves_per_step=4)
    stepno = [0]
    # warm the silent_compare jit cache over every leaf shape first (one-off
    # compilation; production runs amortize this to zero)
    from repro.kernels import ops as _ops
    for leaf in jax.tree_util.tree_leaves(holder["state"].params):
        _ops.silent_fraction(leaf, leaf, tol=det.tol)

    def with_tier3():
        before = holder["state"].params
        s, m = step(holder["state"], batch)
        jax.block_until_ready(m["loss"])
        det.on_step(stepno[0], before, s.params)
        det.on_batch(stepno[0], batch)
        stepno[0] += 1
        holder["state"] = s
    for _ in range(2 if toy else 6):  # populate reservoir + remaining jits
        with_tier3()
    t3 = _time(with_tier3, n=2 if toy else 10)
    rows.append(("overhead.tier3_step", t3 * 1e6,
                 f"slowdown={t3/t_native:.3f}x"))

    # Tier-1: smaller forward-only subject, per period
    fwd = lambda toks: model.forward(  # noqa: E731
        jax.tree_util.tree_map(lambda x: x, holder["state"].params), toks)[0].sum()
    small = toks[:1, :8 if toy else 16]
    for period in (1000, 5000, 10000):
        pc = ProfilerConfig(enabled=True, period=period)
        t0 = time.perf_counter()
        profile_fn(fwd, small, cfg=pc)
        t1 = time.perf_counter() - t0
        rows.append((f"overhead.tier1_p{period}", t1 * 1e6,
                     f"vs_native={t1/t_native:.0f}x"))

    # Tier-1 multi-epoch: trace→replay vs epoch-by-epoch re-interpretation
    # (DESIGN.md §2). Same seed -> the replayed event stream is the
    # recorded stream, so the profiles must be identical bit for bit.
    pc = ProfilerConfig(enabled=True, period=5000)
    epochs = 3 if toy else 8
    t0 = time.perf_counter()
    rep_re = profile_fn(fwd, small, cfg=pc, epochs=epochs, replay=False)
    t_re = time.perf_counter() - t0
    t0 = time.perf_counter()
    rep_rp = profile_fn(fwd, small, cfg=pc, epochs=epochs, replay=True)
    t_rp = time.perf_counter() - t0
    identical = (rep_re == rep_rp
                 and rep_re.fractions() == rep_rp.fractions())
    rows.append(("overhead.tier1_reinterp_e8", t_re * 1e6, "baseline"))
    rows.append(("overhead.tier1_replay_e8", t_rp * 1e6,
                 f"speedup={t_re/t_rp:.1f}x|identical={identical}"))
    rows.extend(run_serve(toy))
    rows.extend(run_spec(toy))
    rows.extend(run_kernels(toy))
    rows.extend(run_fleet(toy))
    rows.extend(run_objects(toy))
    rows.extend(run_matrix(toy))
    return rows


def run_matrix(toy: bool = False):
    """Zoo-matrix tier: what a matrix train cell costs, and what the
    top-ranked fix bought.

    ``matrix_*``: per-cell profiled train step (tier-3 detectors, the
    billing ``launch/matrix.py`` attaches to every train cell) vs the
    unprofiled jitted step, for two zoo configs the matrix flagged —
    the per-cell overhead must stay inside the Tier-3 production
    envelope. ``moe_dispatch_*``: train step under the GShard one-hot
    einsum dispatch (dead expert rows, the pre-fix baseline) vs the
    capacity-mask scatter dispatch the matrix ranking landed."""
    from repro.data.synthetic import batch_at
    from repro.kernels import ops as _ops

    rows = []

    def mk_step(cfg):
        model = build_model(cfg)
        tc = TrainConfig(total_steps=100, warmup_steps=1)
        step = jax.jit(make_train_step(model, tc))
        state = TS.create(model, jax.random.PRNGKey(0))
        b = batch_at(cfg, 2, 32, seed=0, step=0)
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        holder = {"state": state}

        def native():
            s, m = step(holder["state"], batch)
            jax.block_until_ready(m["loss"])
            holder["state"] = s
        return native, holder, b

    nt = 2 if toy else 5
    for arch, short in (("granite-moe-3b-a800m", "granite_moe"),
                        ("whisper-large-v3", "whisper")):
        cfg = registry.get_config(arch).smoke()
        native, holder, b = mk_step(cfg)
        t_nat = _time(native, n=nt)
        rows.append((f"overhead.matrix_{short}_native_step", t_nat * 1e6,
                     "baseline"))
        det = TrainingDetectors(ProfilerConfig(enabled=True),
                                leaves_per_step=4)
        for leaf in jax.tree_util.tree_leaves(holder["state"].params):
            _ops.silent_fraction(leaf, leaf, tol=det.tol)  # warm jits
        stepno = [0]

        def profiled():
            before = holder["state"].params
            det.on_batch(stepno[0], b)
            native()
            det.on_step(stepno[0], before, holder["state"].params)
            stepno[0] += 1
        for _ in range(2):      # populate reservoir
            profiled()
        t_prof = _time(profiled, n=nt)
        rows.append((f"overhead.matrix_{short}_profiled_step",
                     t_prof * 1e6, f"slowdown={t_prof/t_nat:.3f}x"))

    for arch, short in (("granite-moe-3b-a800m", "granite_moe"),
                        ("llama4-scout-17b-a16e", "llama4")):
        base = registry.get_config(arch).smoke()
        ts = {}
        for disp in ("einsum", "scatter"):
            cfg = dataclasses.replace(
                base, moe=dataclasses.replace(base.moe, dispatch=disp))
            native, _, _ = mk_step(cfg)
            ts[disp] = _time(native, n=nt)
        rows.append((f"overhead.moe_dispatch_einsum_{short}",
                     ts["einsum"] * 1e6, "baseline (one-hot dispatch)"))
        rows.append((f"overhead.moe_dispatch_scatter_{short}",
                     ts["scatter"] * 1e6,
                     f"speedup={ts['einsum']/ts['scatter']:.2f}x"))
    return rows


def run_objects(toy: bool = False):
    """Object tier (DESIGN.md § Object tier): registry bookkeeping on
    the serving hot path, and the replica scan.

    The registry inserts one dict entry per page alloc and nothing per
    decode step, so the decode-tick slowdown with the registry attached
    must sit inside the Tier-3 production envelope (<= 1.07x — the
    paper's 7% claim is the budget the object tier shares). The scan row
    is analysis-time (off the serving path): content-hash every live
    object once, sampled above 64 KB."""
    from repro.core.objects import ObjectRegistry
    from repro.core.replicas import ReplicaDetector

    rows = []
    cfg = registry.get_config("qwen3-1.7b").smoke()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, P = 4, 16 if toy else 32
    max_len = 64 if toy else 256
    step_cache = StepCache(model)
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab_size, size=P).astype(np.int32)
               for _ in range(B)]

    def mk(reg):
        eng = ServeEngine(model, params, num_slots=B, max_len=max_len,
                          kv_layout="paged", step_cache=step_cache,
                          registry=reg, owner="bench")
        for b in range(B):
            eng.submit(Request(rid=f"r{b}", tokens=prompts[b].copy(),
                               max_new_tokens=max_len))
        eng._admit()
        for _ in range(2 if toy else 4):        # warm jits
            eng._decode_tick()
        return eng

    nt = 2 if toy else 10
    t_off = _time(mk(None)._decode_tick, n=nt)
    reg = ObjectRegistry()
    eng = mk(reg)
    t_on = _time(eng._decode_tick, n=nt)
    rows.append(("overhead.object_decode_step", t_on * 1e6,
                 f"slowdown={t_on/t_off:.3f}x|envelope<=1.07"))
    t_scan = _time(lambda: ReplicaDetector(reg).scan(), n=nt)
    scan = ReplicaDetector(reg).scan()
    rows.append(("overhead.object_replica_scan", t_scan * 1e6,
                 f"objects={len(reg)}|groups={len(scan.findings)}"))
    return rows


def run_fleet(toy: bool = False):
    """Fleet routing A/B: the same duplicated-prefix trace through two
    replicas under random vs prefix-aware routing (launch/fleet.py).
    Both policies run the trace on fresh fleets sharing one `StepCache`
    (identical compiled steps), warmup pass first, so the percentiles
    compare routing and nothing else. TTFT/TPOT are wall-clock; the
    notes carry the deterministic side — prefix-hit fraction and the
    fleet-level Def.-3 ``fleet_silent_prefix_load`` bytes each policy
    re-paid for prefixes already resident on the other replica."""
    rows = []
    cfg = registry.get_config("qwen3-1.7b").smoke()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    trace = make_trace(n_requests=8 if toy else 16,
                       vocab_size=cfg.vocab_size, seed=0,
                       arrival="poisson", rate=0.3, prompt_len=(48, 48),
                       gen_len=(4, 4), dup_rate=0.8, n_prefixes=1,
                       prefix_len=40)
    max_len = trace.max_prompt_len + trace.max_new_tokens + 1
    step_cache = StepCache(model)
    out = {}
    for policy in ("random", "prefix"):
        fleet, _ = _run_policy(model, params, trace, policy=policy,
                               replicas=2, slots=2, max_len=max_len,
                               page_size=8, num_pages=None, seed=0,
                               step_cache=step_cache)
        out[policy] = (fleet.latency_summary(), fleet.prefix_hit_fraction(),
                       fleet.fleet_waste_bytes())
    (lr, hr, wr), (lp, hp, wp) = out["random"], out["prefix"]
    rows.append(("overhead.fleet_random_ttft_p50", lr["ttft_p50"] * 1e6,
                 f"baseline|hit_frac={hr:.2f}"))
    rows.append(("overhead.fleet_random_ttft_p99", lr["ttft_p99"] * 1e6,
                 f"waste_bytes={wr:.0f}"))
    rows.append(("overhead.fleet_random_tpot", lr["tpot_p50"] * 1e6,
                 "baseline (us/decode tok)"))
    rows.append(("overhead.fleet_prefix_ttft_p50", lp["ttft_p50"] * 1e6,
                 f"speedup={lr['ttft_p50'] / max(lp['ttft_p50'], 1e-9):.2f}x"
                 f"|hit_frac={hp:.2f}"))
    rows.append(("overhead.fleet_prefix_ttft_p99", lp["ttft_p99"] * 1e6,
                 f"speedup={lr['ttft_p99'] / max(lp['ttft_p99'], 1e-9):.2f}x"))
    rows.append(("overhead.fleet_prefix_tpot", lp["tpot_p50"] * 1e6,
                 f"waste_bytes={wp:.0f}_vs_random={wr:.0f}"))
    return rows


def run_kernels(toy: bool = False):
    """Pallas serving-kernel tier: paged decode / fused prefill / fused
    width-k verify against the reference scatter-gather-mask
    compositions, on a hostile page table (out-of-order pages, partially
    filled last page).

    Wall time on CPU runs the kernels in *interpret mode* (a Python
    emulation, orders of magnitude slower than the compiled TPU kernel)
    so the honest speed number is the modeled HBM-byte ratio from
    ``roofline.ideal_paged_attention_bytes``: reference path = gather
    materialization (view write + read-back), kernel path = page-granular
    in-kernel gather. The notes also carry the parity/counter checks so
    a silently-diverging kernel fails the CI row smoke."""
    from repro.kernels import ops as kops
    from repro.kernels import ref as kref
    from repro.kernels.flash_prefill import paged_window_attention
    from repro.kernels.paged_attention import paged_decode_attention
    from repro.launch.roofline import ideal_paged_attention_bytes

    rows = []
    interp = kops._pallas_interpret()
    if toy:
        B, Hq, Hkv, D, ps, M = 2, 4, 2, 16, 8, 4
    else:
        B, Hq, Hkv, D, ps, M = 4, 8, 4, 32, 16, 8
    rng = np.random.RandomState(0)
    pool_pages = B * M + 2
    pool_k = jnp.asarray(rng.randn(pool_pages, ps, Hkv, D), jnp.float32)
    pool_v = jnp.asarray(rng.randn(pool_pages, ps, Hkv, D), jnp.float32)
    # hostile table: out-of-order pages per slot, partially filled last
    # page (idx not a page multiple), unmapped tail entries
    perm = rng.permutation(pool_pages - 1)[:B * M].reshape(B, M)
    pt = np.asarray(perm, np.int32)
    idx = np.zeros(B, np.int32)
    for b in range(B):
        used = rng.randint(1, M)                # pages actually holding rows
        pt[b, used:] = -1
        idx[b] = used * ps - rng.randint(1, ps)  # partial last page
    pt = jnp.asarray(pt)
    idx = jnp.asarray(idx)
    mapped = int((np.asarray(pt) >= 0).sum())

    q1 = jnp.asarray(rng.randn(B, 1, Hq, D), jnp.float32)
    kn = jnp.asarray(rng.randn(B, 1, Hkv, D), jnp.float32)
    vn = jnp.asarray(rng.randn(B, 1, Hkv, D), jnp.float32)

    def decode_ref(q, k_new, v_new, ck, cv, pt, idx):
        cnt = kref.paged_store_counts(ck, cv, k_new, v_new, pt, idx,
                                      tol=kops.COUNTER_TOL)
        ck, cv = kref.paged_update(ck, cv, k_new, v_new, pt, idx)
        gk, valid = kref.paged_gather(ck, pt)
        gv, _ = kref.paged_gather(cv, pt)
        out = kref.attention_ref(q, gk, gv, causal=True, q_offset=idx,
                                 kv_len=idx + 1, kv_valid=valid)
        return out, cnt

    j_ref = jax.jit(decode_ref)
    j_pal = jax.jit(partial(paged_decode_attention, interpret=interp))
    o_ref, c_ref = j_ref(q1, kn, vn, pool_k, pool_v, pt, idx)
    o_pal, _, c_pal = j_pal(q1, kn, vn, pool_k, pool_v, pt, idx)
    err = float(jnp.max(jnp.abs(o_ref - o_pal)))
    cnt_ok = bool(jnp.array_equal(c_ref, c_pal))
    n_t = 2 if toy else 3
    t_ref = _time(lambda: jax.block_until_ready(
        j_ref(q1, kn, vn, pool_k, pool_v, pt, idx)), n=n_t)
    t_pal = _time(lambda: jax.block_until_ready(
        j_pal(q1, kn, vn, pool_k, pool_v, pt, idx)), n=n_t)
    kwargs = dict(batch=B, q_len=1, mapped_pages=mapped, max_pages=M,
                  page_size=ps, num_heads=Hq, num_kv_heads=Hkv,
                  head_dim=D, kv_bytes=4.0, act_bytes=4.0)
    hbm = (ideal_paged_attention_bytes(materialize=True, **kwargs)
           / ideal_paged_attention_bytes(materialize=False, **kwargs))
    rows.append(("overhead.kernel_paged_decode_ref", t_ref * 1e6,
                 "baseline (gather materialization)"))
    rows.append(("overhead.kernel_paged_decode_pallas", t_pal * 1e6,
                 f"modeled_hbm_speedup={hbm:.2f}x|max_err={err:.1e}"
                 f"|counters_match={cnt_ok}"
                 + ("|interpret" if interp else "")))

    # fused prefill: window store into an EMPTY slot region (the admit
    # path), ref = paged_window_ref
    S = ps if toy else 2 * ps
    qw = jnp.asarray(rng.randn(B, S, Hq, D), jnp.float32)
    kw = jnp.asarray(rng.randn(B, S, Hkv, D), jnp.float32)
    vw = jnp.asarray(rng.randn(B, S, Hkv, D), jnp.float32)
    pt0 = np.full((B, M), -1, np.int32)
    need = -(-S // ps)
    pt0[:, :need] = rng.permutation(pool_pages - 1)[:B * need].reshape(B, need)
    pt0 = jnp.asarray(pt0)
    idx0 = jnp.zeros(B, jnp.int32)
    j_pw = jax.jit(partial(paged_window_attention, store=True,
                           interpret=interp))
    j_pw_ref = jax.jit(partial(kref.paged_window_ref, store=True,
                               tol=kops.COUNTER_TOL))
    ow, _, cw, pk1, pv1 = j_pw(qw, kw, vw, pool_k, pool_v, pt0, idx0)
    owr, pk1r, pv1r, cwr = j_pw_ref(qw, kw, vw, pool_k, pool_v, pt0, idx0)
    perr = float(jnp.max(jnp.abs(ow - owr)))
    pool_ok = bool(jnp.array_equal(pk1, pk1r) and jnp.array_equal(pv1, pv1r))
    pcnt_ok = bool(jnp.array_equal(cw, cwr))
    t_pw = _time(lambda: jax.block_until_ready(
        j_pw(qw, kw, vw, pool_k, pool_v, pt0, idx0)), n=n_t)
    rows.append(("overhead.kernel_prefill_pallas", t_pw * 1e6,
                 f"max_err={perr:.1e}|pool_equal={pool_ok}"
                 f"|counters_match={pcnt_ok}"))

    # fused width-(k+1) verify on the populated hostile table: store mode
    # (overwrite) parity + defer mode must count zero stores
    K1 = 4
    qv = jnp.asarray(rng.randn(B, K1, Hq, D), jnp.float32)
    kv = jnp.asarray(rng.randn(B, K1, Hkv, D), jnp.float32)
    vv = jnp.asarray(rng.randn(B, K1, Hkv, D), jnp.float32)
    ov, _, cv_, _, _ = j_pw(qv, kv, vv, pool_k, pool_v, pt, idx)
    ovr, _, _, cvr = j_pw_ref(qv, kv, vv, pool_k, pool_v, pt, idx)
    verr = float(jnp.max(jnp.abs(ov - ovr)))
    vcnt_ok = bool(jnp.array_equal(cv_, cvr))
    j_defer = jax.jit(partial(paged_window_attention, store=False,
                              interpret=interp))
    _, _, cd, _, _ = j_defer(qv, kv, vv, pool_k, pool_v, pt, idx)
    defer_ok = bool(jnp.all(cd == 0))
    t_v = _time(lambda: jax.block_until_ready(
        j_pw(qv, kv, vv, pool_k, pool_v, pt, idx)), n=n_t)
    rows.append(("overhead.kernel_verify_pallas", t_v * 1e6,
                 f"max_err={verr:.1e}|counters_match={vcnt_ok}"
                 f"|defer_zero_stores={defer_ok}"))
    return rows


def _git_rev() -> str:
    try:
        return subprocess.check_output(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            text=True).strip()
    except Exception:
        return "unknown"


def _machine_info() -> dict:
    import platform
    return {
        "platform": platform.platform(),
        "python": platform.python_version(),
        "cpu_count": os.cpu_count(),
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
    }


def emit_json(rows, toy: bool, path: str = None, findings=None) -> str:
    """Write the row set to ``BENCH_<rev>.json`` (the comparable artifact
    ``bench_diff.py`` consumes) and return the path.

    ``findings``: optional per-kind waste-finding counts (e.g. from
    ``launch/lint.py``'s tier-0 profile) — ``bench_diff.py`` fails on
    count increases the same way it fails on latency regressions."""
    rev = _git_rev()
    doc = {
        "schema": 1,
        "rev": rev,
        "toy": bool(toy),
        "machine": _machine_info(),
        "rows": [{"name": n, "us_per_call": float(us), "note": note}
                 for n, us, note in rows],
    }
    if findings is not None:
        doc["findings"] = {str(k): int(v) for k, v in findings.items()}
    if path is None:
        path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                            f"BENCH_{rev}.json")
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    return path


def run_serve(toy: bool = False):
    """Serving-tier entries: prefill speedup + detector decode overhead."""
    rows = []
    cfg = registry.get_config("qwen3-1.7b").smoke()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, P = 4, 16 if toy else 32
    max_len = 64 if toy else 256    # engine cache: slots stay live a while
    prompts = jax.random.randint(jax.random.PRNGKey(2), (B, P), 0,
                                 cfg.vocab_size)
    # prefill comparison cache sized to the workload (prompt + headroom)
    cache0 = model.init_cache(params, B, 64, kv_dtype=jnp.float32)

    # batched prefill (one forward fills the cache) vs the seed's
    # token-by-token teacher-forced loop through the decode step
    serve_step = jax.jit(make_serve_step(model))
    prefill = jax.jit(model.prefill)

    def tokenloop():
        c = cache0
        for t in range(P):
            nxt, c = serve_step(params, c, prompts[:, t:t + 1])
        jax.block_until_ready(nxt)

    def batched():
        lg, c = prefill(params, cache0, prompts)
        jax.block_until_ready(lg)
    t_loop = _time(tokenloop, n=1 if toy else 3)
    t_batch = _time(batched, n=1 if toy else 3)
    rows.append(("overhead.serve_prefill_tokenloop", t_loop * 1e6,
                 "baseline"))
    rows.append(("overhead.serve_prefill_batched", t_batch * 1e6,
                 f"speedup={t_loop/t_batch:.1f}x"))

    # serve-side Tier-3 detector overhead on the continuous decode loop
    def mk_engine(det, kv="dense"):
        eng = ServeEngine(model, params, num_slots=B, max_len=max_len,
                          detectors=det, kv_layout=kv)
        rng = np.random.RandomState(0)
        for b in range(B):
            eng.submit(Request(
                rid=f"r{b}",
                tokens=rng.randint(0, cfg.vocab_size, size=P).astype(np.int32),
                max_new_tokens=max_len))       # slots stay live throughout
        eng._admit()
        for _ in range(2 if toy else 4):        # warm jits + reservoir
            eng._decode_tick()
        return eng

    nt = 2 if toy else 10
    eng0 = mk_engine(None)
    t_plain = _time(eng0._decode_tick, n=nt)
    eng3 = mk_engine(ServingDetectors(ProfilerConfig(enabled=True)))
    t_det = _time(eng3._decode_tick, n=nt)
    rows.append(("overhead.serve_decode_step", t_plain * 1e6, "baseline"))
    rows.append(("overhead.serve_tier3_step", t_det * 1e6,
                 f"slowdown={t_det/t_plain:.3f}x"))

    # paged KV heap: decode tick vs dense, prefix-hit prefill speedup,
    # and detector overhead in paged mode — the serving-side perf
    # trajectory the detect→optimize loop opened
    engp = mk_engine(None, kv="paged")
    t_paged = _time(engp._decode_tick, n=nt)
    rows.append(("overhead.serve_paged_decode_step", t_paged * 1e6,
                 f"vs_dense={t_paged/t_plain:.3f}x"))
    engp3 = mk_engine(ServingDetectors(ProfilerConfig(enabled=True)),
                      kv="paged")
    t_paged_det = _time(engp3._decode_tick, n=nt)
    rows.append(("overhead.serve_paged_tier3_step", t_paged_det * 1e6,
                 f"slowdown={t_paged_det/t_paged:.3f}x"))

    # prefix-hit prefill: a duplicated prompt's admission re-pays the
    # whole prompt in dense mode but only the final position in paged
    # mode (the rest maps in from the prefix cache). Measured on the
    # engine's own prefill clock (the jitted prefill dispatch; page-table
    # pushes are host-side bookkeeping outside the hot call).
    dup = np.random.RandomState(1).randint(
        0, cfg.vocab_size, size=P).astype(np.int32)

    def dup_prefill_time(kv, n=2 if toy else 6):
        eng = ServeEngine(model, params, num_slots=2, max_len=max_len,
                          kv_layout=kv)
        eng.submit(Request(rid="donor", tokens=dup, max_new_tokens=1))
        eng.run()                               # donor registers P tokens
        def one():
            eng.submit(Request(rid=f"d{eng.step_no}", tokens=dup,
                               max_new_tokens=1))
            eng._admit()
            eng.step_no += 1
        one()                                   # warm the jit
        t0 = eng.stats["prefill_s"]
        for _ in range(n):
            one()
        return (eng.stats["prefill_s"] - t0) / n, eng.stats
    t_dense_admit, _ = dup_prefill_time("dense")
    t_paged_admit, stats_p = dup_prefill_time("paged")
    hit_frac = (stats_p["prefix_hit_tokens"]
                / max(stats_p["prefill_tokens"], 1))
    rows.append(("overhead.serve_paged_prefill_hit", t_paged_admit * 1e6,
                 f"speedup={t_dense_admit/t_paged_admit:.1f}x"
                 f"|hit_frac={hit_frac:.2f}"))
    return rows


def run_spec(toy: bool = False):
    """Speculative decoding: decode tok/s of draft+verify vs plain
    one-token decode on a repetitive-prompt workload (each prompt tiles
    a short block, the canonical high-accept traffic).

    Every engine serves TWO request waves; the second wave (warm jits,
    and — for the n-gram drafter — a populated self-speculation corpus)
    is what is measured, so the numbers are steady-state µs per emitted
    decode token, not compile time. The replay-oracle row is the
    mechanism's upper bound (accept-rate 1.0); the n-gram row is what a
    drafter earns on repeating traffic; the rollback row shows the
    paged no-dead-store commit costs nothing extra."""
    rows = []
    cfg = registry.get_config("qwen3-1.7b").smoke()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B = 2 if toy else 4
    P = 16 if toy else 32
    G = 8 if toy else 24
    rng = np.random.RandomState(0)
    prompts = []
    for b in range(B):
        block = rng.randint(0, cfg.vocab_size, size=4).astype(np.int32)
        prompts.append(np.tile(block, -(-P // 4))[:P])

    def serve(kv, drafter, rollback=True):
        eng = ServeEngine(model, params, num_slots=B, max_len=P + G + 1,
                          kv_layout=kv, drafter=drafter, spec_k=4,
                          spec_rollback=rollback)
        outs = None
        for wave in range(2):
            for b in range(B):
                eng.submit(Request(rid=f"w{wave}b{b}",
                                   tokens=prompts[b].copy(),
                                   max_new_tokens=G))
            before = dict(eng.stats)
            eng.run(max_steps=2000)
            if wave == 0:
                outs = [list(eng.finished[f"w0b{b}"].generated)
                        for b in range(B)]
        st = eng.stats
        # the drafter's host time is part of the decode cost (numbers,
        # honestly separated): a drafter whose proposals cost more than
        # the verify saves must show up as a slowdown here
        dt = (st["decode_s"] + st["draft_s"]
              - before["decode_s"] - before["draft_s"])
        dtok = st["decode_tokens"] - before["decode_tokens"]
        us_tok = dt / max(dtok, 1) * 1e6
        prop = st["draft_proposed"] - before["draft_proposed"]
        acc = st["draft_accepted"] - before["draft_accepted"]
        return outs, us_tok, (acc / prop if prop else 0.0)

    out0, t_plain, _ = serve("dense", None)
    rows.append(("overhead.serve_spec_plain_decode", t_plain,
                 "baseline (us/decode tok)"))
    seqs = [np.concatenate([prompts[b], np.asarray(out0[b], np.int32)])
            for b in range(B)]
    _, t_or, a_or = serve("dense", ReplayDrafter(seqs))
    rows.append(("overhead.serve_spec_oracle_decode", t_or,
                 f"speedup={t_plain/t_or:.1f}x|accept={a_or:.2f}"))
    _, t_ng, a_ng = serve("dense", NGramDrafter())
    rows.append(("overhead.serve_spec_ngram_decode", t_ng,
                 f"speedup={t_plain/t_ng:.1f}x|accept={a_ng:.2f}"))
    _, t_rb, a_rb = serve("paged", ReplayDrafter(seqs), rollback=True)
    rows.append(("overhead.serve_spec_rollback_decode", t_rb,
                 f"speedup={t_plain/t_rb:.1f}x|accept={a_rb:.2f}"))
    return rows


if __name__ == "__main__":
    import sys
    _toy = "--toy" in sys.argv
    _rows = run(toy=_toy)
    for _n, _us, _note in _rows:
        print(f"{_n},{_us:.1f},{_note}")
    _out = [a for a in sys.argv[1:] if a != "--toy"]
    print("wrote", emit_json(_rows, _toy, path=_out[0] if _out else None))
