"""Tab. 1 / Fig. 6 analogue: detection overhead vs native execution.

Numbers, honestly separated (DESIGN.md §2):
  * Tier-3 (production mode): % step-time overhead of the detectors on a
    real jitted train step — the analogue of the paper's 7% claim;
  * Tier-1 (analysis mode): interpreter slowdown vs the jitted step at
    several sampling periods — expensive by construction (software
    watchpoints), reported for completeness;
  * Serving: batched prefill vs the seed's token-by-token cache fill,
    the serve-side Tier-3 detectors' overhead on the engine's decode
    loop, and speculative decoding (`serve_spec_*`): decode tok/s of
    draft+verify against plain one-token decode on a repetitive-prompt
    workload, with accept rates reported per drafter.

Every row can also run at toy sizes (``run(toy=True)``) — the CI smoke
(`tests/test_benchmarks.py`) executes the full row set once so a broken
row (the PR-3 `serve_paged_*` bit-rot failure mode) fails loudly
instead of silently vanishing from the report.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.configs.base import ProfilerConfig, TrainConfig
from repro.core.detectors import ServingDetectors, TrainingDetectors
from repro.core.interpreter import profile_fn
from repro.models.zoo import build_model
from repro.serve.decode import make_serve_step
from repro.serve.engine import Request, ServeEngine
from repro.serve.spec import NGramDrafter, ReplayDrafter
from repro.train import state as TS
from repro.train.step import make_train_step


def _time(fn, n=5):
    fn()                                    # warmup
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n


def run(toy: bool = False):
    rows = []
    cfg = registry.get_config("qwen3-1.7b").smoke()
    model = build_model(cfg)
    tc = TrainConfig(total_steps=100, warmup_steps=1)
    step = jax.jit(make_train_step(model, tc))
    state = TS.create(model, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 64), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}

    holder = {"state": state}

    def native():
        s, m = step(holder["state"], batch)
        jax.block_until_ready(m["loss"])
        holder["state"] = s
    t_native = _time(native)
    rows.append(("overhead.native_step", t_native * 1e6, "baseline"))

    det = TrainingDetectors(ProfilerConfig(enabled=True), leaves_per_step=4)
    stepno = [0]
    # warm the silent_compare jit cache over every leaf shape first (one-off
    # compilation; production runs amortize this to zero)
    from repro.kernels import ops as _ops
    for leaf in jax.tree_util.tree_leaves(holder["state"].params):
        _ops.silent_fraction(leaf, leaf, tol=det.tol)

    def with_tier3():
        before = holder["state"].params
        s, m = step(holder["state"], batch)
        jax.block_until_ready(m["loss"])
        det.on_step(stepno[0], before, s.params)
        det.on_batch(stepno[0], batch)
        stepno[0] += 1
        holder["state"] = s
    for _ in range(2 if toy else 6):  # populate reservoir + remaining jits
        with_tier3()
    t3 = _time(with_tier3, n=2 if toy else 10)
    rows.append(("overhead.tier3_step", t3 * 1e6,
                 f"slowdown={t3/t_native:.3f}x"))

    # Tier-1: smaller forward-only subject, per period
    fwd = lambda toks: model.forward(  # noqa: E731
        jax.tree_util.tree_map(lambda x: x, holder["state"].params), toks)[0].sum()
    small = toks[:1, :8 if toy else 16]
    for period in (1000, 5000, 10000):
        pc = ProfilerConfig(enabled=True, period=period)
        t0 = time.perf_counter()
        profile_fn(fwd, small, cfg=pc)
        t1 = time.perf_counter() - t0
        rows.append((f"overhead.tier1_p{period}", t1 * 1e6,
                     f"vs_native={t1/t_native:.0f}x"))

    # Tier-1 multi-epoch: trace→replay vs epoch-by-epoch re-interpretation
    # (DESIGN.md §2). Same seed -> the replayed event stream is the
    # recorded stream, so the profiles must be identical bit for bit.
    pc = ProfilerConfig(enabled=True, period=5000)
    epochs = 3 if toy else 8
    t0 = time.perf_counter()
    rep_re = profile_fn(fwd, small, cfg=pc, epochs=epochs, replay=False)
    t_re = time.perf_counter() - t0
    t0 = time.perf_counter()
    rep_rp = profile_fn(fwd, small, cfg=pc, epochs=epochs, replay=True)
    t_rp = time.perf_counter() - t0
    identical = (rep_re == rep_rp
                 and rep_re.fractions() == rep_rp.fractions())
    rows.append(("overhead.tier1_reinterp_e8", t_re * 1e6, "baseline"))
    rows.append(("overhead.tier1_replay_e8", t_rp * 1e6,
                 f"speedup={t_re/t_rp:.1f}x|identical={identical}"))
    rows.extend(run_serve(toy))
    rows.extend(run_spec(toy))
    return rows


def run_serve(toy: bool = False):
    """Serving-tier entries: prefill speedup + detector decode overhead."""
    rows = []
    cfg = registry.get_config("qwen3-1.7b").smoke()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, P = 4, 16 if toy else 32
    max_len = 64 if toy else 256    # engine cache: slots stay live a while
    prompts = jax.random.randint(jax.random.PRNGKey(2), (B, P), 0,
                                 cfg.vocab_size)
    # prefill comparison cache sized to the workload (prompt + headroom)
    cache0 = model.init_cache(params, B, 64, kv_dtype=jnp.float32)

    # batched prefill (one forward fills the cache) vs the seed's
    # token-by-token teacher-forced loop through the decode step
    serve_step = jax.jit(make_serve_step(model))
    prefill = jax.jit(model.prefill)

    def tokenloop():
        c = cache0
        for t in range(P):
            nxt, c = serve_step(params, c, prompts[:, t:t + 1])
        jax.block_until_ready(nxt)

    def batched():
        lg, c = prefill(params, cache0, prompts)
        jax.block_until_ready(lg)
    t_loop = _time(tokenloop, n=1 if toy else 3)
    t_batch = _time(batched, n=1 if toy else 3)
    rows.append(("overhead.serve_prefill_tokenloop", t_loop * 1e6,
                 "baseline"))
    rows.append(("overhead.serve_prefill_batched", t_batch * 1e6,
                 f"speedup={t_loop/t_batch:.1f}x"))

    # serve-side Tier-3 detector overhead on the continuous decode loop
    def mk_engine(det, kv="dense"):
        eng = ServeEngine(model, params, num_slots=B, max_len=max_len,
                          detectors=det, kv_layout=kv)
        rng = np.random.RandomState(0)
        for b in range(B):
            eng.submit(Request(
                rid=f"r{b}",
                tokens=rng.randint(0, cfg.vocab_size, size=P).astype(np.int32),
                max_new_tokens=max_len))       # slots stay live throughout
        eng._admit()
        for _ in range(2 if toy else 4):        # warm jits + reservoir
            eng._decode_tick()
        return eng

    nt = 2 if toy else 10
    eng0 = mk_engine(None)
    t_plain = _time(eng0._decode_tick, n=nt)
    eng3 = mk_engine(ServingDetectors(ProfilerConfig(enabled=True)))
    t_det = _time(eng3._decode_tick, n=nt)
    rows.append(("overhead.serve_decode_step", t_plain * 1e6, "baseline"))
    rows.append(("overhead.serve_tier3_step", t_det * 1e6,
                 f"slowdown={t_det/t_plain:.3f}x"))

    # paged KV heap: decode tick vs dense, prefix-hit prefill speedup,
    # and detector overhead in paged mode — the serving-side perf
    # trajectory the detect→optimize loop opened
    engp = mk_engine(None, kv="paged")
    t_paged = _time(engp._decode_tick, n=nt)
    rows.append(("overhead.serve_paged_decode_step", t_paged * 1e6,
                 f"vs_dense={t_paged/t_plain:.3f}x"))
    engp3 = mk_engine(ServingDetectors(ProfilerConfig(enabled=True)),
                      kv="paged")
    t_paged_det = _time(engp3._decode_tick, n=nt)
    rows.append(("overhead.serve_paged_tier3_step", t_paged_det * 1e6,
                 f"slowdown={t_paged_det/t_paged:.3f}x"))

    # prefix-hit prefill: a duplicated prompt's admission re-pays the
    # whole prompt in dense mode but only the final position in paged
    # mode (the rest maps in from the prefix cache). Measured on the
    # engine's own prefill clock (the jitted prefill dispatch; page-table
    # pushes are host-side bookkeeping outside the hot call).
    dup = np.random.RandomState(1).randint(
        0, cfg.vocab_size, size=P).astype(np.int32)

    def dup_prefill_time(kv, n=2 if toy else 6):
        eng = ServeEngine(model, params, num_slots=2, max_len=max_len,
                          kv_layout=kv)
        eng.submit(Request(rid="donor", tokens=dup, max_new_tokens=1))
        eng.run()                               # donor registers P tokens
        def one():
            eng.submit(Request(rid=f"d{eng.step_no}", tokens=dup,
                               max_new_tokens=1))
            eng._admit()
            eng.step_no += 1
        one()                                   # warm the jit
        t0 = eng.stats["prefill_s"]
        for _ in range(n):
            one()
        return (eng.stats["prefill_s"] - t0) / n, eng.stats
    t_dense_admit, _ = dup_prefill_time("dense")
    t_paged_admit, stats_p = dup_prefill_time("paged")
    hit_frac = (stats_p["prefix_hit_tokens"]
                / max(stats_p["prefill_tokens"], 1))
    rows.append(("overhead.serve_paged_prefill_hit", t_paged_admit * 1e6,
                 f"speedup={t_dense_admit/t_paged_admit:.1f}x"
                 f"|hit_frac={hit_frac:.2f}"))
    return rows


def run_spec(toy: bool = False):
    """Speculative decoding: decode tok/s of draft+verify vs plain
    one-token decode on a repetitive-prompt workload (each prompt tiles
    a short block, the canonical high-accept traffic).

    Every engine serves TWO request waves; the second wave (warm jits,
    and — for the n-gram drafter — a populated self-speculation corpus)
    is what is measured, so the numbers are steady-state µs per emitted
    decode token, not compile time. The replay-oracle row is the
    mechanism's upper bound (accept-rate 1.0); the n-gram row is what a
    drafter earns on repeating traffic; the rollback row shows the
    paged no-dead-store commit costs nothing extra."""
    rows = []
    cfg = registry.get_config("qwen3-1.7b").smoke()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B = 2 if toy else 4
    P = 16 if toy else 32
    G = 8 if toy else 24
    rng = np.random.RandomState(0)
    prompts = []
    for b in range(B):
        block = rng.randint(0, cfg.vocab_size, size=4).astype(np.int32)
        prompts.append(np.tile(block, -(-P // 4))[:P])

    def serve(kv, drafter, rollback=True):
        eng = ServeEngine(model, params, num_slots=B, max_len=P + G + 1,
                          kv_layout=kv, drafter=drafter, spec_k=4,
                          spec_rollback=rollback)
        outs = None
        for wave in range(2):
            for b in range(B):
                eng.submit(Request(rid=f"w{wave}b{b}",
                                   tokens=prompts[b].copy(),
                                   max_new_tokens=G))
            before = dict(eng.stats)
            eng.run(max_steps=2000)
            if wave == 0:
                outs = [list(eng.finished[f"w0b{b}"].generated)
                        for b in range(B)]
        st = eng.stats
        # the drafter's host time is part of the decode cost (numbers,
        # honestly separated): a drafter whose proposals cost more than
        # the verify saves must show up as a slowdown here
        dt = (st["decode_s"] + st["draft_s"]
              - before["decode_s"] - before["draft_s"])
        dtok = st["decode_tokens"] - before["decode_tokens"]
        us_tok = dt / max(dtok, 1) * 1e6
        prop = st["draft_proposed"] - before["draft_proposed"]
        acc = st["draft_accepted"] - before["draft_accepted"]
        return outs, us_tok, (acc / prop if prop else 0.0)

    out0, t_plain, _ = serve("dense", None)
    rows.append(("overhead.serve_spec_plain_decode", t_plain,
                 "baseline (us/decode tok)"))
    seqs = [np.concatenate([prompts[b], np.asarray(out0[b], np.int32)])
            for b in range(B)]
    _, t_or, a_or = serve("dense", ReplayDrafter(seqs))
    rows.append(("overhead.serve_spec_oracle_decode", t_or,
                 f"speedup={t_plain/t_or:.1f}x|accept={a_or:.2f}"))
    _, t_ng, a_ng = serve("dense", NGramDrafter())
    rows.append(("overhead.serve_spec_ngram_decode", t_ng,
                 f"speedup={t_plain/t_ng:.1f}x|accept={a_ng:.2f}"))
    _, t_rb, a_rb = serve("paged", ReplayDrafter(seqs), rollback=True)
    rows.append(("overhead.serve_spec_rollback_decode", t_rb,
                 f"speedup={t_plain/t_rb:.1f}x|accept={a_rb:.2f}"))
    return rows
