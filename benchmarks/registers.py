"""Fig. 5 analogue: fraction stability across 1-4 watchpoint slots at a
fixed period — validates the reservoir scheme's insensitivity claim."""
from __future__ import annotations

import time

from repro.configs.base import ProfilerConfig
from repro.core.interpreter import profile_fn

from benchmarks.corpus import CORPUS


def run():
    rows = []
    bug = next(b for b in CORPUS if b.name == "linear_search_contains")
    fn, args = bug.build()
    for n in (1, 2, 3, 4):
        cfg = ProfilerConfig(enabled=True, period=2000, num_watchpoints=n)
        t0 = time.perf_counter()
        rep = profile_fn(fn, *args, cfg=cfg)
        us = (time.perf_counter() - t0) * 1e6
        fr = rep.fractions()
        rows.append((f"registers.linear_search.n{n}", us,
                     f"SL={fr['silent_load']:.3f}"))
    return rows
