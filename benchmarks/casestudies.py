"""Tab. 3 analogue: profiler-guided optimizations with measured speedups.

For each case the profiler's dominant finding motivates the fix (exactly
the paper's §7 methodology); both variants are jitted and timed on CPU and
the wasteful fraction is shown before/after.
"""
from __future__ import annotations

import time

import jax

from repro.configs.base import ProfilerConfig
from repro.core.interpreter import profile_fn

from benchmarks.corpus import CORPUS
import jax.numpy as jnp


def _scaled_inputs(name):
    """Larger inputs for wall-clock timing (the asymptotic win needs size;
    profiling runs on the corpus-sized inputs)."""
    import jax as _jax
    if name == "linear_search_contains":
        return (jnp.arange(2048) % 97, jnp.arange(16384))
    if name == "repeated_segment_scan":
        segs = jnp.sort(_jax.random.uniform(_jax.random.PRNGKey(0), (65536,)))
        return (jnp.linspace(0, 1, 512), segs)
    if name == "loop_invariant_pow":
        return (jnp.arange(512.0), jnp.linspace(0, 1, 65536))
    return None


def _time(fn, args, n=20):
    jfn = jax.jit(fn)
    jax.block_until_ready(jfn(*args))
    t0 = time.perf_counter()
    for _ in range(n):
        out = jfn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n


def run():
    rows = []
    cfg = ProfilerConfig(enabled=True, period=30, num_watchpoints=4)
    for bug in CORPUS:
        if bug.fixed is None:
            continue
        fn, args = bug.build()
        ffn, fargs = bug.fixed()
        rep_b = profile_fn(fn, *args, cfg=cfg)
        rep_a = profile_fn(ffn, *fargs, cfg=cfg)
        frac_before = rep_b.fractions()[bug.kind]
        frac_after = rep_a.fractions()[bug.kind]
        # the paper's headline metric: total memory-op reduction (§7)
        ld_cut = rep_b.total_load_events / max(rep_a.total_load_events, 1)
        big = _scaled_inputs(bug.name)
        t_before = _time(fn, big or args)
        t_after = _time(ffn, big or fargs)
        rows.append((f"casestudy.{bug.name}", t_before * 1e6,
                     f"speedup={t_before/max(t_after,1e-9):.2f}x"
                     f"|{bug.kind}:{frac_before:.2f}->{frac_after:.2f}"
                     f"|loads_cut={ld_cut:.1f}x|{bug.source}"))
    return rows
