"""Diff two ``BENCH_<rev>.json`` artifacts inside a noise band.

``overhead.py`` (run as a script) writes a versioned row-set snapshot:
row names, µs-per-call, notes, machine info and the git rev it was
measured at. This tool compares a current run against a committed
baseline and fails (exit 1) when

  * a baseline row is MISSING from the current run — the PR-3 bit-rot
    failure mode (a renamed kwarg silently dropping a row from the
    report), or
  * a row got slower by more than the noise band (default 2.0x — CI
    runners are shared machines; the band is deliberately wide so only
    step-function regressions trip, not scheduler jitter).

New rows in the current run are reported but never fail the diff: the
exact-manifest check lives in tests/test_benchmarks.py EXPECTED_ROWS,
which forces them to be registered.

Usage::

    python benchmarks/bench_diff.py BASELINE.json CURRENT.json [--band 2.0]
    python benchmarks/bench_diff.py --latest CURRENT.json

``--latest`` picks the newest committed ``BENCH_*.json`` in this
directory (by git log order, falling back to mtime) as the baseline —
what the CI bench-diff job uses. Exit 0 with a notice when no baseline
exists yet (first run on a fresh branch must not fail).
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import subprocess
import sys

HERE = os.path.dirname(os.path.abspath(__file__))


def load(path: str) -> dict:
    with open(path) as f:
        doc = json.load(f)
    if doc.get("schema") != 1:
        raise SystemExit(f"{path}: unknown schema {doc.get('schema')!r}")
    return doc


def latest_committed() -> str | None:
    """Newest BENCH_*.json tracked in git (commit order), else mtime."""
    cands = sorted(glob.glob(os.path.join(HERE, "BENCH_*.json")))
    if not cands:
        return None
    try:
        out = subprocess.check_output(
            ["git", "log", "--format=%H", "--name-only", "--diff-filter=AM",
             "--", "benchmarks/BENCH_*.json"],
            cwd=HERE, text=True, stderr=subprocess.DEVNULL)
        for line in out.splitlines():
            line = line.strip()
            if line.startswith("benchmarks/BENCH_") and line.endswith(".json"):
                p = os.path.join(HERE, os.path.basename(line))
                if os.path.exists(p):
                    return p
    except Exception:
        pass
    return max(cands, key=os.path.getmtime)


def diff(base: dict, cur: dict, band: float) -> int:
    brows = {r["name"]: r for r in base["rows"]}
    crows = {r["name"]: r for r in cur["rows"]}
    if base.get("toy") != cur.get("toy"):
        print(f"note: comparing toy={base.get('toy')} baseline against "
              f"toy={cur.get('toy')} run — ratios are not size-for-size")
    rc = 0
    missing = sorted(set(brows) - set(crows))
    if missing:
        print(f"FAIL: rows missing from current run: {missing}")
        rc = 1
    for name in sorted(set(crows) - set(brows)):
        print(f"new row (not in baseline): {name}")
    width = max((len(n) for n in brows), default=0)
    for name in sorted(set(brows) & set(crows)):
        b, c = brows[name]["us_per_call"], crows[name]["us_per_call"]
        ratio = c / b if b > 0 else float("inf")
        tag = "ok"
        if ratio > band:
            tag = f"REGRESSION (> {band:.2f}x band)"
            rc = 1
        elif ratio < 1.0 / band:
            tag = "improved"
        print(f"{name:<{width}}  {b:>12.1f} -> {c:>12.1f} us  "
              f"{ratio:>6.2f}x  {tag}")
    rc = max(rc, diff_findings(base.get("findings"), cur.get("findings")))
    print(f"baseline rev={base.get('rev')} current rev={cur.get('rev')} "
          f"band={band:.2f}x -> {'FAIL' if rc else 'OK'}")
    return rc


def diff_findings(base: dict | None, cur: dict | None) -> int:
    """Per-kind waste-finding count diff (exact, no noise band — counts
    are deterministic). A kind whose count GREW, or a brand-new kind,
    fails; drops are improvements; a baseline without the optional
    ``findings`` key only produces a notice (old artifacts stay valid)."""
    if cur is None:
        return 0
    if base is None:
        if cur:
            print(f"note: baseline has no findings counts; current has "
                  f"{sum(cur.values())} across {len(cur)} kinds")
        return 0
    rc = 0
    for kind in sorted(set(base) | set(cur)):
        b, c = int(base.get(kind, 0)), int(cur.get(kind, 0))
        if c > b:
            print(f"FAIL: findings[{kind}] grew {b} -> {c}")
            rc = 1
        elif c < b:
            print(f"findings[{kind}] improved {b} -> {c}")
    return rc


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("files", nargs="+", metavar="JSON",
                    help="BASELINE CURRENT, or just CURRENT with --latest")
    ap.add_argument("--band", type=float, default=2.0,
                    help="noise band: fail when current > band * baseline")
    ap.add_argument("--latest", action="store_true",
                    help="baseline = newest committed benchmarks/"
                         "BENCH_*.json")
    args = ap.parse_args(argv)
    if args.latest:
        if len(args.files) != 1:
            ap.error("--latest takes exactly one file (the current run)")
        base_path, cur_path = latest_committed(), args.files[0]
        if base_path is None:
            print("no committed BENCH_*.json baseline yet — nothing to "
                  "diff (ok)")
            return 0
    else:
        if len(args.files) != 2:
            ap.error("need BASELINE and CURRENT (or --latest CURRENT)")
        base_path, cur_path = args.files
    if os.path.abspath(base_path) == os.path.abspath(cur_path):
        print("baseline and current are the same file — nothing to diff")
        return 0
    base, cur = load(base_path), load(cur_path)
    # say which baseline won (--latest picks silently otherwise)
    print(f"baseline: {os.path.basename(base_path)} "
          f"(rev {base.get('rev')}, toy={base.get('toy')})")
    return diff(base, cur, args.band)


if __name__ == "__main__":
    sys.exit(main())
