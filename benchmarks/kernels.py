"""Kernel microbenchmarks: Pallas (interpret) vs jnp oracle vs jitted
oracle. Wall-times on CPU are indicative only; correctness deltas are the
real payload (TPU perf comes from the dry-run roofline)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_xla import flash_xla
from repro.kernels.silent_compare import silent_compare


def _time(fn, n=3):
    jax.block_until_ready(fn())
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n


def run():
    rows = []
    key = jax.random.PRNGKey(0)
    B, S, Hq, Hkv, D = 1, 256, 4, 2, 64
    q = jax.random.normal(key, (B, S, Hq, D), jnp.float32)
    k = jax.random.normal(key, (B, S, Hkv, D), jnp.float32)
    v = jax.random.normal(key, (B, S, Hkv, D), jnp.float32)

    want = ref.attention_ref(q, k, v, causal=True)
    got = flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                          interpret=True)
    err = float(jnp.abs(want - got).max())
    t = _time(jax.jit(lambda: ref.attention_ref(q, k, v, causal=True)))
    rows.append(("kernel.flash_pallas_interp", t * 1e6,
                 f"max_err_vs_ref={err:.2e}"))

    got2 = flash_xla(q, k, v, True, 0, 128)
    err2 = float(jnp.abs(want - got2).max())
    t2 = _time(jax.jit(lambda: flash_xla(q, k, v, True, 0, 128)))
    rows.append(("kernel.flash_xla", t2 * 1e6, f"max_err_vs_ref={err2:.2e}"))

    a = jax.random.normal(key, (1 << 18,))
    b = a.at[: 1 << 14].mul(1.5)
    cnt_k = int(silent_compare(a, b, 0.0, interpret=True))
    cnt_r = int(ref.silent_compare_ref(a, b, 0.0))
    t3 = _time(jax.jit(lambda: ref.silent_compare_ref(a, b, 0.0)))
    rows.append(("kernel.silent_compare", t3 * 1e6,
                 f"kernel=={cnt_k}|ref=={cnt_r}|match={cnt_k == cnt_r}"))
    rows.extend(run_paged())
    return rows


def run_paged():
    """Paged serving kernels at bench sizes (larger pool/table than the
    CI toy rows in overhead.run_kernels): interpret-mode parity vs the
    ref composition + the modeled HBM-byte ratio at these sizes."""
    from repro.kernels.flash_prefill import paged_window_attention
    from repro.kernels.paged_attention import paged_decode_attention
    from repro.launch.roofline import ideal_paged_attention_bytes

    rows = []
    B, Hq, Hkv, D, ps, M = 4, 8, 4, 64, 16, 16
    rng = np.random.RandomState(7)
    pool_pages = B * M + 4
    pool_k = jnp.asarray(rng.randn(pool_pages, ps, Hkv, D), jnp.float32)
    pool_v = jnp.asarray(rng.randn(pool_pages, ps, Hkv, D), jnp.float32)
    pt = np.asarray(
        rng.permutation(pool_pages - 1)[:B * M].reshape(B, M), np.int32)
    idx = np.zeros(B, np.int32)
    for b in range(B):
        used = rng.randint(M // 2, M)
        pt[b, used:] = -1
        idx[b] = used * ps - rng.randint(1, ps)
    pt, idx = jnp.asarray(pt), jnp.asarray(idx)
    mapped = int((np.asarray(pt) >= 0).sum())

    q1 = jnp.asarray(rng.randn(B, 1, Hq, D), jnp.float32)
    kn = jnp.asarray(rng.randn(B, 1, Hkv, D), jnp.float32)
    vn = jnp.asarray(rng.randn(B, 1, Hkv, D), jnp.float32)

    def decode_ref():
        ck, cv = ref.paged_update(pool_k, pool_v, kn, vn, pt, idx)
        gk, valid = ref.paged_gather(ck, pt)
        gv, _ = ref.paged_gather(cv, pt)
        return ref.attention_ref(q1, gk, gv, causal=True, q_offset=idx,
                                 kv_len=idx + 1, kv_valid=valid)
    want = decode_ref()
    got, _, _ = paged_decode_attention(q1, kn, vn, pool_k, pool_v, pt, idx,
                                       interpret=True)
    err = float(jnp.abs(want - got).max())
    kwargs = dict(batch=B, q_len=1, mapped_pages=mapped, max_pages=M,
                  page_size=ps, num_heads=Hq, num_kv_heads=Hkv, head_dim=D,
                  kv_bytes=4.0, act_bytes=4.0)
    hbm = (ideal_paged_attention_bytes(materialize=True, **kwargs)
           / ideal_paged_attention_bytes(materialize=False, **kwargs))
    t = _time(jax.jit(decode_ref))
    rows.append(("kernel.paged_decode", t * 1e6,
                 f"max_err_vs_ref={err:.2e}|modeled_hbm_speedup={hbm:.2f}x"))

    S = 2 * ps
    qw = jnp.asarray(rng.randn(B, S, Hq, D), jnp.float32)
    kw = jnp.asarray(rng.randn(B, S, Hkv, D), jnp.float32)
    vw = jnp.asarray(rng.randn(B, S, Hkv, D), jnp.float32)
    ow, _, cw, pk1, pv1 = paged_window_attention(
        qw, kw, vw, pool_k, pool_v, pt, idx, store=True, interpret=True)
    owr, pk1r, pv1r, cwr = ref.paged_window_ref(
        qw, kw, vw, pool_k, pool_v, pt, idx, store=True, tol=0.0)
    werr = float(jnp.abs(ow - owr).max())
    ok = bool(jnp.array_equal(pk1, pk1r) and jnp.array_equal(pv1, pv1r)
              and jnp.array_equal(cw, cwr))
    t_w = _time(jax.jit(lambda: ref.paged_window_ref(
        qw, kw, vw, pool_k, pool_v, pt, idx, store=True, tol=0.0)[0]))
    rows.append(("kernel.paged_window", t_w * 1e6,
                 f"max_err_vs_ref={werr:.2e}|pool_and_counters_match={ok}"))
    return rows
