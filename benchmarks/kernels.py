"""Kernel microbenchmarks: Pallas (interpret) vs jnp oracle vs jitted
oracle. Wall-times on CPU are indicative only; correctness deltas are the
real payload (TPU perf comes from the dry-run roofline)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_xla import flash_xla
from repro.kernels.silent_compare import silent_compare


def _time(fn, n=3):
    jax.block_until_ready(fn())
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n


def run():
    rows = []
    key = jax.random.PRNGKey(0)
    B, S, Hq, Hkv, D = 1, 256, 4, 2, 64
    q = jax.random.normal(key, (B, S, Hq, D), jnp.float32)
    k = jax.random.normal(key, (B, S, Hkv, D), jnp.float32)
    v = jax.random.normal(key, (B, S, Hkv, D), jnp.float32)

    want = ref.attention_ref(q, k, v, causal=True)
    got = flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                          interpret=True)
    err = float(jnp.abs(want - got).max())
    t = _time(jax.jit(lambda: ref.attention_ref(q, k, v, causal=True)))
    rows.append(("kernel.flash_pallas_interp", t * 1e6,
                 f"max_err_vs_ref={err:.2e}"))

    got2 = flash_xla(q, k, v, True, 0, 128)
    err2 = float(jnp.abs(want - got2).max())
    t2 = _time(jax.jit(lambda: flash_xla(q, k, v, True, 0, 128)))
    rows.append(("kernel.flash_xla", t2 * 1e6, f"max_err_vs_ref={err2:.2e}"))

    a = jax.random.normal(key, (1 << 18,))
    b = a.at[: 1 << 14].mul(1.5)
    cnt_k = int(silent_compare(a, b, 0.0, interpret=True))
    cnt_r = int(ref.silent_compare_ref(a, b, 0.0))
    t3 = _time(jax.jit(lambda: ref.silent_compare_ref(a, b, 0.0)))
    rows.append(("kernel.silent_compare", t3 * 1e6,
                 f"kernel=={cnt_k}|ref=={cnt_r}|match={cnt_k == cnt_r}"))
    return rows
