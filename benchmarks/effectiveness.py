"""Tab. 2 analogue: detection rate over the 12-pattern bug corpus.

A bug counts as detected when its expected waste kind's sampled fraction
exceeds the detection threshold. The sampling period is scaled to the
corpus programs' event counts at the paper's period/event ratio (~5e-4).

Note on `adjacent_shift` (Ant#53637 class): JXPerf documents this as a
MISS (same values move to adjacent locations, same-location watchpoints
never fire). JXPerf-JAX watches logical BUFFERS rather than single
elements, so repeated reads of the shifted-but-unchanged container DO
trap — the adaptation detects the class the original cannot (recorded in
EXPERIMENTS.md as a deviation-with-improvement).
"""
from __future__ import annotations

import time

from repro.configs.base import ProfilerConfig
from repro.core.interpreter import profile_fn

from benchmarks.corpus import CORPUS

THRESHOLD = 0.25


def run():
    rows = []
    detected = expected = agree = 0
    for bug in CORPUS:
        fn, args = bug.build()
        cfg = ProfilerConfig(enabled=True, period=30, num_watchpoints=4)
        t0 = time.perf_counter()
        rep = profile_fn(fn, *args, cfg=cfg)
        us = (time.perf_counter() - t0) * 1e6
        frac = rep.fractions()[bug.kind]
        hit = frac > THRESHOLD
        ok = hit == bug.expect_detected
        agree += ok
        expected += bug.expect_detected
        detected += hit and bug.expect_detected
        rows.append((f"effectiveness.{bug.name}", us,
                     f"kind={bug.kind}|frac={frac:.3f}|detected={hit}"
                     f"|expected={bug.expect_detected}|{'OK' if ok else 'MISS'}"))
    rows.append(("effectiveness.summary", 0.0,
                 f"reproduced={detected}/{expected} expected bugs; "
                 f"corpus_agreement={agree}/{len(CORPUS)}"))
    return rows
