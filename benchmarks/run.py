"""Benchmark aggregator: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows per the harness contract.
  fraction.*      — Fig. 4 (wasteful-op fractions vs sampling period)
  registers.*     — Fig. 5 (fractions vs #watchpoints)
  overhead.*      — Tab. 1/Fig. 6 (runtime slowdown / memory of detection)
  effectiveness.* — Tab. 2 (bug-corpus detection rate)
  casestudy.*     — Tab. 3 (guided-optimization speedups)
  kernel.*        — Pallas kernels vs oracles
  roofline.*      — §Roofline summary from the dry-run artifacts
"""
from __future__ import annotations

import sys


def main() -> None:
    from benchmarks import (casestudies, effectiveness, fraction, kernels,
                            overhead, registers, roofline)
    mods = [fraction, registers, overhead, effectiveness, casestudies,
            kernels, roofline]
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    for m in mods:
        if only and only not in m.__name__:
            continue
        for row in m.run():
            name, us, derived = row
            print(f"{name},{us:.1f},{derived}")
        sys.stdout.flush()


if __name__ == "__main__":
    main()
