"""Fig. 4 analogue: F^{DS,SS,SL} across the corpus suite at sampling
periods {500, 1K, 5K, 10K} events (scaled to interpreter event rates)."""
from __future__ import annotations

import time

from repro.configs.base import ProfilerConfig
from repro.core.interpreter import profile_fn

from benchmarks.corpus import CORPUS

PERIODS = (500, 1000, 5000, 10000)
SUITE = ("linear_search_contains", "loop_invariant_pow",
         "dead_intermediates", "repeated_segment_scan")


def run():
    rows = []
    bugs = {b.name: b for b in CORPUS}
    for name in SUITE:
        b = bugs[name]
        fn, args = b.build()
        for period in PERIODS:
            cfg = ProfilerConfig(enabled=True, period=period,
                                 num_watchpoints=4)
            t0 = time.perf_counter()
            rep = profile_fn(fn, *args, cfg=cfg)
            us = (time.perf_counter() - t0) * 1e6
            fr = rep.fractions()
            rows.append((f"fraction.{name}.p{period}", us,
                         f"DS={fr['dead_store']:.3f}|SS={fr['silent_store']:.3f}"
                         f"|SL={fr['silent_load']:.3f}"))
    return rows
