"""§Roofline summary rows from the dry-run artifacts (experiments/dryrun).

The dry-run (repro.launch.dryrun) must have produced the per-cell JSON
records; this module renders the single-pod baseline table per the
assignment (the multi-pod pass is recorded too)."""
from __future__ import annotations

import glob
import json
from pathlib import Path

OUT = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def run():
    rows = []
    files = sorted(glob.glob(str(OUT / "*_single_baseline.json")))
    if not files:
        return [("roofline.missing", 0.0,
                 "run: PYTHONPATH=src python -m repro.launch.dryrun")]
    n_ok = n_skip = 0
    for f in files:
        r = json.loads(Path(f).read_text())
        cell = f"{r['arch']}.{r['shape']}"
        if r["status"] == "skipped":
            n_skip += 1
            rows.append((f"roofline.{cell}", 0.0, "skipped:" + r["reason"][:40]))
            continue
        if r["status"] != "ok":
            rows.append((f"roofline.{cell}", 0.0, "ERROR"))
            continue
        n_ok += 1
        rows.append((
            f"roofline.{cell}", r["t_compute_s"] * 1e6,
            f"tc={r['t_compute_s']:.3f}s|tm={r['t_memory_s']:.3f}s"
            f"|tcoll={r['t_collective_s']:.3f}s|dom={r['dominant']}"
            f"|rf={r.get('roofline_fraction', 0):.3f}"
            f"|useful={r.get('useful_flops_ratio', 0):.2f}"))
    rows.append(("roofline.summary", 0.0,
                 f"cells_ok={n_ok}|cells_skipped={n_skip}"))
    return rows
