"""Fleet serving example: two `ServeEngine` replicas behind the
prefix-aware router, fed a duplicated-prefix trace.

Quickstart (CPU):

    PYTHONPATH=src python examples/fleet_decode.py --arch qwen3-1.7b

What it demonstrates:

  * ``serve.workload.duplicated_prefix_trace`` — a seeded, replayable
    request trace (bursty arrivals, 80% of prompts share one system
    prefix) that serializes to JSON (``--trace-out``);
  * ``serve.global_prefix.GlobalPrefixIndex`` — after the first replica
    prefills the shared prefix, its pages are published fleet-wide
    (pinned through the owner's allocator, refcount-safe);
  * ``serve.router.FleetRouter`` with ``policy="prefix"`` — later
    duplicates route to the replica that already holds the prefix (a
    dispatch lease keeps the pages alive until admission) and prefill
    only their unique suffix, instead of re-paying the prefix on
    whichever replica load balancing would have picked;
  * the same trace under ``policy="random"`` re-prefills the resident
    prefix — the fleet-level Def.-3 ``fleet_silent_prefix_load`` bytes
    the router charges and prefix routing eliminates;
  * both policies emit greedy outputs bit-identical to one big engine.
"""
import argparse

import jax
import numpy as np

from repro.configs import registry
from repro.models.zoo import build_model
from repro.serve.decode import StepCache
from repro.serve.engine import Request, ServeEngine
from repro.serve.router import FleetRouter
from repro.serve.workload import duplicated_prefix_trace


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b",
                    choices=registry.ARCH_IDS)
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace-out", default=None)
    args = ap.parse_args()

    cfg = registry.get_config(args.arch).smoke()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))

    # staggered arrivals (one request every few ticks): each duplicate
    # lands after the prefix was published, so the policies differ only
    # in WHERE they send it — the waste comparison below is pure routing
    trace = duplicated_prefix_trace(
        n_requests=args.requests, vocab_size=cfg.vocab_size,
        seed=args.seed, prompt_len=32, prefix_len=24, gen=6,
        burst_size=1, burst_gap=3)
    if args.trace_out:
        trace.save(args.trace_out)
        print(f"trace written to {args.trace_out}")
    max_len = trace.max_prompt_len + trace.max_new_tokens + 1
    page_size = 8
    pages = 4 * (-(-max_len // page_size))   # 2 slots + pin headroom
    step_cache = StepCache(model)            # one compile set, all fleets

    def build_fleet(policy):
        engines = [ServeEngine(model, params, num_slots=2, max_len=max_len,
                               kv_layout="paged", page_size=page_size,
                               num_pages=pages, step_cache=step_cache)
                   for _ in range(args.replicas)]
        fleet = FleetRouter(engines, policy=policy, seed=args.seed)
        fleet.submit_trace(trace)
        fleet.run()
        fleet.check()                        # fleet-wide refcount audit
        return fleet

    outputs = {}
    for policy in ("prefix", "random"):
        fleet = build_fleet(policy)
        outputs[policy] = {rid: list(r.generated)
                           for rid, r in fleet.finished.items()}
        s = fleet.stats
        print(f"[{policy:6s}] dispatched {s['dispatched']} | "
              f"prefix routes {s['prefix_routes']} "
              f"(cross-replica {s['cross_replica_prefix_routes']}) | "
              f"hit fraction {fleet.prefix_hit_fraction():.2f} | "
              f"fleet silent-prefix-load "
              f"{fleet.fleet_waste_bytes():.0f} bytes")

    single = ServeEngine(model, params, num_slots=2 * args.replicas,
                         max_len=max_len, kv_layout="paged",
                         page_size=page_size, step_cache=step_cache)
    for treq in sorted(trace.requests, key=lambda r: r.arrival):
        single.submit(Request(rid=treq.rid, tokens=np.asarray(treq.tokens),
                              max_new_tokens=treq.max_new_tokens))
    single.run()
    ref = {rid: list(r.generated) for rid, r in single.finished.items()}
    same = all(outputs[p] == ref for p in outputs)
    print(f"greedy outputs bit-identical to a single engine: {same}")
    assert same


if __name__ == "__main__":
    main()
