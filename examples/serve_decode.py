"""Batched serving example: prefill + greedy decode with a KV cache.

    PYTHONPATH=src python examples/serve_decode.py --arch zamba2-1.2b
"""
import argparse

from repro.configs import registry
from repro.launch.serve import run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b", choices=registry.ARCH_IDS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen", type=int, default=12)
    a = ap.parse_args()
    run(a.arch, smoke=True, batch=a.batch, prompt_len=a.prompt_len, gen=a.gen)


if __name__ == "__main__":
    main()
