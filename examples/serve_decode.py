"""Serving-engine example: continuous batching with batched prefill,
slot recycling and KV-cache waste detectors.

Quickstart (CPU):

    PYTHONPATH=src python examples/serve_decode.py --arch qwen3-1.7b

Submits a staggered stream of requests (more requests than decode
slots, some sharing a prompt prefix, different generation budgets) to
``repro.serve.engine.ServeEngine``:

  * each prompt fills its KV-cache row in ONE batched ``model.prefill``
    call at admission;
  * requests finish independently (max-new-tokens early exit) and their
    slots recycle to waiting requests;
  * prefill and decode throughput are reported separately, decode over
    live slots only;
  * ``ServingDetectors`` watches the KV cache: idle-slot rewrites trap
    as dead/silent KV stores, duplicated prompt prefixes as silent
    prefix loads — one merged WasteProfile, same schema as training;
  * with ``--kv paged`` the engine runs the block-paged KV heap
    (refcounted pages, copy-on-write prefix reuse): the duplicated
    prefixes become cache hits, idle/finished slots write nothing, and
    the same detectors report the waste eliminated;
  * with ``--spec`` the engine decodes speculatively: the n-gram
    drafter proposes from the request's own history and a corpus of
    served sequences (duplicated traffic drafts itself), one width-k
    verify forward accepts the greedy-consistent prefix, and rejected
    drafts surface as ``rejected_draft_store`` dead stores — eliminated
    by rollback in the paged layout.
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.configs.base import ProfilerConfig
from repro.core.detectors import ServingDetectors
from repro.models.zoo import build_model
from repro.serve.engine import Request, ServeEngine
from repro.serve.spec import NGramDrafter


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b",
                    choices=registry.ARCH_IDS)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen", type=int, default=12)
    ap.add_argument("--kv", default="dense", choices=("dense", "paged"))
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--spec", action="store_true",
                    help="speculative decode with the n-gram drafter")
    ap.add_argument("--spec-k", type=int, default=4)
    a = ap.parse_args()

    cfg = registry.get_config(a.arch).smoke()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    det = ServingDetectors(ProfilerConfig(enabled=True))
    eng = ServeEngine(model, params, num_slots=a.slots,
                      max_len=a.prompt_len + a.gen + 1, detectors=det,
                      kv_layout=a.kv, page_size=a.page_size,
                      drafter=NGramDrafter() if a.spec else None,
                      spec_k=a.spec_k)

    rng = np.random.RandomState(0)
    shared = rng.randint(0, cfg.vocab_size, size=a.prompt_len // 2)
    for i in range(a.requests):
        if i % 2 == 0:   # every other request shares a prompt prefix
            tail = rng.randint(0, cfg.vocab_size, size=a.prompt_len // 2)
            toks = np.concatenate([shared, tail])
        else:
            toks = rng.randint(0, cfg.vocab_size, size=a.prompt_len)
        eng.submit(Request(rid=f"req{i}", tokens=toks.astype(np.int32),
                           max_new_tokens=max(1, a.gen - (i % 3) * 2),
                           arrival=i))          # staggered arrivals
    eng.run()

    tp = eng.throughput()
    s = eng.stats
    print(f"[example] {a.requests} requests over {a.slots} slots "
          f"[kv={a.kv}]: prefill {tp['prefill_tok_s']:.0f} tok/s, "
          f"decode {tp['decode_tok_s']:.0f} tok/s (live slots)")
    print(f"[example] prefix hits: {s['prefix_hits']} "
          f"({s['prefix_hit_tokens']} tokens from cache), computed "
          f"{s['prefill_computed_tokens']}/{s['prefill_tokens']} prompt "
          f"tokens, {s['pages_freed']} pages freed")
    if a.spec:
        print(f"[example] spec: accepted drafts: {s['draft_accepted']} "
              f"of {s['draft_proposed']} proposed "
              f"(accept rate {tp.get('accept_rate', 0.0):.2f})")
    for rid in sorted(eng.finished):
        r = eng.finished[rid]
        print(f"  {rid}: {len(r.generated)} tokens, "
              f"steps {r.prefill_step}->{r.finish_step}, "
              f"first: {r.generated[:6]}")
    print(det.report.render(top_k=3))


if __name__ == "__main__":
    main()
