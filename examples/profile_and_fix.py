"""The paper's workflow end to end: profile -> read the context pair ->
apply the guided fix -> re-profile + measure speedup.

Subject: the JFreeChart getExceptionSegmentCount() analogue — a linear
scan over a sorted array repeated per query (paper §7.7).

    PYTHONPATH=src python examples/profile_and_fix.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ProfilerConfig
from repro.core import WasteProfile, profile_fn, render


def count_intersections_slow(queries, segments):
    def body(c, q):
        n = jnp.sum(segments < q)            # full scan per query
        return c + n, None
    out, _ = jax.lax.scan(body, jnp.int32(0), queries)
    return out


def count_intersections_fast(queries, segments):
    # the guided fix: the array is sorted -> binary search, no re-reads
    return jnp.searchsorted(segments, queries).sum().astype(jnp.int32)


def timeit(fn, *args, n=30):
    jfn = jax.jit(fn)
    jax.block_until_ready(jfn(*args))
    t0 = time.perf_counter()
    for _ in range(n):
        out = jfn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n


def main():
    segs = jnp.sort(jax.random.uniform(jax.random.PRNGKey(0), (2048,)))
    qs = jnp.linspace(0, 1, 64)
    cfg = ProfilerConfig(enabled=True, period=200)

    print("== profiling the slow version ==")
    # 4 epochs via trace→replay: interpret once, replay the recorded
    # event trace — the multi-epoch cost is the sampler, not re-binding
    rep = profile_fn(count_intersections_slow, qs, segs, cfg=cfg, epochs=4)
    print(render(rep, top_k=1))
    # the unified profile ships as JSON (merge per-shard files post-mortem)
    assert WasteProfile.from_json(rep.to_json()) == rep
    sl = rep.fractions()["silent_load"]
    print(f"\n-> F^silent_load = {sl:.0%}: the same segment array is "
          "re-read unchanged for every query (paper §7.7 symptom).")
    print("-> guided fix: the array is sorted; replace the linear scan "
          "with binary search.\n")

    rep2 = profile_fn(count_intersections_fast, qs, segs, cfg=cfg)
    print("== after the fix ==")
    cut = rep.total_load_events / max(rep2.total_load_events, 1)
    print(f"total memory loads cut {cut:.0f}x "
          f"({rep.total_load_events:,} -> {rep2.total_load_events:,}) — "
          "the paper's §7 headline metric")

    a = int(count_intersections_slow(qs, segs))
    b = int(count_intersections_fast(qs, segs))
    assert a == b, (a, b)
    t_slow = timeit(count_intersections_slow, qs, segs)
    t_fast = timeit(count_intersections_fast, qs, segs)
    print(f"result identical ({a}); speedup {t_slow/t_fast:.1f}x "
          f"({t_slow*1e6:.0f}us -> {t_fast*1e6:.0f}us)")


if __name__ == "__main__":
    main()
