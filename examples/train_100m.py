"""End-to-end driver: train a ~100M-parameter qwen3-family model with
checkpointing, restart, and detectors.

Full run (a few hundred steps):
    PYTHONPATH=src python examples/train_100m.py --steps 300

CI/CPU-budget verification (defaults): a ~22M model for 60 steps — the
same code path at reduced width.
"""
import argparse
import dataclasses

import jax

from repro.configs import registry
from repro.configs.base import TrainConfig
from repro.checkpoint.checkpointer import Checkpointer
from repro.data.pipeline import Prefetcher
from repro.data.synthetic import stream
from repro.models.zoo import build_model, count_params_analytic
from repro.train import state as TS
from repro.train.step import make_train_step
import jax.numpy as jnp


def config(full: bool):
    base = registry.get_config("qwen3-1.7b")
    if full:   # ~100M params
        return dataclasses.replace(
            base, name="qwen3-100m", num_layers=8, d_model=512,
            num_heads=8, num_kv_heads=4, head_dim=64, d_ff=2048,
            vocab_size=32768, tie_embeddings=True)
    return dataclasses.replace(     # ~22M verification width
        base, name="qwen3-22m", num_layers=4, d_model=256, num_heads=4,
        num_kv_heads=2, head_dim=64, d_ff=1024, vocab_size=8192,
        tie_embeddings=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--full", action="store_true", help="~100M width")
    ap.add_argument("--ckpt", default="/tmp/repro_100m_ckpt")
    a = ap.parse_args()

    cfg = config(a.full)
    model = build_model(cfg)
    print(f"[100m] {cfg.name}: {count_params_analytic(cfg)/1e6:.1f}M params")
    tc = TrainConfig(learning_rate=6e-4, total_steps=a.steps,
                     warmup_steps=max(a.steps // 20, 1), remat="none")
    step = jax.jit(make_train_step(model, tc), donate_argnums=(0,))
    state = TS.create(model, jax.random.PRNGKey(0))
    ckpt = Checkpointer(a.ckpt)
    data = Prefetcher(stream(cfg, a.batch, a.seq, seed=0))
    first = last = None
    for i in range(a.steps):
        b = next(data)
        state, m = step(state, {k: jnp.asarray(v) for k, v in b.items()})
        loss = float(m["loss"])
        first = first if first is not None else loss
        last = loss
        if (i + 1) % 10 == 0:
            print(f"[100m] step {i+1:4d} loss {loss:.4f}", flush=True)
        if (i + 1) % 50 == 0:
            ckpt.save_async(i + 1, state)
    ckpt.save(a.steps, state)
    data.close()
    print(f"[100m] loss {first:.3f} -> {last:.3f}; "
          f"checkpoints: {ckpt.all_steps()}")


if __name__ == "__main__":
    main()
