"""Quickstart: train a tiny model with JXPerf-JAX watching, then read the
three detection tiers' reports.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.configs.base import ProfilerConfig
from repro.core import merge, profile_fn, render
from repro.launch.train import run as train_run


def main():
    # 1) end-to-end smoke train with Tier-3 detectors + Tier-2 waste
    #    report — train_run returns one merged WasteProfile
    print("=" * 70)
    print("Training qwen3-1.7b (reduced) with Tier-3 detectors on:")
    _, train_profile = train_run("qwen3-1.7b", smoke=True, steps=15,
                                 batch=4, seq=64, profile=True,
                                 waste_report=True, log_every=5)

    # 2) Tier-1: profile a deliberately wasteful function
    print("=" * 70)
    print("Tier-1 on a linear-search-in-loop (Collections#588 analogue):")

    def linear_search(keys, arr):
        def body(c, k):
            return c + jnp.any(arr == k).astype(jnp.int32), None
        out, _ = jax.lax.scan(body, jnp.int32(0), keys)
        return out

    rep = profile_fn(linear_search, jnp.arange(48) % 7, jnp.arange(256),
                     cfg=ProfilerConfig(enabled=True, period=100))
    print(render(rep, top_k=2))

    # 3) every tier speaks the same schema: one report across all three
    print("=" * 70)
    print("Unified cross-tier profile (Tier-1 + Tier-2 + Tier-3 merged):")
    print(render(merge(train_profile, rep), top_k=2))


if __name__ == "__main__":
    main()
