"""Per-architecture smoke tests (reduced configs) + decode/forward
consistency, on CPU. The full configs are exercised only via the dry-run."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import TrainConfig
from repro.models.zoo import build_model, count_params_analytic
from repro.train import state as TS
from repro.train.step import make_train_step

KEY = jax.random.PRNGKey(0)


def _inputs(cfg, B, S):
    kw = {}
    if cfg.family == "vlm":
        kw["img"] = jax.random.normal(KEY, (B, cfg.num_image_tokens, cfg.d_model))
    if cfg.family == "audio":
        kw["frames"] = jax.random.normal(KEY, (B, cfg.encoder_frames, cfg.d_model))
    return kw


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = registry.get_config(arch).smoke()
    model = build_model(cfg)
    B, S = 2, 16
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    kw = _inputs(cfg, B, S)

    params = model.init(KEY)
    logits, aux = model.forward(params, toks, **kw)
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())

    tc = TrainConfig(total_steps=10, warmup_steps=1)
    step = jax.jit(make_train_step(model, tc))
    state = TS.create(model, KEY)
    batch = {"tokens": toks, "labels": toks, **{k: jnp.asarray(v) for k, v in kw.items()}}
    state, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert int(state.step) == 1


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "zamba2-1.2b", "xlstm-1.3b",
                                  "whisper-large-v3"])
def test_decode_matches_forward(arch):
    cfg = registry.get_config(arch).smoke()
    cfg = dataclasses.replace(cfg, dtype="float32")
    model = build_model(cfg)
    B, S = 2, 12
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    kw = _inputs(cfg, B, S)
    params = model.init(KEY)
    want, _ = model.forward(params, toks, **kw)
    cache = model.init_cache(params, B, S, kv_dtype=jnp.float32, **kw)
    outs = []
    for t in range(S):
        lg, cache = model.decode_step(params, cache, toks[:, t:t + 1])
        outs.append(lg[:, 0])
    got = jnp.stack(outs, axis=1)
    rel = float(jnp.abs(got - want).max() / (jnp.abs(want).max() + 1e-9))
    assert rel < 5e-4, rel


def test_moe_decode_matches_forward_high_capacity():
    cfg = registry.get_config("granite-moe-3b-a800m").smoke()
    cfg = dataclasses.replace(
        cfg, dtype="float32",
        moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    model = build_model(cfg)
    B, S = 2, 10
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    params = model.init(KEY)
    want, _ = model.forward(params, toks)
    cache = model.init_cache(params, B, S, kv_dtype=jnp.float32)
    outs = []
    for t in range(S):
        lg, cache = model.decode_step(params, cache, toks[:, t:t + 1])
        outs.append(lg[:, 0])
    got = jnp.stack(outs, axis=1)
    rel = float(jnp.abs(got - want).max() / (jnp.abs(want).max() + 1e-9))
    assert rel < 5e-4, rel


def test_param_counts_match_decl():
    """Analytic counts == materialized leaf sums (decl machinery sanity)."""
    for arch in ("qwen3-1.7b", "granite-moe-3b-a800m"):
        cfg = registry.get_config(arch).smoke()
        model = build_model(cfg)
        params = model.init(KEY)
        total = sum(int(np.prod(p.shape))
                    for p in jax.tree_util.tree_leaves(params))
        assert total == count_params_analytic(cfg)


def test_padded_vocab_is_masked():
    cfg = registry.get_config("granite-moe-3b-a800m").smoke()
    cfg = dataclasses.replace(cfg, vocab_size=250)   # force a pad tail
    assert cfg.padded_vocab == 256 > cfg.vocab_size
    model = build_model(cfg)
    params = model.init(KEY)
    toks = jax.random.randint(KEY, (1, 4), 0, cfg.vocab_size)
    logits, _ = model.forward(params, toks)
    pad = np.asarray(logits[..., cfg.vocab_size:], np.float32)
    assert (pad <= -1e29).all()


def test_loss_decreases_tiny_train():
    cfg = registry.get_config("qwen3-1.7b").smoke()
    model = build_model(cfg)
    tc = TrainConfig(learning_rate=1e-3, total_steps=30, warmup_steps=2)
    step = jax.jit(make_train_step(model, tc), donate_argnums=(0,))
    state = TS.create(model, KEY)
    toks = jax.random.randint(KEY, (4, 32), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    losses = []
    for _ in range(25):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, (losses[0], losses[-1])
