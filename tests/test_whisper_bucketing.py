"""Length-bucketed encoder prefill for encoder-decoder serving.

The legacy serve path pads every audio request's frames to one run
extent. With per-row frame-length masking threaded through the encoder
self-attention (kv_valid) and the cross-attention cache (xvalid),
outputs on valid rows are independent of that extent — so the extent
can shrink from capacity (cfg.encoder_frames) to the power-of-two
bucket of the batch's longest true length, cutting prefill_padding
bytes by a measured factor while greedy outputs stay identical.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.data.synthetic import batch_at, frame_lengths
from repro.launch import serve as serve_mod
from repro.models.zoo import build_model

KEY = jax.random.PRNGKey(0)


def _setup(batch=4, prompt_len=16, seed=0):
    cfg = registry.get_config("whisper-large-v3").smoke()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    data = batch_at(cfg, batch, prompt_len, seed=seed, step=0)
    prompts = jnp.asarray(data["tokens"])
    kw = {"frames": jnp.asarray(data["frames"])}
    lens = frame_lengths(cfg, batch, seed=seed)
    return cfg, model, params, prompts, kw, lens


def test_bucketed_outputs_identical_and_padding_reduced():
    cfg, model, params, prompts, kw, lens = _setup()
    gen = 8
    out_cap, _, _, _, st_cap = serve_mod._run_legacy(
        cfg, model, params, prompts, gen, kw,
        frame_lengths=lens, bucket_frames=False)
    out_b, _, _, _, st_b = serve_mod._run_legacy(
        cfg, model, params, prompts, gen, kw,
        frame_lengths=lens, bucket_frames=True)
    assert np.array_equal(np.asarray(out_cap), np.asarray(out_b)), \
        "bucketing the encoder extent changed greedy outputs"
    # the bucket actually shrank the extent and the padding bytes
    assert st_b["frames_run"] < st_cap["frames_run"]
    assert st_cap["padded_bytes"] > 0
    factor = st_cap["padded_bytes"] / max(st_b["padded_bytes"], 1)
    assert factor >= 2.0, (st_cap, st_b)
    # identical true content, smaller swept extent
    assert st_b["true_frames"] == st_cap["true_frames"]


def test_encoder_masked_rows_independent_of_extent():
    """Valid encoder rows must be bit-identical whether the batch is
    padded to capacity or to the bucket — the invariant bucketing
    relies on."""
    cfg, model, params, _, kw, lens = _setup()
    frames = np.asarray(kw["frames"])
    cap = frames.shape[1]
    lens = np.minimum(np.asarray(lens), cap)
    mask = np.arange(cap)[None, :] < lens[:, None]
    fz = np.where(mask[..., None], frames, 0.0)
    bucket = serve_mod._bucket_pow2(int(lens.max()), cap)
    assert bucket < cap  # seeded lengths leave bucketing headroom
    e_cap = model.encode(params, jnp.asarray(fz), jnp.asarray(lens))
    e_b = model.encode(params, jnp.asarray(fz[:, :bucket]),
                       jnp.asarray(lens))
    for b in range(frames.shape[0]):
        n = int(lens[b])
        assert bool(jnp.all(e_cap[b, :n] == e_b[b, :n])), b


def test_cross_kv_mask_rides_the_cache():
    cfg, model, params, prompts, kw, lens = _setup()
    cache = model.init_cache(params, prompts.shape[0], 32,
                             kv_dtype=jnp.float32,
                             frame_lengths=jnp.asarray(lens), **kw)
    subs = [s for s in cache["main"].values() if "xvalid" in s]
    assert subs, "encdec cache should carry the xvalid mask"
    xv = subs[0]["xvalid"]
    assert xv.shape[-1] == kw["frames"].shape[1]
    assert xv.dtype == jnp.bool_
    # decode_step must thread the mask through unchanged
    dparams = model.decode_params(params)
    _, cache2 = model.decode_step(dparams, cache, prompts[:, :1])
    subs2 = [s for s in cache2["main"].values() if "xvalid" in s]
    assert subs2 and bool(jnp.all(subs2[0]["xvalid"] == xv))


def test_unbucketed_cache_has_no_mask():
    """Without frame_lengths the cache layout is unchanged (no xvalid
    leaf) — the pre-existing whisper decode path keeps its trace."""
    cfg, model, params, prompts, kw, _ = _setup()
    cache = model.init_cache(params, prompts.shape[0], 32,
                             kv_dtype=jnp.float32, **kw)
    assert not any("xvalid" in s for s in cache["main"].values())


def test_bucket_pow2():
    assert serve_mod._bucket_pow2(3, 64) == 8   # lo floor
    assert serve_mod._bucket_pow2(9, 64) == 16
    assert serve_mod._bucket_pow2(16, 64) == 16
    assert serve_mod._bucket_pow2(33, 64) == 64
    assert serve_mod._bucket_pow2(200, 64) == 64  # capped at capacity
