"""Serving engine: batched-prefill equivalence (bit-identical cache,
identical greedy continuations), continuous batching against per-sequence
references, slot recycling, honest throughput accounting, and the
serve-side Tier-3 KV-cache waste detectors."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import ProfilerConfig
from repro.core.detectors import ServingDetectors
from repro.models.zoo import build_model
from repro.serve.engine import Request, ServeEngine

KEY = jax.random.PRNGKey(0)


def _model(arch="qwen3-1.7b"):
    cfg = dataclasses.replace(registry.get_config(arch).smoke(),
                              dtype="float32")
    model = build_model(cfg)
    return cfg, model, model.init(KEY)


def _reference_generate(model, params, prompt, gen, max_len):
    """Per-sequence token-by-token greedy loop (the seed serving path)."""
    cache = model.init_cache(params, 1, max_len, kv_dtype=jnp.float32)
    toks = jnp.asarray(prompt)[None, :]
    for t in range(prompt.size):
        lg, cache = model.decode_step(params, cache, toks[:, t:t + 1])
    out = [int(jnp.argmax(lg[:, -1]))]
    cur = jnp.asarray([[out[-1]]], jnp.int32)
    for _ in range(gen - 1):
        lg, cache = model.decode_step(params, cache, cur)
        cur = jnp.argmax(lg[:, -1:], -1).astype(jnp.int32)
        out.append(int(cur[0, 0]))
    return out, cache


# ----------------------------------------------------------------------
# Batched prefill == token-by-token loop (the PR's regression criterion)
# ----------------------------------------------------------------------
def test_batched_prefill_bit_identical_cache_and_continuation():
    cfg, model, params = _model()
    B, P, G = 2, 12, 5
    toks = jax.random.randint(KEY, (B, P), 0, cfg.vocab_size)
    max_len = P + G + 1

    loop = model.init_cache(params, B, max_len, kv_dtype=jnp.float32)
    for t in range(P):
        lg_loop, loop = model.decode_step(params, loop, toks[:, t:t + 1])
    batched = model.init_cache(params, B, max_len, kv_dtype=jnp.float32)
    lg_pre, batched = model.prefill(params, batched, toks)

    for a, b in zip(jax.tree_util.tree_leaves(loop),
                    jax.tree_util.tree_leaves(batched)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(lg_loop[:, -1]),
                                  np.asarray(lg_pre[:, -1]))

    def continue_greedy(cache, lg):
        nxt = jnp.argmax(lg[:, -1:], -1).astype(jnp.int32)
        out = [np.asarray(nxt)]
        for _ in range(G - 1):
            lg, cache = model.decode_step(params, cache, nxt)
            nxt = jnp.argmax(lg[:, -1:], -1).astype(jnp.int32)
            out.append(np.asarray(nxt))
        return np.concatenate(out, 1)
    np.testing.assert_array_equal(continue_greedy(loop, lg_loop),
                                  continue_greedy(batched, lg_pre))


def test_prefill_per_row_lengths_match_per_sequence():
    """Padded variable-length prefill with per-slot write indices equals
    each sequence prefilled alone."""
    cfg, model, params = _model()
    B, Pmax, G = 2, 10, 4
    lens = np.array([10, 6])
    toks = np.asarray(jax.random.randint(KEY, (B, Pmax), 0, cfg.vocab_size))
    max_len = Pmax + G + 2

    cache = model.init_cache(params, B, max_len, kv_dtype=jnp.float32)
    cache = model.with_cache_index(cache, jnp.zeros((B,), jnp.int32))
    lg, cache = model.prefill(params, cache, jnp.asarray(toks),
                              lengths=jnp.asarray(lens))
    nxt = jnp.argmax(lg[jnp.arange(B), lens - 1], -1).astype(jnp.int32)
    got = [np.asarray(nxt)]
    cur = nxt[:, None]
    for _ in range(G - 1):
        lg, cache = model.decode_step(params, cache, cur)
        cur = jnp.argmax(lg[:, -1:], -1).astype(jnp.int32)
        got.append(np.asarray(cur[:, 0]))
    got = np.stack(got, 1)

    for b in range(B):
        ref, _ = _reference_generate(model, params, toks[b, :lens[b]], G,
                                     max_len)
        np.testing.assert_array_equal(got[b], np.array(ref))


# ----------------------------------------------------------------------
# Continuous batching
# ----------------------------------------------------------------------
def test_engine_continuous_batching_matches_isolated_requests():
    """More requests than slots, staggered arrivals, different prompt
    lengths and budgets: every request's greedy output must equal the
    same prompt served alone."""
    cfg, model, params = _model()
    max_len = 24
    eng = ServeEngine(model, params, num_slots=2, max_len=max_len)
    rng = np.random.RandomState(3)
    reqs = []
    for i, (plen, gen, arr) in enumerate(
            [(8, 4, 0), (5, 6, 0), (7, 3, 1), (6, 5, 4)]):
        toks = rng.randint(0, cfg.vocab_size, size=plen).astype(np.int32)
        reqs.append(Request(rid=f"q{i}", tokens=toks,
                            max_new_tokens=gen, arrival=arr))
        eng.submit(reqs[-1])
    finished = eng.run(max_steps=200)
    assert sorted(finished) == [f"q{i}" for i in range(4)]
    for r in reqs:
        ref, _ = _reference_generate(model, params, r.tokens,
                                     r.max_new_tokens, max_len)
        assert finished[r.rid].generated == ref, r.rid


def test_engine_slot_recycling_and_eos():
    """EOS early exit frees the slot; a waiting request recycles it."""
    cfg, model, params = _model()
    # pick the token the model actually emits first as the EOS id so the
    # request terminates on step one
    rng = np.random.RandomState(1)
    toks = rng.randint(0, cfg.vocab_size, size=6).astype(np.int32)
    ref, _ = _reference_generate(model, params, toks, 1, 32)
    eos = ref[0]

    eng = ServeEngine(model, params, num_slots=1, max_len=32, eos_id=eos)
    eng.submit(Request(rid="a", tokens=toks, max_new_tokens=50))
    other = rng.randint(0, cfg.vocab_size, size=4).astype(np.int32)
    eng.submit(Request(rid="b", tokens=other, max_new_tokens=3))
    finished = eng.run(max_steps=100)
    assert finished["a"].generated == [eos]        # stopped at EOS
    assert len(finished["b"].generated) <= 3
    assert finished["b"].prefill_step >= finished["a"].finish_step


def test_engine_throughput_accounting_live_slots_only():
    """Prefill and decode tokens are tracked separately; decode counts
    live slots only (idle ticks do not inflate throughput)."""
    cfg, model, params = _model()
    eng = ServeEngine(model, params, num_slots=2, max_len=32)
    rng = np.random.RandomState(2)
    plens, gens = [6, 4], [2, 8]
    for i, (plen, gen) in enumerate(zip(plens, gens)):
        eng.submit(Request(
            rid=f"t{i}",
            tokens=rng.randint(0, cfg.vocab_size, size=plen).astype(np.int32),
            max_new_tokens=gen))
    eng.run(max_steps=100)
    assert eng.stats["prefill_tokens"] == sum(plens)
    # first token of each request comes from its prefill; every later
    # token is one live decode tick
    assert eng.stats["decode_tokens"] == sum(g - 1 for g in gens)
    # the batch kept ticking after t0 finished: ticks > live decode work
    assert eng.stats["ticks"] >= max(gens) - 1
    tp = eng.throughput()
    assert tp["prefill_tok_s"] > 0 and tp["decode_tok_s"] > 0


# ----------------------------------------------------------------------
# Serve-side Tier-3 detectors
# ----------------------------------------------------------------------
def test_engine_detectors_flag_injected_kv_waste():
    """Injected waste: a duplicated prompt (prefix-cache opportunity) and
    an early-finishing request whose slot idles while the batch keeps
    decoding (dead + silent KV stores)."""
    cfg, model, params = _model()
    det = ServingDetectors(ProfilerConfig(enabled=True, num_watchpoints=8,
                                          seed=0), sites_per_step=4)
    eng = ServeEngine(model, params, num_slots=2, max_len=48,
                      detectors=det)
    rng = np.random.RandomState(7)
    shared = rng.randint(0, cfg.vocab_size, size=8).astype(np.int32)
    # slot waste: w0 finishes after 2 tokens, w1 keeps the batch running
    eng.submit(Request(rid="w0", tokens=shared, max_new_tokens=2))
    eng.submit(Request(rid="w1", tokens=shared.copy(),    # duplicate prompt
                       max_new_tokens=30))
    eng.run(max_steps=200)

    rep = det.report
    fr = rep.fractions()
    kinds = {f.kind for f in rep.findings}
    # duplicated prompt: the second admission re-loads w0's prefix
    assert "silent_prefix_load" in kinds
    dup = [f for f in rep.findings if f.kind == "silent_prefix_load"]
    assert any("req:w0" in " ".join(f.c1) and "req:w1" in " ".join(f.c2)
               for f in dup)
    # w0's idle slot is rewritten every tick: dead stores (no live
    # request) whose values are identical (silent) — both trapped
    assert "dead_kv_store" in kinds
    assert fr["dead_kv_store"] > 0
    assert "silent_kv_store" in kinds, fr
    assert fr["silent_kv_store"] > 0.5, fr
    dead = [f for f in rep.findings if f.kind == "dead_kv_store"]
    assert all(len(f.c1) >= 1 and len(f.c2) >= 1 for f in dead)
    # ⟨C1,C2⟩: armed on the KV row, trapped at an engine step
    assert any("serve.kv" in f.c1[0] for f in dead)
    assert any(any("serve.engine" in c for c in f.c2) for f in dead)


def test_paged_mode_eliminates_detected_kv_waste():
    """The closed detect→optimize loop (ISSUE 3 acceptance): on the
    duplicated-prefix workload the dense layout's detectors flag silent
    prefix loads and dead/silent KV stores; the paged layout turns the
    prefixes into cache hits and drops idle/finished-slot writes, so the
    same detectors must report strictly lower waste fractions — while
    greedy outputs stay identical (covered in test_kv_cache)."""
    cfg, model, params = _model()
    rng = np.random.RandomState(7)
    shared = rng.randint(0, cfg.vocab_size, size=12).astype(np.int32)

    def run(kvl):
        det = ServingDetectors(ProfilerConfig(enabled=True,
                                              num_watchpoints=8, seed=0),
                               sites_per_step=4)
        eng = ServeEngine(model, params, num_slots=2, max_len=48,
                          detectors=det, kv_layout=kvl, page_size=16)
        # three requests sharing a 12-token prefix, staggered so each
        # admission can reuse the previous prefill's pages; w0 finishes
        # early and its slot idles while w1 keeps the batch decoding
        for i, (gen, arr) in enumerate([(2, 0), (20, 2), (4, 4)]):
            tail = rng.randint(0, cfg.vocab_size, size=8).astype(np.int32)
            eng.submit(Request(rid=f"w{i}",
                               tokens=np.concatenate([shared, tail]),
                               max_new_tokens=gen, arrival=arr))
        eng.run(max_steps=200)
        return det.report.fractions(), eng.stats

    rng_state = rng.get_state()
    fr_dense, st_dense = run("dense")
    rng.set_state(rng_state)               # identical prompt tails
    fr_paged, st_paged = run("paged")

    # dense flags the waste...
    assert fr_dense["silent_prefix_load"] > 0
    assert fr_dense.get("dead_kv_store", 0) > 0
    # ...paged eliminates it: strictly lower where dense flagged, and
    # never higher anywhere
    assert (fr_paged.get("silent_prefix_load", 0.0)
            < fr_dense["silent_prefix_load"]), (fr_dense, fr_paged)
    assert (fr_paged.get("dead_kv_store", 0.0)
            < fr_dense["dead_kv_store"]), (fr_dense, fr_paged)
    assert (fr_paged.get("silent_kv_store", 0.0)
            <= fr_dense.get("silent_kv_store", 0.0)), (fr_dense, fr_paged)
    # the eliminated Def.-3 waste shows up as prefix-cache hits instead
    assert st_paged["prefix_hits"] >= 1
    assert st_dense["prefix_hits"] == 0
    assert (st_paged["prefill_computed_tokens"]
            < st_dense["prefill_computed_tokens"])


def test_paged_detector_traps_survive_page_free():
    """Stale traps disarm on page free (the substrate's out-of-extent
    rule): after a heavy paged run with recycling, no armed watchpoint
    may reference a page that is currently unallocated."""
    cfg, model, params = _model()
    det = ServingDetectors(ProfilerConfig(enabled=True, num_watchpoints=8,
                                          seed=1), sites_per_step=4)
    eng = ServeEngine(model, params, num_slots=2, max_len=32,
                      detectors=det, kv_layout="paged", page_size=8)
    rng = np.random.RandomState(9)
    for i in range(6):
        eng.submit(Request(
            rid=f"s{i}",
            tokens=rng.randint(0, cfg.vocab_size,
                               size=rng.randint(4, 12)).astype(np.int32),
            max_new_tokens=1 + i % 3, arrival=i))
    eng.run(max_steps=200)
    eng.kv.check()
    allocated = {p for p in range(eng.kv.num_pages)
                 if eng.kv.alloc.refcount[p] > 0}
    for wp in det.wp.armed():
        assert wp.meta["page"] in allocated, wp.meta


def test_engine_rejects_unindexed_families():
    cfg = registry.get_config("zamba2-1.2b").smoke()
    model = build_model(cfg)
    params = model.init(KEY)
    with pytest.raises(ValueError):
        ServeEngine(model, params, num_slots=2, max_len=16)


def test_engine_stats_monotonic_across_generations():
    """Satellite of the fleet tier: the router and the fleet benchmarks
    aggregate per-replica counters by snapshot deltas, which silently
    undercounts if any counter ever decreases (the historical symptom:
    padded_prefill_tokens zeroed between waves). Stats are now
    `MonotonicStats`: every numeric key is non-decreasing across full
    serve generations with slot recycling, and an explicit decrement
    raises instead of corrupting fleet accounting."""
    cfg, model, params = _model()
    eng = ServeEngine(model, params, num_slots=2, max_len=24,
                      kv_layout="paged", page_size=8)
    rng = np.random.RandomState(3)
    snap = dict(eng.stats)
    for wave in range(3):
        for b in range(3):     # 3 requests > 2 slots: recycling each wave
            eng.submit(Request(
                rid=f"w{wave}r{b}",
                tokens=rng.randint(0, cfg.vocab_size,
                                   size=rng.randint(6, 16)).astype(np.int32),
                max_new_tokens=2 + b))
        eng.run(max_steps=300)
        for k, v in snap.items():
            if isinstance(v, (int, float)):
                assert eng.stats[k] >= v, \
                    f"stat {k} decreased across generations: {v} -> " \
                    f"{eng.stats[k]}"
        snap = dict(eng.stats)
    assert eng.stats["prefill_tokens"] > 0 and eng.stats["ticks"] > 0

    with pytest.raises(ValueError, match="may not decrease"):
        eng.stats["ticks"] = eng.stats["ticks"] - 1
    eng.stats["ticks"] = eng.stats["ticks"]          # equal is fine
    eng.stats["new_gauge"] = 1.5                     # fresh keys are fine
    before = dict(eng.stats)
    eng.stats["new_gauge"] += 1
    assert eng.stats["new_gauge"] == 2.5 and before["new_gauge"] == 1.5
