"""Substrate tests: optimizer, schedule, data determinism, prefetch,
checkpoint roundtrip/atomicity, fault monitor scenarios."""
import os
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypo import given, settings, st

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import registry
from repro.configs.base import TrainConfig
from repro.data.pipeline import Prefetcher
from repro.data.synthetic import batch_at
from repro.optim import adamw
from repro.optim.schedule import lr_at
from repro.runtime.fault import FleetMonitor


# ----------------------------------------------------------------------
def test_adamw_optimizes_quadratic():
    tc = TrainConfig(learning_rate=0.1, weight_decay=0.0, warmup_steps=0,
                     total_steps=100)
    target = jnp.asarray([3.0, -2.0, 0.5])
    params = {"w": jnp.zeros(3)}
    opt = adamw.init(params)
    for step in range(150):
        grads = {"w": 2 * (params["w"] - target)}
        params, opt = adamw.update(tc, grads, opt, params,
                                   jnp.float32(0.05), jnp.int32(step))
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)


def test_clip_by_global_norm():
    g = {"a": jnp.ones((10,)) * 100.0}
    clipped, norm = adamw.clip_by_global_norm(g, 1.0)
    assert float(adamw.global_norm(clipped)) <= 1.0 + 1e-5
    assert float(norm) > 100.0


@given(st.integers(0, 2000))
@settings(max_examples=30, deadline=None)
def test_schedule_bounds(step):
    tc = TrainConfig(learning_rate=3e-4, warmup_steps=100, total_steps=1000)
    lr = float(lr_at(tc, step))
    assert 0.0 <= lr <= tc.learning_rate + 1e-9


# ----------------------------------------------------------------------
def test_data_determinism_and_host_sharding():
    cfg = registry.get_config("qwen3-1.7b").smoke()
    a = batch_at(cfg, 8, 64, seed=3, step=7)
    b = batch_at(cfg, 8, 64, seed=3, step=7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = batch_at(cfg, 8, 64, seed=3, step=8)
    assert not np.array_equal(a["tokens"], c["tokens"])
    h0 = batch_at(cfg, 8, 64, seed=3, step=7, host=0, num_hosts=2)
    h1 = batch_at(cfg, 8, 64, seed=3, step=7, host=1, num_hosts=2)
    assert h0["tokens"].shape[0] == 4
    assert not np.array_equal(h0["tokens"], h1["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])


def test_prefetcher_order_and_error():
    pf = Prefetcher(iter(range(10)), depth=3)
    assert list(pf) == list(range(10))

    def boom():
        yield 1
        raise ValueError("boom")
    pf = Prefetcher(boom())
    assert next(pf) == 1
    with pytest.raises(ValueError):
        next(pf)


# ----------------------------------------------------------------------
def test_checkpoint_roundtrip_and_gc(tmp_path):
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "n": {"b": jnp.ones((5,), jnp.int32)},
            "s": jnp.float32(7)}
    ck = Checkpointer(tmp_path, keep=2)
    for s in (10, 20, 30):
        ck.save(s, tree)
    assert ck.all_steps() == [20, 30]          # keep=2 gc'd step 10
    template = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(jnp.shape(x), jnp.asarray(x).dtype), tree)
    out = ck.restore(template)
    for a, b in zip(jax.tree_util.tree_leaves(out),
                    jax.tree_util.tree_leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomicity(tmp_path):
    """A stale .tmp dir from a crashed writer is never listed/restored."""
    ck = Checkpointer(tmp_path)
    ck.save(5, {"x": jnp.ones(3)})
    (tmp_path / "step_00000009.tmp").mkdir()
    assert ck.latest_step() == 5


def test_checkpoint_async(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save_async(1, {"x": jnp.ones((256, 256))})
    ck.wait()
    assert ck.latest_step() == 1


def test_checkpoint_shape_mismatch_raises(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save(1, {"x": jnp.ones((4,))})
    with pytest.raises(ValueError):
        ck.restore({"x": jax.ShapeDtypeStruct((5,), jnp.float32)})


# ----------------------------------------------------------------------
class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_fault_monitor_dead_host_elastic_restart():
    clk = FakeClock()
    mon = FleetMonitor([0, 1, 2, 3], dead_after=10.0, clock=clk)
    clk.t = 5.0
    for h in (0, 1, 2, 3):
        mon.heartbeat(h, 1.0)
    assert mon.plan()["action"] == "continue"
    clk.t = 20.0
    for h in (0, 1, 2):
        mon.heartbeat(h, 1.0)                 # host 3 silent
    plan = mon.plan()
    assert plan["action"] == "elastic_restart"
    assert plan["dead"] == [3]
    assert plan["survivors"] == [0, 1, 2]


def test_fault_monitor_straggler_detection():
    clk = FakeClock()
    mon = FleetMonitor([0, 1, 2, 3], dead_after=1e9, straggler_factor=2.0,
                       straggler_patience=2, clock=clk)
    for tick in range(3):
        for h in (0, 1, 2):
            mon.heartbeat(h, 1.0)
        mon.heartbeat(3, 5.0)                 # consistently 5x median
        plan = mon.plan()
    assert plan["action"] == "mitigate_stragglers"
    assert plan["hosts"] == [3]


def test_fault_monitor_restart_budget():
    clk = FakeClock()
    mon = FleetMonitor([0, 1], dead_after=1.0, max_restarts=1, clock=clk)
    clk.t = 5.0
    mon.heartbeat(0)
    assert mon.plan()["action"] == "elastic_restart"
    clk.t = 10.0
    mon.heartbeat(0)
    assert mon.plan()["action"] == "abort"


# ----------------------------------------------------------------------
# Event-substrate determinism: stale-trap disarm under equal-address ties
# ----------------------------------------------------------------------
def _tie_engine_profile():
    """Arm several same-address watchpoints at spread offsets, then trap
    them with a SHORTER store at that (recycled) address: high-offset
    watchpoints are stale (the watched element no longer exists) and
    must disarm without classification; low-offset ones classify."""
    from repro.configs.base import ProfilerConfig
    from repro.core.events import EventEngine, MemEvent, STORE

    eng = EventEngine(ProfilerConfig(enabled=True, period=1,
                                     num_watchpoints=4, seed=0))
    vals = np.arange(16.0, dtype=np.float32)
    eng.on_event(MemEvent(kind=STORE, address=100, nelems=16, itemsize=4,
                          values=vals, ctx=("writerA",)))
    armed_before = [(w.offset, w.meta) for w in eng.wp[STORE].armed()]
    eng.on_event(MemEvent(kind=STORE, address=100, nelems=8, itemsize=4,
                          values=vals[:8], ctx=("writerB",)))
    return eng, armed_before


def test_stale_trap_disarm_deterministic_under_address_ties():
    """Two identical event streams -> byte-identical profiles, and the
    equal-address tie resolves the same way every run: every stale
    watchpoint (offset past the shorter event) disarms unclassified, so
    only the in-extent ones contribute checked counts."""
    eng1, armed1 = _tie_engine_profile()
    eng2, armed2 = _tie_engine_profile()
    assert armed1 == armed2
    assert eng1.finalize().to_json() == eng2.finalize().to_json()

    prof = eng1.profile
    in_extent = sum(1 for off, _ in armed1 if off < 8)
    stale = sum(1 for off, _ in armed1 if off >= 8)
    assert stale >= 1 and in_extent >= 1       # the tie is exercised
    # stale watchpoints disarmed WITHOUT classification: only in-extent
    # ones were checked against Defs. 1-2
    assert (prof.checked.get("dead_store", 0)
            + prof.checked.get("silent_store", 0)) == in_extent
    # and nothing stayed armed at the recycled address
    assert all(w.address != 100 or w.context != ("writerA",)
               for w in eng1.wp["store"].armed())


def test_tier3_leaf_addresses_stable_across_processes():
    """Detector leaf addresses must not depend on PYTHONHASHSEED: the
    seed-era hash(path) salted addresses per process, so equal-address
    collisions — and trap/disarm behavior — varied run to run. crc32 is
    process-independent and pinned here by value."""
    import zlib
    from repro.core.detectors import _leaf_event
    leaf = jnp.zeros((4,), jnp.float32)
    ev = _leaf_event("params.layer0.w", leaf)
    assert ev.address == zlib.crc32(b"params.layer0.w") & 0x7FFFFFFF
    assert ev.address == 307156108      # frozen: any drift is a break
