"""HLO cost model: trip-count accounting, dot flops, collective wire
model; Tier-2 waste analysis finds planted redundancy."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hlo_cost import HloCostModel, analyze
from repro.core.hlo_waste import analyze_waste

ONE = 2 * 128 ** 3  # flops of a 128^3 matmul


def _scan_fn(length):
    def f(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=length)
        return y
    return f


def test_while_trip_count_multiplied():
    x = jnp.ones((128, 128))
    w = jnp.ones((128, 128))
    c = jax.jit(_scan_fn(7)).lower(x, w).compile()
    got = analyze(c.as_text()).flops
    assert abs(got / ONE - 7) < 0.1


def test_grad_and_remat_flops():
    x = jnp.ones((128, 128))
    w = jnp.ones((128, 128))

    def g(x, w):
        return _scan_fn(7)(x, w).sum()
    c = jax.jit(jax.grad(g, argnums=1)).lower(x, w).compile()
    assert abs(analyze(c.as_text()).flops / ONE - 21) < 1.0

    def h(x, w):
        def body(c, _):
            return jax.checkpoint(lambda c: jnp.tanh(c @ w))(c), None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y.sum()
    c2 = jax.jit(jax.grad(h, argnums=1)).lower(x, w).compile()
    # remat adds ~7 recompute matmuls on top of ~21
    assert abs(analyze(c2.as_text()).flops / ONE - 28) < 1.5


def test_dot_flops_exact():
    a = jnp.ones((64, 32))
    b = jnp.ones((32, 96))
    c = jax.jit(lambda a, b: a @ b).lower(a, b).compile()
    got = analyze(c.as_text()).flops
    assert abs(got - 2 * 64 * 32 * 96) / (2 * 64 * 32 * 96) < 0.05


def test_nested_scan_multiplies():
    def f(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, None
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y
    x = jnp.ones((128, 128))
    w = jnp.ones((128, 128))
    c = jax.jit(f).lower(x, w).compile()
    assert abs(analyze(c.as_text()).flops / ONE - 15) < 0.5


def test_wire_model_factors():
    """Synthetic HLO exercising every collective kind."""
    hlo = """
HloModule m

ENTRY %main (p0: f32[1024]) -> f32[1024] {
  %p0 = f32[1024]{0} parameter(0)
  %ag = f32[1024]{0} all-gather(%p0), replica_groups=[2,8]<=[16], dimensions={0}
  %ar = f32[1024]{0} all-reduce(%ag), replica_groups=[2,8]<=[16], to_apply=%add
  ROOT %cp = f32[1024]{0} collective-permute(%ar), source_target_pairs={{0,1}}
}
"""
    cm = HloCostModel(hlo)
    c = cm.total()
    b = 1024 * 4
    want = b * 7 / 8 + 2 * b * 7 / 8 + b      # ag + ar + permute
    assert abs(c.coll_wire_bytes - want) / want < 0.01
    assert c.coll_by_kind["all-gather"] > 0


def test_tier2_finds_redundant_gather_pattern():
    """Two gathers of the same tensor -> redundant-collective finding."""
    hlo = """
HloModule m

ENTRY %main (p0: f32[4096]) -> f32[4096] {
  %p0 = f32[4096]{0} parameter(0)
  %ag1 = f32[4096]{0} all-gather(%p0), replica_groups=[2,8]<=[16], dimensions={0}
  %ag2 = f32[4096]{0} all-gather(%p0), replica_groups=[2,8]<=[16], dimensions={0}
  ROOT %s = f32[4096]{0} add(%ag1, %ag2)
}
"""
    rep = analyze_waste(hlo)
    assert rep.totals["redundant_collective_bytes"] > 0
    assert rep.redundant_collectives[0]["copies"] == 2


# ---------------------------------------------------------------------
# recompute fingerprinting (shapes + operand producer provenance)
# ---------------------------------------------------------------------
def test_recompute_not_flagged_for_different_producers():
    """Two matmuls with IDENTICAL shapes but different operand producers
    are different computations, not recompute (the old shapes-only
    fingerprint false-flagged every same-shaped layer pair)."""
    hlo = """
HloModule m

ENTRY %main (p0: f32[128,128]) -> f32[128,128] {
  %p0 = f32[128,128]{1,0} parameter(0)
  %e1 = f32[128,128]{1,0} exponential(%p0)
  %t1 = f32[128,128]{1,0} tanh(%p0)
  %d1 = f32[128,128]{1,0} dot(%e1, %e1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %d2 = f32[128,128]{1,0} dot(%t1, %t1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %s = f32[128,128]{1,0} add(%d1, %d2)
}
"""
    rep = analyze_waste(hlo)
    assert rep.recompute == []
    assert rep.totals["recompute_flops"] == 0


def test_recompute_flagged_for_true_duplicate():
    hlo = """
HloModule m

ENTRY %main (p0: f32[128,128]) -> f32[128,128] {
  %p0 = f32[128,128]{1,0} parameter(0)
  %e1 = f32[128,128]{1,0} exponential(%p0)
  %d1 = f32[128,128]{1,0} dot(%e1, %e1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %d2 = f32[128,128]{1,0} dot(%e1, %e1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %s = f32[128,128]{1,0} add(%d1, %d2)
}
"""
    rep = analyze_waste(hlo)
    assert len(rep.recompute) == 1
    assert rep.recompute[0]["copies"] == 2
    assert rep.totals["recompute_flops"] > 0


def test_recompute_covers_convolution():
    hlo = """
HloModule m

ENTRY %main (p0: f32[1,8,8,4], w: f32[3,3,4,4]) -> f32[1,8,8,4] {
  %p0 = f32[1,8,8,4]{3,2,1,0} parameter(0)
  %w = f32[3,3,4,4]{3,2,1,0} parameter(1)
  %c1 = f32[1,8,8,4]{3,2,1,0} convolution(%p0, %w), window={size=3x3 pad=1_1x1_1}, dim_labels=b01f_01io->b01f
  %c2 = f32[1,8,8,4]{3,2,1,0} convolution(%p0, %w), window={size=3x3 pad=1_1x1_1}, dim_labels=b01f_01io->b01f
  ROOT %s = f32[1,8,8,4]{3,2,1,0} add(%c1, %c2)
}
"""
    rep = analyze_waste(hlo)
    assert len(rep.recompute) == 1
    assert rep.recompute[0]["fingerprint"].startswith("convolution")


def test_recompute_covers_large_reductions_only():
    hlo = """
HloModule m

ENTRY %main (p0: f32[500000], q0: f32[16]) -> f32[] {
  %p0 = f32[500000]{0} parameter(0)
  %q0 = f32[16]{0} parameter(1)
  %z = f32[] constant(0)
  %r1 = f32[] reduce(%p0, %z), dimensions={0}, to_apply=%add
  %r2 = f32[] reduce(%p0, %z), dimensions={0}, to_apply=%add
  %s1 = f32[] reduce(%q0, %z), dimensions={0}, to_apply=%add
  %s2 = f32[] reduce(%q0, %z), dimensions={0}, to_apply=%add
  %a = f32[] add(%r1, %r2)
  %b = f32[] add(%s1, %s2)
  ROOT %out = f32[] add(%a, %b)
}
"""
    rep = analyze_waste(hlo)
    # the 2 MB reduce duplicates; the 64 B one is below the size floor
    assert len(rep.recompute) == 1
    assert rep.recompute[0]["fingerprint"].startswith("reduce")
    assert "f32[500000]" in rep.recompute[0]["fingerprint"]


# ---------------------------------------------------------------------
# reshard threshold parameter + summary rows
# ---------------------------------------------------------------------
_RESHARD_HLO = """
HloModule m

ENTRY %main (p0: f32[250000]) -> f32[250000] {
  %p0 = f32[250000]{0} parameter(0)
  ROOT %cp = f32[250000]{0} copy(%p0), metadata={op_name="jit(f)/reshard"}
}
"""


def test_reshard_threshold_is_a_parameter_and_summary_prints_rows():
    # 1 MB copy: under the 64 MB default, over a lowered threshold
    rep = analyze_waste(_RESHARD_HLO)
    assert rep.reshard_copies == []
    rep = analyze_waste(_RESHARD_HLO, reshard_threshold=1e5)
    assert len(rep.reshard_copies) == 1
    assert rep.totals["reshard_bytes"] > 0
    text = rep.summary()
    assert "[reshard]" in text
    assert "reshard" in text.split("[reshard]")[1]    # op_name provenance
