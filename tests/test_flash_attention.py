"""flash_attention.py edge cases the original sweep missed: sequence
lengths that are NOT multiples of block_q/block_k (the padded tail must
be masked, not attended), GQA group ratios > 1 under those ragged
shapes, and bf16 inputs — each against the pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention

KEY = jax.random.PRNGKey(42)


def _qkv(B, Sq, Skv, Hq, Hkv, D, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Sq, Hq, D), dtype)
    k = jax.random.normal(ks[1], (B, Skv, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, Skv, Hkv, D), dtype)
    return q, k, v


def _check(q, k, v, causal, **kw):
    out = flash_attention(q, k, v, causal=causal, interpret=True, **kw)
    want = ref.attention_ref(q, k, v, causal=causal)
    tol = 2e-2 if q.dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("Sq,Skv", [
    (100, 100),    # not a multiple of either block size
    (33, 97),      # both ragged, primes
    (130, 64),     # q ragged only
    (64, 70),      # kv ragged only
    (1, 100),      # single-row q against ragged kv
])
@pytest.mark.parametrize("causal", [True, False])
def test_ragged_seq_not_block_multiple(Sq, Skv, causal):
    if causal and Sq > Skv:
        pytest.skip("causal ref assumes q suffix-aligned to kv")
    q, k, v = _qkv(2, Sq, Skv, 4, 2, 32, jnp.float32)
    _check(q, k, v, causal, block_q=32, block_k=32)


@pytest.mark.parametrize("Hq,Hkv", [(8, 2), (6, 3), (8, 1)])
def test_gqa_groups_on_ragged_seq(Hq, Hkv):
    q, k, v = _qkv(1, 100, 100, Hq, Hkv, 16, jnp.float32)
    _check(q, k, v, True, block_q=32, block_k=32)


@pytest.mark.parametrize("Sq,Skv,causal", [
    (100, 100, True), (33, 97, False), (96, 96, True),
])
def test_bf16_ragged_and_aligned(Sq, Skv, causal):
    q, k, v = _qkv(2, Sq, Skv, 8, 2, 32, jnp.bfloat16)
    _check(q, k, v, causal, block_q=32, block_k=32)


def test_block_larger_than_seq():
    # whole sequence fits in one (padded) block
    q, k, v = _qkv(1, 20, 20, 4, 4, 32, jnp.float32)
    _check(q, k, v, True, block_q=128, block_k=128)


def test_jit_and_vmap_compose():
    q, k, v = _qkv(2, 100, 100, 4, 2, 16, jnp.float32)
    f = jax.jit(lambda q, k, v: flash_attention(
        q, k, v, causal=True, interpret=True, block_q=32, block_k=32))
    out = f(q, k, v)
    want = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)
