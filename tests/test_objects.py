"""Object tier (DESIGN.md § Object tier): DJXPerf-style registry with
allocation-site provenance + OJXPerf-style replica detection, and the
content-addressed dedup that turns the replica findings into zero.

The acceptance pair at the bottom is the PR's story: a duplicated-prefix
trace whose duplicates land in the SAME burst (dispatched before either
publishes, with the prefix ending mid-page so granularity boundaries
mismatch) produces bit-identical KV pages across replicas that the
PrefixIndex missed — and the router+engine ``content_dedup`` drives the
cross-replica bytes to exactly 0 with greedy outputs unchanged.
"""
import jax
import numpy as np
import pytest

from repro.configs import registry as arch_registry
from repro.core.findings import TIER_OBJECT, WasteProfile
from repro.core.objects import ObjectRegistry, register_tree
from repro.core.replicas import (FIXES, ReplicaDetector,
                                 cross_replica_bytes, object_digest)
from repro.models.zoo import build_model
from repro.serve.decode import StepCache
from repro.serve.engine import Request, ServeEngine
from repro.serve.router import FleetRouter
from repro.serve.workload import make_trace


# ----------------------------------------------------------------------
# Registry basics: provenance, lifecycle, ownership
# ----------------------------------------------------------------------
def test_registry_provenance_and_lifecycle():
    reg = ObjectRegistry()
    rec = reg.register("replica0/kv/page3", "kv_page", 4096)
    assert rec.site.startswith("test_objects.py:")
    assert rec.func == "test_registry_provenance_and_lifecycle"
    assert rec.owner == "replica0"
    assert rec.object_key == f"kv_page|replica0/kv/page3|{rec.site}"
    assert len(reg) == 1 and reg.get(rec.oid) is rec
    assert reg.nbytes_live("kv_page") == 4096
    reg.release(rec.oid)
    assert len(reg) == 0 and reg.get(rec.oid) is None
    reg.release(rec.oid)                 # double release is a no-op


def test_register_tree_names_and_reader():
    reg = ObjectRegistry()
    tree = {"a": {"w": np.ones((4, 4), np.float32)},
            "b": np.zeros((8,), np.float32)}
    recs = register_tree(reg, "replica1/params", tree)
    names = {r.name for r in recs}
    assert "replica1/params/a.w" in names
    assert all(r.kind == "param" for r in recs)
    assert all(r.owner == "replica1" for r in recs)
    w = next(r for r in recs if r.name.endswith("a.w"))
    assert np.array_equal(w.reader(), np.ones((4, 4), np.float32))
    assert register_tree(None, "x", tree) == []   # registry off: no-op


# ----------------------------------------------------------------------
# Content digest: replicas always collide, non-replicas don't
# ----------------------------------------------------------------------
def test_object_digest_small_and_sampled():
    rng = np.random.RandomState(0)
    small = rng.rand(100).astype(np.float32)
    assert object_digest(small) == object_digest(small.copy())
    other = small.copy()
    other[50] += 1.0
    assert object_digest(small) != object_digest(other)
    # shape/dtype qualify the digest even for identical bytes
    assert object_digest(small) != object_digest(small.reshape(4, 25))
    assert (object_digest(np.zeros(8, np.float32))
            != object_digest(np.zeros(8, np.int32)))
    # large buffers hash sampled chunks: identical still collides,
    # a differing tail (the near-duplicate KV suffix case) never does
    big = rng.rand(1 << 16).astype(np.float64)       # 512 KB > _FULL_BELOW
    assert object_digest(big) == object_digest(big.copy())
    tail = big.copy()
    tail[-1] += 1.0
    assert object_digest(big) != object_digest(tail)


# ----------------------------------------------------------------------
# Replica detector: weights duplicated across fleet replicas
# ----------------------------------------------------------------------
def test_weight_replicas_across_two_replicas():
    reg = ObjectRegistry()
    tree = {"wq": np.arange(64, dtype=np.float32),
            "wk": np.arange(64, dtype=np.float32) * 2}
    register_tree(reg, "replica0/params", tree)
    register_tree(reg, "replica1/params", tree)
    prof = ReplicaDetector(reg).scan()
    groups = [f for f in prof.findings if f.kind == "replica_param"]
    assert len(groups) == 2              # wq pair + wk pair
    for f in groups:
        assert f.tier == TIER_OBJECT
        assert f.count == 1 and f.bytes == 256.0
        assert f.meta["cross_replica"] is True
        assert f.meta["replicas"] == ["replica0", "replica1"]
        assert f.meta["fix"] == FIXES["replica_param"]
        assert f.meta["file"].endswith("test_objects.py")
    # duplicate bytes also billed to the object table (DJXPerf view)
    assert cross_replica_bytes(prof, "replica_param") == 512.0
    billed = {r["name"] for r in prof.top_objects()}
    assert billed == {"replica1/params/wq", "replica1/params/wk"}
    assert "top objects by attributed waste" in prof.render(by="object")


def test_identical_zero_opt_state_is_replica_but_zero_kv_page_is_not():
    reg = ObjectRegistry()
    z = np.zeros(32, np.float32)
    reg.register("opt/m/w", "opt_state", z.nbytes, reader=lambda: z)
    reg.register("opt/v/w", "opt_state", z.nbytes, reader=lambda: z)
    reg.register("replica0/kv/page0", "kv_page", z.nbytes,
                 reader=lambda: z)
    reg.register("replica1/kv/page0", "kv_page", z.nbytes,
                 reader=lambda: z)
    prof = ReplicaDetector(reg).scan()
    kinds = {f.kind for f in prof.findings}
    # zero moments ARE the lazy-materialize finding; all-zero KV pages
    # are unwritten budget capacity, skipped rather than flagged
    assert kinds == {"replica_opt_state"}


def test_scan_profile_merges_and_roundtrips():
    reg = ObjectRegistry()
    a = np.arange(128, dtype=np.float32)
    register_tree(reg, "replica0/params", {"w": a})
    register_tree(reg, "replica1/params", {"w": a})
    prof = ReplicaDetector(reg).scan()
    again = WasteProfile.from_json(prof.to_json())
    assert again.to_json() == prof.to_json()
    merged = WasteProfile(tier=TIER_OBJECT)
    merged.merge(prof)
    merged.merge(prof)
    f = next(f for f in merged.findings if f.kind == "replica_param")
    assert f.count == 2                  # §5.6 coalescing across scans
    row = merged.top_objects(1)[0]
    assert row["waste"]["replica"] == 2 * a.nbytes


# ----------------------------------------------------------------------
# Acceptance: same-burst duplicated prefixes at mismatched page
# boundaries -> cross-replica KV page replicas; content dedup -> zero
# ----------------------------------------------------------------------
@pytest.fixture(scope="module")
def fleet_env():
    cfg = arch_registry.get_config("qwen3-1.7b").smoke()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    # every burst is a same-tick pair of duplicates; the 36-token shared
    # prefix ends mid-page (page_size 8), the OJXPerf granularity-
    # boundary mismatch the pow2/page candidate ladder alone won't probe
    trace = make_trace(n_requests=6, vocab_size=cfg.vocab_size, seed=0,
                       arrival="bursty", burst_size=2, burst_gap=4,
                       prompt_len=(48, 48), gen_len=(4, 4), dup_rate=1.0,
                       n_prefixes=1, prefix_len=36)
    return model, params, trace, StepCache(model)


def _run_fleet(model, params, trace, step_cache, *, dedup):
    max_len = trace.max_prompt_len + trace.max_new_tokens + 1
    reg = ObjectRegistry()
    engines = [ServeEngine(model, params, num_slots=2, max_len=max_len,
                           kv_layout="paged", page_size=8,
                           num_pages=4 * (-(-max_len // 8)),
                           step_cache=step_cache, registry=reg,
                           owner=f"replica{i}", content_dedup=dedup)
               for i in range(2)]
    fleet = FleetRouter(engines, policy="prefix", seed=0,
                        content_dedup=dedup)
    fleet.submit_trace(trace)
    fleet.run()
    fleet.check()
    scan = ReplicaDetector(reg).scan()
    outs = {rid: list(r.generated) for rid, r in fleet.finished.items()}
    return fleet, scan, outs


def _single_outputs(model, params, trace, step_cache):
    max_len = trace.max_prompt_len + trace.max_new_tokens + 1
    eng = ServeEngine(model, params, num_slots=4, max_len=max_len,
                      kv_layout="paged", page_size=8,
                      step_cache=step_cache)
    for tr in sorted(trace.requests, key=lambda r: r.arrival):
        eng.submit(Request(rid=tr.rid, tokens=np.asarray(tr.tokens),
                           max_new_tokens=tr.max_new_tokens))
    eng.run()
    return {rid: list(r.generated) for rid, r in eng.finished.items()}


def test_same_burst_duplicates_make_cross_replica_kv_replicas(fleet_env):
    model, params, trace, sc = fleet_env
    fleet, scan, _ = _run_fleet(model, params, trace, sc, dedup=False)
    kv = [f for f in scan.findings
          if f.kind == "replica_kv_page" and f.meta["cross_replica"]]
    assert kv, "expected cross-replica duplicate KV pages pre-dedup"
    assert cross_replica_bytes(scan, "replica_kv_page") > 0
    for f in kv:
        # provenance points at the page allocator, the actionable site
        assert f.meta["file"].endswith("kv_cache.py")
        assert f.meta["fix"] == FIXES["replica_kv_page"]
    assert fleet.stats["content_dedup_routes"] == 0


def test_content_dedup_drives_cross_replica_kv_bytes_to_zero(fleet_env):
    model, params, trace, sc = fleet_env
    fleet, scan, outs = _run_fleet(model, params, trace, sc, dedup=True)
    assert cross_replica_bytes(scan, "replica_kv_page") == 0
    # the fix actually fired: at least one duplicate was co-located and
    # at least one same-group follower was deferred into an index hit
    assert fleet.stats["content_dedup_routes"] >= 1
    assert sum(e.stats["dedup_deferred"] for e in fleet.engines) >= 1
    # and the outputs are exactly the single-engine greedy stream
    assert outs == _single_outputs(model, params, trace, sc)
