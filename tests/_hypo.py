"""Property-testing shim: real hypothesis when installed, otherwise a
seeded-random fallback implementing the tiny subset the suite uses
(`given` + `settings(max_examples=..., deadline=...)` + `st.integers`),
so the tier-1 verify command runs in minimal environments instead of
erroring at collection time.
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import inspect
    import random
    import zlib

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

        def example(self, rng):
            return self.draw(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def sampled_from(options):
            options = list(options)
            return _Strategy(lambda rng: rng.choice(options))

        @staticmethod
        def lists(elems, min_size=0, max_size=10):
            return _Strategy(lambda rng: [
                elems.example(rng)
                for _ in range(rng.randint(min_size, max_size))])

    st = _Strategies()

    def settings(**kwargs):
        def deco(f):
            f._shim_settings = kwargs
            return f
        return deco

    def given(*strategies):
        def deco(f):
            conf = getattr(f, "_shim_settings", {})
            n = conf.get("max_examples", 25)

            def wrapper(*args, **kwargs):
                # deterministic per-test seed: failures reproduce
                rng = random.Random(zlib.crc32(f.__qualname__.encode()))
                for _ in range(n):
                    drawn = [s.example(rng) for s in strategies]
                    f(*args, *drawn, **kwargs)
            wrapper.__name__ = f.__name__
            wrapper.__qualname__ = f.__qualname__
            wrapper.__doc__ = f.__doc__
            wrapper.__module__ = f.__module__
            # strategy-drawn params must not look like pytest fixtures
            wrapper.__signature__ = inspect.Signature()
            return wrapper
        return deco
