"""Parity matrix for the Pallas serving kernels (interpret mode on CPU)
against the pure-jnp reference compositions, on HOSTILE page tables:
out-of-order pages, partially filled last pages, unmapped tail entries,
idle slots. Plus the engine-level bit-consistency and kernel-tier
waste-counter acceptance checks, and the 2-device sharded fast paths in
a subprocess.

The kernels must be drop-in: identical pool contents (bit for bit,
the store epilogue is an exact copy after the pool-dtype round-trip),
identical store-site counters, and attention outputs within float
tolerance of the scatter->gather->masked-attention reference.
"""
import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.kernels.flash_prefill import paged_window_attention
from repro.kernels.paged_attention import paged_decode_attention

KEY = jax.random.PRNGKey(0)

# one table exercising everything at once: slot 0 out-of-order pages +
# partially filled last mapped page, slot 1 short history + unmapped
# tail, slot 2 idle (negative sentinel: no store, output don't-care)
HOSTILE_PT = np.array([[5, 1, 6, -1],
                       [2, 7, -1, -1],
                       [-1, -1, -1, -1]], np.int32)
HOSTILE_IDX = np.array([9, 5, -1], np.int32)
# idle sentinel for width-S windows: the engine parks idle slots below
# -S so every window position stays negative (cf. test_sharding.py)
HOSTILE_IDX_W = np.array([9, 5, -8], np.int32)
B, P, PS, M = 3, 8, 4, 4
HQ, HKV, D = 4, 2, 8


def _pools(dtype):
    ks = jax.random.split(KEY, 2)
    pk = jax.random.normal(ks[0], (P, PS, HKV, D), dtype)
    pv = jax.random.normal(ks[1], (P, PS, HKV, D), dtype)
    return pk, pv


def _rows(S, seed=3, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, S, HQ, D), dtype)
    kn = jax.random.normal(ks[1], (B, S, HKV, D), dtype)
    vn = jax.random.normal(ks[2], (B, S, HKV, D), dtype)
    return q, kn, vn


def _decode_ref(q, kn, vn, pk, pv, pt, idx):
    cnt = kref.paged_store_counts(pk, pv, kn, vn, pt, idx, tol=0.0)
    ck, cv = kref.paged_update(pk, pv, kn, vn, pt, idx)
    gk, valid = kref.paged_gather(ck, pt)
    gv, _ = kref.paged_gather(cv, pt)
    out = kref.attention_ref(q, gk.astype(q.dtype), gv.astype(q.dtype),
                             causal=True, q_offset=idx, kv_len=idx + 1,
                             kv_valid=valid)
    return out, ck, cv, cnt


@pytest.mark.parametrize("pool_dtype", [jnp.float32, jnp.bfloat16])
def test_decode_kernel_hostile_table(pool_dtype):
    pk, pv = _pools(pool_dtype)
    q, kn, vn = _rows(1)
    pt, idx = jnp.asarray(HOSTILE_PT), jnp.asarray(HOSTILE_IDX)
    want, ck_r, cv_r, cnt_r = _decode_ref(q, kn, vn, pk, pv, pt, idx)
    out, lse, cnt = paged_decode_attention(q, kn, vn, pk, pv, pt, idx,
                                           interpret=True)
    live = np.asarray(idx) >= 0
    tol = 2e-2 if pool_dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out)[live], np.asarray(want)[live],
                               atol=tol, rtol=tol)
    np.testing.assert_array_equal(np.asarray(cnt), np.asarray(cnt_r))
    # idle slot: no stores attempted, no elements counted
    assert np.asarray(cnt)[~live].sum() == 0
    assert np.isfinite(np.asarray(lse)[live]).all()


def test_decode_kernel_silent_restore_counts():
    """Storing the value already in the pool (after dtype round-trip)
    must count every element as silent — paper Def. 2 at the store site."""
    pk, pv = _pools(jnp.float32)
    q, kn, vn = _rows(1)
    pt, idx = jnp.asarray(HOSTILE_PT), jnp.asarray(HOSTILE_IDX)
    ck, cv = kref.paged_update(pk, pv, kn, vn, pt, idx)
    _, _, cnt = paged_decode_attention(q, kn, vn, ck, cv, pt, idx,
                                       interpret=True)
    c = np.asarray(cnt)
    live = np.asarray(idx) >= 0
    per_tok = 2 * HKV * D
    assert (c[live, 0] == per_tok).all()
    assert (c[live, 1] == per_tok).all()       # every element silent
    assert (c[:, 2] == 0).all()                # all targets mapped


def test_decode_kernel_gqa_and_full_pages():
    # GQA 8:2, history exactly filling whole pages (idx on page boundary)
    pk = jax.random.normal(KEY, (6, PS, 2, 16), jnp.float32)
    pv = jax.random.normal(jax.random.PRNGKey(9), (6, PS, 2, 16),
                           jnp.float32)
    pt = jnp.array([[4, 2, 0], [1, 3, -1]], jnp.int32)
    idx = jnp.array([PS * 2, PS - 1], jnp.int32)   # new row opens page 3 / fills page 1
    ks = jax.random.split(jax.random.PRNGKey(11), 3)
    q = jax.random.normal(ks[0], (2, 1, 8, 16), jnp.float32)
    kn = jax.random.normal(ks[1], (2, 1, 2, 16), jnp.float32)
    vn = jax.random.normal(ks[2], (2, 1, 2, 16), jnp.float32)
    want, _, _, cnt_r = _decode_ref(q, kn, vn, pk, pv, pt, idx)
    out, _, cnt = paged_decode_attention(q, kn, vn, pk, pv, pt, idx,
                                         interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_array_equal(np.asarray(cnt), np.asarray(cnt_r))


@pytest.mark.parametrize("pool_dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("S", [1, 3, 5])
def test_window_kernel_store_hostile_table(pool_dtype, S):
    pk, pv = _pools(pool_dtype)
    q, kw, vw = _rows(S, seed=7)
    pt, idx = jnp.asarray(HOSTILE_PT), jnp.asarray(HOSTILE_IDX_W)
    out, lse, cnt, ck, cv = paged_window_attention(
        q, kw, vw, pk, pv, pt, idx, store=True, interpret=True)
    want, ck_r, cv_r, cnt_r = kref.paged_window_ref(
        q, kw, vw, pk, pv, pt, idx, store=True, tol=0.0)
    live = np.asarray(idx) >= 0
    tol = 2e-2 if pool_dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out)[live], np.asarray(want)[live],
                               atol=tol, rtol=tol)
    # pool writes are exact copies: bit-equal, idle slot untouched
    np.testing.assert_array_equal(np.asarray(ck), np.asarray(ck_r))
    np.testing.assert_array_equal(np.asarray(cv), np.asarray(cv_r))
    np.testing.assert_array_equal(np.asarray(cnt), np.asarray(cnt_r))


def test_window_kernel_rows_past_table_end_drop():
    """A window running past the last mapped page (slot 1: idx 5 + 5
    rows crosses into unmapped page 2) must count dropped elements and
    leave those rows unstored — the dead-store lanes the kernel tier
    reports."""
    pk, pv = _pools(jnp.float32)
    q, kw, vw = _rows(5, seed=13)
    pt, idx = jnp.asarray(HOSTILE_PT), jnp.asarray(HOSTILE_IDX_W)
    _, _, cnt, ck, cv = paged_window_attention(
        q, kw, vw, pk, pv, pt, idx, store=True, interpret=True)
    _, ck_r, cv_r, cnt_r = kref.paged_window_ref(
        q, kw, vw, pk, pv, pt, idx, store=True, tol=0.0)
    np.testing.assert_array_equal(np.asarray(cnt), np.asarray(cnt_r))
    np.testing.assert_array_equal(np.asarray(ck), np.asarray(ck_r))
    c = np.asarray(cnt)
    assert c[1, 2] > 0                  # slot 1 drops the overflow rows
    assert c[2].sum() == 0              # idle slot counts nothing


@pytest.mark.parametrize("S", [1, 4])
def test_window_kernel_defer_leaves_pool_untouched(S):
    pk, pv = _pools(jnp.float32)
    q, kw, vw = _rows(S, seed=5)
    pt, idx = jnp.asarray(HOSTILE_PT), jnp.asarray(HOSTILE_IDX_W)
    out, _, cnt, ck, cv = paged_window_attention(
        q, kw, vw, pk, pv, pt, idx, store=False, interpret=True)
    want, ck_r, cv_r, cnt_r = kref.paged_window_ref(
        q, kw, vw, pk, pv, pt, idx, store=False, tol=0.0)
    live = np.asarray(idx) >= 0
    np.testing.assert_allclose(np.asarray(out)[live], np.asarray(want)[live],
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_array_equal(np.asarray(ck), np.asarray(pk))
    np.testing.assert_array_equal(np.asarray(cv), np.asarray(pv))
    assert np.asarray(cnt).sum() == 0   # defer: no machine-level stores
    assert np.asarray(cnt_r).sum() == 0


def test_window_kernel_store_equals_defer_attention():
    """Overwrite and defer are the same attention math (the verify
    forward must not depend on commit policy) — outputs bit-equal."""
    pk, pv = _pools(jnp.float32)
    q, kw, vw = _rows(3, seed=21)
    pt, idx = jnp.asarray(HOSTILE_PT), jnp.asarray(HOSTILE_IDX_W)
    o1, _, _, _, _ = paged_window_attention(q, kw, vw, pk, pv, pt, idx,
                                            store=True, interpret=True)
    o2, _, _, _, _ = paged_window_attention(q, kw, vw, pk, pv, pt, idx,
                                            store=False, interpret=True)
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))


def test_verify_wrapper_modes_match_window_kernel():
    from repro.kernels.paged_verify import paged_verify_attention
    pk, pv = _pools(jnp.float32)
    q, kw, vw = _rows(3, seed=17)
    pt, idx = jnp.asarray(HOSTILE_PT), jnp.asarray(HOSTILE_IDX_W)
    for mode, store in (("overwrite", True), ("defer", False)):
        got = paged_verify_attention(q, kw, vw, pk, pv, pt, idx,
                                     mode=mode, interpret=True)
        want = paged_window_attention(q, kw, vw, pk, pv, pt, idx,
                                      store=store, interpret=True)
        for g, w in zip(got, want):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
    with pytest.raises(AssertionError):
        paged_verify_attention(q, kw, vw, pk, pv, pt, idx, mode="bogus",
                               interpret=True)


def test_ops_dispatch_parity(monkeypatch):
    """ops.paged_decode / ops.paged_window agree between the two
    dispatch targets (counters included) on the hostile table."""
    pk, pv = _pools(jnp.float32)
    q, kn, vn = _rows(1)
    pt, idx = jnp.asarray(HOSTILE_PT), jnp.asarray(HOSTILE_IDX)
    monkeypatch.setenv("REPRO_USE_PALLAS", "0")
    o_r, ck_r, cv_r, c_r = kops.paged_decode(q, kn, vn, pk, pv, pt, idx,
                                             counters=True)
    monkeypatch.setenv("REPRO_USE_PALLAS", "1")
    o_p, ck_p, cv_p, c_p = kops.paged_decode(q, kn, vn, pk, pv, pt, idx,
                                             counters=True)
    live = np.asarray(idx) >= 0
    np.testing.assert_allclose(np.asarray(o_p)[live], np.asarray(o_r)[live],
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_array_equal(np.asarray(ck_p), np.asarray(ck_r))
    np.testing.assert_array_equal(np.asarray(cv_p), np.asarray(cv_r))
    np.testing.assert_array_equal(np.asarray(c_p), np.asarray(c_r))


# ---------------------------------------------------------------------
# model-level: the kcnt leaf rides the decode scan and reports exact
# element counts at every serving site
# ---------------------------------------------------------------------

def _smoke_model():
    from repro.configs import registry
    from repro.models.zoo import build_model
    cfg = dataclasses.replace(registry.get_config("qwen3-1.7b").smoke(),
                              dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_model_counter_flow_prefill_decode_verify_commit():
    cfg, model, params = _smoke_model()
    nb, page_size, max_len = 3, 4, 32
    cache = model.init_paged_cache(params, nb, max_len, page_size=page_size,
                                   kv_dtype=jnp.float32,
                                   kernel_counters=True)
    base_pt = jnp.arange(nb * (max_len // page_size),
                         dtype=jnp.int32).reshape(nb, -1)
    cache = model.with_page_table(cache, base_pt)
    per_tok = 2 * cfg.num_kv_heads * cfg.head_dim

    def counts():
        kc = model.kernel_counters(cache)
        assert kc is not None
        return {n: np.asarray(c) for n, c in kc.items()}

    toks = jax.random.randint(jax.random.PRNGKey(1), (nb, 5), 0,
                              cfg.vocab_size)
    lengths = jnp.full((nb,), 5, jnp.int32)
    logits, cache = model.prefill(params, cache, toks, lengths=lengths)
    for n, c in counts().items():
        assert (c[..., 0] == 5 * per_tok).all(), (n, c)
        assert (c[..., 1:] == 0).all(), (n, c)

    tok1 = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    _, cache = model.decode_step(params, cache, tok1)
    for n, c in counts().items():
        assert (c[..., 0] == per_tok).all() and (c[..., 1:] == 0).all()

    # silent re-store: rewind the write index, decode the same token
    rewound = model.with_cache_index(cache, lengths)
    _, rewound = model.decode_step(params, rewound, tok1)
    kc = model.kernel_counters(rewound)
    for n, c in kc.items():
        c = np.asarray(c)
        assert (c[..., 0] == per_tok).all() and (c[..., 1] == per_tok).all()

    draft = jax.random.randint(jax.random.PRNGKey(2), (nb, 3), 0,
                               cfg.vocab_size)
    lo, cache_ov = model.verify(params, cache, draft, commit=True)
    kc = model.kernel_counters(cache_ov)
    for n, c in kc.items():
        c = np.asarray(c)
        assert (c[..., 0] == 3 * per_tok).all() and (c[..., 2] == 0).all()

    lo2, cache_df = model.verify(params, cache, draft, commit=False)
    kc = model.kernel_counters(cache_df)
    for n, c in kc.items():
        assert (np.asarray(c) == 0).all()       # defer: nothing stored
    np.testing.assert_array_equal(np.asarray(lo), np.asarray(lo2))

    start = jnp.full((nb,), 6, jnp.int32)
    accept = jnp.array([2, 0, 3], jnp.int32)
    cache_cm = model.commit_verify(cache_df, start, accept)
    kc = model.kernel_counters(cache_cm)
    for n, c in kc.items():
        c = np.asarray(c)
        assert (c[..., 0] == np.asarray(accept)[None, :] * per_tok).all()
        assert (c[..., 2] == 0).all()


# ---------------------------------------------------------------------
# engine-level: greedy serve bit-consistency and the kernel-tier
# rejected_draft_store acceptance criterion
# ---------------------------------------------------------------------

def _serve(model, params, cfg, *, kv="paged", drafter=None, rollback=True,
           detectors=None, kernel_counters=False):
    from repro.serve.engine import Request, ServeEngine
    eng = ServeEngine(model, params, num_slots=2, max_len=32,
                      kv_layout=kv, page_size=8, drafter=drafter,
                      spec_k=3, spec_rollback=rollback, detectors=detectors,
                      kernel_counters=kernel_counters)
    rng = np.random.RandomState(3)
    for i, (plen, gen, arr) in enumerate([(8, 5, 0), (5, 7, 0), (7, 3, 1)]):
        eng.submit(Request(rid=f"q{i}",
                           tokens=rng.randint(0, cfg.vocab_size,
                                              size=plen).astype(np.int32),
                           max_new_tokens=gen, arrival=arr))
    fin = eng.run(max_steps=400)
    return {rid: fin[rid].generated for rid in fin}, eng


class GarbageDrafter:
    def observe(self, t):
        pass

    def propose(self, h, k):
        return np.full(k, 7, np.int32)


def test_engine_greedy_identical_dense_paged_pallas(monkeypatch):
    cfg, model, params = _smoke_model()
    monkeypatch.setenv("REPRO_USE_PALLAS", "0")
    dense, _ = _serve(model, params, cfg, kv="dense")
    paged, _ = _serve(model, params, cfg, kv="paged")
    assert dense == paged
    monkeypatch.setenv("REPRO_USE_PALLAS", "1")
    pallas, _ = _serve(model, params, cfg, kv="paged")
    assert pallas == dense


def test_engine_kernel_tier_rejected_draft_fraction():
    from repro.configs.base import ProfilerConfig
    from repro.core.detectors import ServingDetectors
    cfg, model, params = _smoke_model()
    base, _ = _serve(model, params, cfg)

    # counters on, no drafter: outputs unchanged, silent-store checked
    det = ServingDetectors(ProfilerConfig(enabled=True))
    out, eng = _serve(model, params, cfg, detectors=det,
                      kernel_counters=True)
    assert out == base
    assert det.kernel.checked.get("kernel_silent_store", 0) > 0
    assert det.kernel.fractions().get("kernel_dead_store", 1.0) == 0.0
    assert 4 in det.combined().tiers

    # overwrite commit: kernel-tier rejected fraction == 1 - accept rate
    det1 = ServingDetectors(ProfilerConfig(enabled=True))
    out1, eng1 = _serve(model, params, cfg, drafter=GarbageDrafter(),
                        rollback=False, detectors=det1,
                        kernel_counters=True)
    assert out1 == base
    acc = eng1.stats["draft_accepted"] / eng1.stats["draft_proposed"]
    fr1 = det1.kernel.fractions()["kernel_rejected_draft_store"]
    assert abs(fr1 - (1.0 - acc)) < 1e-9
    assert fr1 == det1.report.fractions()["rejected_draft_store"]

    # rollback commit: provably zero rejected stores
    det2 = ServingDetectors(ProfilerConfig(enabled=True))
    out2, _ = _serve(model, params, cfg, drafter=GarbageDrafter(),
                     rollback=True, detectors=det2, kernel_counters=True)
    assert out2 == base
    assert det2.kernel.fractions()["kernel_rejected_draft_store"] == 0.0
    assert det2.kernel.checked["kernel_rejected_draft_store"] > 0


# ---------------------------------------------------------------------
# sharded fast paths: 2 virtual devices, Pallas vs ref, in a subprocess
# so the main process keeps its 1-device view
# ---------------------------------------------------------------------

_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=2")
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh
from repro.serve import flash_decode as fd

mesh = Mesh(np.array(jax.devices()).reshape(2), ("model",))
B, Hq, Hkv, D = 3, 4, 2, 8
P, ps, M = 8, 4, 4
ks = jax.random.split(jax.random.PRNGKey(0), 8)
pt = jnp.array([[5, 1, 6, -1], [2, 7, -1, -1], [-1, -1, -1, -1]], jnp.int32)
idx = jnp.array([9, 5, -1], jnp.int32)

for dtype in (jnp.float32, jnp.bfloat16):
    pool_k = jax.random.normal(ks[0], (P, ps, Hkv, D), dtype)
    pool_v = jax.random.normal(ks[1], (P, ps, Hkv, D), dtype)
    q = jax.random.normal(ks[2], (B, 1, Hq, D), jnp.float32)
    kn = jax.random.normal(ks[3], (B, 1, Hkv, D), jnp.float32)
    vn = jax.random.normal(ks[4], (B, 1, Hkv, D), jnp.float32)
    qw = jax.random.normal(ks[5], (B, 3, Hq, D), jnp.float32)
    kw = jax.random.normal(ks[6], (B, 3, Hkv, D), jnp.float32)
    vw = jax.random.normal(ks[7], (B, 3, Hkv, D), jnp.float32)
    for entry, a in ((fd.decode_paged_attention_sharded, (q, kn, vn)),
                     (fd.verify_paged_attention_sharded, (qw, kw, vw))):
        with mesh:
            os.environ["REPRO_USE_PALLAS"] = "0"
            o_r, ck_r, cv_r = entry(*a, pool_k, pool_v, pt, idx, mesh=mesh,
                                    batch_axes=(), seq_axes=("model",))
            os.environ["REPRO_USE_PALLAS"] = "1"
            o_p, ck_p, cv_p = entry(*a, pool_k, pool_v, pt, idx, mesh=mesh,
                                    batch_axes=(), seq_axes=("model",))
        np.testing.assert_array_equal(np.asarray(ck_r), np.asarray(ck_p))
        np.testing.assert_array_equal(np.asarray(cv_r), np.asarray(cv_p))
        np.testing.assert_allclose(np.asarray(o_r[:2], np.float32),
                                   np.asarray(o_p[:2], np.float32),
                                   rtol=2e-5, atol=2e-5)
print("SUBPROC_OK")
"""


def test_sharded_pallas_matches_ref_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env["JAX_PLATFORMS"] = "cpu"
    env.pop("REPRO_USE_PALLAS", None)
    out = subprocess.run([sys.executable, "-c", _SUBPROC], env=env,
                         capture_output=True, text=True, timeout=420)
    assert "SUBPROC_OK" in out.stdout, out.stderr[-3000:]
