"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs pure-jnp
oracles, plus the custom-vjp flash (XLA twin) forward and backward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_xla import flash_xla
from repro.kernels.rmsnorm import rmsnorm
from repro.kernels.silent_compare import silent_compare

KEY = jax.random.PRNGKey(0)


def _qkv(B, Sq, Skv, Hq, Hkv, D, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Sq, Hq, D), dtype)
    k = jax.random.normal(ks[1], (B, Skv, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, Skv, Hkv, D), dtype)
    return q, k, v


@pytest.mark.parametrize("B,Sq,Skv,Hq,Hkv,D,causal", [
    (1, 64, 64, 4, 4, 32, True),
    (2, 128, 128, 4, 2, 64, True),     # GQA
    (1, 96, 160, 6, 3, 16, False),     # cross-ish, ragged seq
    (2, 32, 32, 8, 1, 32, True),       # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_pallas_matches_ref(B, Sq, Skv, Hq, Hkv, D, causal, dtype):
    q, k, v = _qkv(B, Sq, Skv, Hq, Hkv, D, dtype)
    out = flash_attention(q, k, v, causal=causal, block_q=32, block_k=32,
                          interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("chunk", [32, 64, 128])
def test_flash_xla_fwd_bwd(chunk):
    q, k, v = _qkv(2, 128, 128, 4, 2, 32, jnp.float32)

    def f_ref(q, k, v):
        return (ref.attention_ref(q, k, v, causal=True) ** 2).sum()

    def f_fx(q, k, v):
        return (flash_xla(q, k, v, True, 0, chunk) ** 2).sum()

    np.testing.assert_allclose(f_fx(q, k, v), f_ref(q, k, v), rtol=1e-5)
    g1 = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_fx, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   atol=5e-5, rtol=5e-4)


def test_flash_xla_decode_offset_matches_masked_ref():
    q, k, v = _qkv(1, 16, 80, 4, 4, 32, jnp.float32)
    out = flash_xla(q, k, v, True, 64, 32)      # q starts at position 64
    want = ref.attention_ref(q, k, v, causal=True, q_offset=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("n,tol", [(100, 0.0), (1000, 0.0), (4096, 0.01),
                                   (100000, 0.01), (33000, 0.0)])
def test_silent_compare_sweep(n, tol):
    a = jax.random.normal(KEY, (n,))
    nflip = max(1, n // 7)
    b = a.at[:nflip].mul(2.0)
    got = int(silent_compare(a, b, tol, interpret=True))
    want = int(ref.silent_compare_ref(a, b, tol))
    assert got == want == n - nflip


def test_silent_compare_int_exact_and_nan():
    a = jnp.arange(1000, dtype=jnp.float32)
    assert int(silent_compare(a, a, 0.0, interpret=True)) == 1000
    b = a.at[0].set(jnp.nan)
    assert int(silent_compare(b, b, 0.0, interpret=True)) == 999


@pytest.mark.parametrize("shape", [(8, 64), (37, 128), (3, 5, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_kernel_sweep(shape, dtype):
    x = jax.random.normal(KEY, shape, dtype)
    s = jax.random.normal(jax.random.PRNGKey(1), (shape[-1],), jnp.float32)
    got = rmsnorm(x, s, interpret=True)
    want = ref.rmsnorm_ref(x, s)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=2e-2 if dtype == jnp.bfloat16 else 1e-5)
