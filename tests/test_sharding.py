"""Sharding rules: spec trees match param trees for every arch; leaf specs
never imply padding (hypothesis over random leaf shapes); distributed
pieces (fused xent, flash decoding, dry-run lowering) run in a subprocess
with 8 virtual devices so the main test process keeps a 1-device view."""
import subprocess
import sys
import os

import jax
import numpy as np
import pytest
from _hypo import given, settings, st

from repro.configs import registry
from repro.models.zoo import build_model
from repro.sharding.rules import leaf_spec_fsdp, leaf_spec_tp


class FakeMesh:
    def __init__(self, data=16, model=16):
        self.shape = {"data": data, "model": model}


@given(st.lists(st.integers(1, 4096), min_size=1, max_size=4),
       st.sampled_from(["ffn", "embed", "vocab", "experts", None]))
@settings(max_examples=120, deadline=None)
def test_leaf_specs_never_pad(shape, ax):
    """Every sharded dim must be divisible by its mesh axes (no implicit
    GSPMD padding -> honest cost_analysis)."""
    mesh = FakeMesh()
    axes = tuple([ax] + [None] * (len(shape) - 1))
    for fn in (leaf_spec_tp, leaf_spec_fsdp):
        spec = fn(axes, tuple(shape), mesh)
        for dim, names in zip(shape, tuple(spec)):
            if names is None:
                continue
            names = names if isinstance(names, tuple) else (names,)
            n = 1
            for a in names:
                n *= mesh.shape[a]
            assert dim % n == 0


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_spec_trees_match_param_trees(arch):
    """param_specs/opt_specs trees are congruent with the real param tree
    for the FULL config (structure only, no allocation)."""
    cfg = registry.get_config(arch)
    model = build_model(cfg)
    mesh = FakeMesh()
    from repro.sharding import rules

    class S(rules.DpTp):
        def __init__(self):
            self.mesh = mesh
            self.dp = ("data",)
    strat = S()
    abstract = model.abstract_params()
    specs = strat.param_specs(model)
    t1 = jax.tree_util.tree_structure(abstract)
    t2 = jax.tree_util.tree_structure(
        jax.tree_util.tree_map(lambda s: 0, specs,
                               is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)))
    assert t1 == t2


_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as PS

mesh = jax.make_mesh((2, 4), ("data", "model"))

# --- fused vocab-parallel xent == reference (value + grads) -----------
from repro.train.fused_xent import make_fused_xent
B, S, d, V = 4, 8, 16, 32
key = jax.random.PRNGKey(0)
x = jax.random.normal(key, (B, S, d), jnp.float32)
w = jax.random.normal(jax.random.PRNGKey(1), (V, d), jnp.float32)
labels = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, V)
def ref(x, w):
    logits = jnp.einsum('bsd,vd->bsv', x, w)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
    return jnp.mean(lse - ll)
with mesh:
    fused = make_fused_xent(mesh, ("data",), 0.0)
    lf = jax.jit(fused)(x, w, labels)
    assert abs(float(lf) - float(ref(x, w))) < 1e-5
    gx, gw = jax.jit(jax.grad(fused, argnums=(0, 1)))(x, w, labels)
    rx, rw = jax.grad(lambda x, w: ref(x, w), argnums=(0, 1))(x, w)
    assert float(jnp.abs(gx - rx).max()) < 1e-5
    assert float(jnp.abs(gw - rw).max()) < 1e-5

# --- flash decoding == masked reference -------------------------------
from repro.serve.flash_decode import decode_attention_sharded
from repro.kernels.ref import attention_ref
B, Smax, Hq, Hkv, D = 2, 64, 4, 2, 16
q = jax.random.normal(key, (B, 1, Hq, D))
kn = jax.random.normal(jax.random.PRNGKey(3), (B, 1, Hkv, D))
vn = jax.random.normal(jax.random.PRNGKey(4), (B, 1, Hkv, D))
ck = jax.random.normal(jax.random.PRNGKey(5), (B, Smax, Hkv, D))
cv = jax.random.normal(jax.random.PRNGKey(6), (B, Smax, Hkv, D))
idx = jnp.int32(37)
with mesh:
    out, nck, ncv = jax.jit(lambda *a: decode_attention_sharded(
        *a, mesh=mesh, batch_axes=("data",), seq_axes=("model",)))(
        q, kn, vn, ck, cv, idx)
ck_ref = jax.lax.dynamic_update_slice_in_dim(ck, kn, 37, 1)
cv_ref = jax.lax.dynamic_update_slice_in_dim(cv, vn, 37, 1)
want = attention_ref(q, ck_ref, cv_ref, causal=False, kv_len=38)
assert float(jnp.abs(out - want).max()) < 1e-4, float(jnp.abs(out - want).max())
assert float(jnp.abs(nck - ck_ref).max()) == 0.0

# --- paged flash decoding == page-table-gathered reference -------------
from repro.serve.flash_decode import decode_paged_attention_sharded
from repro.kernels.ref import paged_gather, paged_update
P, ps, M = 16, 8, 4                     # pool pages shard 4-way over model
pk = jax.random.normal(jax.random.PRNGKey(7), (P, ps, Hkv, D))
pv = jax.random.normal(jax.random.PRNGKey(8), (P, ps, Hkv, D))
# slot 0 live at pos 19 (page row 2, shared page 5 with slot 1's prefix);
# slot 1 idle (negative sentinel: store drops, output is don't-care)
pt = jnp.array([[3, 5, 9, -1], [5, 2, -1, -1]], jnp.int32)
pidx = jnp.array([19, -2], jnp.int32)
with mesh:
    pout, npk, npv = jax.jit(lambda *a: decode_paged_attention_sharded(
        *a, mesh=mesh, batch_axes=("data",), seq_axes=("model",)))(
        q, kn, vn, pk, pv, pt, pidx)
rpk, rpv = paged_update(pk, pv, kn, vn, pt, pidx)
kg, valid = paged_gather(rpk, pt)
vg, _ = paged_gather(rpv, pt)
pwant = attention_ref(q, kg, vg, causal=False, kv_len=pidx + 1,
                      kv_valid=valid)
assert float(jnp.abs(pout[0] - pwant[0]).max()) < 1e-4
assert float(jnp.abs(npk - rpk).max()) == 0.0   # idle-slot store dropped
assert float(jnp.abs(npv - rpv).max()) == 0.0

# --- width-k speculative verify == page-table-gathered reference -------
from repro.serve.flash_decode import verify_paged_attention_sharded
W = 3
qw = jax.random.normal(jax.random.PRNGKey(9), (B, W, Hq, D))
knw = jax.random.normal(jax.random.PRNGKey(10), (B, W, Hkv, D))
vnw = jax.random.normal(jax.random.PRNGKey(11), (B, W, Hkv, D))
vidx = jnp.array([13, -4], jnp.int32)           # slot 1 idle: stores drop
with mesh:
    vout, vpk, vpv = jax.jit(lambda *a: verify_paged_attention_sharded(
        *a, mesh=mesh, batch_axes=("data",), seq_axes=("model",)))(
        qw, knw, vnw, pk, pv, pt, vidx)
wpk, wpv = paged_update(pk, pv, knw, vnw, pt, vidx)
kg, valid = paged_gather(wpk, pt)
vg, _ = paged_gather(wpv, pt)
vwant = attention_ref(qw, kg, vg, causal=True, q_offset=vidx,
                      kv_len=vidx + W, kv_valid=valid)
assert float(jnp.abs(vout[0] - vwant[0]).max()) < 1e-4
assert float(jnp.abs(vpk - wpk).max()) == 0.0
assert float(jnp.abs(vpv - wpv).max()) == 0.0

# --- mini dry-run lowering on an 8-device mesh -------------------------
from repro.configs import registry
from repro.configs.base import TrainConfig
from repro.models.zoo import build_model
from repro.sharding.rules import make_strategy
from repro.train import state as TS
from repro.train.step import make_train_step
from jax.sharding import NamedSharding
cfg = registry.get_config("qwen3-1.7b").smoke()
model = build_model(cfg)
strat = make_strategy("dp_tp", mesh)
step = make_train_step(model, TrainConfig(), strat)
named = lambda t: jax.tree_util.tree_map(
    lambda s: NamedSharding(mesh, s), t,
    is_leaf=lambda x: isinstance(x, PS))
batch = {"tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32),
         "labels": jax.ShapeDtypeStruct((8, 32), jnp.int32)}
bspec = {k: NamedSharding(mesh, PS(("data",), None)) for k in batch}
with mesh:
    jitted = jax.jit(step, in_shardings=(named(TS.state_specs(model, strat)), bspec),
                     out_shardings=(named(TS.state_specs(model, strat)), None))
    compiled = jitted.lower(TS.abstract(model), batch).compile()
assert compiled.cost_analysis() is not None
print("SUBPROC_OK")
"""


def test_distributed_pieces_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, "-c", _SUBPROC], env=env,
                         capture_output=True, text=True, timeout=420)
    assert "SUBPROC_OK" in out.stdout, out.stderr[-3000:]
