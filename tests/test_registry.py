"""configs/registry.py: the zoo is complete, cell gating is explained,
and every config's train and decode steps trace abstractly.

The trace tests run under jax.eval_shape — no parameter allocation, no
compile — so a registry entry whose model cannot even build a jaxpr for
its assigned work fails here rather than deep inside a matrix run.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import registry
from repro.configs.base import SHAPES, TrainConfig
from repro.launch.lint import _abstract_cache, _train_batch
from repro.models.zoo import build_model
from repro.serve.decode import make_engine_tick, make_serve_step
from repro.serve.engine import ENGINE_FAMILIES
from repro.train import state as TS
from repro.train.step import make_train_step

EXPECTED_ARCHS = [
    "starcoder2-7b", "qwen3-14b", "qwen3-1.7b", "granite-20b",
    "llama4-scout-17b-a16e", "granite-moe-3b-a800m",
    "llama-3.2-vision-90b", "whisper-large-v3", "zamba2-1.2b",
    "xlstm-1.3b",
]


def test_registry_is_the_assigned_zoo():
    assert registry.ARCH_IDS == EXPECTED_ARCHS
    names = [registry.get_config(a).name for a in registry.ARCH_IDS]
    assert len(set(names)) == len(names)


def test_unknown_arch_raises():
    with pytest.raises(KeyError, match="unknown arch"):
        registry.get_config("gpt-5")


def test_get_shape_roundtrip():
    for s in SHAPES:
        assert registry.get_shape(s.name) is s
    assert {s.kind for s in SHAPES} == {"train", "prefill", "decode"}


def test_all_cells_yields_every_config_times_every_shape():
    cells = list(registry.all_cells())
    assert len(cells) == len(registry.ARCH_IDS) * len(SHAPES)
    seen = [(arch, shape.name) for arch, _, shape, _, _ in cells]
    assert seen == [(a, s.name) for a in registry.ARCH_IDS for s in SHAPES]


def test_cell_applicable_reasons():
    """Inapplicable cells carry a human-readable reason; applicable ones
    an empty reason. Only quadratic-attention archs skip long_500k."""
    for arch, cfg, shape, ok, why in registry.all_cells():
        if ok:
            assert why == "", (arch, shape.name)
        else:
            assert shape.name == "long_500k", (arch, shape.name)
            assert not cfg.subquadratic
            assert "quadratic" in why, why
    subq = [a for a in registry.ARCH_IDS
            if registry.get_config(a).subquadratic]
    assert subq == ["zamba2-1.2b", "xlstm-1.3b"]
    for a in subq:
        ok, _ = registry.cell_applicable(registry.get_config(a),
                                         registry.get_shape("long_500k"))
        assert ok


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_train_step_traces_abstractly(arch):
    cfg = registry.get_config(arch).smoke()
    model = build_model(cfg)
    tc = TrainConfig(learning_rate=1e-3, total_steps=10, warmup_steps=1)
    step_fn = make_train_step(model, tc, None)
    state = TS.abstract(model)
    new_state, metrics = jax.eval_shape(step_fn, state,
                                        _train_batch(cfg, 2, 32))
    assert jax.tree_util.tree_structure(new_state.params) == \
        jax.tree_util.tree_structure(state.params)
    assert metrics["loss"].shape == ()


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_decode_step_traces_abstractly(arch):
    cfg = registry.get_config(arch).smoke()
    model = build_model(cfg)
    params = model.abstract_params()
    dparams = model.decode_params(params)
    batch, max_len = 2, 48
    cache = _abstract_cache(model, params, batch, max_len)
    if cfg.family in ENGINE_FAMILIES:
        out = jax.eval_shape(make_engine_tick(model), dparams, cache,
                             jax.ShapeDtypeStruct((batch, 1), jnp.int32),
                             jax.ShapeDtypeStruct((batch,), jnp.bool_))
    else:
        out = jax.eval_shape(make_serve_step(model), dparams, cache,
                             jax.ShapeDtypeStruct((batch, 1), jnp.int32))
    nxt, new_cache = out[0], out[1]
    assert nxt.shape[0] == batch
    assert jax.tree_util.tree_structure(new_cache) == \
        jax.tree_util.tree_structure(cache)
