"""Benchmark smoke: every row `benchmarks/overhead.py` can emit runs
once at toy sizes. PR 3's `serve_paged_*` rows silently bit-rotted once
because nothing executed them in CI — a renamed engine kwarg or stats
key now fails here instead of vanishing from the report."""
import importlib.util
import math
import os

_BENCH = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                      "overhead.py")

EXPECTED_ROWS = {
    "overhead.native_step",
    "overhead.tier3_step",
    "overhead.tier1_p1000",
    "overhead.tier1_p5000",
    "overhead.tier1_p10000",
    "overhead.tier1_reinterp_e8",
    "overhead.tier1_replay_e8",
    "overhead.serve_prefill_tokenloop",
    "overhead.serve_prefill_batched",
    "overhead.serve_decode_step",
    "overhead.serve_tier3_step",
    "overhead.serve_paged_decode_step",
    "overhead.serve_paged_tier3_step",
    "overhead.serve_paged_prefill_hit",
    "overhead.serve_spec_plain_decode",
    "overhead.serve_spec_oracle_decode",
    "overhead.serve_spec_ngram_decode",
    "overhead.serve_spec_rollback_decode",
    "overhead.kernel_paged_decode_ref",
    "overhead.kernel_paged_decode_pallas",
    "overhead.kernel_prefill_pallas",
    "overhead.kernel_verify_pallas",
    "overhead.fleet_random_ttft_p50",
    "overhead.fleet_random_ttft_p99",
    "overhead.fleet_random_tpot",
    "overhead.fleet_prefix_ttft_p50",
    "overhead.fleet_prefix_ttft_p99",
    "overhead.fleet_prefix_tpot",
    "overhead.object_decode_step",
    "overhead.object_replica_scan",
    "overhead.matrix_granite_moe_native_step",
    "overhead.matrix_granite_moe_profiled_step",
    "overhead.matrix_whisper_native_step",
    "overhead.matrix_whisper_profiled_step",
    "overhead.moe_dispatch_einsum_granite_moe",
    "overhead.moe_dispatch_scatter_granite_moe",
    "overhead.moe_dispatch_einsum_llama4",
    "overhead.moe_dispatch_scatter_llama4",
}


def _load_overhead():
    spec = importlib.util.spec_from_file_location("bench_overhead", _BENCH)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_every_overhead_row_runs_at_toy_sizes():
    mod = _load_overhead()
    rows = mod.run(toy=True)
    names = [r[0] for r in rows]
    assert len(names) == len(set(names)), "duplicate benchmark row names"
    missing = EXPECTED_ROWS - set(names)
    extra = set(names) - EXPECTED_ROWS
    assert not missing, f"benchmark rows vanished: {sorted(missing)}"
    assert not extra, (f"new rows {sorted(extra)}: add them to "
                       f"EXPECTED_ROWS so CI keeps executing them")
    for name, value, note in rows:
        assert isinstance(value, float) and value > 0 \
            and math.isfinite(value), (name, value)
        assert isinstance(note, str) and note, (name, note)
    # the replay row must still certify profile identity at toy sizes
    replay = next(note for name, _, note in rows
                  if name == "overhead.tier1_replay_e8")
    assert "identical=True" in replay
    # the Pallas kernel rows must certify counter parity with the ref
    # compositions, and the decode row's modeled HBM speedup (the honest
    # paged-gather-vs-materialization number) must clear 1.3x
    notes = {name: note for name, _, note in rows}
    for name in ("overhead.kernel_paged_decode_pallas",
                 "overhead.kernel_prefill_pallas",
                 "overhead.kernel_verify_pallas"):
        assert "counters_match=True" in notes[name], (name, notes[name])
    dec = notes["overhead.kernel_paged_decode_pallas"]
    speedup = float(dec.split("modeled_hbm_speedup=")[1].split("x")[0])
    assert speedup >= 1.3, dec
    assert "defer_zero_stores=True" in notes["overhead.kernel_verify_pallas"]
    # fleet A/B: the waste counts are logical-tick deterministic — the
    # prefix-aware policy must re-pay zero cross-replica prefix bytes
    # while random routing pays some
    fl = notes["overhead.fleet_prefix_tpot"]
    assert fl.startswith("waste_bytes=0_vs_random="), fl
    assert not fl.endswith("_vs_random=0"), fl
    # the MoE dispatch A/B rows must carry the measured speedup
    for name in ("overhead.moe_dispatch_scatter_granite_moe",
                 "overhead.moe_dispatch_scatter_llama4"):
        assert notes[name].startswith("speedup="), (name, notes[name])


def test_bench_json_emit_and_diff(tmp_path):
    import json
    import subprocess
    import sys
    mod = _load_overhead()
    rows = [("overhead.fake_a", 100.0, "baseline"),
            ("overhead.fake_b", 250.0, "x")]
    base = mod.emit_json(rows, toy=True, path=str(tmp_path / "BENCH_a.json"))
    doc = json.load(open(base))
    assert doc["schema"] == 1 and len(doc["rows"]) == 2
    assert doc["machine"]["backend"]
    diff = os.path.join(os.path.dirname(_BENCH), "bench_diff.py")
    # within band -> rc 0; regression beyond band -> rc 1; missing -> rc 1
    cur_ok = mod.emit_json([("overhead.fake_a", 110.0, ""),
                            ("overhead.fake_b", 240.0, "")],
                           toy=True, path=str(tmp_path / "ok.json"))
    cur_bad = mod.emit_json([("overhead.fake_a", 500.0, ""),
                             ("overhead.fake_b", 240.0, "")],
                            toy=True, path=str(tmp_path / "bad.json"))
    cur_miss = mod.emit_json([("overhead.fake_a", 100.0, "")],
                             toy=True, path=str(tmp_path / "miss.json"))
    run = lambda cur: subprocess.run(  # noqa: E731
        [sys.executable, diff, base, cur, "--band", "1.5"],
        capture_output=True, text=True)
    assert run(cur_ok).returncode == 0
    r_bad = run(cur_bad)
    assert r_bad.returncode == 1 and "REGRESSION" in r_bad.stdout
    r_miss = run(cur_miss)
    assert r_miss.returncode == 1 and "missing" in r_miss.stdout


def test_bench_diff_findings_counts(tmp_path):
    """Per-kind waste-finding counts ride the BENCH json: growth fails,
    shrinkage is an improvement, count-free baselines only notice."""
    import json
    import subprocess
    import sys
    mod = _load_overhead()
    rows = [("overhead.fake_a", 100.0, "")]
    diff = os.path.join(os.path.dirname(_BENCH), "bench_diff.py")
    run = lambda b, c: subprocess.run(  # noqa: E731
        [sys.executable, diff, b, c, "--band", "3.0"],
        capture_output=True, text=True)

    base = mod.emit_json(rows, toy=True, path=str(tmp_path / "b.json"),
                         findings={"dead_store": 2, "silent_store": 5})
    assert json.load(open(base))["findings"] == {"dead_store": 2,
                                                 "silent_store": 5}
    same = mod.emit_json(rows, toy=True, path=str(tmp_path / "same.json"),
                         findings={"dead_store": 2, "silent_store": 5})
    fewer = mod.emit_json(rows, toy=True, path=str(tmp_path / "less.json"),
                          findings={"dead_store": 2, "silent_store": 1})
    grew = mod.emit_json(rows, toy=True, path=str(tmp_path / "grew.json"),
                         findings={"dead_store": 3, "silent_store": 5})
    newkind = mod.emit_json(rows, toy=True, path=str(tmp_path / "nk.json"),
                            findings={"dead_store": 2, "silent_store": 5,
                                      "redundant_load": 1})
    nocounts = mod.emit_json(rows, toy=True, path=str(tmp_path / "nc.json"))

    assert run(base, same).returncode == 0
    r = run(base, fewer)
    assert r.returncode == 0 and "improved" in r.stdout
    r = run(base, grew)
    assert r.returncode == 1 and "findings[dead_store] grew" in r.stdout
    r = run(base, newkind)
    assert r.returncode == 1 and "findings[redundant_load]" in r.stdout
    # current without counts never fails; baseline without counts notices
    assert run(base, nocounts).returncode == 0
    r = run(nocounts, base)
    assert r.returncode == 0 and "note" in r.stdout
