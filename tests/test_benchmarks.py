"""Benchmark smoke: every row `benchmarks/overhead.py` can emit runs
once at toy sizes. PR 3's `serve_paged_*` rows silently bit-rotted once
because nothing executed them in CI — a renamed engine kwarg or stats
key now fails here instead of vanishing from the report."""
import importlib.util
import math
import os

_BENCH = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                      "overhead.py")

EXPECTED_ROWS = {
    "overhead.native_step",
    "overhead.tier3_step",
    "overhead.tier1_p1000",
    "overhead.tier1_p5000",
    "overhead.tier1_p10000",
    "overhead.tier1_reinterp_e8",
    "overhead.tier1_replay_e8",
    "overhead.serve_prefill_tokenloop",
    "overhead.serve_prefill_batched",
    "overhead.serve_decode_step",
    "overhead.serve_tier3_step",
    "overhead.serve_paged_decode_step",
    "overhead.serve_paged_tier3_step",
    "overhead.serve_paged_prefill_hit",
    "overhead.serve_spec_plain_decode",
    "overhead.serve_spec_oracle_decode",
    "overhead.serve_spec_ngram_decode",
    "overhead.serve_spec_rollback_decode",
}


def _load_overhead():
    spec = importlib.util.spec_from_file_location("bench_overhead", _BENCH)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_every_overhead_row_runs_at_toy_sizes():
    mod = _load_overhead()
    rows = mod.run(toy=True)
    names = [r[0] for r in rows]
    assert len(names) == len(set(names)), "duplicate benchmark row names"
    missing = EXPECTED_ROWS - set(names)
    extra = set(names) - EXPECTED_ROWS
    assert not missing, f"benchmark rows vanished: {sorted(missing)}"
    assert not extra, (f"new rows {sorted(extra)}: add them to "
                       f"EXPECTED_ROWS so CI keeps executing them")
    for name, value, note in rows:
        assert isinstance(value, float) and value > 0 \
            and math.isfinite(value), (name, value)
        assert isinstance(note, str) and note, (name, note)
    # the replay row must still certify profile identity at toy sizes
    replay = next(note for name, _, note in rows
                  if name == "overhead.tier1_replay_e8")
    assert "identical=True" in replay
