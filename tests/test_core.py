"""JXPerf-JAX core: reservoir properties (hypothesis), Definitions 1-3 on
crafted programs, Tier-3 detectors, pair-table merge semantics."""
import collections

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypo import given, settings, st

from repro.configs.base import ProfilerConfig
from repro.core.context import PairTable
from repro.core.detectors import TrainingDetectors
from repro.core.events import LOAD, STORE, EventEngine, MemEvent
from repro.core.interpreter import profile_fn
from repro.core.reservoir import ReservoirWatchpoints, Watchpoint


def _wp(i):
    return Watchpoint(address=i, offset=0, size=4, value=i, context=(f"c{i}",),
                      trap_type="W_TRAP")


# ----------------------------------------------------------------------
# Reservoir (§5.2)
# ----------------------------------------------------------------------
@given(st.integers(1, 4), st.integers(1, 200), st.integers(0, 10_000))
@settings(max_examples=50, deadline=None)
def test_reservoir_slot_invariant(nslots, nsamples, seed):
    """Never more than N armed; armed set is always a subset of samples."""
    rw = ReservoirWatchpoints(nslots, seed)
    for i in range(nsamples):
        rw.on_sample(_wp(i))
        armed = rw.armed()
        assert len(armed) <= nslots
        assert all(0 <= w.address < nsamples for w in armed)
    s = rw.stats
    assert s["armed"] + s["replaced"] + s["rejected"] == nsamples


def test_reservoir_uniform_survival():
    """With 1 slot and k samples, each sample survives w.p. ~1/k (paper's
    central claim; chi-square-ish tolerance over many trials)."""
    k, trials = 8, 4000
    counts = collections.Counter()
    for t in range(trials):
        rw = ReservoirWatchpoints(1, seed=t)
        for i in range(k):
            rw.on_sample(_wp(i))
        counts[rw.armed()[0].address] += 1
    expect = trials / k
    for i in range(k):
        assert abs(counts[i] - expect) < 0.35 * expect, (i, counts[i], expect)


def test_reservoir_trap_frees_slot():
    rw = ReservoirWatchpoints(2, 0)
    w1, w2 = _wp(1), _wp(2)
    rw.on_sample(w1)
    rw.on_sample(w2)
    rw.disarm(w1)
    assert len(rw.armed()) == 1
    assert rw.on_sample(_wp(3)) is True      # freed slot re-armed for sure
    rw.disarm_all()
    assert rw.armed() == []


# ----------------------------------------------------------------------
# Tier-1 per Definitions 1-3
# ----------------------------------------------------------------------
CFG = ProfilerConfig(enabled=True, period=20, num_watchpoints=4)


def test_silent_loads_linear_search():
    """Paper §6 Collections#588 analogue: repeated traversal of an
    unchanged collection shows up as silent loads."""
    def linear_search(keys, arr):
        def body(c, k):
            return c + jnp.any(arr == k).astype(jnp.int32), None
        out, _ = jax.lax.scan(body, jnp.int32(0), keys)
        return out
    rep = profile_fn(linear_search, jnp.arange(48) % 7, jnp.arange(256), cfg=CFG)
    assert rep.fractions()["silent_load"] > 0.5
    # two-party attribution exists
    assert rep.silent_loads.total_count > 0
    (c1, c2), _ = rep.silent_loads.top(1)[0]
    assert len(c1) >= 1 and len(c2) >= 1


def test_silent_stores_loop_invariant_recompute():
    """Paper §7.4 NPB-IS analogue: recomputing the same values every
    iteration writes identical values to recycled addresses."""
    def recompute(keys, x):
        def body(c, k):
            w = jnp.exp(x)                     # loop-invariant
            return c + w.sum() * k, None
        out, _ = jax.lax.scan(body, jnp.float32(0), keys)
        return out
    rep = profile_fn(recompute, jnp.ones((24,)), jnp.linspace(0, 1, 256), cfg=CFG)
    assert rep.fractions()["silent_store"] > 0.5


def test_dead_stores_unused_values():
    """Values stored and overwritten without any intervening load."""
    def wasteful(x):
        acc = jnp.float32(0)
        for i in range(20):
            w = jnp.exp(x) * (i + 1)          # stored, never loaded
            acc = acc + x.sum()
        return acc, w
    rep = profile_fn(wasteful, jnp.linspace(0, 1, 512), cfg=CFG)
    assert rep.fractions()["dead_store"] > 0.3


def test_efficient_program_is_clean():
    def chain(x):
        for _ in range(6):
            x = jnp.tanh(x * 1.1 + 0.3)
        return x.sum()
    rep = profile_fn(chain, jnp.linspace(0, 1, 2048), cfg=CFG)
    fr = rep.fractions()
    assert fr["silent_load"] < 0.15
    assert fr["dead_store"] < 0.15


def test_fp_tolerance_controls_silent_store():
    """1% tolerance (paper default): near-identical FP rewrites are silent,
    large changes are not."""
    def drift(keys, x, eps):
        def body(c, k):
            w = x * (1.0 + eps * k)            # changes by eps each iter
            return c + w.sum(), None
        out, _ = jax.lax.scan(body, jnp.float32(0), keys)
        return out
    small = profile_fn(drift, jnp.arange(24.0), jnp.linspace(1, 2, 128),
                       jnp.float32(1e-5), cfg=CFG)
    big = profile_fn(drift, jnp.arange(24.0), jnp.linspace(1, 2, 128),
                     jnp.float32(0.5), cfg=CFG)
    assert small.fractions()["silent_store"] > big.fractions()["silent_store"]


def test_fractions_stable_across_periods():
    """Paper Fig. 4: sampling period does not change the story."""
    def linear_search(keys, arr):
        def body(c, k):
            return c + jnp.any(arr == k).astype(jnp.int32), None
        out, _ = jax.lax.scan(body, jnp.int32(0), keys)
        return out
    args = (jnp.arange(48) % 7, jnp.arange(256))
    fr = []
    for period in (10, 40, 160):
        cfg = ProfilerConfig(enabled=True, period=period, num_watchpoints=4)
        fr.append(profile_fn(linear_search, *args, cfg=cfg)
                  .fractions()["silent_load"])
    assert max(fr) - min(fr) < 0.35, fr


# ----------------------------------------------------------------------
# Trap-matching edge cases (watchpoint substrate)
# ----------------------------------------------------------------------
def _store_ev(addr, values, ctx=("s",)):
    values = np.asarray(values, np.float32)
    return MemEvent(kind=STORE, address=addr, nelems=values.size,
                    itemsize=4, values=values, ctx=ctx)


def test_value_at_outside_extent_is_none():
    ev = _store_ev(0, [1.0, 2.0, 3.0, 4.0])
    assert float(ev.value_at(3)) == 4.0
    assert ev.value_at(4) is None          # no clamping to the last element
    assert ev.value_at(100) is None
    assert MemEvent(STORE, 0, 4, 4, None, ("s",)).value_at(0) is None


def test_trap_same_address_shorter_event_disarms_without_classify():
    """A watchpoint armed at a high offset must not trap-classify against
    a shorter event at the same (recycled) address: the watched element
    no longer exists, so the slot frees without touching the checked/
    flagged estimator."""
    cfg = ProfilerConfig(enabled=True, period=10_000, num_watchpoints=4,
                         detect=("silent_store",))
    eng = EventEngine(cfg)
    eng.wp[STORE].on_sample(Watchpoint(
        address=7, offset=5, size=4, value=np.float32(5.0),
        context=("arm",), trap_type="W_TRAP", meta="silent_store"))
    eng.on_event(_store_ev(7, [5.0, 5.0], ctx=("short",)))   # nelems=2
    assert eng.wp[STORE].armed() == []                # disarmed (stale)
    assert eng.profile.checked.get("silent_store", 0) == 0
    assert eng.profile.flagged.get("silent_store", 0) == 0

    # in-extent offsets still classify normally
    eng.wp[STORE].on_sample(Watchpoint(
        address=7, offset=1, size=4, value=np.float32(5.0),
        context=("arm",), trap_type="W_TRAP", meta="silent_store"))
    eng.on_event(_store_ev(7, [0.0, 5.0], ctx=("short",)))
    assert eng.profile.checked["silent_store"] == 1
    assert eng.profile.flagged["silent_store"] == 1


def test_trap_value_extent_shorter_than_nelems_disarms():
    """Events whose value payload is shorter than their logical extent
    (external engine clients) skip — never clamp — the compare."""
    cfg = ProfilerConfig(enabled=True, period=10_000, num_watchpoints=4,
                         detect=("silent_load",))
    eng = EventEngine(cfg)
    eng.wp[LOAD].on_sample(Watchpoint(
        address=3, offset=6, size=4, value=np.float32(1.0),
        context=("arm",), trap_type="RW_TRAP", meta="silent_load"))
    ev = MemEvent(kind=LOAD, address=3, nelems=8, itemsize=4,
                  values=np.ones(4, np.float32), ctx=("l",))
    eng.on_event(ev)           # offset 6 < nelems but >= values.size
    assert eng.wp[LOAD].armed() == []
    assert eng.profile.checked.get("silent_load", 0) == 0


@given(st.integers(1, 60), st.integers(0, 10_000))
@settings(max_examples=40, deadline=None)
def test_store_sampling_arms_one_watchpoint_per_sample(k, seed):
    """One PMU sample arms exactly ONE watchpoint even with both store
    clients enabled (the paper's one-sample-one-watchpoint discipline):
    reservoir attempts equal the sample count, not twice it."""
    cfg = ProfilerConfig(enabled=True, period=1, num_watchpoints=1,
                         seed=seed,
                         detect=("dead_store", "silent_store"))
    eng = EventEngine(cfg)
    for i in range(k):       # distinct addresses: no traps interfere
        eng.on_event(_store_ev(100 + i, [float(i)], ctx=(f"c{i}",)))
    s = eng.wp[STORE].stats
    assert s["armed"] + s["replaced"] + s["rejected"] == k
    armed = eng.wp[STORE].armed()
    assert len(armed) == 1
    assert 100 <= armed[0].address < 100 + k
    assert armed[0].meta in ("dead_store", "silent_store")


def test_store_reservoir_survival_uniform_with_single_client():
    """Survival stays uniform across samples after the single-client fix
    (each sample survives w.p. ~1/k regardless of which client it armed)."""
    k, trials = 6, 1500
    counts = collections.Counter()
    for t in range(trials):
        cfg = ProfilerConfig(enabled=True, period=1, num_watchpoints=1,
                             seed=t,
                             detect=("dead_store", "silent_store"))
        eng = EventEngine(cfg)
        for i in range(k):
            eng.on_event(_store_ev(100 + i, [float(i)], ctx=(f"c{i}",)))
        counts[eng.wp[STORE].armed()[0].address - 100] += 1
    expect = trials / k
    for i in range(k):
        assert abs(counts[i] - expect) < 0.35 * expect, (i, counts[i])


# ----------------------------------------------------------------------
# Pair table / merge (§5.6)
# ----------------------------------------------------------------------
def test_pair_table_merge_rule():
    a, b = PairTable(), PairTable()
    a.add(("f:1",), ("g:2",), 4)
    b.add(("f:1",), ("g:2",), 4)       # same pair -> coalesce
    b.add(("f:1",), ("h:3",), 8)       # different trap ctx -> separate
    a.merge(b)
    assert a.pairs[(("f:1",), ("g:2",))].count == 2
    assert len(a.pairs) == 2
    assert a.total_bytes == 16


# ----------------------------------------------------------------------
# Tier-3
# ----------------------------------------------------------------------
def test_tier3_frozen_param_and_dead_grad():
    det = TrainingDetectors(ProfilerConfig(enabled=True), leaves_per_step=8)
    p0 = {"live": jnp.ones((64,)), "frozen": jnp.zeros((32,))}
    g = {"live": jnp.ones((64,)), "frozen": jnp.zeros((32,))}
    for step in range(8):
        p1 = {"live": p0["live"] * (1.0 + 0.1 * (step + 1)),
              "frozen": p0["frozen"]}
        det.on_step(step, p0, p1, g)
    kinds = {f.kind for f in det.report.findings}
    paths = {f.path for f in det.report.findings}
    assert "dead_grad_store" in kinds
    assert any("frozen" in p for p in paths)
    assert not any("live" in f.path for f in det.report.findings
                   if f.kind == "silent_param_store")


def test_tier3_duplicate_batch():
    det = TrainingDetectors(ProfilerConfig(enabled=True))
    b = {"tokens": jnp.arange(32)}
    det.on_batch(0, b)
    found = det.on_batch(1, b)                # identical content
    assert found and found[0].kind == "silent_data_load"
    fresh = det.on_batch(2, {"tokens": jnp.arange(32) + 1})
    assert not fresh
