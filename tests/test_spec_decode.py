"""Speculative decoding: greedy outputs bit-identical across spec
on/off for both KV layouts, the width-k verify forward against
sequential decode, drafter units, rollback-vs-overwrite dead-store
accounting (the detect→optimize acceptance criterion), and the
self-speculation corpus on duplicated traffic."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.configs.base import ProfilerConfig
from repro.core.detectors import ServingDetectors
from repro.models.zoo import build_model
from repro.serve.engine import Request, ServeEngine
from repro.serve.spec import (LMDrafter, NGramDrafter, ReplayDrafter,
                              make_drafter)

KEY = jax.random.PRNGKey(0)


def _model(arch="qwen3-1.7b"):
    cfg = dataclasses.replace(registry.get_config(arch).smoke(),
                              dtype="float32")
    model = build_model(cfg)
    return cfg, model, model.init(KEY)


class GarbageDrafter:
    """Proposes a constant wrong-ish token: high rejection pressure."""

    def __init__(self, tok=7):
        self.tok = tok

    def observe(self, tokens):
        pass

    def propose(self, history, k):
        return np.full(k, self.tok, np.int32)


def _workload(cfg, n=4, seed=3):
    rng = np.random.RandomState(seed)
    reqs = []
    for i, (plen, gen, arr) in enumerate(
            [(8, 5, 0), (5, 7, 0), (7, 3, 1), (6, 6, 4)][:n]):
        toks = rng.randint(0, cfg.vocab_size, size=plen).astype(np.int32)
        reqs.append((f"q{i}", toks, gen, arr))
    return reqs


def _serve(model, params, reqs, *, kv="dense", drafter=None,
           rollback=True, eos_id=None, max_len=32, detectors=None):
    eng = ServeEngine(model, params, num_slots=2, max_len=max_len,
                      kv_layout=kv, page_size=8, drafter=drafter,
                      spec_k=3, spec_rollback=rollback, eos_id=eos_id,
                      detectors=detectors)
    for rid, toks, gen, arr in reqs:
        eng.submit(Request(rid=rid, tokens=toks.copy(),
                           max_new_tokens=gen, arrival=arr))
    fin = eng.run(max_steps=400)
    return {rid: fin[rid].generated for rid in fin}, eng


# ----------------------------------------------------------------------
# The acceptance criterion: spec on/off x dense/paged, identical outputs
# ----------------------------------------------------------------------
def test_spec_outputs_bit_identical_across_modes():
    """Same staggered workload through plain decode and through every
    speculative mode (dense overwrite, paged overwrite, paged rollback)
    with both a perfect and a hostile drafter: every request's greedy
    continuation must match token for token — the acceptance rule only
    ever admits the tokens plain decode would have produced."""
    cfg, model, params = _model()
    reqs = _workload(cfg)
    base, _ = _serve(model, params, reqs)
    lm = LMDrafter(model, params)          # self-draft: accepts fully
    cases = [("dense", lm, False), ("paged", lm, False),
             ("paged", lm, True), ("dense", GarbageDrafter(), False),
             ("paged", GarbageDrafter(), True)]
    for kv, drafter, rollback in cases:
        out, eng = _serve(model, params, reqs, kv=kv, drafter=drafter,
                          rollback=rollback)
        assert out == base, (kv, type(drafter).__name__, rollback)
        assert eng.stats["spec_ticks"] > 0
        if isinstance(drafter, LMDrafter):
            # the target drafting for itself is always accepted, so the
            # batch emits more than one token per verify tick
            assert eng.stats["draft_accepted"] == eng.stats["draft_proposed"]
            assert eng.stats["draft_accepted"] > 0
        else:
            # a hostile drafter is overwhelmingly rejected (a constant
            # token can still luck into a greedy match) — and whatever
            # it proposed never corrupted the output stream
            assert (eng.stats["draft_accepted"]
                    < eng.stats["draft_proposed"])


def test_spec_bit_identical_with_eos_early_exit():
    """EOS inside an accepted window must truncate exactly like plain
    decode (no token after EOS is ever emitted)."""
    cfg, model, params = _model()
    reqs = _workload(cfg, n=2)
    base, _ = _serve(model, params, reqs)
    # the EOS id is a token plain decode actually emits mid-stream
    eos = base["q0"][2]
    base_eos, _ = _serve(model, params, reqs, eos_id=eos)
    out, _ = _serve(model, params, reqs, eos_id=eos,
                    drafter=LMDrafter(model, params), kv="paged")
    assert out == base_eos
    assert out["q0"][-1] == eos or len(out["q0"]) < len(base["q0"])


# ----------------------------------------------------------------------
# LM.verify against sequential decode (the model-layer contract)
# ----------------------------------------------------------------------
def test_verify_chain_matches_sequential_decode_dense_and_paged():
    """One width-W verify call must reproduce W sequential greedy decode
    steps: same greedy tokens at every window position, and (for the
    committed prefix) the same cache-visible behaviour afterwards."""
    cfg, model, params = _model()
    B, P, W = 2, 6, 4
    toks = np.asarray(jax.random.randint(KEY, (B, P), 0, cfg.vocab_size))
    max_len = 24

    # sequential greedy chain from the prefilled cache
    cache = model.init_cache(params, B, max_len, kv_dtype=jnp.float32)
    cache = model.with_cache_index(cache, jnp.zeros((B,), jnp.int32))
    lg, cache = model.prefill(params, cache, jnp.asarray(toks),
                              lengths=jnp.full((B,), P, jnp.int32))
    cur = jnp.argmax(lg[:, P - 1:P], -1).astype(jnp.int32)
    seq_cache = cache
    chain = [np.asarray(cur[:, 0])]
    for _ in range(W):
        lg, seq_cache = model.decode_step(params, seq_cache, cur)
        cur = jnp.argmax(lg[:, -1:], -1).astype(jnp.int32)
        chain.append(np.asarray(cur[:, 0]))
    chain = np.stack(chain, 1)          # (B, W+1) greedy continuation

    # verify the chain's first W tokens in ONE call: every draft is the
    # true greedy token, so g must equal the chain shifted by one
    window = jnp.asarray(chain[:, :W])
    vlg, vcache = model.verify(params, cache, window)
    g = np.asarray(jnp.argmax(vlg, -1))
    np.testing.assert_array_equal(g, chain[:, 1:W + 1])


def test_commit_verify_stores_exactly_the_accepted_prefix():
    """Deferred verify + commit_verify(length=L) must leave the paged
    pool bit-identical to the overwrite path's pool for rows < L, and
    bit-identical to the PRE-verify pool everywhere else (rejected rows
    never become stores)."""
    cfg, model, params = _model()
    B, P, W = 2, 6, 3
    toks = np.asarray(jax.random.randint(KEY, (B, P), 0, cfg.vocab_size))
    eng = ServeEngine(model, params, num_slots=B, max_len=24,
                      kv_layout="paged", page_size=4)
    for b in range(B):
        eng.submit(Request(rid=f"r{b}", tokens=toks[b],
                           max_new_tokens=8))
    eng._admit()
    cache0 = eng.cache
    window = jnp.asarray(
        np.asarray(jax.random.randint(jax.random.PRNGKey(5), (B, W), 0,
                                      cfg.vocab_size), np.int32))
    idx0 = model.cache_index(cache0)
    # overwrite: all W rows land in the pool
    _, over = model.verify(params, cache0, window, commit=True)
    # defer + commit rows [0, L)
    L = jnp.asarray([2, 0], jnp.int32)
    _, defer = model.verify(params, cache0, window, commit=False)
    committed = model.commit_verify(defer, idx0, L)

    for name in committed["main"]:
        ck = np.asarray(committed["main"][name]["k"])
        ok = np.asarray(over["main"][name]["k"])
        base = np.asarray(cache0["main"][name]["k"])
        assert "win_k" not in committed["main"][name]
        pt = np.asarray(cache0["main"][name]["pt"])[0]   # same per layer
        idx = np.asarray(idx0)
        ps = ck.shape[2]
        for b in range(B):
            for s in range(W):
                pos = int(idx[b]) + s
                page = pt[b][pos // ps]
                row = (slice(None), page, pos % ps)
                if s < int(L[b]):
                    np.testing.assert_array_equal(ck[row], ok[row])
                else:
                    np.testing.assert_array_equal(ck[row], base[row])


# ----------------------------------------------------------------------
# Drafters
# ----------------------------------------------------------------------
def test_ngram_drafter_self_and_corpus_lookup():
    d = NGramDrafter(max_n=3, min_n=2)
    # self-speculation: the tail bigram (4, 5) occurred earlier; the
    # drafter replays what followed it
    hist = np.array([1, 2, 4, 5, 9, 8, 4, 5], np.int32)
    np.testing.assert_array_equal(d.propose(hist, 2), [9, 8])
    # corpus lookup: an unseen tail matches a served sequence
    d.observe(np.array([7, 7, 3, 1, 2, 6], np.int32))
    np.testing.assert_array_equal(
        d.propose(np.array([50, 60, 7, 7], np.int32), 3), [3, 1, 2])
    # no match -> no draft (never a fabricated token)
    assert d.propose(np.array([100, 101], np.int32), 4).size == 0
    assert d.propose(np.array([1], np.int32), 0).size == 0
    # a tail-flush occurrence (no continuation) must not shadow an
    # earlier occurrence that HAS one
    d2 = NGramDrafter(max_n=3, min_n=2)
    d2.observe(np.array([9, 9, 1, 2, 5, 1, 2], np.int32))
    np.testing.assert_array_equal(
        d2.propose(np.array([40, 41, 1, 2], np.int32), 3), [5, 1, 2])


def test_replay_drafter_prefix_semantics():
    d = ReplayDrafter([[1, 2, 3, 4, 5]])
    np.testing.assert_array_equal(d.propose([1, 2, 3], 2), [4, 5])
    assert d.propose([1, 2, 9], 2).size == 0
    assert d.propose([1, 2, 3, 4, 5], 2).size == 0     # nothing left
    assert make_drafter("ngram").propose([1, 2], 1).size == 0


def test_ngram_corpus_duplicate_prompt_drafts_donor_continuation():
    """Duplicated traffic drafts itself: after a donor request finishes,
    a later duplicate of its prompt is drafted from the served corpus
    and the verify forward accepts the donor's greedy continuation."""
    cfg, model, params = _model()
    rng = np.random.RandomState(11)
    prompt = rng.randint(0, cfg.vocab_size, size=10).astype(np.int32)
    reqs = [("donor", prompt, 6, 0), ("dup", prompt.copy(), 6, 8)]
    out, eng = _serve(model, params, reqs, kv="paged",
                      drafter=NGramDrafter(), max_len=40)
    assert out["donor"] == out["dup"]
    assert eng.stats["draft_accepted"] >= 1, eng.stats
    tp = eng.throughput()
    assert tp["accept_rate"] > 0


def test_lm_drafter_same_model_accepts_everything():
    """The target model drafting for itself is the acceptance rule's
    fixed point: prefill is bit-identical to the token loop, so every
    proposal equals the verify forward's greedy token."""
    cfg, model, params = _model()
    reqs = _workload(cfg, n=2)
    out, eng = _serve(model, params, reqs,
                      drafter=LMDrafter(model, params))
    assert eng.stats["draft_proposed"] > 0
    assert eng.stats["draft_accepted"] == eng.stats["draft_proposed"]
    # multi-token ticks: fewer verify ticks than emitted decode tokens
    assert eng.stats["spec_ticks"] < eng.stats["decode_tokens"]


# ----------------------------------------------------------------------
# The closed loop: rejected-draft dead stores measured, then eliminated
# ----------------------------------------------------------------------
def test_rollback_strictly_lowers_rejected_draft_dead_stores():
    """ISSUE 4 acceptance: under a rejection-heavy drafter the overwrite
    engine stores every rejected draft row (Def.-1 dead stores — the
    `rejected_draft_store` fraction is high), while the rollback engine
    never stores them (fraction 0) — with bit-identical outputs."""
    cfg, model, params = _model()
    reqs = _workload(cfg)

    def run(rollback):
        det = ServingDetectors(ProfilerConfig(enabled=True, seed=0),
                               sites_per_step=2)
        out, eng = _serve(model, params, reqs, kv="paged",
                          drafter=GarbageDrafter(), rollback=rollback,
                          detectors=det)
        return out, det.report.fractions()

    out_ow, fr_ow = run(False)
    out_rb, fr_rb = run(True)
    assert out_ow == out_rb
    assert fr_ow["rejected_draft_store"] > 0.5, fr_ow
    assert (fr_rb.get("rejected_draft_store", 0.0)
            < fr_ow["rejected_draft_store"]), (fr_ow, fr_rb)


def test_partial_accept_fraction_between_modes():
    """A drafter that is right only sometimes: overwrite's dead-store
    fraction sits strictly between 0 and 1 and rollback still reports
    zero, while the outputs stay identical and some drafts land."""
    cfg, model, params = _model()
    rng = np.random.RandomState(11)
    prompt = rng.randint(0, cfg.vocab_size, size=10).astype(np.int32)
    reqs = [("donor", prompt, 6, 0), ("dup", prompt.copy(), 6, 8)]

    class HalfOracle(NGramDrafter):
        """Corpus-backed drafts with the last one corrupted: accepts
        the prefix, rejects the tail."""

        def propose(self, history, k):
            d = super().propose(history, k)
            if d.size:
                d = d.copy()
                d[-1] = (d[-1] + 1) % 50
            return d

    def run(rollback):
        det = ServingDetectors(ProfilerConfig(enabled=True, seed=0))
        out, eng = _serve(model, params, reqs, kv="paged",
                          drafter=HalfOracle(), rollback=rollback,
                          detectors=det, max_len=40)
        return out, eng, det.report.fractions()

    out_ow, eng_ow, fr_ow = run(False)
    out_rb, eng_rb, fr_rb = run(True)
    assert out_ow == out_rb
    assert eng_ow.stats["draft_accepted"] >= 1
    f = fr_ow.get("rejected_draft_store", 0.0)
    assert 0.0 < f < 1.0, fr_ow
    assert fr_rb.get("rejected_draft_store", 1.0) == 0.0, fr_rb


def test_spec_stats_and_throughput_accounting():
    """Emitted-token accounting stays honest under speculation: decode
    tokens equal the plain run's, accepted+ticks bound the emissions,
    and the accept-rate/draft/verify rates are exposed."""
    cfg, model, params = _model()
    reqs = _workload(cfg, n=2)
    base, plain_eng = _serve(model, params, reqs)
    out, eng = _serve(model, params, reqs,
                      drafter=LMDrafter(model, params))
    assert (eng.stats["decode_tokens"]
            == plain_eng.stats["decode_tokens"])
    # each verify tick emits at most 1 bonus token per slot on top of
    # the accepted drafts
    assert eng.stats["decode_tokens"] <= (
        eng.stats["draft_accepted"]
        + eng.stats["spec_ticks"] * eng.num_slots)
    tp = eng.throughput()
    for key in ("draft_tok_s", "verify_tok_s", "accept_rate"):
        assert key in tp
    assert tp["accept_rate"] == 1.0
