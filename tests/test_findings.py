"""Unified waste-profile substrate: JSON round-trip, cross-tier and
cross-shard merge associativity, trace→replay equivalence with the
epoch-by-epoch interpreter, and the shared comparison helper."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ProfilerConfig
from repro.core.detectors import TrainingDetectors
from repro.core.events import approx_equal, silent_mask
from repro.core.findings import Finding, WasteProfile, merge
from repro.core.hlo_waste import analyze_waste
from repro.core.interpreter import profile_fn
from repro.core.report import (dump_json, load_json, merge_reports,
                               merge_shards)

CFG = ProfilerConfig(enabled=True, period=20, num_watchpoints=4)


def _linear_search(keys, arr):
    def body(c, k):
        return c + jnp.any(arr == k).astype(jnp.int32), None
    out, _ = jax.lax.scan(body, jnp.int32(0), keys)
    return out


def _tier1(seed=0):
    cfg = ProfilerConfig(enabled=True, period=20, num_watchpoints=4,
                         seed=seed)
    return profile_fn(_linear_search, jnp.arange(48) % 7, jnp.arange(256),
                      cfg=cfg)


def _tier3():
    det = TrainingDetectors(ProfilerConfig(enabled=True), leaves_per_step=8)
    p0 = {"live": jnp.ones((64,)), "frozen": jnp.zeros((32,))}
    g = {"live": jnp.ones((64,)), "frozen": jnp.zeros((32,))}
    for step in range(6):
        p1 = {"live": p0["live"] * (1.0 + 0.1 * (step + 1)),
              "frozen": p0["frozen"]}
        det.on_step(step, p0, p1, g)
    return det.report


_HLO = """
HloModule m

ENTRY %main (p0: f32[4096]) -> f32[4096] {
  %p0 = f32[4096]{0} parameter(0)
  %ag1 = f32[4096]{0} all-gather(%p0), replica_groups=[2,8]<=[16], dimensions={0}
  %ag2 = f32[4096]{0} all-gather(%p0), replica_groups=[2,8]<=[16], dimensions={0}
  ROOT %s = f32[4096]{0} add(%ag1, %ag2)
}
"""


# ----------------------------------------------------------------------
# JSON round-trip
# ----------------------------------------------------------------------
def test_profile_json_roundtrip_tier1():
    rep = _tier1()
    again = WasteProfile.from_json(rep.to_json())
    assert again == rep
    assert again.fractions() == rep.fractions()
    assert again.silent_loads.total_count == rep.silent_loads.total_count
    assert again.total_load_events == rep.total_load_events


def test_profile_json_roundtrip_merged_tiers(tmp_path):
    """merge(tier1, tier2, tier3) round-trips losslessly through a file."""
    unified = merge(_tier1(), analyze_waste(_HLO).profile, _tier3())
    assert unified.tiers == [1, 2, 3]
    path = str(tmp_path / "profile.json")
    dump_json(unified, path)
    assert load_json(path) == unified


# ----------------------------------------------------------------------
# Merge semantics (§5.6 across shards, epochs and tiers)
# ----------------------------------------------------------------------
def test_cross_shard_merge_associative():
    a, b, c = _tier1(seed=0), _tier1(seed=1), _tier1(seed=2)
    left = merge(merge(a, b), c)
    right = merge(a, merge(b, c))
    assert left == right
    # pure merge: shard inputs untouched
    assert a.tiers == [1] and a == _tier1(seed=0)


def test_cross_tier_merge_associative_and_complete():
    t1, t2, t3 = _tier1(), analyze_waste(_HLO).profile, _tier3()
    left = merge(merge(t1, t2), t3)
    right = merge(t1, merge(t2, t3))
    assert left == right
    fr = left.fractions()
    assert fr["silent_load"] > 0.5                 # tier-1 estimator
    assert fr["redundant_collective"] == 1.0       # tier-2 estimator
    assert "silent_param_store" in fr              # tier-3 estimator
    kinds = {f.kind for f in left.findings}
    assert {"silent_load", "redundant_collective", "dead_grad_store"} <= kinds


def test_shard_merge_coalesces_matching_pairs():
    a, b = _tier1(seed=0), _tier1(seed=0)
    m = merge(a, b)
    # identical shards -> same ⟨C1,C2⟩ keys, doubled counts
    assert m.silent_loads.total_count == 2 * a.silent_loads.total_count
    assert m.total_load_events == 2 * a.total_load_events
    assert m.fractions()["silent_load"] == a.fractions()["silent_load"]
    assert merge_reports([_tier1(seed=0), b]) == m


def test_finding_coalesce_rule():
    p = WasteProfile(tier=1)
    p.add(Finding(kind="dead_store", tier=1, c1=("f:1",), c2=("g:2",),
                  bytes=4.0))
    p.add(Finding(kind="dead_store", tier=1, c1=("f:1",), c2=("g:2",),
                  bytes=4.0))
    p.add(Finding(kind="dead_store", tier=1, c1=("f:1",), c2=("h:3",),
                  bytes=8.0))
    assert len(p.findings) == 2                    # §5.6: both ctxs match
    assert p.pair_table("dead_store").pairs[(("f:1",), ("g:2",))].count == 2


# ----------------------------------------------------------------------
# Trace→replay (tentpole): identical profiles to re-interpretation
# ----------------------------------------------------------------------
def test_trace_replay_identical_to_reinterpretation():
    args = (jnp.arange(48) % 7, jnp.arange(256))
    for epochs in (2, 4):
        cfg = ProfilerConfig(enabled=True, period=20, num_watchpoints=4)
        re_rep = profile_fn(_linear_search, *args, cfg=cfg, epochs=epochs,
                            replay=False)
        cfg = ProfilerConfig(enabled=True, period=20, num_watchpoints=4)
        rp_rep = profile_fn(_linear_search, *args, cfg=cfg, epochs=epochs,
                            replay=True)
        assert rp_rep == re_rep
        assert rp_rep.fractions() == re_rep.fractions()


def test_multi_epoch_accumulates():
    one = _tier1()
    cfg = ProfilerConfig(enabled=True, period=20, num_watchpoints=4)
    four = profile_fn(_linear_search, jnp.arange(48) % 7, jnp.arange(256),
                      cfg=cfg, epochs=4)
    assert four.total_load_events == 4 * one.total_load_events
    assert sum(four.checked.values()) > sum(one.checked.values())


# ----------------------------------------------------------------------
# The one comparison helper (symmetric relative tolerance)
# ----------------------------------------------------------------------
def test_approx_equal_symmetric_near_zero():
    # seed bug: |a-b| <= tol*|a| made a=0 never-silent vs any tiny b and
    # direction-dependent; the shared helper is symmetric
    assert approx_equal(np.float32(0.0), np.float32(0.0), 0.01)
    assert not approx_equal(np.float32(0.0), np.float32(1.0), 0.01)
    a, b = np.float32(1.0), np.float32(1.005)
    assert approx_equal(a, b, 0.01) == approx_equal(b, a, 0.01)
    assert not approx_equal(np.float32(np.nan), np.float32(np.nan), 0.01)
    assert approx_equal(np.int32(3), np.int32(3), 0.0)


def test_silent_mask_matches_scalar_helper():
    a = np.asarray([0.0, 1.0, 1.005, -2.0, np.nan], np.float32)
    b = np.asarray([0.0, 1.005, 1.0, -2.1, np.nan], np.float32)
    mask = np.asarray(silent_mask(a, b, 0.01))
    want = [approx_equal(x, y, 0.01) for x, y in zip(a, b)]
    assert mask.tolist() == want


# ----------------------------------------------------------------------
# Merge fuzz: §5.6 must be an honest commutative monoid, NaN included
# ----------------------------------------------------------------------
def _random_profile(rng) -> WasteProfile:
    """A random shard/tier/epoch profile. Finding meta is a function of
    the coalescing key (as in real detectors: meta describes the site),
    so merge order cannot leak through meta's first-wins rule."""
    kinds = ("dead_store", "silent_store", "silent_load",
             "rejected_draft_store", "silent_prefix_load")
    tier = int(rng.choice([1, 2, 3]))
    p = WasteProfile(tier=tier,
                     sampling_period=int(rng.choice([1, 100, 5000])))
    for _ in range(rng.randint(0, 7)):
        kind = kinds[rng.randint(len(kinds))]
        c1 = (f"site{rng.randint(3)}", f"fn{rng.randint(2)}")
        c2 = (f"ctx{rng.randint(3)}",)
        frac = float("nan") if rng.randint(4) == 0 \
            else float(0.25 * rng.randint(5))
        nbytes = float("nan") if rng.randint(6) == 0 \
            else float(rng.randint(0, 1 << 20))
        p.add(Finding(kind=kind, tier=tier, c1=c1, c2=c2,
                      count=int(rng.randint(1, 5)), bytes=nbytes,
                      flops=float(rng.randint(0, 100)), fraction=frac,
                      step=int(rng.randint(-1, 50)),
                      meta={"site": f"{kind}@{c1[0]}"}))
    for _ in range(rng.randint(0, 8)):
        p.observe(kinds[rng.randint(len(kinds))], bool(rng.randint(2)))
    for key in ("store_events", "load_bytes"):
        if rng.randint(2):
            p.bump_total(key, int(rng.randint(0, 10000)))
    if rng.randint(2):
        p.watchpoint_stats["store"] = {"armed": int(rng.randint(10)),
                                       "traps": int(rng.randint(10))}
    # DJXPerf object table: rows keyed by stable object key; name/site/
    # kind are functions of the key (like finding meta) so first-wins
    # cannot leak merge order. NaN nbytes exercises _fmax's NaN rule.
    okinds = ("kv_page", "param", "opt_state")
    for _ in range(rng.randint(0, 6)):
        i = int(rng.randint(4))
        nbytes = float("nan") if rng.randint(6) == 0 \
            else float(rng.randint(0, 1 << 16))
        p.bill_object({"key": f"{okinds[i % 3]}|obj{i}|alloc.py:{10 + i}",
                       "kind": okinds[i % 3], "name": f"obj{i}",
                       "site": f"alloc.py:{10 + i}", "nbytes": nbytes},
                      ("dead", "silent", "replica")[rng.randint(3)],
                      float(rng.randint(0, 1 << 12)),
                      count=int(rng.randint(1, 4)))
    return p


from _hypo import given, settings, st  # noqa: E402


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=40, deadline=None)
def test_merge_shards_fuzz_associative_commutative_roundtrip(seed):
    """Random shard profiles (NaN-bearing findings included): merge is
    associative and commutative, merge_shards never mutates its inputs,
    and every profile survives a JSON round-trip losslessly. Profiles
    compare via their canonical JSON (sorted findings/keys) so NaN —
    which breaks == — still compares representation-exactly; this
    caught Python max()'s order-dependence under NaN in
    Finding.absorb."""
    rng = np.random.RandomState(seed)
    a, b, c = (_random_profile(rng) for _ in range(3))
    snap = [x.to_json() for x in (a, b, c)]

    ab_c = merge(merge(a, b), c).to_json()
    a_bc = merge(a, merge(b, c)).to_json()
    assert ab_c == a_bc                          # associative
    assert merge(a, b).to_json() == merge(b, a).to_json()   # commutative
    assert merge_shards([a, b, c]).to_json() == ab_c
    assert [x.to_json() for x in (a, b, c)] == snap   # inputs untouched

    for x in (a, b, c, merge_shards([a, b, c])):
        back = WasteProfile.from_json(x.to_json())
        assert back.to_json() == x.to_json()     # lossless round-trip


def test_absorb_nan_fraction_is_order_independent():
    """The deterministic core of the fuzz above: coalescing a NaN
    fraction with a real one must not depend on arrival order (Python's
    max(nan, x) is nan but max(x, nan) is x — the non-NaN value wins
    now)."""
    def f(frac):
        return Finding(kind="dead_store", tier=1, c1=("a",), c2=("b",),
                       fraction=frac)
    p1, p2 = WasteProfile(tier=1), WasteProfile(tier=1)
    p1.add(f(float("nan"))); p1.add(f(0.5))
    p2.add(f(0.5)); p2.add(f(float("nan")))
    assert p1.to_json() == p2.to_json()
    assert p1.findings[0].fraction == 0.5


# ----------------------------------------------------------------------
# Zero-event profiles: every reporting surface must stay finite
# ----------------------------------------------------------------------
def test_zero_event_profile_renders_and_serializes():
    """A cold profile (no events observed yet — a serve tick before the
    first admission, a scan of an empty registry) must not divide by
    zero or print NaN anywhere: fractions(), both render() views, the
    JSON round-trip."""
    p = WasteProfile(tier=1)
    assert all(v == 0.0 for v in p.fractions().values())
    assert "nan" not in p.render().lower()
    assert "nan" not in p.render(by="object").lower()
    assert WasteProfile.from_json(p.to_json()).to_json() == p.to_json()
    # observed-but-never-flagged: the fraction is an honest 0, not 0/0
    p.observe("dead_store", False)
    assert p.fractions()["dead_store"] == 0.0
    # an object billed with zero/NaN size renders a placeholder instead
    # of a divide-by-zero percentage
    p.bill_object({"key": "kv_page|kv/page0|kv_cache.py:102",
                   "kind": "kv_page", "name": "kv/page0",
                   "site": "kv_cache.py:102", "nbytes": 0.0},
                  "replica", 0.0)
    p.bill_object({"key": "param|p|m.py:1", "kind": "param", "name": "p",
                   "site": "m.py:1", "nbytes": float("nan")},
                  "dead", 64.0)
    out = p.render(by="object")
    assert "nan" not in out.lower() and "inf" not in out.lower()
    assert merge(p, WasteProfile(tier=1)).to_json() == p.to_json()
