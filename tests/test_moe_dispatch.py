"""A/B equivalence of the MoE dispatch paths (scatter vs einsum).

The scatter path (default) must reproduce the GShard einsum reference:
bit-identical for experts_per_token == 1, ~1-ulp float32 tolerance for
K >= 2 (the combine contracts over k instead of (e, c), so XLA's
FMA/lane accumulation order differs — documented in models/moe.py).
The dispatch_stats probe must show the einsum path's dead expert rows
and the scatter path's exactly-zero dead fraction.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import registry
from repro.models import moe as M
from repro.models.zoo import build_model

KEY = jax.random.PRNGKey(0)


def _moe_params(cfg):
    """Layer-0 MoE params of a freshly initialized zoo model."""
    params = build_model(cfg).init(KEY)

    def find(tree):
        if isinstance(tree, dict):
            for k, v in tree.items():
                if k == "moe":
                    return v
                r = find(v)
                if r is not None:
                    return r
        return None

    stacked = find(params)
    assert stacked is not None
    return jax.tree.map(lambda a: a[0], stacked)


def _both(cfg, x, pm):
    out = {}
    for mode in ("scatter", "einsum"):
        c = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, dispatch=mode))
        out[mode] = M.apply_moe(pm, c, x)
    return out


@pytest.mark.parametrize("arch,bitwise", [
    ("llama4-scout-17b-a16e", True),   # K=1: single-term combine, exact
    ("granite-moe-3b-a800m", False),   # K=2: reduction-order tolerance
])
def test_scatter_matches_einsum_forward(arch, bitwise):
    cfg = registry.get_config(arch).smoke()
    pm = _moe_params(cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model),
                          jnp.float32)
    r = _both(cfg, x, pm)
    (o_s, a_s), (o_e, a_e) = r["scatter"], r["einsum"]
    assert bool(jnp.all(a_s == a_e))  # aux loss is routing-only: exact
    if bitwise:
        assert bool(jnp.all(o_s == o_e))
    else:
        scale = float(jnp.max(jnp.abs(o_e)))
        assert float(jnp.max(jnp.abs(o_s - o_e))) <= 1e-6 * scale


def test_scatter_matches_einsum_grads():
    cfg = registry.get_config("granite-moe-3b-a800m").smoke()
    pm = _moe_params(cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 32, cfg.d_model),
                          jnp.float32)

    def loss(pm, cfg):
        o, a = M.apply_moe(pm, cfg, x)
        return jnp.mean(o ** 2) + a

    grads = {m: jax.grad(loss)(pm, dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, dispatch=m)))
        for m in ("scatter", "einsum")}
    for k in grads["einsum"]:
        ge, gs = grads["einsum"][k], grads["scatter"][k]
        scale = float(jnp.max(jnp.abs(ge))) or 1.0
        assert float(jnp.max(jnp.abs(gs - ge))) <= 1e-6 * scale, k


def test_scatter_matches_einsum_under_drops():
    """Capacity pressure (factor well below 1) drops tokens; the dropped
    set is decided by routing, identical across paths, and both paths
    must agree on the surviving contributions."""
    cfg = registry.get_config("granite-moe-3b-a800m").smoke()
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, capacity_factor=0.25))
    pm = _moe_params(cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 64, cfg.d_model),
                          jnp.float32)
    st = M.dispatch_stats(pm, dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, dispatch="einsum")), x)
    # the squeeze must actually drop something or the test is vacuous
    assert st["rows_routed"] < 2 * 64 * cfg.moe.experts_per_token
    r = _both(cfg, x, pm)
    o_s, o_e = r["scatter"][0], r["einsum"][0]
    scale = float(jnp.max(jnp.abs(o_e)))
    assert float(jnp.max(jnp.abs(o_s - o_e))) <= 1e-6 * scale


def test_dispatch_stats_dead_fraction():
    cfg = registry.get_config("granite-moe-3b-a800m").smoke()
    pm = _moe_params(cfg)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 32, cfg.d_model),
                          jnp.float32)
    st_e = M.dispatch_stats(pm, dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, dispatch="einsum")), x)
    st_s = M.dispatch_stats(pm, dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, dispatch="scatter")), x)
    # routing is dispatch-independent
    assert st_e["rows_routed"] == st_s["rows_routed"]
    assert st_e["rows_total"] == st_s["rows_total"]
    # einsum materializes the whole buffer -> dead rows; scatter stores
    # only routed rows -> exactly zero dead stores
    assert st_e["rows_stored"] == st_e["rows_total"]
    assert st_e["dead_rows"] > 0 and st_e["dead_bytes"] > 0
    assert st_e["dead_fraction"] > 0
    assert st_s["dead_rows"] == 0 and st_s["dead_bytes"] == 0
    assert st_s["dead_fraction"] == 0.0
    assert st_s["rows_stored"] == st_s["rows_routed"]


def test_default_dispatch_is_scatter():
    for arch in ("granite-moe-3b-a800m", "llama4-scout-17b-a16e"):
        assert registry.get_config(arch).moe.dispatch == "scatter"
