"""Tier-0 static jaxpr lint: golden corpus.

Each waste rule gets a planted-positive program AND a clean twin that
differs only in the property the rule checks — the twin must produce
ZERO findings of that kind (false-positive guard). Positives assert the
kind, the byte accounting, and the ⟨C1⟩ provenance file:line pointing
back into THIS file.
"""
import os

import jax
import jax.numpy as jnp

from repro.core.findings import TIER_STATIC, WasteProfile, merge
from repro.core.jaxpr_lint import lint_fn, lint_jaxpr

HERE = os.path.basename(__file__)


def kinds(prof):
    return sorted({f.kind for f in prof.findings})


def only(prof, kind):
    fs = [f for f in prof.findings if f.kind == kind]
    assert fs, f"no {kind} finding; got {kinds(prof)}"
    return fs


def assert_here(finding, lo=0, hi=10 ** 9):
    """Provenance points into this test file at a plausible line."""
    f = finding.meta.get("file", "")
    assert os.path.basename(f) == HERE, f"provenance file {f!r}"
    assert lo <= finding.meta.get("line", 0) <= hi


# --------------------------------------------------------------- dead store
def test_dead_store_overwritten_region():
    def f(x, u1, u2):
        y = jax.lax.dynamic_update_slice(x, u1, (3,))       # dead: fully
        return jax.lax.dynamic_update_slice(y, u2, (3,))    # overwritten

    x, u = jnp.zeros(17), jnp.ones(5)
    prof = lint_fn(f, x, u, u, subject="t")
    ds = only(prof, "dead_store")
    assert len(ds) == 1
    assert ds[0].bytes == 5 * 4                      # the dead update
    assert ds[0].tier == TIER_STATIC
    assert_here(ds[0])
    assert ds[0].c2, "C2 must name the overwriting store"


def test_dead_store_clean_twin_distinct_offsets():
    def f(x, u1, u2):
        y = jax.lax.dynamic_update_slice(x, u1, (0,))
        return jax.lax.dynamic_update_slice(y, u2, (9,))

    prof = lint_fn(f, jnp.zeros(17), jnp.ones(5), jnp.ones(5), subject="t")
    assert not [f for f in prof.findings if f.kind == "dead_store"]
    assert prof.checked.get("dead_store", 0) == 2    # both sites checked


def test_dead_store_result_never_read():
    def f(x, u):
        _ = jax.lax.dynamic_update_slice(x, u, (3,))
        return x.sum()

    prof = lint_fn(f, jnp.zeros(17), jnp.ones(5), subject="t")
    ds = only(prof, "dead_store")
    assert "never read" in ds[0].meta["rule"]
    assert_here(ds[0])


# ------------------------------------------------------------- silent store
def test_silent_store_zero_add_identity():
    def f(x):
        return x + 0.0                                # provably x

    prof = lint_fn(f, jnp.zeros((3, 5)), subject="t")
    ss = only(prof, "silent_store")
    assert ss[0].bytes == 3 * 5 * 4
    assert_here(ss[0])


def test_silent_store_clean_twin_nonidentity():
    def f(x):
        return x + 1.0

    prof = lint_fn(f, jnp.zeros((3, 5)), subject="t")
    assert not [f for f in prof.findings if f.kind == "silent_store"]


def test_silent_store_slice_written_back_same_offsets():
    def f(x):
        s = jax.lax.dynamic_slice(x, (3,), (5,))
        return jax.lax.dynamic_update_slice(x, s, (3,))   # resident value

    prof = lint_fn(f, jnp.ones(17), subject="t")
    ss = only(prof, "silent_store")
    assert "resident" in ss[0].meta["rule"]
    assert_here(ss[0])


def test_silent_store_clean_twin_modified_before_writeback():
    def f(x):
        s = jax.lax.dynamic_slice(x, (3,), (5,))
        return jax.lax.dynamic_update_slice(x, s * 2.0, (3,))

    prof = lint_fn(f, jnp.ones(17), subject="t")
    assert not [f for f in prof.findings if f.kind == "silent_store"]


def test_silent_store_clean_twin_different_offsets():
    def f(x):
        s = jax.lax.dynamic_slice(x, (0,), (5,))
        return jax.lax.dynamic_update_slice(x, s, (9,))   # moved, not silent

    prof = lint_fn(f, jnp.ones(17), subject="t")
    assert not [f for f in prof.findings if f.kind == "silent_store"]


def test_silent_store_scatter_writeback():
    def f(x, i):
        return x.at[i].set(x[i])                      # gather -> scatter back

    def g(x, i):
        return x.at[i].set(x[i] + 1.0)

    i = jnp.array([2, 11])
    assert "silent_store" in kinds(lint_fn(f, jnp.ones(17), i, subject="t"))
    assert "silent_store" not in kinds(lint_fn(g, jnp.ones(17), i,
                                               subject="t"))


# ----------------------------------------------------------- redundant load
def test_redundant_load_loop_invariant_gather_in_scan():
    def f(table, idx, xs):
        def body(c, x):
            row = jnp.take(table, idx, axis=0)        # invariant per trip
            return c + row.sum() + x, None
        out, _ = jax.lax.scan(body, 0.0, xs)
        return out

    table = jnp.ones((13, 7))
    prof = lint_fn(f, table, jnp.array([1, 4]), jnp.arange(6.0), subject="t")
    rl = only(prof, "redundant_load")
    # re-executed length-1 = 5 extra trips of a (2,7) f32 gather
    assert rl[0].bytes == 5 * 2 * 7 * 4
    assert "scan[length=6]" in rl[0].meta["rule"]


def test_redundant_load_clean_twin_varying_index():
    def f(table, xs):
        def body(c, x):
            row = jnp.take(table, x.astype(jnp.int32), axis=0)
            return c + row.sum(), None
        out, _ = jax.lax.scan(body, 0.0, xs)
        return out

    prof = lint_fn(f, jnp.ones((13, 7)), jnp.arange(6.0), subject="t")
    assert not [f for f in prof.findings if f.kind == "redundant_load"]


def test_redundant_load_duplicate_gather_same_scope():
    def f(x):
        a = jax.lax.dynamic_slice(x, (2,), (5,))
        b = jax.lax.dynamic_slice(x, (2,), (5,))      # identical load
        return a + b

    prof = lint_fn(f, jnp.ones(17), subject="t")
    rl = only(prof, "redundant_load")
    assert rl[0].bytes == 5 * 4                       # one extra copy
    assert_here(rl[0])


def test_redundant_load_clean_twin_distinct_slices():
    def f(x):
        a = jax.lax.dynamic_slice(x, (0,), (5,))
        b = jax.lax.dynamic_slice(x, (9,), (5,))
        return a + b

    prof = lint_fn(f, jnp.ones(17), subject="t")
    assert not [f for f in prof.findings if f.kind == "redundant_load"]


# -------------------------------------------------------------- dead params
def test_dead_param_moe_expert_never_dispatched():
    """The MoE paydirt: routing ignores expert 1, its weights are dead."""
    def f(params, x):
        # "router" statically picks expert 0 only
        h = x @ params["experts"]["e0"]["w"]
        return h.sum() + params["bias"].sum()

    params = {"experts": {"e0": {"w": jnp.ones((7, 7))},
                          "e1": {"w": jnp.ones((7, 7))}},   # dead
              "bias": jnp.zeros(7)}
    prof = lint_fn(f, params, jnp.ones((3, 7)), subject="moe")
    dp = only(prof, "dead_param")
    assert len(dp) == 1
    assert dp[0].bytes == 7 * 7 * 4
    assert "e1" in dp[0].meta["path"]                 # names the buffer
    assert dp[0].meta["subject"] == "moe"


def test_dead_param_clean_twin_all_used():
    def f(params, x):
        h = x @ params["experts"]["e0"]["w"] + x @ params["experts"]["e1"]["w"]
        return h.sum() + params["bias"].sum()

    params = {"experts": {"e0": {"w": jnp.ones((7, 7))},
                          "e1": {"w": jnp.ones((7, 7))}},
              "bias": jnp.zeros(7)}
    prof = lint_fn(f, params, jnp.ones((3, 7)), subject="moe")
    assert not [f for f in prof.findings if f.kind == "dead_param"]
    assert prof.checked.get("dead_param", 0) == 4     # every invar checked


# ----------------------------------------------------------- infrastructure
def test_lint_runs_abstract_no_allocation():
    def f(x, u1, u2):
        y = jax.lax.dynamic_update_slice(x, u1, (3,))
        return jax.lax.dynamic_update_slice(y, u2, (3,))

    sds = jax.ShapeDtypeStruct
    prof = lint_fn(f, sds((17,), jnp.float32), sds((5,), jnp.float32),
                   sds((5,), jnp.float32), subject="abstract")
    assert "dead_store" in kinds(prof)


def test_lint_jaxpr_entry_point_and_tier():
    closed = jax.make_jaxpr(lambda x: x + 0.0)(jnp.ones(4))
    prof = lint_jaxpr(closed, subject="direct")
    assert prof.tiers == [TIER_STATIC]
    assert all(f.tier == TIER_STATIC for f in prof.findings)


def test_tier0_merges_with_other_tiers():
    p0 = lint_fn(lambda x: x + 0.0, jnp.ones(4), subject="t")
    p3 = WasteProfile(tier=3)
    p3.add_pair("silent_store", 3, ("leaf:a",), ("step",), 64.0)
    merged = merge(p0, p3)
    assert merged.tiers == [TIER_STATIC, 3]
    ss = [f for f in merged.findings if f.kind == "silent_store"]
    assert len(ss) == 2                               # distinct keys coexist
    rt = WasteProfile.from_json(merged.to_json())
    assert rt == merged


def test_identity_chain_through_convert_and_broadcast():
    """0 surviving broadcast_in_dim/convert still proves the identity."""
    def f(x):
        z = jnp.zeros((3, 5), jnp.float32)            # broadcast of literal
        return x + z

    prof = lint_fn(f, jnp.ones((3, 5)), subject="t")
    assert "silent_store" in kinds(prof)


def test_checked_counters_populate_fractions():
    def f(x, u):
        y = jax.lax.dynamic_update_slice(x, u, (3,))
        return jax.lax.dynamic_update_slice(y, u, (3,))

    prof = lint_fn(f, jnp.zeros(17), jnp.ones(5), subject="t")
    fr = prof.fractions()
    assert fr["dead_store"] == 0.5                    # 1 of 2 store sites
