"""Shared pytest wiring.

``--pallas-interpret`` forces the Pallas kernel dispatch on
(``REPRO_USE_PALLAS=1``) before any test traces a model: on CPU the
backend check in ``repro.kernels.ops._pallas_interpret`` then routes
every kernel through interpret mode, so the whole suite — including the
serving engine's greedy decode — exercises the TPU kernel code paths
and must reproduce the reference results bit for bit (the CI
kernels-interpret job runs the parity subset this way).
"""
import os


def pytest_addoption(parser):
    parser.addoption(
        "--pallas-interpret", action="store_true", default=False,
        help="force REPRO_USE_PALLAS=1 (Pallas kernels in interpret "
             "mode on CPU) for the whole test process")


def pytest_configure(config):
    if config.getoption("--pallas-interpret"):
        os.environ["REPRO_USE_PALLAS"] = "1"
