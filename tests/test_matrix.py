"""launch/matrix.py: the zoo-wide waste matrix driver.

Covers the report schema, the skip/error accounting, the ranking order,
the markdown leaderboard, seeded determinism across two runs, and the
closed detect->optimize loops: the MoE dead-expert-store fraction is 0
under the default scatter dispatch and reappears (and trips the CI
gate) when the cells are rerun with the einsum reference dispatch.
"""
import json

import pytest

from repro.configs import registry
from repro.launch import matrix

MOE = "granite-moe-3b-a800m"
WHISPER = "whisper-large-v3"


@pytest.fixture(scope="module")
def report():
    """One toy matrix over an MoE and an encoder-decoder config."""
    return matrix.run_cells([MOE, WHISPER], toy=True, verbose=False)["report"]


@pytest.fixture(scope="module")
def einsum_report():
    """The MoE train cell under the pre-fix einsum dispatch."""
    return matrix.run_cells([MOE], toy=True, shapes=["train_4k"],
                            moe_dispatch="einsum",
                            verbose=False)["report"]


def _cell(report, arch, shape):
    (c,) = [c for c in report["cells"]
            if c["arch"] == arch and c["shape"] == shape]
    return c


def test_report_schema_and_cell_accounting(report):
    assert report["schema"] == matrix.SCHEMA
    assert report["configs"] == [MOE, WHISPER]
    # every registry shape gets a cell row per config, in registry order
    assert [(c["arch"], c["shape"]) for c in report["cells"]] == \
        [(a, s.name) for a in (MOE, WHISPER) for s in registry.SHAPES]
    for c in report["cells"]:
        if c["applicable"]:
            assert c["error"] is None, c
            assert c["reason"] == ""
        else:
            # quadratic-attention archs skip the 500k decode cell, with
            # the registry's reason recorded in the report
            assert c["shape"] == "long_500k"
            assert "quadratic" in c["reason"]
            assert c["findings"] == [] and c["fractions"] == {}


def test_moe_dead_expert_store_is_zero_under_scatter(report):
    c = _cell(report, MOE, "train_4k")
    # the probe ran (the kind is accounted) and found nothing: scatter
    # dispatch stores only routed rows, so the dead fraction is 0 by
    # construction
    assert c["fractions"]["dead_expert_store"] == 0.0
    assert not any(f["kind"] == "dead_expert_store" for f in c["findings"])


def test_moe_dead_expert_store_detected_under_einsum(einsum_report):
    c = _cell(einsum_report, MOE, "train_4k")
    assert c["fractions"]["dead_expert_store"] > 0.0
    (f,) = [f for f in c["findings"] if f["kind"] == "dead_expert_store"]
    assert f["tier"] == 3
    assert f["site"].startswith("moe.py:")
    assert f["bytes"] > 0 and f["count"] > 0
    # and the CI gate trips on the regression
    fails = matrix._gate_failures(einsum_report, 0.0)
    assert any("dead_expert_store" in m for m in fails)


def test_whisper_padding_ranks_in_matrix(report):
    c = _cell(report, WHISPER, "prefill_32k")
    (f,) = [f for f in c["findings"] if f["kind"] == "prefill_padding"]
    assert f["tier"] == 2 and f["fraction"] > 0 and f["bytes"] > 0
    # the residual bucketed padding still tops this two-config ranking
    assert report["ranking"][0]["kind"] == "prefill_padding"
    assert report["ranking"][0]["arch"] == WHISPER


def test_ranking_is_all_findings_sorted(report):
    rows = [f for c in report["cells"] for f in c["findings"]]
    assert sorted(map(json.dumps, rows)) == \
        sorted(map(json.dumps, report["ranking"]))
    keys = [(-r["fraction"], -r["bytes"]) for r in report["ranking"]]
    assert keys == sorted(keys)


def test_leaderboard_markdown(report):
    board = matrix.leaderboard(report)
    lines = board.splitlines()
    assert lines[0].startswith("| # | config | shape | tier |")
    assert WHISPER in lines[2] and "prefill_padding" in lines[2]


def test_gate_passes_post_fix(report):
    assert matrix._gate_failures(report, 0.0) == []


def test_gate_reports_cell_errors(report):
    broken = json.loads(json.dumps(report))
    broken["cells"][0]["error"] = "ValueError: boom"
    fails = matrix._gate_failures(broken, None)
    assert fails == [f"{MOE} x train_4k: ValueError: boom"]


def test_two_runs_rank_identically():
    """Seeded end to end: the same tree profiled twice produces a
    byte-identical report (the acceptance criterion for CI)."""
    kw = dict(toy=True, shapes=["train_4k"], verbose=False)
    a = matrix.run_cells([MOE], **kw)["report"]
    b = matrix.run_cells([MOE], **kw)["report"]
    assert json.dumps(a, sort_keys=True) == json.dumps(b, sort_keys=True)
