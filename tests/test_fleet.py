"""Fleet tier: seeded/replayable traces, prefix-aware routing over
multiple `ServeEngine` replicas, the global prefix tier's refcount-safe
publish/lease/evict protocol, and the acceptance story — prefix routing
beats random placement on p99 TTFT and fleet-level silent-prefix-load
bytes on a duplicated-prefix trace, while staying bit-identical to a
single engine serving the same requests."""
import dataclasses

import jax
import numpy as np
import pytest
from _hypo import given, settings, st

from repro.configs import registry
from repro.configs.base import ProfilerConfig
from repro.core.detectors import ServingDetectors
from repro.core.findings import WasteProfile, merge_fleet
from repro.core.report import dump_json, load_json
from repro.core.sarif import write_sarif
from repro.models.zoo import build_model
from repro.serve.decode import StepCache
from repro.serve.engine import Request, ServeEngine
from repro.serve.router import FleetRouter
from repro.serve.workload import (Trace, TraceRequest,
                                  duplicated_prefix_trace, make_trace)

# model/params/compiled steps shared by every test (and — through the
# StepCache — by every replica in every fleet): one compile per shape
# for the whole module. A plain dict instead of a fixture so the
# hypothesis-shim property test (empty signature) can reach it too.
_ENV = {}


def _env():
    if not _ENV:
        cfg = dataclasses.replace(
            registry.get_config("qwen3-1.7b").smoke(), dtype="float32")
        model = build_model(cfg)
        _ENV.update(cfg=cfg, model=model,
                    params=model.init(jax.random.PRNGKey(0)),
                    step_cache=StepCache(model))
    return _ENV


def _engines(n, *, max_len, slots=2, page_size=8, num_pages=None,
             detectors=None):
    e = _env()
    return [ServeEngine(e["model"], e["params"], num_slots=slots,
                        max_len=max_len, kv_layout="paged",
                        page_size=page_size, num_pages=num_pages,
                        detectors=detectors[i] if detectors else None,
                        step_cache=e["step_cache"])
            for i in range(n)]


def _single_outputs(trace, *, max_len, slots=4, page_size=8):
    e = _env()
    eng = ServeEngine(e["model"], e["params"], num_slots=slots,
                      max_len=max_len, kv_layout="paged",
                      page_size=page_size, step_cache=e["step_cache"])
    for treq in sorted(trace.requests, key=lambda r: r.arrival):
        eng.submit(Request(rid=treq.rid, tokens=np.asarray(treq.tokens),
                           max_new_tokens=treq.max_new_tokens))
    eng.run()
    return {rid: list(r.generated) for rid, r in eng.finished.items()}


# ----------------------------------------------------------------------
# Trace generator: seeded, replayable, JSON round-trip
# ----------------------------------------------------------------------
def test_trace_seeded_replayable_and_json_roundtrip(tmp_path):
    kw = dict(n_requests=16, vocab_size=997, seed=3, arrival="poisson",
              rate=0.7, dup_rate=0.6, n_prefixes=2, prefix_len=20,
              prompt_len=(8, 40), gen_len=(2, 6))
    a, b = make_trace(**kw), make_trace(**kw)
    assert a.to_json() == b.to_json(), "same seed must replay byte-equal"
    assert make_trace(**{**kw, "seed": 4}).to_json() != a.to_json()

    back = Trace.from_json(a.to_json())
    assert back.to_json() == a.to_json()
    for r, s in zip(a.requests, back.requests):
        assert (r.rid, r.arrival, r.max_new_tokens, r.prefix_id) == \
               (s.rid, s.arrival, s.max_new_tokens, s.prefix_id)
        assert np.array_equal(r.tokens, s.tokens)
        assert s.tokens.dtype == np.int32
    p = tmp_path / "trace.json"
    a.save(str(p))
    assert Trace.load(str(p)).to_json() == a.to_json()

    # arrivals are scheduler ticks, non-decreasing in submit order
    arr = [r.arrival for r in a.requests]
    assert arr == sorted(arr)
    # duplicated prompts really share the pool prefix
    pools = {}
    for r in a.requests:
        if r.prefix_id is not None:
            head = tuple(int(t) for t in r.tokens[:min(20, r.tokens.size - 1)])
            ref = pools.setdefault(r.prefix_id, head)
            n = min(len(ref), len(head))
            assert head[:n] == ref[:n], "pool members must share the prefix"

    t = duplicated_prefix_trace(n_requests=6, vocab_size=97, seed=0)
    assert t.dup_fraction() >= 0.5
    assert [r.arrival for r in t.requests] == [0, 0, 2, 2, 4, 4]


def test_trace_arrival_patterns_and_validation():
    base = dict(n_requests=9, vocab_size=101, seed=1, prompt_len=(8, 12),
                gen_len=(2, 3))
    uni = make_trace(arrival="uniform", rate=0.5, **base)
    assert [r.arrival for r in uni.requests] == [2 * i for i in range(9)]
    bur = make_trace(arrival="bursty", burst_size=3, burst_gap=5, **base)
    assert [r.arrival for r in bur.requests] == \
           [(i // 3) * 5 for i in range(9)]
    poi = make_trace(arrival="poisson", rate=2.0, **base)
    assert all(x <= y for x, y in zip([r.arrival for r in poi.requests],
                                      [r.arrival for r in poi.requests][1:]))
    with pytest.raises(ValueError):
        make_trace(arrival="adversarial", **base)


# ----------------------------------------------------------------------
# Routing: cross-replica prefix reuse, bit-identity to a single engine
# ----------------------------------------------------------------------
def test_fleet_routes_across_replicas_and_matches_single_engine():
    e = _env()
    trace = duplicated_prefix_trace(n_requests=8,
                                    vocab_size=e["cfg"].vocab_size,
                                    seed=0, prompt_len=24, prefix_len=20,
                                    gen=4)
    max_len = trace.max_prompt_len + trace.max_new_tokens + 1
    pages = 4 * (-(-max_len // 8))      # 2 slots + 2 slots of pin headroom
    fleet = FleetRouter(_engines(2, max_len=max_len, num_pages=pages),
                        policy="prefix", seed=0)
    fleet.submit_trace(trace)
    fleet.run()
    fleet.check()

    assert fleet.stats["dispatched"] == 8
    assert len(fleet.finished) == 8
    assert fleet.stats["prefix_routes"] >= 1
    # at least one dispatch followed the resident prefix AGAINST the
    # load-balanced placement: the global tier changed a routing decision
    assert fleet.stats["cross_replica_prefix_routes"] >= 1
    assert 0.0 < fleet.prefix_hit_fraction() < 1.0
    lat = fleet.latency_summary()
    assert lat["ttft_p50"] > 0 and lat["ttft_p99"] >= lat["ttft_p50"]
    assert lat["tpot_p99"] >= lat["tpot_p50"] > 0

    ours = {rid: list(r.generated) for rid, r in fleet.finished.items()}
    assert ours == _single_outputs(trace, max_len=max_len)


def test_backpressure_admission_control_and_least_policy():
    e = _env()
    trace = duplicated_prefix_trace(n_requests=8,
                                    vocab_size=e["cfg"].vocab_size,
                                    seed=2, prompt_len=24, prefix_len=20,
                                    gen=4, burst_size=8, burst_gap=1)
    max_len = trace.max_prompt_len + trace.max_new_tokens + 1
    fleet = FleetRouter(_engines(2, max_len=max_len,
                                 num_pages=4 * (-(-max_len // 8))),
                        policy="least", seed=0, max_inflight=2)
    fleet.submit_trace(trace)
    fleet.run()
    fleet.check()
    # 8 requests land at once but each replica admits at most 2: the
    # backlog must have waited, FIFO, and still drained completely
    assert fleet.stats["backpressure_ticks"] > 0
    assert fleet.stats["backpressure_requests"] > 0
    assert fleet.stats["dispatched"] == 8 and len(fleet.finished) == 8
    assert max(q["max_depth"] for q in fleet.queue_summary()) <= 2
    ours = {rid: list(r.generated) for rid, r in fleet.finished.items()}
    assert ours == _single_outputs(trace, max_len=max_len)


def test_prefix_policy_requires_paged_replicas():
    e = _env()
    dense = [ServeEngine(e["model"], e["params"], num_slots=1, max_len=16,
                         kv_layout="dense", step_cache=e["step_cache"])
             for _ in range(2)]
    with pytest.raises(ValueError, match="paged"):
        FleetRouter(dense, policy="prefix")
    with pytest.raises(ValueError, match="policy"):
        FleetRouter(dense, policy="round-robin")


# ----------------------------------------------------------------------
# Acceptance: prefix routing strictly beats random on p99 TTFT AND
# fleet-level silent-prefix-load bytes on a duplicated-prefix trace
# ----------------------------------------------------------------------
def test_prefix_routing_beats_random_on_p99_ttft_and_waste():
    """Structural-margin workload: a 256-token shared prefix (prefill
    bucket 256) with 256-token unique suffixes. Under prefix routing
    every duplicate reuses the resident prefix and prefills only the
    suffix bucket; under random placement the first landing on the
    non-resident replica re-prefills the full 512-token bucket, so the
    p99 gap is a whole prefill bucket of compute, not scheduler noise
    (and the re-prefilled bytes are exactly the fleet Def.-3 charge)."""
    e = _env()
    rng = np.random.RandomState(0)
    PFX, SUF, GEN = 256, 256, 2
    prefix = rng.randint(0, e["cfg"].vocab_size, PFX).astype(np.int32)
    reqs = [TraceRequest("r0", 0, prefix.copy(), GEN, 0)]
    for i in range(6):
        suf = rng.randint(0, e["cfg"].vocab_size, SUF).astype(np.int32)
        reqs.append(TraceRequest(f"d{i}", 4 + 4 * (i // 2),
                                 np.concatenate([prefix, suf]), GEN, 0))
    trace = Trace(reqs)
    max_len = PFX + SUF + GEN + 1

    results = {}
    for policy in ("prefix", "random"):
        for _measured in (False, True):   # warm the shared jits first
            fleet = FleetRouter(
                _engines(2, max_len=max_len, page_size=16,
                         num_pages=4 * (-(-max_len // 16))),
                policy=policy, seed=0)
            fleet.submit_trace(trace)
            fleet.run()
            fleet.check()
        results[policy] = fleet

    fp, fr = results["prefix"], results["random"]
    assert fp.stats["prefix_routes"] >= 4
    ttft_p, ttft_r = (f.latency_summary()["ttft_p99"] for f in (fp, fr))
    # prefix routing re-paid nothing; random re-prefilled the resident
    # prefix at least once (count-deterministic: seeded trace + router)
    assert fp.fleet_waste_bytes() == 0.0
    assert fr.fleet_waste_bytes() > 0.0
    assert ttft_p < ttft_r, \
        f"prefix p99 {ttft_p * 1e3:.1f} ms !< random {ttft_r * 1e3:.1f} ms"
    # both policies produced the same greedy text as one big engine
    ours = {rid: list(r.generated) for rid, r in fp.finished.items()}
    theirs = {rid: list(r.generated) for rid, r in fr.finished.items()}
    assert ours == theirs == _single_outputs(trace, max_len=max_len,
                                             page_size=16)


# ----------------------------------------------------------------------
# Property: no freed page is ever reachable from the global tier, and
# greedy outputs stay bit-identical, under random arrival/eviction/
# pool-pressure schedules (the pin -> lease -> evict ordering protocol)
# ----------------------------------------------------------------------
_PROP = {}


def _prop_requests():
    """Fixed token content (so the greedy reference is computed once);
    only schedules/pools/policies vary per example."""
    if not _PROP:
        e = _env()
        rng = np.random.RandomState(7)
        prefix = rng.randint(0, e["cfg"].vocab_size, 16).astype(np.int32)
        toks = []
        for i in range(6):
            if i < 4:       # duplicated-prefix traffic
                t = np.concatenate([prefix, rng.randint(
                    0, e["cfg"].vocab_size, 8).astype(np.int32)])
            else:           # unique fillers
                t = rng.randint(0, e["cfg"].vocab_size, 24).astype(np.int32)
            toks.append(t)
        trace = Trace([TraceRequest(f"p{i}", 0, t, 2, None)
                       for i, t in enumerate(toks)])
        _PROP["tokens"] = toks
        _PROP["ref"] = _single_outputs(trace, max_len=27)
    return _PROP


@settings(max_examples=5, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(10, 16),
       st.sampled_from(["prefix", "least", "random"]))
def test_no_freed_page_reachable_under_random_schedules(
        seed, num_pages, policy):
    env = _prop_requests()
    rng = np.random.RandomState(seed)
    arrivals = np.sort(rng.randint(0, 8, size=6))
    trace = Trace([TraceRequest(f"p{i}", int(arrivals[i]), t, 2, None)
                   for i, t in enumerate(env["tokens"])])
    fleet = FleetRouter(_engines(2, max_len=27, num_pages=num_pages),
                        policy=policy, seed=seed)
    fleet.submit_trace(trace)
    for _ in range(300):
        if not fleet.pending:
            break
        fleet.step()
        # adversarial interleaving: global evictions (LRU and targeted)
        # while dispatch leases and live slots are outstanding
        if rng.rand() < 0.3:
            fleet.gpi.evict_one()
        if rng.rand() < 0.2:
            fleet.gpi.evict_for(int(rng.randint(2)), int(rng.randint(1, 4)))
        # the audit: every global entry/lease page has a live refcount,
        # and each replica's pool balances against local + global holders
        fleet.check()
    assert not fleet.pending, "fleet failed to drain under eviction churn"
    fleet.check()
    ours = {rid: list(r.generated) for rid, r in fleet.finished.items()}
    assert ours == env["ref"], \
        f"outputs diverged under schedule seed={seed} policy={policy}"


# ----------------------------------------------------------------------
# §5.6 at fleet scale: merged profile round-trips JSON and SARIF
# ----------------------------------------------------------------------
def test_fleet_profile_merges_roundtrips_json_and_sarif(tmp_path):
    e = _env()
    trace = duplicated_prefix_trace(n_requests=8,
                                    vocab_size=e["cfg"].vocab_size,
                                    seed=0, prompt_len=24, prefix_len=20,
                                    gen=4)
    max_len = trace.max_prompt_len + trace.max_new_tokens + 1
    dets = [ServingDetectors(ProfilerConfig(enabled=True, seed=i))
            for i in range(2)]
    fleet = FleetRouter(_engines(2, max_len=max_len,
                                 num_pages=4 * (-(-max_len // 8)),
                                 detectors=dets),
                        policy="random", seed=0)
    fleet.submit_trace(trace)
    fleet.run()
    fleet.check()
    # random placement on duplicated-prefix traffic must charge the
    # fleet-level Def.-3 kind (deterministic: seeded trace + router rng)
    assert fleet.fleet_waste_bytes() > 0
    kinds = {f.kind for f in fleet.profile.findings}
    assert kinds == {"fleet_silent_prefix_load"}
    for f in fleet.profile.findings:
        assert f.c1[0] == "serve.global_prefix:resident"
        assert f.c2[0] == "serve.router:dispatch"
        assert f.c1[1] != f.c2[1], "waste charged to the resident replica"

    members = {f"replica{i}": d.combined() for i, d in enumerate(dets)}
    members["router"] = fleet.profile
    merged = merge_fleet(members)
    assert set(merged.meta["fleet"]) == {"replica0", "replica1", "router"}
    assert merged.meta["fleet"]["router"]["findings"] >= 1
    total = sum(m["findings"] for m in merged.meta["fleet"].values())
    assert len(merged.findings) <= total   # coalescing never invents

    # associative, §5.6: member-wise merge == re-merge of the halves
    again = merge_fleet({"a": merge_fleet({"replica0": members["replica0"],
                                           "router": members["router"]}),
                         "b": members["replica1"]})
    assert {f.key for f in again.findings} == \
           {f.key for f in merged.findings}

    back = WasteProfile.from_json(merged.to_json())
    assert back == merged
    p = str(tmp_path / "fleet_profile.json")
    dump_json(merged, p)
    assert load_json(p) == merged

    doc = write_sarif(merged, str(tmp_path / "fleet.sarif"))
    rules = {r["id"] for r in doc["runs"][0]["tool"]["driver"]["rules"]}
    assert "fleet_silent_prefix_load" in rules
    hits = [r for r in doc["runs"][0]["results"]
            if r["ruleId"] == "fleet_silent_prefix_load"]
    assert hits, "fleet finding must surface as a SARIF result"
