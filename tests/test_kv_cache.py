"""Paged KV cache: allocator/refcount/COW property tests over random
admit/decode/finish/recycle schedules, prefix-index reuse semantics, and
dense-vs-paged engine equivalence (bit-identical greedy outputs with
fewer computed prefill tokens)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypo import given, settings, st

from repro.configs import registry
from repro.models.zoo import build_model
from repro.serve.engine import Request, ServeEngine
from repro.serve.kv_cache import (PageAllocator, PagedKV, PoolExhausted,
                                  PrefixIndex, prefix_candidates)

KEY = jax.random.PRNGKey(0)


# ----------------------------------------------------------------------
# Allocator + prefix-index invariants under random schedules
# ----------------------------------------------------------------------
@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=40, deadline=None)
def test_paged_kv_invariants_under_random_schedule(seed):
    """Random admit/decode/finish sequences: refcounts always equal the
    number of holders (slot tables + index pins), the free list and
    refcounts partition the pool (no leak, no double-free), and shared
    pages are never mapped writable by two slots (COW isolation: a
    partially reused page is copied, so every slot's writable tail —
    pages past its full-page shared prefix — is exclusively owned)."""
    rng = np.random.RandomState(seed)
    ps, slots, max_len = 4, 3, 32
    M = max_len // ps
    kv = PagedKV(slots, ps, slots * M, M, prefix_window=4)
    live = {}                                  # slot -> (tokens, shared_n)
    pool = [rng.randint(0, 50, size=rng.randint(2, max_len // 2))
            .astype(np.int32) for _ in range(5)]
    for _ in range(60):
        op = rng.randint(3)
        free = [b for b in range(slots) if b not in live]
        if op == 0 and free:                   # admit (maybe shared prefix)
            b = int(rng.choice(free))
            base = pool[rng.randint(len(pool))]
            toks = np.concatenate(
                [base, rng.randint(0, 50, size=rng.randint(1, 8))
                 .astype(np.int32)])[:max_len - 1]
            budget = int(rng.randint(1, 8))
            plan = kv.admit(b, toks, budget)
            kv.release(plan.cow_pins)      # the engine's post-copy step
            assert 0 <= plan.reuse_len < toks.size
            assert len(set(plan.row)) == len(plan.row)   # no double map
            # COW isolation: beyond the whole-page shared prefix every
            # page in the row is exclusively this slot's to write
            n_full = plan.reuse_len // ps
            owned = plan.row[n_full:]
            for other, (otoks, o_full) in live.items():
                orow = [p for p in kv.pt[other] if p >= 0]
                writable_other = orow[o_full:]
                assert not set(owned) & set(writable_other)
            kv.register_prefix(b, toks)
            live[b] = (toks, n_full)
        elif op == 1 and live:                 # finish/recycle
            b = int(rng.choice(list(live)))
            freed = kv.free_slot(b)
            assert len(set(freed)) == len(freed)
            del live[b]
        elif op == 2 and live:                 # decode positions advance;
            pass                               # pages were pre-allocated
        kv.check()                             # the cross-structure audit
    for b in list(live):
        kv.free_slot(b)
    kv.index.clear()
    kv.check()
    assert kv.alloc.free_count == kv.alloc.num_pages   # no leak


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=25, deadline=None)
def test_prefix_index_safe_under_digest_collisions_and_pressure(seed):
    """Adversarial digests (a 3-bucket hash, so distinct prompts collide
    constantly) + pool pressure: whatever the index believes about
    content, its memory discipline must hold after every operation —
    no pinned page is ever evicted out from under an entry, and no
    entry ever maps a page that returned to the free list."""
    import repro.serve.kv_cache as kvmod
    real_digest = kvmod._digest
    kvmod._digest = lambda tokens: f"weak{int(np.sum(tokens)) % 3}"
    try:
        rng = np.random.RandomState(seed)
        ps, slots = 4, 3
        kv = PagedKV(slots, ps, num_pages=10, max_pages_per_slot=4,
                     prefix_window=3)
        live = set()

        def audit():
            free = set(kv.alloc._free)
            for e in kv.index._entries.values():
                for p in e.pages:
                    assert p not in free, "pinned page on the free list"
                    assert kv.alloc.refcount[p] > 0, \
                        "index entry maps a refless page"
            kv.check()

        for _ in range(50):
            op = rng.randint(4)
            free_slots = [b for b in range(slots) if b not in live]
            if op == 0 and free_slots:
                b = int(rng.choice(free_slots))
                toks = rng.randint(0, 9, size=rng.randint(2, 13)) \
                    .astype(np.int32)
                try:
                    plan = kv.admit(b, toks, budget=int(rng.randint(1, 5)))
                except (PoolExhausted, ValueError):
                    audit()
                    continue
                kv.release(plan.cow_pins)
                kv.register_prefix(b, toks)
                live.add(b)
            elif op == 1 and live:
                b = int(rng.choice(sorted(live)))
                kv.free_slot(b)
                live.discard(b)
            elif op == 2:
                kv.index.evict_one(prefer_freeing=bool(rng.randint(2)))
            elif op == 3 and len(kv.index) > 1:
                kv.index.evict_one()
            audit()
        for b in sorted(live):
            kv.free_slot(b)
        kv.index.clear()
        kv.check()
        assert kv.alloc.free_count == kv.alloc.num_pages
    finally:
        kvmod._digest = real_digest


def test_allocator_rejects_double_free_and_overcommit():
    a = PageAllocator(4)
    pages = a.alloc(4)
    with pytest.raises(PoolExhausted):
        a.alloc(1)
    a.decref(pages[:1])
    with pytest.raises(AssertionError):
        a.decref(pages[:1])                    # double free
    a.incref(pages[1:2])
    assert a.decref(pages[1:2]) == []          # still held once
    assert a.decref(pages[1:2]) == [pages[1]]  # now freed


def test_prefix_index_pins_and_evicts():
    """Index entries pin pages past the donor slot's lifetime; LRU
    eviction (window pressure) releases them back to the pool."""
    ps = 4
    kv = PagedKV(num_slots=2, page_size=ps, num_pages=8,
                 max_pages_per_slot=4, prefix_window=2)
    toks = np.arange(10, dtype=np.int32)
    kv.admit(0, toks, budget=2)
    kv.register_prefix(0, toks)
    used_before = kv.alloc.used_count
    freed = kv.free_slot(0)
    # prefix pins survive the slot: not every page returned
    assert kv.alloc.used_count > 0
    assert len(freed) < used_before
    # a duplicate prompt is served from the pinned pages
    plan = kv.admit(1, toks.copy(), budget=2)
    kv.release(plan.cow_pins)
    assert plan.reuse_len == toks.size - 1
    kv.free_slot(1)
    kv.index.clear()
    kv.check()
    assert kv.alloc.free_count == 8


def test_admit_under_pressure_never_double_maps_matched_pages():
    """Pool pressure evicts prefix entries mid-admission; the matched
    donor's pages are pinned before eviction/alloc, so the allocator
    must never hand them back as fresh pages (double mapping would let
    the COW copy clobber the shared prefix). With a second, unmatched
    dead donor supplying freeable pages, the admission succeeds, keeps
    the matched entries (freeing-first eviction) and the row is clean."""
    ps = 4
    kv = PagedKV(num_slots=3, page_size=ps, num_pages=10,
                 max_pages_per_slot=5, prefix_window=8)
    donor = np.arange(8, dtype=np.int32)
    kv.admit(0, donor, budget=1)               # 3 pages (8 tok + budget)
    kv.register_prefix(0, donor)
    kv.free_slot(0)                            # prefix survives via pins
    other = np.arange(200, 208, dtype=np.int32)
    kv.admit(0, other, budget=1)               # dead unmatched donor
    kv.register_prefix(0, other)
    kv.free_slot(0)
    kv.admit(1, np.arange(100, 117, dtype=np.int32), budget=3)  # hog
    # duplicate of donor under pressure: eviction must target the dead
    # unmatched donor's refcount-1 pins, not the just-matched pages
    plan = kv.admit(2, donor.copy(), budget=8)
    assert plan.reuse_len == 7
    assert len(set(plan.row)) == len(plan.row), plan
    shared = plan.row[:plan.reuse_len // ps]
    for src, dst in plan.cow:
        assert dst not in shared and src != dst
        assert src not in plan.row             # source stays donor-owned
    kv.release(plan.cow_pins)
    kv.check()


def test_admit_pressure_on_matched_pages_defers_instead_of_corrupting():
    """The reviewer repro: the ONLY evictable pins are the matched
    donor's own pages. Pre-pin makes those pages unavailable, so the
    admission must defer (PoolExhausted) with consistent state — never
    double-map."""
    kv = PagedKV(num_slots=3, page_size=4, num_pages=8,
                 max_pages_per_slot=5, prefix_window=8)
    donor = np.arange(8, dtype=np.int32)
    kv.admit(0, donor, budget=1)
    kv.register_prefix(0, donor)
    kv.free_slot(0)
    kv.admit(1, np.arange(100, 117, dtype=np.int32), budget=3)  # 5 pages
    with pytest.raises(PoolExhausted) as ei:
        kv.admit(2, donor.copy(), budget=8)
    # the unwound pins freed the donor pages; they are reported
    assert len(ei.value.freed) >= 2
    kv.check()


def test_pool_exhausted_reports_pages_freed_by_partial_eviction():
    """A failed admission still reports the pages its eviction pass
    freed, so the engine can disarm their stale watchpoints."""
    ps = 4
    kv = PagedKV(num_slots=2, page_size=ps, num_pages=4,
                 max_pages_per_slot=4, prefix_window=8)
    toks = np.arange(6, dtype=np.int32)
    kv.admit(0, toks, budget=1)                # 2 pages
    kv.register_prefix(0, toks)
    kv.free_slot(0)                            # both pages stay pinned
    kv.admit(1, np.arange(50, 57, dtype=np.int32), budget=1)  # 2 fresh
    with pytest.raises(PoolExhausted) as ei:
        kv.admit(0, np.arange(80, 94, dtype=np.int32), budget=2)  # 4 pages
    assert len(ei.value.freed) > 0             # eviction freed the pins
    kv.check()


def test_admit_rejects_request_larger_than_pool():
    """A request whose page need exceeds the whole pool can never be
    satisfied by waiting — it must fail loudly, not requeue forever."""
    kv = PagedKV(num_slots=2, page_size=16, num_pages=2,
                 max_pages_per_slot=8, prefix_window=4)
    with pytest.raises(ValueError):
        kv.admit(0, np.arange(40, dtype=np.int32), budget=16)


def test_prefix_candidates_cover_pow2_and_page_boundaries():
    assert prefix_candidates(24, 16) == [8, 16, 24]
    assert prefix_candidates(7, 4) == [4, 7]
    assert 32 in prefix_candidates(40, 16)     # pow2 AND boundary overlap


def test_admit_exhaustion_raises_after_full_eviction():
    kv = PagedKV(num_slots=2, page_size=4, num_pages=2,
                 max_pages_per_slot=2, prefix_window=4)
    kv.admit(0, np.arange(6, dtype=np.int32), budget=1)
    with pytest.raises(PoolExhausted):
        kv.admit(1, np.arange(6, dtype=np.int32) + 50, budget=1)


# ----------------------------------------------------------------------
# Dense vs paged: the optimization must not change a single token
# ----------------------------------------------------------------------
def _model():
    cfg = dataclasses.replace(registry.get_config("qwen3-1.7b").smoke(),
                              dtype="float32")
    model = build_model(cfg)
    return cfg, model, model.init(KEY)


def _duplicated_prefix_requests(cfg, n=5, prompt_len=24):
    """The serve_decode.py workload shape: staggered arrivals, every
    other request sharing a prompt prefix, varying budgets."""
    rng = np.random.RandomState(1)
    shared = rng.randint(0, cfg.vocab_size, size=prompt_len // 2)
    reqs = []
    for i in range(n):
        if i % 2 == 0:
            tail = rng.randint(0, cfg.vocab_size, size=prompt_len // 2)
            toks = np.concatenate([shared, tail])
        else:
            toks = rng.randint(0, cfg.vocab_size, size=prompt_len)
        reqs.append(Request(rid=f"r{i}", tokens=toks.astype(np.int32),
                            max_new_tokens=6 - (i % 3), arrival=i))
    return reqs


def test_dense_vs_paged_greedy_bit_identical():
    """Same staggered duplicated-prefix workload through both KV
    layouts: every request's greedy continuation must match token for
    token, while paged mode serves prefix tokens from cache (fewer
    computed prefill tokens) and frees pages at recycle."""
    cfg, model, params = _model()
    outs, stats = {}, {}
    for kvl in ("dense", "paged"):
        eng = ServeEngine(model, params, num_slots=3, max_len=40,
                          kv_layout=kvl, page_size=16)
        for r in _duplicated_prefix_requests(cfg):
            eng.submit(Request(rid=r.rid, tokens=r.tokens.copy(),
                               max_new_tokens=r.max_new_tokens,
                               arrival=r.arrival))
        fin = eng.run(max_steps=300)
        outs[kvl] = {rid: fin[rid].generated for rid in fin}
        stats[kvl] = dict(eng.stats)
    assert sorted(outs["dense"]) == sorted(outs["paged"])
    for rid in outs["dense"]:
        assert outs["dense"][rid] == outs["paged"][rid], rid
    # the detected Def.-3 waste became cache hits: fewer computed tokens
    assert stats["paged"]["prefix_hits"] >= 1
    assert stats["paged"]["prefix_hit_tokens"] > 0
    assert (stats["paged"]["prefill_computed_tokens"]
            < stats["dense"]["prefill_computed_tokens"])
    # served-prompt accounting is layout-independent
    assert (stats["paged"]["prefill_tokens"]
            == stats["dense"]["prefill_tokens"])
    # recycling frees pages instead of leaving rows to rewrite
    assert stats["paged"]["pages_freed"] > 0
    assert stats["dense"]["pages_freed"] == 0


def test_paged_full_prompt_duplicate_recomputes_one_position():
    """A fully duplicated prompt reuses everything but the last position
    (its logits seed the continuation) and still matches dense output."""
    cfg, model, params = _model()
    rng = np.random.RandomState(3)
    # 10 tokens over 4-token pages: the reused [0, 9) prefix ends
    # mid-page, so admission must COW the partial page
    toks = rng.randint(0, cfg.vocab_size, size=10).astype(np.int32)
    outs = {}
    for kvl in ("dense", "paged"):
        eng = ServeEngine(model, params, num_slots=1, max_len=24,
                          kv_layout=kvl, page_size=4)
        eng.submit(Request(rid="a", tokens=toks, max_new_tokens=3))
        eng.submit(Request(rid="b", tokens=toks.copy(), max_new_tokens=3))
        fin = eng.run(max_steps=100)
        outs[kvl] = (fin["a"].generated, fin["b"].generated)
        if kvl == "paged":
            assert eng.stats["prefix_hit_tokens"] == toks.size - 1
            assert eng.stats["cow_copies"] >= 1     # partial page COW'd
    assert outs["dense"] == outs["paged"]
    # duplicate prompt => identical continuation for both requests
    assert outs["paged"][0] == outs["paged"][1]


def test_paged_engine_padding_waste_accounting():
    """`_bucket` padding burn is counted: whole-batch sweep minus useful
    suffix tokens, in both layouts."""
    cfg, model, params = _model()
    eng = ServeEngine(model, params, num_slots=2, max_len=32)
    rng = np.random.RandomState(5)
    eng.submit(Request(rid="a", tokens=rng.randint(
        0, cfg.vocab_size, size=5).astype(np.int32), max_new_tokens=2))
    eng.run(max_steps=50)
    # one admission: 2 slots x bucket(5)=8 padded positions, 5 useful
    assert eng.stats["prefill_computed_tokens"] == 5
    assert eng.stats["padded_prefill_tokens"] == 2 * 8 - 5


def test_prefix_match_probes_partial_granularity_boundaries():
    """Granularity-boundary regression (fleet satellite): a donor prompt
    ending mid-bucket — 37 tokens at page_size 16 is neither a pow2 nor
    a page boundary — was only findable through the candidate ladder,
    which caps a 45-token follower's probe at 32 and silently re-pays 5
    tokens. `probe_lengths` now adds every registered entry length as a
    final partial-boundary probe, so the follower reuses all 37."""
    ps = 16
    kv = PagedKV(num_slots=2, page_size=ps, num_pages=16,
                 max_pages_per_slot=4)
    rng = np.random.RandomState(0)
    donor = rng.randint(0, 100, size=37).astype(np.int32)
    kv.admit(0, donor, budget=3)
    kv.register_prefix(0, donor)

    # the ladder alone stops at 32: the gap this fix closes
    assert max(c for c in prefix_candidates(45, ps) if c <= 37) == 32
    assert 37 in kv.index.probe_lengths(45)

    follower = np.concatenate([donor,
                               rng.randint(0, 100, 8).astype(np.int32)])
    plan = kv.admit(1, follower, budget=3)
    kv.release(plan.cow_pins)
    assert plan.reuse_len == 37, \
        f"partial-boundary prefix re-paid: reused {plan.reuse_len}/37"
    kv.check()

    # the registered-length table is refcounted: once every entry at 37
    # is gone, the probe ladder shrinks back to the pure candidates
    kv.free_slot(0)
    kv.free_slot(1)
    kv.index.clear()
    assert kv.index.probe_lengths(45) == prefix_candidates(45, ps)
    kv.check()
    assert kv.alloc.free_count == 16
