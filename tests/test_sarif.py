"""SARIF 2.1.0 export: schema shape, fingerprint stability, and the
baseline-waiver round trip over a merged tier-{0,2,3} profile."""
import json

import jax
import jax.numpy as jnp

from repro.core.findings import Finding, WasteProfile, merge
from repro.core.hlo_waste import analyze_waste
from repro.core.jaxpr_lint import lint_fn
from repro.core.sarif import (finding_fingerprint, to_sarif, write_sarif)
from repro.launch.lint import baseline_doc, load_baseline, split_new

_HLO_DUP_COLLECTIVE = """
HloModule m

ENTRY %main (p0: f32[4096]) -> f32[4096] {
  %p0 = f32[4096]{0} parameter(0)
  %ag1 = f32[4096]{0} all-gather(%p0), replica_groups=[2,8]<=[16], dimensions={0}
  %ag2 = f32[4096]{0} all-gather(%p0), replica_groups=[2,8]<=[16], dimensions={0}
  ROOT %s = f32[4096]{0} add(%ag1, %ag2)
}
"""


def merged_profile() -> WasteProfile:
    # tier 0: static lint with real file:line provenance
    t0 = lint_fn(lambda x: x + 0.0, jnp.ones((4, 4)), subject="probe")
    # tier 2: HLO analysis of a planted redundant collective
    t2 = analyze_waste(_HLO_DUP_COLLECTIVE).profile
    # tier 3: a detector-style finding with a leaf path, no file
    t3 = WasteProfile(tier=3)
    t3.add(Finding(kind="silent_store", tier=3, c1=("params/w",),
                   c2=("train_step",), bytes=128.0,
                   meta={"path": "params/w"}))
    return merge(t0, t2, t3)


def test_sarif_shape_of_merged_profile():
    prof = merged_profile()
    assert sorted(prof.tiers) == [0, 2, 3]
    doc = to_sarif(prof)
    assert doc["version"] == "2.1.0"
    assert "sarif-schema-2.1.0" in doc["$schema"]
    assert len(doc["runs"]) == 1
    run = doc["runs"][0]
    rules = run["tool"]["driver"]["rules"]
    rule_ids = [r["id"] for r in rules]
    assert len(rule_ids) == len(set(rule_ids))
    for r in rules:
        assert r["shortDescription"]["text"]
        assert r["help"]["text"]
    assert len(run["results"]) == len(prof.findings)
    for res in run["results"]:
        assert res["ruleId"] in rule_ids
        assert rules[res["ruleIndex"]]["id"] == res["ruleId"]
        assert 0 <= res["rank"] <= 100
        assert res["message"]["text"]
        loc = res["locations"][0]
        assert "physicalLocation" in loc or "logicalLocations" in loc
        assert res["partialFingerprints"]["wasteKey/v1"]


def test_sarif_physical_location_from_tier0_provenance():
    t0 = lint_fn(lambda x: x + 0.0, jnp.ones(4), subject="probe")
    res = to_sarif(t0)["runs"][0]["results"][0]
    phys = res["locations"][0]["physicalLocation"]
    assert phys["artifactLocation"]["uri"].endswith("test_sarif.py")
    assert phys["region"]["startLine"] > 0


def test_sarif_src_root_relativizes_uris():
    import os
    t0 = lint_fn(lambda x: x + 0.0, jnp.ones(4), subject="probe")
    here = os.path.dirname(os.path.abspath(__file__))
    doc = to_sarif(t0, src_root=here)
    run = doc["runs"][0]
    art = run["results"][0]["locations"][0]["physicalLocation"][
        "artifactLocation"]
    assert art["uri"] == "test_sarif.py"
    assert art["uriBaseId"] == "SRCROOT"
    assert "SRCROOT" in run["originalUriBaseIds"]


def test_fingerprints_stable_across_runs_and_magnitudes():
    f1 = Finding(kind="dead_store", tier=0, c1=("a.py:3:f", "scatter"),
                 c2=("a.py:9:g",), bytes=100.0, count=1)
    f2 = Finding(kind="dead_store", tier=0, c1=("a.py:3:f", "scatter"),
                 c2=("a.py:9:g",), bytes=999999.0, count=77)
    assert finding_fingerprint(f1) == finding_fingerprint(f2)
    f3 = Finding(kind="dead_store", tier=0, c1=("a.py:4:f", "scatter"),
                 c2=("a.py:9:g",))
    assert finding_fingerprint(f1) != finding_fingerprint(f3)
    # and the exported doc is deterministic end to end
    prof = merged_profile()
    assert to_sarif(prof) == to_sarif(prof)


def test_sarif_results_ranked_by_bytes():
    prof = WasteProfile(tier=0)
    prof.add(Finding(kind="dead_store", tier=0, c1=("small",), bytes=10.0))
    prof.add(Finding(kind="dead_store", tier=0, c1=("big",), bytes=1e9))
    res = to_sarif(prof)["runs"][0]["results"]
    assert res[0]["properties"]["bytes"] == 1e9
    assert res[0]["rank"] > res[1]["rank"]


def test_write_sarif_round_trips_valid_json(tmp_path):
    path = str(tmp_path / "out.sarif")
    doc = write_sarif(merged_profile(), path)
    with open(path) as fh:
        assert json.load(fh) == doc


def test_unknown_kind_gets_generic_rule():
    prof = WasteProfile(tier=5)
    prof.add(Finding(kind="future_waste_kind", tier=5, c1=("x",)))
    run = to_sarif(prof)["runs"][0]
    assert run["tool"]["driver"]["rules"][0]["id"] == "future_waste_kind"
    assert run["results"][0]["ruleId"] == "future_waste_kind"


def test_baseline_waiver_suppresses_known_but_not_new(tmp_path):
    prof = merged_profile()
    path = str(tmp_path / "baseline.json")
    with open(path, "w") as fh:
        json.dump(baseline_doc(prof), fh)
    waived = load_baseline(path)
    new, hit = split_new(prof, waived)
    assert not new and len(hit) == len(prof.findings)
    # a finding at a NEW site fails the gate
    prof.add(Finding(kind="dead_store", tier=0, c1=("new_site.py:1:f",),
                     bytes=4.0))
    new, _ = split_new(prof, waived)
    assert len(new) == 1 and new[0].c1 == ("new_site.py:1:f",)
    # missing baseline file = empty waiver set, everything is new
    assert load_baseline(str(tmp_path / "nope.json")) == {}
