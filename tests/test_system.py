"""End-to-end behaviour: train driver (checkpoint/resume determinism),
serve driver, elastic data replay, Tier-2 report on a compiled step."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.data.synthetic import batch_at
from repro.launch.serve import run as serve_run
from repro.launch.train import run as train_run


def test_train_e2e_loss_decreases(tmp_path):
    losses, _ = train_run("qwen3-1.7b", smoke=True, steps=20, batch=4,
                          seq=64, ckpt_dir=str(tmp_path), ckpt_every=10,
                          log_every=100)
    assert losses[-1] < losses[0]


def test_train_resume_is_deterministic(tmp_path):
    """Crash/restart equivalence: 10 straight steps == 5 + resume(5)."""
    l_full, _ = train_run("qwen3-1.7b", smoke=True, steps=10, batch=4,
                          seq=32, seed=7, log_every=100)
    train_run("qwen3-1.7b", smoke=True, steps=5, total_steps=10, batch=4,
              seq=32, seed=7, ckpt_dir=str(tmp_path), ckpt_every=5,
              log_every=100)
    l_resumed, _ = train_run("qwen3-1.7b", smoke=True, steps=10, batch=4,
                             seq=32, seed=7, ckpt_dir=str(tmp_path),
                             resume=True, log_every=100)
    np.testing.assert_allclose(l_resumed[-1], l_full[-1], rtol=1e-4)


def test_train_profile_mode(tmp_path):
    _, rep = train_run("qwen3-1.7b", smoke=True, steps=6, batch=2, seq=32,
                       profile=True, log_every=100)
    assert rep is not None
    assert rep.checked.get("silent_param_store", 0) > 0


def test_serve_e2e():
    out, profile = serve_run("qwen3-1.7b", smoke=True, batch=2,
                             prompt_len=8, gen=4)
    assert profile is None                     # no --profile requested
    assert out.shape == (2, 4)
    cfg = registry.get_config("qwen3-1.7b").smoke()
    assert int(jnp.max(out)) < cfg.vocab_size   # pad vocab never sampled


def test_moe_arch_trains():
    losses, _ = train_run("granite-moe-3b-a800m", smoke=True, steps=10,
                          batch=2, seq=32, log_every=100)
    assert np.isfinite(losses).all()


def test_hybrid_arch_trains():
    losses, _ = train_run("zamba2-1.2b", smoke=True, steps=8, batch=2,
                          seq=32, log_every=100)
    assert np.isfinite(losses).all()
